//! Fig 2: compression overhead of LWTopk vs MSTopk across CRs — real
//! timings on this host, at a realistically layered 10M-parameter tensor.
//! Also the perf-pass ablation: heap Top-k (paper's choice) vs quickselect.
//!
//!     cargo bench --bench fig2_compress_overhead

use flexcomm::compress::{Compressor, LwTopk, MsTopk, TopK};
use flexcomm::runtime::host_model::synthetic_model_layout;
use flexcomm::util::bench::Bencher;
use flexcomm::util::rng::Rng;
use flexcomm::util::table::Table;

fn main() {
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    let dim: usize = if fast { 1_000_000 } else { 10_000_000 };
    let layout = synthetic_model_layout(dim);
    let mut rng = Rng::new(1);
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);

    let mut b = Bencher::from_env();
    println!("Fig 2 — compression overhead on a {dim}-param layered tensor\n");
    let mut t = Table::new(["compressor", "CR", "mean (ms)", "p95 (ms)"]);
    for cr in [0.1, 0.01, 0.001] {
        for (name, mut comp) in [
            ("LWTopk", Box::new(LwTopk::new()) as Box<dyn Compressor>),
            ("MSTopk(25)", Box::new(MsTopk::new(25))),
            ("Topk-heap", Box::new(TopK::new())),
            ("Topk-quickselect", Box::new(TopK::with_quickselect())),
        ] {
            let m = b.bench(&format!("{name} cr={cr}"), || {
                Bencher::black_box(comp.compress(&g, cr, &layout));
            });
            t.row([
                name.to_string(),
                format!("{cr}"),
                format!("{:.2}", m.mean.as_secs_f64() * 1e3),
                format!("{:.2}", m.p95.as_secs_f64() * 1e3),
            ]);
        }
    }
    println!();
    t.print();
    println!(
        "\nShape checks (paper Fig 2): MSTopk cost >> LWTopk at equal CR \
         (multi-round threshold estimation); cost falls as CR shrinks for \
         selection-based methods; quickselect beats the paper's max-heap \
         (perf-pass ablation, EXPERIMENTS.md §Perf)."
    );
}
