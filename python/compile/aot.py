"""AOT compiler: lower every L2 graph to HLO *text* artifacts for rust.

HLO text (NOT ``lowered.compile()`` or serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Per preset this writes into ``artifacts/``:

  <name>_grad.hlo.txt    (params, batch...)             -> (loss, grads)
  <name>_eval.hlo.txt    (params, batch...)             -> (loss, ncorrect)
  <name>_step.hlo.txt    (params, mom, grads, lr, m, wd) -> (params', mom')
  <name>_layout.txt      "name offset size" per parameter tensor
  <name>_meta.txt        key=value shape/config manifest
  ef_topk_<P>.hlo.txt    (g[P], res[P], k)  -> (g_c, res', |gc|^2, |ge|^2, tau)

Python runs ONCE at build time (``make artifacts``); the rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ef_compress as efc
from .kernels import topk_threshold as tkt

DEFAULT_PRESETS = ["mlp", "mlp-wide", "tiny", "small"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} bytes)")


def _f32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def export_ef_topk(out_dir: str, p: int, rounds: int, force: bool) -> None:
    """Fused threshold-estimation + EF-compress graph over a size-p gradient."""
    path = os.path.join(out_dir, f"ef_topk_{p}.hlo.txt")
    if os.path.exists(path) and not force:
        print(f"  skip {path} (exists)")
        return

    def f(g, residual, k):
        g_e = g + residual
        tau = tkt.estimate_threshold(g_e, k, rounds=rounds)
        g_c, res, norm_c, norm_e = efc.ef_compress(g, residual, tau)
        return g_c, res, norm_c, norm_e, tau

    lowered = jax.jit(f).lower(_f32((p,)), _f32((p,)), _f32())
    _write(path, to_hlo_text(lowered))


def export_preset(out_dir: str, name: str, force: bool) -> None:
    if name in M.TRANSFORMER_PRESETS:
        kind, cfg = "transformer", M.TRANSFORMER_PRESETS[name]
        layout = M.transformer_layout(cfg)
        batch_specs = [_i32((cfg.batch, cfg.seq + 1))]
        meta = dict(
            kind=kind, vocab=cfg.vocab, dim=cfg.dim, layers=cfg.layers,
            heads=cfg.heads, seq=cfg.seq, batch=cfg.batch,
            use_pallas=int(cfg.use_pallas),
        )
    elif name in M.MLP_PRESETS:
        kind, cfg = "mlp", M.MLP_PRESETS[name]
        layout = M.mlp_layout(cfg)
        batch_specs = [_f32((cfg.batch, cfg.features)), _i32((cfg.batch,))]
        meta = dict(
            kind=kind, features=cfg.features, classes=cfg.classes,
            batch=cfg.batch, hidden=",".join(map(str, cfg.hidden)),
        )
    else:
        raise SystemExit(f"unknown preset {name!r}")

    p = M.param_count(layout)
    meta["param_count"] = p
    print(f"preset {name}: kind={kind} params={p:,}")

    layout_path = os.path.join(out_dir, f"{name}_layout.txt")
    if not os.path.exists(layout_path) or force:
        rows = "\n".join(f"{n} {o} {s}" for n, o, s in M.layout_sizes(layout))
        _write(layout_path, rows + "\n")
    meta_path = os.path.join(out_dir, f"{name}_meta.txt")
    if not os.path.exists(meta_path) or force:
        _write(meta_path, "".join(f"{k}={v}\n" for k, v in sorted(meta.items())))

    jobs = [
        (f"{name}_grad.hlo.txt", M.grad_fn(kind, cfg), [_f32((p,))] + batch_specs),
        (f"{name}_eval.hlo.txt", M.eval_fn(kind, cfg), [_f32((p,))] + batch_specs),
        (
            f"{name}_step.hlo.txt",
            M.sgd_step_fn(),
            [_f32((p,)), _f32((p,)), _f32((p,)), _f32(), _f32(), _f32()],
        ),
    ]
    for fname, fn, specs in jobs:
        path = os.path.join(out_dir, fname)
        if os.path.exists(path) and not force:
            print(f"  skip {path} (exists)")
            continue
        lowered = jax.jit(fn).lower(*specs)
        _write(path, to_hlo_text(lowered))

    export_ef_topk(out_dir, p, rounds=25, force=force)

    # Initial parameter snapshot so rust and python agree on init exactly.
    init_path = os.path.join(out_dir, f"{name}_init.f32")
    if not os.path.exists(init_path) or force:
        params = M.init_params(layout, seed=0)
        import numpy as np

        np.asarray(params, dtype="<f4").tofile(init_path)
        digest = hashlib.sha256(open(init_path, "rb").read()).hexdigest()[:16]
        print(f"  wrote {init_path} ({p} f32, sha256:{digest})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets", default=",".join(DEFAULT_PRESETS),
        help="comma-separated preset names "
        f"(transformers: {sorted(M.TRANSFORMER_PRESETS)}, mlps: {sorted(M.MLP_PRESETS)})",
    )
    ap.add_argument("--force", action="store_true", help="rebuild even if present")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for name in [s for s in args.presets.split(",") if s]:
        export_preset(args.out_dir, name, args.force)
    print("aot: done")


if __name__ == "__main__":
    sys.exit(main())
