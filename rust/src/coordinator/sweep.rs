//! The sweep server: many Sessions, one pool (DESIGN.md §12).
//!
//! A [`SweepSpec`] names a grid — model × strategy × network scenario ×
//! controller, every axis a list of registry specs — plus the run shape
//! shared by every cell. [`SweepSpec::run`] expands the grid into
//! [`SweepCell`]s and executes them CONCURRENTLY: a bounded window of
//! `in_flight` OS threads claim cells off a shared atomic cursor, build
//! each [`Session`] with the one shared persistent
//! [`ThreadPool`](crate::util::pool::ThreadPool) injected through the
//! [`SessionBuilder::pool`] seam, and write finished [`SweepRow`]s back by
//! cell index. The pool's region lock serializes parallel regions across
//! sessions and its chunking depends only on `(threads, n)`, so every
//! recorded metric is bitwise identical for ANY `--threads` width and ANY
//! in-flight window — concurrency moves wall-clock time, never results
//! (the engine pins `comp_scale = 0`, the one wall-clock-coupled input).
//!
//! Sessions report progress through a batched [`SweepObserver`] (local
//! event counters flushed into shared atomics every `OBSERVER_BATCH`
//! events — cells never contend per step), and the finished grid
//! aggregates into a [`SweepReport`]: per-cell rows in grid order, a
//! ranked time-to-target-accuracy view, CSV, and the hand-rolled
//! `BENCH_sweep.json` document `scripts/verify.sh` gates on.
//!
//! Axis validation happens before any cell runs: each axis resolves
//! against its own registry and a bad spec is that axis's typed error
//! ([`SweepError`]) listing every valid name. A cell that validates but
//! still fails to build (e.g. a CR-adapting controller paired with a
//! dense strategy) is not a hole in the table: its row records the
//! [`ConfigError`] string and the sweep completes.

use crate::coordinator::controller::{self, ControllerError};
use crate::coordinator::observer::{EvalRecord, TrainObserver};
use crate::coordinator::session::{Session, SessionBuilder, TrainReport};
use crate::coordinator::trainer::Strategy;
use crate::coordinator::worker::ComputeModel;
use crate::experiments;
use crate::models::{self, ModelError};
use crate::netsim::model::{parse_spec as parse_net_spec, NetModelError};
use crate::util::pool::ThreadPool;
use crate::util::table::Table;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Events a [`SweepObserver`] buffers locally before one atomic flush.
const OBSERVER_BATCH: u64 = 32;

/// An axis of the grid was rejected at validation, before any cell ran.
/// One variant per axis, each carrying (or producing) the full list of
/// valid names for that axis's registry — the `NET_TABLE` error style.
#[derive(Debug, Clone, PartialEq)]
pub enum SweepError {
    /// Model axis: not a [`MODEL_TABLE`](crate::models::MODEL_TABLE) name
    /// or `synthetic:<dim>`.
    Model(ModelError),
    /// Strategy axis: not a
    /// [`STRATEGY_TABLE`](crate::coordinator::strategy::STRATEGY_TABLE)
    /// name.
    Strategy { spec: String },
    /// Network axis: not a
    /// [`NET_TABLE`](crate::netsim::model::NET_TABLE) scenario or a
    /// loadable `trace:<path>`.
    Net(NetModelError),
    /// Controller axis: not a
    /// [`CONTROLLER_TABLE`](crate::coordinator::controller::CONTROLLER_TABLE)
    /// name.
    Controller(ControllerError),
    /// An axis with zero entries: the grid would be empty.
    EmptyAxis { axis: &'static str },
}

impl fmt::Display for SweepError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SweepError::Model(e) => write!(f, "sweep model axis: {e}"),
            SweepError::Strategy { spec } => write!(
                f,
                "sweep strategy axis: unknown strategy `{spec}` (valid: {})",
                Strategy::names().collect::<Vec<_>>().join(", ")
            ),
            SweepError::Net(e) => write!(f, "sweep network axis: {e}"),
            SweepError::Controller(e) => write!(f, "sweep controller axis: {e}"),
            SweepError::EmptyAxis { axis } => {
                write!(f, "sweep {axis} axis is empty: the grid has no cells")
            }
        }
    }
}

impl std::error::Error for SweepError {}

/// One grid point: four registry specs. Cells are value objects — the
/// engine rebuilds the Session from these strings inside whichever worker
/// thread claims the cell.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepCell {
    pub model: String,
    pub strategy: String,
    pub net: String,
    pub controller: String,
}

impl SweepCell {
    /// Stable display id, `model/strategy/net/controller`.
    pub fn id(&self) -> String {
        format!("{}/{}/{}/{}", self.model, self.strategy, self.net, self.controller)
    }
}

/// The grid plus the run shape every cell shares. Axis entries are
/// registry specs (model / strategy / scenario / controller names);
/// [`SweepSpec::validate`] resolves each against its table before
/// anything runs.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub models: Vec<String>,
    pub strategies: Vec<String>,
    pub nets: Vec<String>,
    pub controllers: Vec<String>,
    /// Simulated workers per session.
    pub workers: usize,
    pub steps: u64,
    pub steps_per_epoch: u64,
    /// Learning rate for every cell; `0.0` = each model's registry
    /// [`lr_hint`](crate::models::lr_hint) (the default — parameter
    /// scales differ per learner).
    pub lr: f32,
    pub momentum: f32,
    /// Static compression ratio for compressed strategies (dense cells
    /// carry it inertly).
    pub cr: f64,
    /// Held-out eval cadence in steps (0 = final eval only).
    pub eval_every: u64,
    pub seed: u64,
    /// Fixed per-step compute seconds (simulated; keeps cells comparable).
    pub compute_s: f64,
    /// Shared-pool width (0 = all cores, DESIGN.md §7).
    pub threads: usize,
    /// Concurrent-session window: how many cells run at once.
    pub in_flight: usize,
    /// Accuracy target for the ranked time-to-accuracy summary.
    pub target_acc: f64,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            models: vec!["mlp".into(), "matreg".into()],
            strategies: vec!["ag-topk".into(), "artopk-star".into(), "flexible".into()],
            nets: vec!["c1".into(), "c2".into(), "flaky".into()],
            controllers: vec!["static".into(), "gravac".into()],
            workers: 4,
            steps: 200,
            steps_per_epoch: 50,
            lr: 0.0,
            momentum: 0.9,
            cr: 0.1,
            eval_every: 50,
            seed: 7,
            compute_s: 0.005,
            threads: 0,
            in_flight: 4,
            target_acc: 0.6,
        }
    }
}

impl SweepSpec {
    /// The verify.sh gate's grid: 2 real learners x 2 compressed
    /// strategies x 2 scenarios x 1 controller, sized so every cell
    /// finishes fast AND demonstrably learns past its chance floor.
    pub fn smoke() -> Self {
        SweepSpec {
            models: vec!["mlp".into(), "matreg".into()],
            strategies: vec!["ag-topk".into(), "flexible".into()],
            nets: vec!["c1".into(), "c2".into()],
            controllers: vec!["static".into()],
            steps: 400,
            steps_per_epoch: 100,
            eval_every: 50,
            in_flight: 4,
            target_acc: 0.6,
            ..SweepSpec::default()
        }
    }

    /// Resolve every axis entry against its registry. Per-axis typed
    /// errors; nothing has run yet when this rejects.
    pub fn validate(&self) -> Result<(), SweepError> {
        for (axis, list) in [
            ("model", &self.models),
            ("strategy", &self.strategies),
            ("network", &self.nets),
            ("controller", &self.controllers),
        ] {
            if list.is_empty() {
                return Err(SweepError::EmptyAxis { axis });
            }
        }
        for m in &self.models {
            // Probe-construct (seed irrelevant): unknown names carry the
            // full MODEL_TABLE listing.
            models::build_model(m, 0).map(drop).map_err(SweepError::Model)?;
        }
        for s in &self.strategies {
            if Strategy::parse(s).is_err() {
                return Err(SweepError::Strategy { spec: s.clone() });
            }
        }
        for n in &self.nets {
            parse_net_spec(n, 1.0).map(drop).map_err(SweepError::Net)?;
        }
        for c in &self.controllers {
            if !controller::controller_names().any(|n| n == c.as_str()) {
                return Err(SweepError::Controller(ControllerError::UnknownController {
                    spec: c.clone(),
                }));
            }
        }
        Ok(())
    }

    /// Expand the grid in fixed axis order (model outermost, controller
    /// innermost) — row order in the report is this order.
    pub fn expand(&self) -> Vec<SweepCell> {
        let mut cells =
            Vec::with_capacity(self.models.len() * self.strategies.len() * self.nets.len());
        for m in &self.models {
            for s in &self.strategies {
                for n in &self.nets {
                    for c in &self.controllers {
                        cells.push(SweepCell {
                            model: m.clone(),
                            strategy: s.clone(),
                            net: n.clone(),
                            controller: c.clone(),
                        });
                    }
                }
            }
        }
        cells
    }

    /// Validate, expand and execute the whole grid (see module docs for
    /// the concurrency model), returning per-cell rows in grid order.
    pub fn run(&self) -> Result<SweepReport, SweepError> {
        self.validate()?;
        let cells = self.expand();
        let n = cells.len();
        let pool = ThreadPool::auto(self.threads);
        let progress = Arc::new(SweepProgress::default());
        let window = self.in_flight.clamp(1, n);
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SweepRow>>> = (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..window {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let row = run_cell(self, &cells[i], &pool, &progress);
                    *slots[i].lock().unwrap() = Some(row);
                    progress.cells_done.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        let rows = slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("every claimed cell writes its row"))
            .collect();
        Ok(SweepReport { rows, target_acc: self.target_acc, progress })
    }
}

/// Build and run one cell's Session on the shared pool. Build rejections
/// (typed [`ConfigError`](crate::coordinator::session::ConfigError)s —
/// e.g. a CR-adapting controller on a dense strategy) become error rows,
/// not sweep failures.
fn run_cell(
    spec: &SweepSpec,
    cell: &SweepCell,
    pool: &ThreadPool,
    progress: &Arc<SweepProgress>,
) -> SweepRow {
    let lr = if spec.lr > 0.0 { spec.lr } else { models::lr_hint(&cell.model) };
    let builder: SessionBuilder = Session::builder()
        .workers(spec.workers)
        .steps(spec.steps)
        .steps_per_epoch(spec.steps_per_epoch)
        .lr(lr)
        .momentum(spec.momentum)
        .static_cr(spec.cr)
        .eval_every(spec.eval_every)
        .seed(spec.seed)
        .threads(spec.threads)
        .compute(ComputeModel::fixed(spec.compute_s))
        // The one wall-clock-coupled metric input: pinned off so recorded
        // metrics are bitwise identical at any threads/in-flight window.
        .comp_scale(0.0)
        .model_spec(&cell.model)
        .network_spec(&cell.net)
        .controller_spec(&cell.controller)
        .pool(pool.clone())
        .observer(Box::new(SweepObserver::new(progress.clone())));
    let builder = match Strategy::parse(&cell.strategy) {
        Ok(s) => builder.strategy(s),
        Err(e) => return SweepRow::failed(cell, &e.to_string()),
    };
    match builder.build() {
        Ok(session) => SweepRow::from_report(cell, &session.run(), spec),
        Err(e) => SweepRow::failed(cell, &e.to_string()),
    }
}

/// Sweep-wide progress counters, fed in batches by every cell's
/// [`SweepObserver`]. Read them live from another thread (they are plain
/// atomics) or after the fact for totals.
#[derive(Debug, Default)]
pub struct SweepProgress {
    pub steps_done: AtomicU64,
    pub evals_done: AtomicU64,
    pub cells_done: AtomicU64,
}

impl SweepProgress {
    /// `(steps, evals, cells)` completed so far.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.steps_done.load(Ordering::Relaxed),
            self.evals_done.load(Ordering::Relaxed),
            self.cells_done.load(Ordering::Relaxed),
        )
    }
}

/// The batched per-session observer: counts events locally and flushes
/// into the shared [`SweepProgress`] atomics every [`OBSERVER_BATCH`]
/// events (and on drop), so N concurrent sessions never contend on a
/// cache line per step.
pub struct SweepObserver {
    shared: Arc<SweepProgress>,
    buf_steps: u64,
    buf_evals: u64,
}

impl SweepObserver {
    pub fn new(shared: Arc<SweepProgress>) -> Self {
        SweepObserver { shared, buf_steps: 0, buf_evals: 0 }
    }

    fn flush(&mut self) {
        if self.buf_steps > 0 {
            self.shared.steps_done.fetch_add(self.buf_steps, Ordering::Relaxed);
            self.buf_steps = 0;
        }
        if self.buf_evals > 0 {
            self.shared.evals_done.fetch_add(self.buf_evals, Ordering::Relaxed);
            self.buf_evals = 0;
        }
    }
}

impl TrainObserver for SweepObserver {
    fn on_step(&mut self, _m: &crate::coordinator::metrics::StepMetrics) {
        self.buf_steps += 1;
        if self.buf_steps + self.buf_evals >= OBSERVER_BATCH {
            self.flush();
        }
    }

    fn on_eval(&mut self, _e: &EvalRecord) {
        self.buf_evals += 1;
    }
}

impl Drop for SweepObserver {
    fn drop(&mut self) {
        self.flush();
    }
}

/// One finished (or failed) cell. `error = Some(..)` rows carry the
/// build rejection verbatim and NaN/None measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    pub cell: SweepCell,
    /// Resolved model display name (`TrainReport::model`), `""` on error.
    pub model_name: String,
    /// Final held-out loss (last eval record).
    pub final_loss: f64,
    pub best_acc: f64,
    pub final_acc: f64,
    /// Simulated cluster seconds for the whole run.
    pub virtual_time_s: f64,
    /// Simulated seconds to the first eval at/above the sweep's
    /// `target_acc` (incl. exploration overhead); `None` = never reached.
    pub time_to_target_s: Option<f64>,
    pub final_cr: f64,
    pub error: Option<String>,
}

impl SweepRow {
    fn from_report(cell: &SweepCell, r: &TrainReport, spec: &SweepSpec) -> SweepRow {
        let (final_loss, final_acc) =
            r.metrics.evals.last().map_or((f64::NAN, f64::NAN), |&(_, l, a)| (l, a));
        SweepRow {
            cell: cell.clone(),
            model_name: r.model.clone(),
            final_loss,
            best_acc: r.best_accuracy().unwrap_or(f64::NAN),
            final_acc,
            virtual_time_s: r.virtual_time_s,
            time_to_target_s: experiments::time_to_accuracy(
                r,
                spec.target_acc,
                spec.steps_per_epoch,
            ),
            final_cr: r.final_cr,
            error: None,
        }
    }

    fn failed(cell: &SweepCell, error: &str) -> SweepRow {
        SweepRow {
            cell: cell.clone(),
            model_name: String::new(),
            final_loss: f64::NAN,
            best_acc: f64::NAN,
            final_acc: f64::NAN,
            virtual_time_s: f64::NAN,
            time_to_target_s: None,
            final_cr: f64::NAN,
            error: Some(error.to_string()),
        }
    }

    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// The finished grid: rows in grid order plus the ranked views and
/// emitters (`BENCH_sweep.json`, CSV, terminal table).
pub struct SweepReport {
    pub rows: Vec<SweepRow>,
    pub target_acc: f64,
    /// Final progress counters (all cells have flushed by now).
    pub progress: Arc<SweepProgress>,
}

impl SweepReport {
    pub fn failed_cells(&self) -> usize {
        self.rows.iter().filter(|r| !r.ok()).count()
    }

    /// Time-to-target ranking: cells that reached the target first (by
    /// ascending simulated time), then unreached-but-finished cells by
    /// descending best accuracy, then error rows. NaN sorts last within
    /// its group.
    pub fn ranked(&self) -> Vec<&SweepRow> {
        let mut rows: Vec<&SweepRow> = self.rows.iter().collect();
        let key = |r: &SweepRow| -> (u8, f64) {
            match (&r.error, r.time_to_target_s) {
                (Some(_), _) => (2, f64::INFINITY),
                (None, Some(t)) => (0, if t.is_nan() { f64::INFINITY } else { t }),
                // Negate best_acc so "higher accuracy first" is ascending.
                (None, None) => {
                    (1, if r.best_acc.is_nan() { f64::INFINITY } else { -r.best_acc })
                }
            }
        };
        rows.sort_by(|a, b| {
            let (ga, ka) = key(a);
            let (gb, kb) = key(b);
            ga.cmp(&gb).then(crate::tensor::nan_min_cmp(ka, kb))
        });
        rows
    }

    /// The verify.sh smoke gate: every grid cell of `spec` produced
    /// exactly one row, none errored, every cell evaluated, and every
    /// cell's best accuracy beat its model's registry chance floor
    /// ([`chance_acc`](crate::models::chance_acc)) — i.e. every learner
    /// demonstrably learned under every strategy/scenario in the grid.
    pub fn verify_full_coverage(&self, spec: &SweepSpec) -> Result<(), String> {
        let cells = spec.expand();
        if self.rows.len() != cells.len() {
            return Err(format!(
                "coverage hole: {} rows for {} grid cells",
                self.rows.len(),
                cells.len()
            ));
        }
        for (cell, row) in cells.iter().zip(&self.rows) {
            if row.cell != *cell {
                return Err(format!(
                    "row order broke: expected {}, found {}",
                    cell.id(),
                    row.cell.id()
                ));
            }
            if let Some(e) = &row.error {
                return Err(format!("cell {} failed: {e}", cell.id()));
            }
            let floor = models::chance_acc(&cell.model);
            if !(row.best_acc > floor) {
                return Err(format!(
                    "cell {} best accuracy {:.4} not above the {} chance floor {:.4}",
                    cell.id(),
                    row.best_acc,
                    cell.model,
                    floor
                ));
            }
        }
        Ok(())
    }

    /// CSV of every row in grid order (empty cells for `None`/errors).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "model,strategy,net,controller,final_loss,best_acc,final_acc,\
             virtual_time_s,time_to_target_s,final_cr,error\n",
        );
        for r in &self.rows {
            let tta = r.time_to_target_s.map_or(String::new(), |t| format!("{t:.6}"));
            let err = r.error.as_deref().unwrap_or("").replace(',', ";");
            out.push_str(&format!(
                "{},{},{},{},{:.6},{:.4},{:.4},{:.6},{},{:.4},{}\n",
                r.cell.model,
                r.cell.strategy,
                r.cell.net,
                r.cell.controller,
                r.final_loss,
                r.best_acc,
                r.final_acc,
                r.virtual_time_s,
                tta,
                r.final_cr,
                err
            ));
        }
        out
    }

    /// The `BENCH_sweep.json` document (hand-rolled — offline build, no
    /// serde; same convention as
    /// [`Bencher::write_json`](crate::util::bench::Bencher::write_json)).
    /// Shape: `{"bench": "sweep", "target_acc": .., "cells": N,
    /// "failed": k, "rows": [{..}, ..]}` with rows in grid order.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"bench\": \"sweep\", \"target_acc\": {}, \"cells\": {}, \"failed\": {},\n \
             \"rows\": [",
            self.target_acc,
            self.rows.len(),
            self.failed_cells()
        ));
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let tta = r
                .time_to_target_s
                .map_or("null".to_string(), |t| format!("{t}"));
            let err = r.error.as_deref().map_or("null".to_string(), json_str);
            out.push_str(&format!(
                "\n  {{\"model\": {}, \"strategy\": {}, \"net\": {}, \"controller\": {}, \
                 \"final_loss\": {}, \"best_acc\": {}, \"final_acc\": {}, \
                 \"virtual_time_s\": {}, \"time_to_target_s\": {}, \"final_cr\": {}, \
                 \"error\": {}}}",
                json_str(&r.cell.model),
                json_str(&r.cell.strategy),
                json_str(&r.cell.net),
                json_str(&r.cell.controller),
                json_num(r.final_loss),
                json_num(r.best_acc),
                json_num(r.final_acc),
                json_num(r.virtual_time_s),
                tta,
                json_num(r.final_cr),
                err
            ));
        }
        out.push_str("\n]}\n");
        out
    }

    /// Write `BENCH_sweep.json` + the CSV (parent dirs created); returns
    /// the two paths.
    pub fn write_files(&self, json_path: &str, csv_path: &str) -> anyhow::Result<(String, String)> {
        let j = experiments::write_csv(json_path, &self.to_json())?;
        let c = experiments::write_csv(csv_path, &self.to_csv())?;
        Ok((j, c))
    }

    /// Print the ranked time-to-accuracy table.
    pub fn print_ranked(&self) {
        let mut t = Table::new([
            "rank",
            "model",
            "strategy",
            "net",
            "controller",
            "tta_s",
            "best_acc",
            "vtime_s",
            "status",
        ]);
        for (i, r) in self.ranked().iter().enumerate() {
            t.row([
                format!("{}", i + 1),
                r.cell.model.clone(),
                r.cell.strategy.clone(),
                r.cell.net.clone(),
                r.cell.controller.clone(),
                r.time_to_target_s.map_or("-".into(), |t| format!("{t:.3}")),
                if r.best_acc.is_nan() { "-".into() } else { format!("{:.3}", r.best_acc) },
                if r.virtual_time_s.is_nan() {
                    "-".into()
                } else {
                    format!("{:.3}", r.virtual_time_s)
                },
                match &r.error {
                    Some(e) => format!("ERROR: {e}"),
                    None if r.time_to_target_s.is_some() => "reached".into(),
                    None => "below target".into(),
                },
            ]);
        }
        t.print();
    }
}

/// JSON number: finite values verbatim, non-finite as null (JSON has no
/// NaN/Infinity literals).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Minimal JSON string encoder (same contract as the bench harness's
/// private helper — registry names are ASCII, escape correctly anyway).
fn json_str(s: &str) -> String {
    let mut q = String::with_capacity(s.len() + 2);
    q.push('"');
    for c in s.chars() {
        match c {
            '"' => q.push_str("\\\""),
            '\\' => q.push_str("\\\\"),
            '\n' => q.push_str("\\n"),
            '\r' => q.push_str("\\r"),
            '\t' => q.push_str("\\t"),
            c if (c as u32) < 0x20 => q.push_str(&format!("\\u{:04x}", c as u32)),
            c => q.push(c),
        }
    }
    q.push('"');
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fast 2x2x1x1 grid for engine tests.
    fn tiny() -> SweepSpec {
        SweepSpec {
            models: vec!["matreg".into(), "host-mlp".into()],
            strategies: vec!["ag-topk".into(), "dense-ring".into()],
            nets: vec!["c1".into()],
            controllers: vec!["static".into()],
            workers: 2,
            steps: 4,
            steps_per_epoch: 4,
            eval_every: 2,
            in_flight: 4,
            ..SweepSpec::default()
        }
    }

    #[test]
    fn grid_expands_in_fixed_axis_order() {
        let spec = tiny();
        let cells = spec.expand();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].id(), "matreg/ag-topk/c1/static");
        assert_eq!(cells[1].id(), "matreg/dense-ring/c1/static");
        assert_eq!(cells[2].id(), "host-mlp/ag-topk/c1/static");
        assert_eq!(cells[3].id(), "host-mlp/dense-ring/c1/static");
    }

    // Satellite: per-axis typed validation errors, each listing its
    // registry's valid names.

    /// NaN-poisoned ranking keys (NaN time-to-target, NaN best_acc) must
    /// neither panic nor perturb the group order now that the tiebreak
    /// runs through the crate NaN total order.
    #[test]
    fn ranked_survives_nan_rows_deterministically() {
        let cell = |m: &str| SweepCell {
            model: m.into(),
            strategy: "s".into(),
            net: "n".into(),
            controller: "c".into(),
        };
        let row = |m: &str, ttt: Option<f64>, best: f64| SweepRow {
            cell: cell(m),
            model_name: m.into(),
            final_loss: 0.0,
            best_acc: best,
            final_acc: best,
            virtual_time_s: 1.0,
            time_to_target_s: ttt,
            final_cr: 0.1,
            error: None,
        };
        let report = SweepReport {
            rows: vec![
                row("a", Some(f64::NAN), 0.9),
                row("b", Some(2.0), 0.9),
                row("c", None, f64::NAN),
                row("d", None, 0.8),
                row("e", Some(1.0), 0.9),
            ],
            target_acc: 0.9,
            progress: Arc::new(SweepProgress::default()),
        };
        let ids: Vec<String> =
            report.ranked().iter().map(|r| r.cell.model.clone()).collect();
        // Reached cells ascending by time (NaN maps to INFINITY, last);
        // then unreached by descending accuracy (NaN last).
        assert_eq!(ids, vec!["e", "b", "a", "d", "c"]);
        let again: Vec<String> =
            report.ranked().iter().map(|r| r.cell.model.clone()).collect();
        assert_eq!(ids, again, "ranking must be deterministic");
    }

    #[test]
    fn bad_model_axis_is_a_typed_listing_error() {
        let spec = SweepSpec { models: vec!["nope".into()], ..tiny() };
        match spec.validate() {
            Err(SweepError::Model(ModelError::UnknownModel { spec })) => {
                assert_eq!(spec, "nope")
            }
            other => panic!("expected Model error, got {other:?}"),
        }
        let msg = spec.validate().unwrap_err().to_string();
        assert!(msg.contains("mlp") && msg.contains("matreg"), "{msg}");
    }

    #[test]
    fn bad_strategy_axis_is_a_typed_listing_error() {
        let spec = SweepSpec { strategies: vec!["nope".into()], ..tiny() };
        match spec.validate() {
            Err(SweepError::Strategy { spec }) => assert_eq!(spec, "nope"),
            other => panic!("expected Strategy error, got {other:?}"),
        }
        let msg = spec.validate().unwrap_err().to_string();
        assert!(msg.contains("ag-topk") && msg.contains("flexible"), "{msg}");
    }

    #[test]
    fn bad_net_axis_is_a_typed_listing_error() {
        let spec = SweepSpec { nets: vec!["nope".into()], ..tiny() };
        match spec.validate() {
            Err(SweepError::Net(NetModelError::UnknownScenario { spec })) => {
                assert_eq!(spec, "nope")
            }
            other => panic!("expected Net error, got {other:?}"),
        }
        let msg = spec.validate().unwrap_err().to_string();
        assert!(msg.contains("c1") && msg.contains("flaky"), "{msg}");
    }

    #[test]
    fn bad_controller_axis_is_a_typed_listing_error() {
        let spec = SweepSpec { controllers: vec!["nope".into()], ..tiny() };
        match spec.validate() {
            Err(SweepError::Controller(ControllerError::UnknownController { spec })) => {
                assert_eq!(spec, "nope")
            }
            other => panic!("expected Controller error, got {other:?}"),
        }
        let msg = spec.validate().unwrap_err().to_string();
        assert!(msg.contains("static") && msg.contains("gravac"), "{msg}");
    }

    #[test]
    fn empty_axis_is_a_typed_error() {
        let spec = SweepSpec { nets: vec![], ..tiny() };
        assert_eq!(spec.validate(), Err(SweepError::EmptyAxis { axis: "network" }));
    }

    /// The acceptance pin: the SAME grid over different shared-pool
    /// widths and in-flight windows produces bitwise-identical recorded
    /// metrics in every row — concurrency never leaks into results.
    #[test]
    fn recorded_metrics_are_bitwise_invariant_to_threads_and_window() {
        let serial = SweepSpec { threads: 1, in_flight: 1, ..tiny() };
        let wide = SweepSpec { threads: 3, in_flight: 4, ..tiny() };
        let a = serial.run().unwrap();
        let b = wide.run().unwrap();
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.cell, y.cell);
            assert_eq!(x.final_loss.to_bits(), y.final_loss.to_bits(), "{}", x.cell.id());
            assert_eq!(x.best_acc.to_bits(), y.best_acc.to_bits(), "{}", x.cell.id());
            assert_eq!(
                x.virtual_time_s.to_bits(),
                y.virtual_time_s.to_bits(),
                "{}",
                x.cell.id()
            );
            assert_eq!(x.time_to_target_s, y.time_to_target_s, "{}", x.cell.id());
        }
        // Progress counters observed every step/eval of every cell.
        let (steps, evals, cells) = a.progress.snapshot();
        assert_eq!(steps, 4 * 4);
        assert_eq!(cells, 4);
        assert!(evals >= 4, "{evals}");
    }

    /// A grid cell that validates but cannot build (CR-adapting gravac on
    /// a dense strategy) becomes an error ROW; the sweep still completes
    /// and the row carries the ConfigError text.
    #[test]
    fn unbuildable_cells_become_error_rows_not_failures() {
        let spec = SweepSpec {
            models: vec!["matreg".into()],
            strategies: vec!["dense-ring".into(), "ag-topk".into()],
            controllers: vec!["gravac".into()],
            ..tiny()
        };
        let report = spec.run().unwrap();
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.failed_cells(), 1);
        let bad = &report.rows[0];
        assert!(!bad.ok());
        assert!(bad.error.as_ref().unwrap().contains("gravac"), "{:?}", bad.error);
        assert!(report.rows[1].ok());
        // And the coverage gate refuses such a grid.
        let err = report.verify_full_coverage(&spec).unwrap_err();
        assert!(err.contains("dense-ring"), "{err}");
    }

    #[test]
    fn ranking_orders_reached_then_unreached_then_errors() {
        let cell = |m: &str| SweepCell {
            model: m.into(),
            strategy: "s".into(),
            net: "n".into(),
            controller: "c".into(),
        };
        let mut fast = SweepRow::failed(&cell("fast"), "x");
        fast.error = None;
        fast.time_to_target_s = Some(1.0);
        fast.best_acc = 0.9;
        let mut slow = fast.clone();
        slow.cell = cell("slow");
        slow.time_to_target_s = Some(2.0);
        let mut high = SweepRow::failed(&cell("high"), "x");
        high.error = None;
        high.best_acc = 0.5;
        let mut low = high.clone();
        low.cell = cell("low");
        low.best_acc = 0.2;
        let err = SweepRow::failed(&cell("err"), "boom");
        let report = SweepReport {
            rows: vec![err, low, slow, high, fast],
            target_acc: 0.6,
            progress: Arc::new(SweepProgress::default()),
        };
        let order: Vec<&str> =
            report.ranked().iter().map(|r| r.cell.model.as_str()).collect();
        assert_eq!(order, ["fast", "slow", "high", "low", "err"]);
    }

    #[test]
    fn json_and_csv_cover_every_row() {
        let spec = SweepSpec {
            models: vec!["matreg".into()],
            strategies: vec!["ag-topk".into(), "dense-ring".into()],
            controllers: vec!["gravac".into()], // dense cell -> error row
            ..tiny()
        };
        let report = spec.run().unwrap();
        let json = report.to_json();
        assert!(json.starts_with("{\"bench\": \"sweep\""), "{json}");
        assert!(json.contains("\"cells\": 2") && json.contains("\"failed\": 1"), "{json}");
        assert_eq!(json.matches("\"strategy\":").count(), 2, "{json}");
        // Error rows: null measurements + the error string; ok rows: a
        // real number and a null error.
        assert!(json.contains("\"error\": \"controller rejected"), "{json}");
        assert!(json.contains("\"error\": null"), "{json}");
        let csv = report.to_csv();
        assert_eq!(csv.lines().count(), 3, "{csv}");
        assert!(csv.lines().next().unwrap().starts_with("model,strategy,"), "{csv}");
        assert!(csv.contains("matreg,ag-topk,c1,gravac"), "{csv}");
    }

    #[test]
    fn coverage_gate_accepts_a_clean_grid_and_checks_the_chance_floor() {
        let spec = SweepSpec {
            models: vec!["matreg".into()],
            strategies: vec!["ag-topk".into()],
            controllers: vec!["static".into()],
            steps: 120,
            steps_per_epoch: 40,
            eval_every: 40,
            ..tiny()
        };
        let report = spec.run().unwrap();
        report.verify_full_coverage(&spec).unwrap();
        // Tampering with a row's accuracy trips the floor check.
        let mut bad = SweepReport {
            rows: report.rows.clone(),
            target_acc: report.target_acc,
            progress: report.progress.clone(),
        };
        bad.rows[0].best_acc = 0.0;
        let err = bad.verify_full_coverage(&spec).unwrap_err();
        assert!(err.contains("chance floor"), "{err}");
    }

    #[test]
    fn json_num_and_str_helpers() {
        assert_eq!(json_num(1.5), "1.5");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_str("a\"b"), "\"a\\\"b\"");
    }
}
