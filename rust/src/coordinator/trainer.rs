//! The synchronous data-parallel training loop (Eqn 1/3) with flexible
//! compression-communication (the paper's full system).
//!
//! Per step: every worker computes a gradient (PJRT artifact or host
//! model), the configured [`CommStrategy`] plans and executes the exchange
//! (real data movement, simulated α-β time), and the shared parameters
//! take a momentum-SGD step. After every recorded step the configured
//! [`Controller`] observes the step and may retune the CR, switch the
//! selection policy, or request a checkpointed exploration (the control
//! plane, DESIGN.md §10); every recorded step streams through the
//! registered [`TrainObserver`](crate::coordinator::observer::TrainObserver)s.
//! The loop itself is mechanism-free: plan → exchange → control → observe,
//! with no per-strategy or per-controller branches.
//!
//! Construction goes through
//! [`Session::builder`](crate::coordinator::session::Session::builder) —
//! the builder validates the configuration (typed errors, not panics) and
//! assembles the trainer; [`TrainConfig`] remains the serialized form.

use crate::artopk::{ArFlavor, SelectionPolicy};
use crate::collectives::CollectiveKind;
use crate::compress::{CompressorKind, EfState};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::controller::{
    AdaptiveConfig, ControlAction, ControlCtx, ControlDecision, Controller,
    ExplorationHarness, StaticController,
};
use crate::coordinator::metrics::{MetricsLog, StepMetrics};
use crate::coordinator::observer::{
    CrChange, EvalRecord, MembershipChange, NetChange, StrategySwitch, SwitchDimension,
    TrainObserver,
};
use crate::coordinator::strategy::{CommStrategy, ExchangeCtx, StepCtx};
use crate::coordinator::worker::{ComputeModel, GradSource};
use crate::netsim::cost_model::{LinkParams, Topology};
use crate::netsim::model::NetworkModel;
use crate::netsim::probe::Probe;
use crate::netsim::schedule::NetSchedule;
use crate::netsim::VirtualClock;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

/// Dense allreduce flavour for the DenseSGD baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseFlavor {
    Ring,
    Tree,
    /// Recursive halving-doubling (Rabenseifner): ring's β at tree's α.
    HalvingDoubling,
    /// Two-level intra-reduce / inter-ring / intra-broadcast over the
    /// schedule's [`Topology`] (falls back to ring on flat clusters).
    Hierarchical,
    /// Parameter-server star (scale-out strawman).
    Ps,
    /// Pick ring/tree per step from the probed link (the paper's original
    /// two-way dense choice).
    Auto,
    /// Pick the cheapest of {ring, tree, HD, hierarchical} per step from
    /// the probed link and the schedule's topology
    /// ([`selector::choose_dense_topo`](crate::coordinator::selector::choose_dense_topo)).
    TopoAuto,
}

/// Compression-communication strategy — the pure config/CLI surface.
///
/// Parse names via [`Strategy::parse`] (one shared table,
/// [`STRATEGY_TABLE`](crate::coordinator::strategy::STRATEGY_TABLE));
/// behaviour lives behind the [`CommStrategy`] objects that
/// [`instantiate`](crate::coordinator::strategy::instantiate) builds from
/// these values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// No compression; dense allreduce (the paper's DenseSGD baseline).
    DenseSgd { flavor: DenseFlavor },
    /// Compress with `kind`, synchronize via Allgather (LW/MS-Topk path).
    AgCompress { kind: CompressorKind },
    /// AR-Topk with a fixed AR flavour (§3-A/B).
    ArTopkFixed { policy: SelectionPolicy, flavor: ArFlavor },
    /// Full flexible strategy: pick AG vs ART-Ring vs ART-Tree per step by
    /// Eqn 5 on the probed link (§3-D).
    Flexible { policy: SelectionPolicy },
    /// AR-Topk that AUTO-switches STAR<->VAR from observed loss improvement
    /// (the paper's §5 future work), with the Eqn 5 ring/tree choice.
    ArTopkAuto { flavor: ArFlavor },
    /// AR-Topk over the sampled-threshold selection backend
    /// ([`crate::compress::sampledk`]): bitwise-identical trajectories to
    /// [`Strategy::ArTopkFixed`], cheaper selection (`t_comp` only).
    ArTopkSampled { policy: SelectionPolicy, flavor: ArFlavor },
}

impl Strategy {
    pub fn is_compressed(&self) -> bool {
        !matches!(self, Strategy::DenseSgd { .. })
    }
}

/// Compression-ratio control — the serialized config surface. `Static`
/// implies the no-op `static` controller, `Adaptive` the `moo` controller
/// (§3-E); both can be overridden per run with
/// [`SessionBuilder::controller_spec`](crate::coordinator::session::SessionBuilder::controller_spec)
/// or a custom [`Controller`] object (DESIGN.md §10).
#[derive(Debug, Clone)]
pub enum CrControl {
    Static(f64),
    /// MOO-adaptive (§3-E): candidate exploration + NSGA-II knee point.
    Adaptive(AdaptiveConfig),
}

/// Full training configuration — the SERIALIZED form (config files,
/// experiment presets). All construction of a runnable trainer goes
/// through [`Session::builder`](crate::coordinator::session::Session::builder)
/// / [`Session::from_config`](crate::coordinator::session::Session::from_config),
/// which validate these fields into typed errors instead of panics.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub n_workers: usize,
    pub steps: u64,
    pub steps_per_epoch: u64,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// `(step, factor)` learning-rate decay events.
    pub lr_decay: Vec<(u64, f32)>,
    pub strategy: Strategy,
    pub cr: CrControl,
    /// The network environment — any [`NetworkModel`]: a
    /// [`NetSchedule`], a replayed
    /// [`TraceModel`](crate::netsim::trace::TraceModel), or a
    /// [`modifiers`](crate::netsim::modifiers) composition. The trainer,
    /// probe and selector read conditions ONLY through this trait object
    /// (DESIGN.md §9).
    pub net: Box<dyn NetworkModel>,
    pub compute: ComputeModel,
    /// Probe observation noise fraction.
    pub probe_noise: f64,
    /// Message-size scale for SIMULATED communication/compression time:
    /// proxy-model experiments set this to `paper_params / proxy_params`
    /// so step-time tables carry the paper's message magnitudes while the
    /// numerics stay real (DESIGN.md §3). 1.0 = honest proxy size.
    pub msg_scale: f64,
    /// Multiplier on MEASURED compression time. Proxy experiments use
    /// `msg_scale / GPU_COMPRESS_SPEEDUP`: compression is O(G) so it
    /// extrapolates linearly in size, divided by the accelerator-vs-CPU
    /// throughput ratio (experiments::GPU_COMPRESS_SPEEDUP). 1.0 = honest
    /// measured time on this host.
    pub comp_scale: f64,
    /// Evaluate every N steps (0 = only at the end).
    pub eval_every: u64,
    pub seed: u64,
    /// Worker threads for per-worker gradient computation and compression
    /// (CLI `--threads`): 0 = available hardware parallelism, 1 = fully
    /// sequential. The builder spawns ONE persistent pool of this width
    /// per session; workers park between parallel regions, so thread
    /// spawn cost is paid once, not per step (DESIGN.md §7). With static
    /// CR control, numerics are bitwise identical
    /// for every value — only measured wall time changes (DESIGN.md §7).
    /// The `moo` controller ([`CrControl::Adaptive`]) feeds MEASURED
    /// compression time into CR selection and so is not run-to-run
    /// bitwise reproducible, with or without threads — unless that input
    /// is removed (`comp_scale = 0`, how the §10 determinism tests pin
    /// every controller); `gravac` decides on simulated gain alone and
    /// stays bitwise thread-invariant.
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_workers: 8,
            steps: 200,
            steps_per_epoch: 50,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 0.0,
            lr_decay: Vec::new(),
            strategy: Strategy::DenseSgd { flavor: DenseFlavor::Ring },
            cr: CrControl::Static(0.01),
            net: Box::new(NetSchedule::static_link(
                crate::netsim::cost_model::LinkParams::from_ms_gbps(4.0, 20.0),
            )),
            compute: ComputeModel::fixed(0.02),
            probe_noise: 0.02,
            msg_scale: 1.0,
            comp_scale: 1.0,
            eval_every: 0,
            seed: 0,
            threads: 0,
        }
    }
}

/// The coordinator-side trainer (engine). State that checkpoints must
/// cover (params, momentum, error-feedback residuals) lives here; the
/// strategy object owns only its own operator state. Fields are
/// crate-internal — external consumers read results through the
/// [`TrainReport`](crate::coordinator::session::TrainReport) and the
/// observer stream, or through the read accessors below.
pub struct Trainer {
    pub(crate) cfg: TrainConfig,
    pub(crate) source: Box<dyn GradSource>,
    pub(crate) params: Vec<f32>,
    pub(crate) momentum_buf: Vec<f32>,
    /// Per-worker error-feedback residuals (Eqn 2) — engine-owned so
    /// checkpoint/restore covers them for every strategy.
    pub(crate) ef: Vec<EfState>,
    /// The pluggable communication strategy (DESIGN.md §8).
    pub(crate) strategy: Box<dyn CommStrategy>,
    /// Execution engine for the per-worker hot path (DESIGN.md §7).
    pub(crate) pool: ThreadPool,
    pub(crate) probe: Probe,
    pub(crate) clock: VirtualClock,
    pub(crate) metrics: MetricsLog,
    pub(crate) observers: Vec<Box<dyn TrainObserver>>,
    /// Dedicated stream for [`ComputeModel`] jitter/straggler draws.
    /// Formerly a shared trainer `Rng`: because compute was its only
    /// consumer the old stream is retired outright, and the dedicated
    /// seed guarantees NO future consumer can entangle its draws with
    /// compute jitter — trajectories stay comparable across compute
    /// configs (the jitter-decoupling contract, pinned in
    /// rust/tests/determinism.rs).
    pub(crate) compute_rng: Rng,
    pub(crate) step: u64,
    pub(crate) cur_cr: f64,
    /// The control plane (DESIGN.md §10): consulted once per recorded
    /// step; its decisions (CR moves, policy switches, explorations) are
    /// applied by `control_phase` — the engine has no per-mechanism
    /// control branches of its own.
    pub(crate) controller: Box<dyn Controller>,
    pub(crate) lr_cur: f32,
    /// Simulated seconds spent in candidate exploration (kept out of the
    /// restored clock, reported separately; charged by the
    /// [`ExplorationHarness`]).
    pub(crate) explore_overhead_s: f64,
    /// Collective used by the previous RECORDED step (switch detection
    /// for the observer stream).
    last_collective: Option<CollectiveKind>,
    /// TRUE (unscaled) inter link of the previous recorded step — fires
    /// [`NetChange`] when the environment crosses a phase/episode
    /// boundary between recorded steps.
    last_net_link: Option<LinkParams>,
    /// Active membership of the previous recorded step — fires
    /// [`MembershipChange`] (and charges the scenario's declared catch-up
    /// cost on growth) when a churn event lands between recorded steps.
    last_active: Option<usize>,
    /// Worst per-worker straggler slowdown observed by the latest step
    /// (1.0 on straggler-free environments) — surfaced to controllers via
    /// [`ControlCtx::straggler_factor`].
    cur_straggler_factor: f64,
}

impl Trainer {
    /// Assemble a trainer from pre-validated parts (the builder's job —
    /// `SessionBuilder::build` is the only construction path that
    /// validates; this constructor trusts its inputs).
    pub(crate) fn with_parts(
        cfg: TrainConfig,
        mut source: Box<dyn GradSource>,
        strategy: Box<dyn CommStrategy>,
        observers: Vec<Box<dyn TrainObserver>>,
        pool: ThreadPool,
        controller: Box<dyn Controller>,
    ) -> Self {
        let params = source.init_params();
        // params.len() == dim is enforced by SessionBuilder::build (a
        // typed SourceDimMismatch error) right after this runs.
        let dim = source.dim();
        let n = cfg.n_workers;
        // The configured CR, unless the controller wants a different
        // starting rung (the adaptive controllers start at their ladder's
        // c_high, as the paper does).
        let cfg_cr = match &cfg.cr {
            CrControl::Static(c) => *c,
            CrControl::Adaptive(a) => a.c_high,
        };
        let cur_cr = controller.initial_cr().unwrap_or(cfg_cr);
        let probe = Probe::new(cfg.net.clone(), cfg.probe_noise, cfg.seed ^ 0xBEEF);
        Trainer {
            momentum_buf: vec![0.0; dim],
            ef: (0..n).map(|_| EfState::new(dim)).collect(),
            strategy,
            pool,
            probe,
            clock: VirtualClock::new(),
            metrics: MetricsLog::default(),
            observers,
            compute_rng: Rng::new(cfg.seed ^ 0xC0317),
            step: 0,
            cur_cr,
            controller,
            lr_cur: cfg.lr,
            explore_overhead_s: 0.0,
            last_collective: None,
            last_net_link: None,
            last_active: None,
            cur_straggler_factor: 1.0,
            params,
            cfg,
            source,
        }
    }

    /// Test-only convenience: registry strategy + default controller
    /// stack, no observers. All real construction goes through the
    /// validating
    /// [`Session::builder`](crate::coordinator::session::Session::builder).
    #[cfg(test)]
    pub(crate) fn new(cfg: TrainConfig, source: Box<dyn GradSource>) -> Self {
        let pool = ThreadPool::auto(cfg.threads);
        let strategy = crate::coordinator::strategy::instantiate(
            cfg.strategy,
            cfg.n_workers,
            cfg.seed,
            pool.clone(),
        );
        let controller = crate::coordinator::controller::default_stack(&cfg);
        Trainer::with_parts(cfg, source, strategy, Vec::new(), pool, controller)
    }

    // -- read accessors (the demoted public fields) -------------------------

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> &MetricsLog {
        &self.metrics
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn cur_cr(&self) -> f64 {
        self.cur_cr
    }

    /// Accumulated simulated cluster seconds.
    pub fn virtual_time_s(&self) -> f64 {
        self.clock.now()
    }

    pub fn explore_overhead_s(&self) -> f64 {
        self.explore_overhead_s
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    pub fn epoch(&self) -> f64 {
        self.step as f64 / self.cfg.steps_per_epoch as f64
    }

    pub fn source_name(&self) -> String {
        self.source.name()
    }

    /// Effective message bytes (selector + cost predictions): the flat
    /// gradient size scaled by `msg_scale`.
    pub fn model_bytes(&self) -> f64 {
        4.0 * self.source.dim() as f64 * self.cfg.msg_scale
    }

    /// Scale the topology's links so β-terms charge `msg_scale`-times the
    /// actual bytes (equivalent to a msg_scale-times bigger message; α
    /// unchanged) — see [`Topology::scale_beta`].
    fn scaled_topo(&self, t: Topology) -> Topology {
        t.scale_beta(self.cfg.msg_scale)
    }

    /// Run the configured number of steps (with eval + control hooks).
    pub fn run(&mut self) {
        while self.step < self.cfg.steps {
            self.run_one_scheduled_step();
        }
        // Final eval — unless the last step was already a periodic one
        // (steps divisible by eval_every), which would evaluate the same
        // parameters twice and double every on_eval event.
        let last_step_evaluated = self.cfg.eval_every > 0
            && self.cfg.steps > 0
            && self.cfg.steps % self.cfg.eval_every == 0;
        if !last_step_evaluated {
            self.eval_and_record();
        }
    }

    /// One public step incl. the control phase + periodic eval: probe →
    /// recorded step → controller decisions → eval. Mechanism-free — every
    /// adaptation behavior lives behind the [`Controller`] object.
    pub fn run_one_scheduled_step(&mut self) {
        let epoch = self.epoch();
        let (obs, net_changed) = self.probe.measure_and_detect(epoch);
        let m = self.step_once(true, obs.link());
        self.control_phase(&m, net_changed, obs.link());
        if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0 {
            self.eval_and_record();
        }
    }

    /// Consult the controller about the recorded step `m` and apply its
    /// decisions. The controller is swapped out for the duration so
    /// exploration can re-enter [`Trainer::step_once`] without aliasing —
    /// the ONE place in the engine that dance exists.
    fn control_phase(&mut self, m: &StepMetrics, net_changed: bool, probed: LinkParams) {
        let mut controller: Box<dyn Controller> =
            std::mem::replace(&mut self.controller, Box::new(StaticController));
        let decisions = controller.observe(&ControlCtx {
            metrics: m,
            net_changed,
            probed,
            cur_cr: self.cur_cr,
            model_bytes: self.model_bytes(),
            n_workers: self.cfg.n_workers,
            compressed: self.strategy.is_compressed(),
            straggler_factor: self.cur_straggler_factor,
            active_workers: self.last_active.unwrap_or(self.cfg.n_workers),
        });
        self.apply_decisions(decisions, controller.as_mut(), probed, 0);
        self.controller = controller;
    }

    /// Apply control decisions in order, firing the corresponding observer
    /// events (stamped with the committed step counter — a decision born
    /// around a checkpointed exploration is reported on the real
    /// timeline). `RequestExploration` runs the [`ExplorationHarness`] and
    /// recurses into the controller's follow-up decisions (one level; a
    /// deeper exploration-from-exploration is dropped as a runaway guard).
    fn apply_decisions(
        &mut self,
        decisions: Vec<ControlDecision>,
        controller: &mut dyn Controller,
        probed: LinkParams,
        depth: u32,
    ) {
        for d in decisions {
            match d.action {
                ControlAction::SetCr(cr) => {
                    if cr != self.cur_cr {
                        let ev = CrChange {
                            step: self.step,
                            from: self.cur_cr,
                            to: cr,
                            by: d.by,
                            reason: d.reason,
                        };
                        self.cur_cr = cr;
                        for o in self.observers.iter_mut() {
                            o.on_cr_change(&ev);
                        }
                    }
                }
                ControlAction::SwitchSelectionPolicy(p) => {
                    if let Some(prev) = self.strategy.set_selection_policy(p) {
                        let ev = StrategySwitch {
                            step: self.step,
                            dimension: SwitchDimension::SelectionPolicy,
                            from: prev.name(),
                            to: p.name(),
                            by: d.by,
                            reason: d.reason,
                        };
                        for o in self.observers.iter_mut() {
                            o.on_strategy_switch(&ev);
                        }
                    }
                }
                ControlAction::SwitchCollective(k) => {
                    // Applied silently when the strategy supports pinning;
                    // the observable collective change surfaces through
                    // the per-step switch detection in step_once.
                    let _ = self.strategy.set_collective(k);
                }
                ControlAction::RequestExploration(req) => {
                    if depth >= 1 {
                        // Runaway guard: a follow-up may not request
                        // another exploration (dropped, not recursed).
                        continue;
                    }
                    let profiles =
                        ExplorationHarness::new(self).probe_candidates(&req, probed);
                    let outcome = crate::coordinator::controller::ExplorationOutcome {
                        by: d.by,
                        reason: d.reason,
                        probed,
                        profiles,
                    };
                    let more = controller.on_exploration(&outcome);
                    self.apply_decisions(more, controller, probed, depth + 1);
                }
            }
        }
    }

    fn eval_and_record(&mut self) {
        let (loss, acc) = self.source.eval(&self.params);
        let epoch = self.epoch();
        self.metrics.record_eval(epoch, loss, acc);
        let ev = EvalRecord { epoch, loss, accuracy: acc };
        for o in self.observers.iter_mut() {
            o.on_eval(&ev);
        }
    }

    /// Execute exactly one training step at the current CR/strategy.
    /// `record` controls whether it lands in the main metrics log, the
    /// observer stream and the strategy's `observe` feedback (the
    /// [`ExplorationHarness`]'s checkpointed steps do not). Returns the
    /// step's metrics either way.
    pub(crate) fn step_once(
        &mut self,
        record: bool,
        probed: LinkParams,
    ) -> StepMetrics {
        let n = self.cfg.n_workers;
        let epoch = self.epoch();
        // True data-movement topology (β scaled by msg_scale) and the
        // selector's view of it: the probe observes the inter link, the
        // intra link is known in-machine hardware.
        let base_topo = self.cfg.net.topology_at(epoch);
        let true_topo = self.scaled_topo(base_topo);
        let probed_topo = Topology { inter: probed, ..base_topo };
        // Per-worker straggler slowdowns (pure fn of (worker, step) — the
        // §7 thread-invariance contract): the synchronous step waits for
        // the slowest straggler-scaled worker. 1.0 everywhere on
        // straggler-free environments, where `t * 1.0 == t` keeps the
        // trajectory bitwise identical to the homogeneous path.
        let factors: Vec<f64> =
            (0..n).map(|w| self.cfg.net.straggler_factor(w, self.step)).collect();
        self.cur_straggler_factor = factors.iter().fold(1.0, |a: f64, &f| a.max(f));
        let t_compute =
            self.cfg.compute.step_time_stragglers(n, &mut self.compute_rng, |w| factors[w]);
        // Elastic membership (churn environments): joins charge the
        // scenario's declared catch-up cost to the step that observes
        // them. Committed steps only — exploration timelines are rolled
        // back and must not consume membership edges.
        let active = self.cfg.net.active_workers_at(epoch, n);
        let t_catchup = match (record, self.last_active) {
            (true, Some(prev)) if active > prev => {
                self.cfg.net.catchup_cost_at(epoch, self.model_bytes())
            }
            _ => 0.0,
        };

        // Per-worker gradients (real computation — PJRT or host backprop),
        // concurrent across TrainConfig::threads. Each worker's shard is an
        // independent pure function of (params, worker, step), so results
        // are bitwise identical for any thread count.
        let per_worker = {
            let src: &dyn GradSource = &*self.source;
            let params = &self.params;
            let step = self.step;
            self.pool.map(n, |w| src.grad(params, w, n, step))
        };
        let mut losses = Vec::with_capacity(n);
        let mut grads = Vec::with_capacity(n);
        for (loss, g) in per_worker {
            losses.push(loss);
            grads.push(g);
        }
        let loss = losses.iter().sum::<f64>() / n as f64;

        // Plan + exchange: the strategy seam. Measured compression time is
        // rescaled by comp_scale (see TrainConfig::comp_scale).
        let plan = self.strategy.plan(&StepCtx {
            step: self.step,
            n_workers: n,
            model_bytes: self.model_bytes(),
            cr: self.cur_cr,
            probed_topo,
        });
        let outcome = self.strategy.exchange(&mut ExchangeCtx {
            plan,
            grads: &grads,
            ef: &mut self.ef,
            layout: self.source.layout(),
            true_topo,
            cr: self.cur_cr,
            step: self.step,
            pool: self.pool.clone(),
        });
        let t_comp = outcome.t_comp * self.cfg.comp_scale;

        // Momentum-SGD update (identical params on every worker).
        self.apply_lr_decay();
        let lr = self.lr_cur;
        let mu = self.cfg.momentum;
        let wd = self.cfg.weight_decay;
        for i in 0..self.params.len() {
            let g = outcome.update[i] + wd * self.params[i];
            self.momentum_buf[i] = mu * self.momentum_buf[i] + g;
            self.params[i] -= lr * self.momentum_buf[i];
        }

        let m = StepMetrics {
            step: self.step,
            epoch,
            loss,
            t_compute,
            t_comp,
            // `+ 0.0` is not bitwise-neutral for a `-0.0` sync time, so
            // the catch-up charge is folded in only when one was declared.
            t_sync: if t_catchup > 0.0 {
                outcome.comm.seconds + t_catchup
            } else {
                outcome.comm.seconds
            },
            collective: outcome.collective,
            cr: if self.strategy.is_compressed() { self.cur_cr } else { 1.0 },
            selected_rank: outcome.selected_rank,
            gain: outcome.gain,
            alpha_ms: probed.alpha_ms(),
            bw_gbps: probed.bw_gbps(),
        };
        self.clock.advance(m.t_step());
        if record {
            // Ground-truth network event: the environment's (unscaled)
            // inter link changed since the previous recorded step. Fires
            // before on_step so sinks interleave it ahead of the step row.
            let cur_link = base_topo.inter;
            if let Some(prev) = self.last_net_link {
                if prev != cur_link {
                    let ev = NetChange { step: m.step, epoch, from: prev, to: cur_link };
                    for o in self.observers.iter_mut() {
                        o.on_net_change(&ev);
                    }
                }
            }
            self.last_net_link = Some(cur_link);
            if let Some(prev) = self.last_active {
                if prev != active {
                    let ev = MembershipChange { step: m.step, epoch, from: prev, to: active };
                    for o in self.observers.iter_mut() {
                        o.on_membership_change(&ev);
                    }
                }
            }
            self.last_active = Some(active);
            if let Some(prev) = self.last_collective {
                if prev != m.collective {
                    let ev = StrategySwitch {
                        step: m.step,
                        dimension: SwitchDimension::Collective,
                        from: prev.name(),
                        to: m.collective.name(),
                        by: self.strategy.name(),
                        reason: "plan",
                    };
                    for o in self.observers.iter_mut() {
                        o.on_strategy_switch(&ev);
                    }
                }
            }
            self.last_collective = Some(m.collective);
            // The strategy's post-step feedback runs for RECORDED steps
            // only: exploration steps are rolled back, so strategy state
            // never learns from a timeline that did not happen
            // (DESIGN.md §10); any reported mode change is delivered
            // immediately.
            if let Some(ev) = self.strategy.observe(&m) {
                for o in self.observers.iter_mut() {
                    o.on_strategy_switch(&ev);
                }
            }
            self.metrics.record(m.clone());
            for o in self.observers.iter_mut() {
                o.on_step(&m);
            }
        }
        self.step += 1;
        m
    }

    fn apply_lr_decay(&mut self) {
        let mut lr = self.cfg.lr;
        for &(at, factor) in &self.cfg.lr_decay {
            if self.step >= at {
                lr *= factor;
            }
        }
        self.lr_cur = lr;
    }

    // -- checkpoint/restore (used by the ExplorationHarness) ---------------

    pub fn snapshot(&self) -> Checkpoint {
        Checkpoint {
            params: self.params.clone(),
            momentum: self.momentum_buf.clone(),
            residuals: self.ef.iter().map(|e| e.residual.clone()).collect(),
            step: self.step,
            clock: self.clock.now(),
        }
    }

    pub fn restore(&mut self, ck: &Checkpoint) {
        self.params = ck.params.clone();
        self.momentum_buf = ck.momentum.clone();
        for (e, r) in self.ef.iter_mut().zip(&ck.residuals) {
            e.residual = r.clone();
        }
        self.step = ck.step;
        self.clock = VirtualClock::new();
        self.clock.advance(ck.clock);
    }

    pub fn eval_now(&mut self) -> (f64, f64) {
        self.source.eval(&self.params)
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::LinkParams;
    use crate::runtime::host_model::HostMlp;

    fn quick_cfg(strategy: Strategy, cr: f64, steps: u64) -> TrainConfig {
        TrainConfig {
            n_workers: 4,
            steps,
            steps_per_epoch: 20,
            lr: 0.3,
            momentum: 0.6,
            weight_decay: 0.0,
            strategy,
            cr: CrControl::Static(cr),
            compute: ComputeModel::fixed(0.01),
            eval_every: 0,
            seed: 42,
            ..Default::default()
        }
    }

    fn train(strategy: Strategy, cr: f64, steps: u64) -> Trainer {
        let cfg = quick_cfg(strategy, cr, steps);
        let src = Box::new(HostMlp::default_preset(7));
        let mut t = Trainer::new(cfg, src);
        t.run();
        t
    }

    #[test]
    fn dense_sgd_learns() {
        let t = train(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 120);
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.8, "dense accuracy {acc}");
        let s = t.metrics.summary();
        assert!(s.final_loss < 0.5, "loss {}", s.final_loss);
        assert_eq!(s.mean_comp_s, 0.0);
    }

    #[test]
    fn ag_topk_learns_with_error_feedback() {
        let t = train(
            Strategy::AgCompress { kind: CompressorKind::TopK },
            0.05,
            250,
        );
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.75, "AG topk accuracy {acc}");
        assert!(t.metrics.summary().mean_gain < 1.0);
    }

    #[test]
    fn artopk_star_learns() {
        let t = train(
            Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            },
            0.05,
            250,
        );
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.75, "STAR accuracy {acc}");
        // Round-robin rank density (Fig 4 shape).
        let ranks = t.metrics.selected_ranks();
        assert_eq!(ranks.len(), 250);
        for r in 0..4 {
            let count = ranks.iter().filter(|&&x| x as usize == r).count();
            assert!((count as i64 - 62).abs() <= 2, "rank {r} count {count}");
        }
    }

    #[test]
    fn compressed_steps_are_faster_than_dense_on_slow_net() {
        let slow = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 0.05));
        let mk = |s: Strategy, cr| {
            let mut cfg = quick_cfg(s, cr, 20);
            cfg.net = Box::new(slow.clone());
            let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(1)));
            t.run();
            t.metrics.summary().mean_step_s
        };
        let dense = mk(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0);
        let comp = mk(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            0.01,
        );
        assert!(comp < dense, "compressed {comp} vs dense {dense}");
    }

    #[test]
    fn flexible_switches_collectives_when_link_crosses_eqn5_boundary() {
        // 2M params at CR 0.1, N=4: Eqn 5b threshold α/β ≈ Mc·0.417 ≈ 3.3e5.
        // Phase A (0.1 ms, 1 Gbps): α/β = 1.25e4  -> ART-Ring.
        // Phase B (100 ms, 25 Gbps): α/β = 3.1e8  -> AG.
        use crate::netsim::schedule::Phase;
        let sched = NetSchedule::piecewise(
            "boundary",
            vec![
                Phase { from_epoch: 0.0, link: LinkParams::from_ms_gbps(0.1, 1.0) },
                Phase { from_epoch: 2.0, link: LinkParams::from_ms_gbps(100.0, 25.0) },
            ],
        );
        let mut cfg = quick_cfg(Strategy::Flexible { policy: SelectionPolicy::Star }, 0.1, 80);
        cfg.net = Box::new(sched);
        cfg.steps_per_epoch = 20;
        let src = Box::new(crate::runtime::host_model::SyntheticGrad::new(2_000_000, 3));
        let mut t = Trainer::new(cfg, src);
        t.run();
        let used: Vec<&str> = t.metrics.collectives_used().iter().map(|c| c.name()).collect();
        assert!(used[..30].iter().all(|&c| c == "ART-Ring"), "phase A: {:?}", &used[..5]);
        assert!(used[50..].iter().all(|&c| c == "AG"), "phase B: {:?}", &used[75..]);
    }

    #[test]
    fn halving_doubling_dense_learns_like_ring() {
        let ring = train(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 120);
        let hd = train(Strategy::DenseSgd { flavor: DenseFlavor::HalvingDoubling }, 1.0, 120);
        // Identical numerics (both are exact sums), cheaper sync.
        let a_ring = ring.metrics.final_accuracy().unwrap();
        let a_hd = hd.metrics.final_accuracy().unwrap();
        assert!(a_hd > 0.8, "HD accuracy {a_hd} (ring {a_ring})");
        assert!(
            hd.metrics.summary().mean_sync_s < ring.metrics.summary().mean_sync_s,
            "HD must beat ring on the default latency-bearing link"
        );
        assert!(hd
            .metrics
            .collectives_used()
            .iter()
            .all(|c| *c == CollectiveKind::HalvingDoublingAllreduce));
    }

    #[test]
    fn topo_auto_picks_hierarchical_on_asymmetric_cluster() {
        use crate::netsim::cost_model::LinkParams;
        // 2 nodes x 2 ranks: NVLink-class intra, congested 10ms/1Gbps inter.
        let sched = NetSchedule::static_link(LinkParams::from_ms_gbps(10.0, 1.0))
            .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 2);
        let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::TopoAuto }, 1.0, 30);
        cfg.net = Box::new(sched);
        let src = Box::new(crate::runtime::host_model::SyntheticGrad::new(2_000_000, 5));
        let mut t = Trainer::new(cfg, src);
        t.run();
        let used = t.metrics.collectives_used();
        assert!(
            used.iter().all(|c| *c == CollectiveKind::HierarchicalAllreduce),
            "expected Hier-AR everywhere, got {:?}",
            used.first()
        );
    }

    #[test]
    fn hierarchical_flavor_falls_back_to_ring_on_flat_cluster() {
        let t = train(Strategy::DenseSgd { flavor: DenseFlavor::Hierarchical }, 1.0, 20);
        // Flat schedule (workers_per_node = 1): the op degenerates to ring
        // but is still reported as the hierarchical flavour.
        assert!(t
            .metrics
            .collectives_used()
            .iter()
            .all(|c| *c == CollectiveKind::HierarchicalAllreduce));
        assert!(t.metrics.summary().mean_sync_s > 0.0);
    }

    #[test]
    fn lr_decay_applies() {
        let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 10);
        cfg.lr = 1.0;
        cfg.lr_decay = vec![(5, 0.1)];
        let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(2)));
        t.run();
        assert!((t.lr_cur - 0.1).abs() < 1e-6);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let cfg = quick_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            0.05,
            0,
        );
        let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(3)));
        let link = LinkParams::from_ms_gbps(4.0, 20.0);
        for _ in 0..5 {
            t.step_once(false, link);
        }
        let ck = t.snapshot();
        let params_at_ck = t.params.clone();
        for _ in 0..5 {
            t.step_once(false, link);
        }
        assert_ne!(t.params, params_at_ck);
        t.restore(&ck);
        assert_eq!(t.params, params_at_ck);
        assert_eq!(t.step_count(), 5);
    }

    #[test]
    fn clock_accumulates_step_times() {
        let t = train(Strategy::DenseSgd { flavor: DenseFlavor::Tree }, 1.0, 10);
        let total: f64 = t.metrics.steps.iter().map(|m| m.t_step()).sum();
        assert!((t.clock.now() - total).abs() < 1e-9);
    }

    /// Wraps a real model but poisons one worker's gradient with NaN at a
    /// chosen step — the exploding-loss regression fixture.
    struct NanAt {
        inner: HostMlp,
        at_step: u64,
        at_worker: usize,
    }

    impl crate::coordinator::worker::GradSource for NanAt {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn layout(&self) -> &crate::tensor::Layout {
            self.inner.layout()
        }
        fn init_params(&mut self) -> Vec<f32> {
            self.inner.init_params()
        }
        fn grad(
            &self,
            params: &[f32],
            worker: usize,
            n_workers: usize,
            step: u64,
        ) -> (f64, Vec<f32>) {
            let (loss, mut g) = self.inner.grad(params, worker, n_workers, step);
            if step == self.at_step && worker == self.at_worker {
                g.iter_mut().for_each(|v| *v = f32::NAN);
                return (f64::NAN, g);
            }
            (loss, g)
        }
        fn eval(&mut self, params: &[f32]) -> (f64, f64) {
            self.inner.eval(params)
        }
        fn name(&self) -> String {
            format!("nan-at-{}@{}", self.at_worker, self.at_step)
        }
    }

    /// A NaN gradient mid-run (exploding loss) must not panic the trainer:
    /// the poisoned step surfaces as a NaN loss in the metrics (the
    /// diagnosable state), VAR selection avoids the poisoned worker, and
    /// subsequent steps still execute. Regression for the
    /// `partial_cmp(..).unwrap()` panic at the old artopk.rs:158.
    #[test]
    fn trains_through_a_nan_step_without_panicking() {
        let cfg = quick_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Var, flavor: ArFlavor::Ring },
            0.05,
            0,
        );
        let src = NanAt { inner: HostMlp::default_preset(7), at_step: 2, at_worker: 1 };
        let mut t = Trainer::new(cfg, Box::new(src));
        let link = LinkParams::from_ms_gbps(4.0, 20.0);
        let mut steps = Vec::new();
        for _ in 0..5 {
            steps.push(t.step_once(false, link));
        }
        assert!(steps[0].loss.is_finite() && steps[1].loss.is_finite());
        assert!(steps[2].loss.is_nan(), "the poisoned step must be visible");
        assert_ne!(
            steps[2].selected_rank,
            Some(1),
            "VAR must not broadcast the NaN worker's indices"
        );
        // The run keeps stepping (no panic) even though params now carry
        // NaNs at the exchanged coordinates.
        assert_eq!(t.step_count(), 5);
    }

    /// The compute-RNG decoupling bugfix: jitter draws live on their own
    /// seeded stream, so toggling compute jitter changes t_compute and
    /// NOTHING else — loss/parameter trajectories stay bitwise identical.
    #[test]
    fn compute_jitter_never_perturbs_the_trajectory() {
        let mk = |jitter: f64| {
            let mut cfg = quick_cfg(
                Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
                0.05,
                30,
            );
            cfg.compute = if jitter > 0.0 {
                ComputeModel::with_jitter(0.01, jitter)
            } else {
                ComputeModel::fixed(0.01)
            };
            let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(7)));
            t.run();
            t
        };
        let off = mk(0.0);
        let on = mk(0.3);
        assert_eq!(off.params, on.params, "jitter must not leak into numerics");
        for (a, b) in off.metrics.steps.iter().zip(&on.metrics.steps) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
            assert_eq!(a.t_sync.to_bits(), b.t_sync.to_bits(), "step {}", a.step);
        }
        assert!(
            off.metrics.steps.iter().zip(&on.metrics.steps).any(|(a, b)| a.t_compute
                != b.t_compute),
            "jitter must actually move t_compute"
        );
    }

    /// StragglerTail stretches the synchronous-step critical path
    /// (t_compute) without touching numerics or sync time.
    #[test]
    fn straggler_factors_stretch_t_compute_only() {
        use crate::netsim::modifiers::StragglerTail;
        let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
        let mk = |straggle: bool| {
            let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 30);
            cfg.net = if straggle {
                Box::new(StragglerTail::wrap(base.clone(), 0.5, 8.0, 7).unwrap())
            } else {
                Box::new(base.clone())
            };
            let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(7)));
            t.run();
            t
        };
        let plain = mk(false);
        let tail = mk(true);
        assert_eq!(plain.params, tail.params, "stragglers are a time model, not a numeric one");
        let stretched = tail
            .metrics
            .steps
            .iter()
            .filter(|m| m.t_compute > 0.01 + 1e-15)
            .count();
        assert!(stretched > 10, "p=0.5 over 4 workers stretches most steps: {stretched}");
        for (a, b) in plain.metrics.steps.iter().zip(&tail.metrics.steps) {
            assert_eq!(a.t_sync.to_bits(), b.t_sync.to_bits());
            assert!(b.t_compute >= a.t_compute);
        }
    }

    /// Churn wiring end-to-end: membership edges fire the observer event,
    /// and the JOIN edge charges the declared catch-up cost into t_sync.
    #[test]
    fn churn_fires_membership_events_and_charges_catchup_on_joins() {
        use crate::coordinator::observer::MembershipChange;
        use crate::netsim::modifiers::Churn;
        use std::sync::{Arc, Mutex};

        struct Capture(Arc<Mutex<Vec<MembershipChange>>>);
        impl TrainObserver for Capture {
            fn on_membership_change(&mut self, m: &MembershipChange) {
                self.0.lock().unwrap().push(*m);
            }
        }

        // 20 steps/epoch: a quarter leaves at epoch 1, rejoins at epoch 2.
        let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
        let net = Churn::wrap(base, vec![(1.0, -0.25), (2.0, 0.25)], 1.0).unwrap();
        let cfg = {
            let mut c = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 50);
            c.net = Box::new(net);
            c
        };
        let events = Arc::new(Mutex::new(Vec::new()));
        let pool = ThreadPool::auto(cfg.threads);
        let strategy = crate::coordinator::strategy::instantiate(
            cfg.strategy,
            cfg.n_workers,
            cfg.seed,
            pool.clone(),
        );
        let controller = crate::coordinator::controller::default_stack(&cfg);
        let mut t = Trainer::with_parts(
            cfg,
            Box::new(HostMlp::default_preset(7)),
            strategy,
            vec![Box::new(Capture(events.clone()))],
            pool,
            controller,
        );
        t.run();
        let evs = events.lock().unwrap().clone();
        assert_eq!(evs.len(), 2, "{evs:?}");
        assert_eq!((evs[0].from, evs[0].to, evs[0].step), (4, 3, 20));
        assert_eq!((evs[1].from, evs[1].to, evs[1].step), (3, 4, 40));
        // Leaves are free; the join step pays α + M·β on top of its ring.
        let sync = |s: usize| t.metrics.steps[s].t_sync;
        assert_eq!(sync(20).to_bits(), sync(19).to_bits(), "a leave charges nothing");
        let link = LinkParams::from_ms_gbps(4.0, 20.0);
        let catchup = link.alpha + t.model_bytes() * link.beta;
        assert!(
            (sync(40) - (sync(39) + catchup)).abs() < 1e-12,
            "join step {} vs {} + {catchup}",
            sync(40),
            sync(39)
        );
    }

    /// Every pool width runs the loop to completion — the smoke half of
    /// the §7 thread-invariance contract (the bitwise half lives in
    /// rust/tests/determinism.rs).
    #[test]
    fn pool_widths_run_to_completion() {
        for threads in [1usize, 2, 7] {
            let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 5);
            cfg.threads = threads;
            let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(7)));
            t.run();
            assert_eq!(t.metrics.steps.len(), 5, "threads={threads}");
        }
    }
}
