//! First-party scoped thread pool (offline build: no `rayon`) — the
//! execution engine behind the trainer's per-worker parallelism
//! (DESIGN.md §7).
//!
//! Built on [`std::thread::scope`], so borrowed data (parameters,
//! gradients, error-feedback state) crosses into worker threads without
//! `Arc`/cloning, and every region joins before it returns — no detached
//! threads, no channels, zero dependencies.
//!
//! Determinism contract: results are returned **by item index**, work is
//! split into contiguous index chunks, and items never share mutable
//! state (no atomics on floats, no reduction across threads), so the
//! output of [`ThreadPool::map`]/[`ThreadPool::map_mut`] is bitwise
//! identical for every thread count — only the wall-clock time changes.
//! The trainer's parallel-vs-sequential property tests
//! (`rust/tests/determinism.rs`) pin this end to end.

/// A scoped fork-join pool: `threads` is the maximum worker-thread count
/// per parallel region (1 = run inline on the caller's thread).
///
/// The pool is a cost-free handle (no spawned threads are kept alive
/// between regions), so it is `Copy` and can be embedded in operators
/// like [`crate::artopk::ArTopk`]. The flip side: every region pays a
/// spawn/join, so for workloads whose per-item cost is smaller than a
/// thread spawn (tens of µs), prefer `threads = 1` — results are
/// identical by contract (DESIGN.md §7 records the trade-off).
///
/// ```
/// use flexcomm::util::pool::ThreadPool;
/// let pool = ThreadPool::new(4);
/// let squares = pool.map(8, |i| i * i);
/// assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// Pool with an explicit thread cap (clamped to >= 1).
    pub fn new(threads: usize) -> Self {
        ThreadPool { threads: threads.max(1) }
    }

    /// `threads == 0` means "use the available hardware parallelism"
    /// (the `TrainConfig::threads` / `--threads` convention).
    pub fn auto(threads: usize) -> Self {
        if threads == 0 {
            ThreadPool::new(Self::available())
        } else {
            ThreadPool::new(threads)
        }
    }

    /// Single-threaded pool: every region runs inline.
    pub fn serial() -> Self {
        ThreadPool::new(1)
    }

    /// Hardware parallelism of this host (>= 1).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Compute `f(0), f(1), .., f(n-1)` across up to `threads` scoped
    /// worker threads; returns the results in index order.
    ///
    /// `f` runs at most once per index. Panics in `f` propagate to the
    /// caller after the scope joins.
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let chunk = (n + workers - 1) / workers;
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let f = &f;
        std::thread::scope(|s| {
            for (ci, slots) in out.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        *slot = Some(f(ci * chunk + j));
                    }
                });
            }
        });
        out.into_iter().map(|r| r.expect("every slot filled")).collect()
    }

    /// Like [`ThreadPool::map`] over disjoint mutable items: each worker
    /// thread owns a contiguous sub-slice of `items`, so per-item state
    /// (error-feedback residuals, per-worker compressors) mutates without
    /// locks. Results come back in item order.
    ///
    /// ```
    /// use flexcomm::util::pool::ThreadPool;
    /// let pool = ThreadPool::new(2);
    /// let mut xs = vec![1, 2, 3];
    /// let idx = pool.map_mut(&mut xs, |i, x| {
    ///     *x *= 2;
    ///     i
    /// });
    /// assert_eq!(xs, vec![2, 4, 6]);
    /// assert_eq!(idx, vec![0, 1, 2]);
    /// ```
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n);
        if workers <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let chunk = (n + workers - 1) / workers;
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let f = &f;
        std::thread::scope(|s| {
            for ((ci, slots), part) in
                out.chunks_mut(chunk).enumerate().zip(items.chunks_mut(chunk))
            {
                s.spawn(move || {
                    for (j, (slot, item)) in slots.iter_mut().zip(part.iter_mut()).enumerate() {
                        *slot = Some(f(ci * chunk + j, item));
                    }
                });
            }
        });
        out.into_iter().map(|r| r.expect("every slot filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn map_returns_results_in_index_order() {
        for threads in [1, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let got = pool.map(10, |i| i * 3);
            assert_eq!(got, (0..10).map(|i| i * 3).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn map_handles_edge_sizes() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
        // More threads than items.
        assert_eq!(pool.map(2, |i| i), vec![0, 1]);
    }

    #[test]
    fn map_mut_mutates_every_item_once() {
        for threads in [1, 2, 5] {
            let pool = ThreadPool::new(threads);
            let mut xs = vec![0u64; 13];
            let idx = pool.map_mut(&mut xs, |i, x| {
                *x += 1 + i as u64;
                i
            });
            assert_eq!(idx, (0..13).collect::<Vec<_>>(), "threads={threads}");
            for (i, x) in xs.iter().enumerate() {
                assert_eq!(*x, 1 + i as u64, "threads={threads} item {i}");
            }
        }
    }

    #[test]
    fn borrows_shared_state_without_cloning() {
        let data: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let pool = ThreadPool::new(4);
        let sums = pool.map(4, |w| {
            data[w * 250..(w + 1) * 250].iter().map(|&v| v as f64).sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert!((total - 999.0 * 1000.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn results_bitwise_identical_across_thread_counts() {
        check("pool map deterministic across thread counts", 30, |g| {
            let n = g.usize_in(1, 17);
            let len = g.usize_in(1, 64);
            let base: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(len, 1.0)).collect();
            let work = |pool: &ThreadPool| -> Vec<f64> {
                pool.map(n, |w| base[w].iter().map(|&v| (v as f64).powi(2)).sum())
            };
            let serial = work(&ThreadPool::serial());
            for t in [2usize, 3, 8] {
                let par = work(&ThreadPool::new(t));
                ensure(
                    serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                    format!("threads={t} diverged"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn auto_and_available() {
        assert!(ThreadPool::available() >= 1);
        assert_eq!(ThreadPool::auto(0).threads(), ThreadPool::available());
        assert_eq!(ThreadPool::auto(3).threads(), 3);
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert_eq!(ThreadPool::serial().threads(), 1);
    }

    #[test]
    #[should_panic] // scope re-raises after joining (payload may be rewrapped)
    fn worker_panics_propagate() {
        let pool = ThreadPool::new(2);
        pool.map(4, |i| {
            if i == 3 {
                panic!("boom");
            }
            i
        });
    }
}
