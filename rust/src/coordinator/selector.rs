//! Flexible collective selection (§3-D): given the probed link, model
//! size, cluster size and current CR, pick the cheapest of
//! {AG, ART-Ring, ART-Tree} — the paper's Eqn 5 decision procedure.
//!
//! Two equivalent deciders are provided: the threshold form (Eqn 5a/5b/5c,
//! exactly as printed) and the argmin of the closed-form costs. They agree
//! everywhere (property-tested in `cost_model`); the trainer uses
//! [`choose`] and the tests cross-check [`choose_eqn5`].

use crate::artopk::ArFlavor;
use crate::collectives::CollectiveKind;
use crate::netsim::cost_model::{
    self, prefer_ring_over_ag, prefer_ring_over_tree, prefer_tree_over_ag,
    CompressedCollective, LinkParams, Topology,
};

/// Decision record (also logged so Fig 8 can be regenerated).
#[derive(Debug, Clone, Copy)]
pub struct Choice {
    pub kind: CollectiveKind,
    /// Predicted communication seconds at the probed link.
    pub predicted_s: f64,
}

/// Cheapest compressed collective by direct cost evaluation.
pub fn choose(link: LinkParams, m_bytes: f64, n: usize, cr: f64) -> Choice {
    let best = cost_model::optimal_collective(link, m_bytes, n, cr);
    let kind = match best {
        CompressedCollective::AllgatherTopk => CollectiveKind::AllgatherTopk,
        CompressedCollective::ArTopkRing => CollectiveKind::ArTopkRing,
        CompressedCollective::ArTopkTree => CollectiveKind::ArTopkTree,
    };
    Choice { kind, predicted_s: best.cost(link, m_bytes, n, cr) }
}

/// The paper's literal decision procedure: Eqn 5a picks the AR flavour,
/// then Eqn 5b/5c compares that flavour against AG.
pub fn choose_eqn5(link: LinkParams, m_bytes: f64, n: usize, cr: f64) -> CollectiveKind {
    if prefer_ring_over_tree(link, m_bytes, n, cr) {
        if prefer_ring_over_ag(link, m_bytes, n, cr) {
            CollectiveKind::ArTopkRing
        } else {
            CollectiveKind::AllgatherTopk
        }
    } else if prefer_tree_over_ag(link, m_bytes, n, cr) {
        CollectiveKind::ArTopkTree
    } else {
        CollectiveKind::AllgatherTopk
    }
}

/// Dense path: ring vs tree allreduce for DenseSGD (the paper's original
/// two-way choice; see [`choose_dense_topo`] for the full candidate set).
pub fn choose_dense(link: LinkParams, m_bytes: f64, n: usize) -> CollectiveKind {
    if cost_model::ring_allreduce(link, m_bytes, n)
        <= cost_model::tree_allreduce(link, m_bytes, n)
    {
        CollectiveKind::RingAllreduce
    } else {
        CollectiveKind::TreeAllreduce
    }
}

/// Topology-aware dense path: argmin over the [`Collective` registry's
/// auto-candidates](crate::collectives::dense_registry) priced on `topo` —
/// {Ring-AR, Tree-AR, HD-AR} on the bottleneck (inter) link, plus Hier-AR
/// when the topology is two-level (PS is flagged out as the scale-out
/// strawman). In the pure α-β model HD-AR dominates both ring and tree for
/// power-of-two N, and Hier-AR overtakes it once the intra/inter asymmetry
/// outweighs the extra full-vector intra rounds. A new dense collective
/// becomes selectable by registering itself — no selector change needed.
pub fn choose_dense_topo(topo: Topology, m_bytes: f64, n: usize) -> Choice {
    let mut best: Option<Choice> = None;
    for op in crate::collectives::dense_registry() {
        if !op.auto_candidate(topo, n) {
            continue;
        }
        let cost = op.predict(topo, m_bytes, n, 1.0);
        if best.map_or(true, |b| cost < b.predicted_s) {
            best = Some(Choice { kind: op.kind(), predicted_s: cost });
        }
    }
    best.expect("registry always has auto-candidates")
}

/// Map the chosen collective to the AR flavour AR-Topk should run with
/// (None = the AG path).
pub fn ar_flavor(kind: CollectiveKind) -> Option<ArFlavor> {
    match kind {
        CollectiveKind::ArTopkRing => Some(ArFlavor::Ring),
        CollectiveKind::ArTopkTree => Some(ArFlavor::Tree),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    fn l(ms: f64, gbps: f64) -> LinkParams {
        LinkParams::from_ms_gbps(ms, gbps)
    }

    #[test]
    fn eqn5_and_argmin_agree() {
        check("selector: eqn5 == argmin", 400, |g| {
            let n = *g.choose(&[2usize, 4, 8, 16]);
            let link = l(g.f64_in(0.1, 100.0), g.f64_in(0.3, 50.0));
            let m = g.f64_in(1e6, 4e9);
            let cr = g.f64_in(1e-4, 0.3);
            let a = choose(link, m, n, cr).kind;
            let b = choose_eqn5(link, m, n, cr);
            ensure(a == b, format!("argmin {a:?} vs eqn5 {b:?} (n={n}, m={m}, cr={cr})"))
        });
    }

    #[test]
    fn paper_regimes() {
        let resnet18 = 4.0 * 11.7e6;
        let vit = 4.0 * 86.6e6;
        // Table VI anchors.
        assert_eq!(choose(l(1.0, 10.0), resnet18, 8, 0.001).kind, CollectiveKind::AllgatherTopk);
        assert_eq!(choose(l(1.0, 10.0), resnet18, 8, 0.1).kind, CollectiveKind::ArTopkRing);
        assert_eq!(choose(l(1.0, 1.0), vit, 8, 0.01).kind, CollectiveKind::ArTopkRing);
        // Dense: high latency favours tree.
        assert_eq!(choose_dense(l(100.0, 10.0), 4e6, 8), CollectiveKind::TreeAllreduce);
        assert_eq!(choose_dense(l(0.1, 10.0), 4e8, 8), CollectiveKind::RingAllreduce);
    }

    #[test]
    fn predicted_cost_is_positive_and_minimal() {
        let c = choose(l(4.0, 20.0), 4e8, 8, 0.01);
        assert!(c.predicted_s > 0.0);
        for k in [
            CompressedCollective::AllgatherTopk,
            CompressedCollective::ArTopkRing,
            CompressedCollective::ArTopkTree,
        ] {
            assert!(c.predicted_s <= k.cost(l(4.0, 20.0), 4e8, 8, 0.01) + 1e-15);
        }
    }

    /// Acceptance anchor: on a fast-intra/slow-inter (asymmetric) topology
    /// the selector must pick Hier-AR over the flat ring — the slow link is
    /// paid nodes-wide instead of N-wide.
    #[test]
    fn picks_hierarchical_over_flat_ring_on_asymmetric_topology() {
        let topo = Topology::two_level(l(0.01, 100.0), l(10.0, 1.0), 4);
        let m = 4e8; // 1e8 params
        let c = choose_dense_topo(topo, m, 8);
        assert_eq!(c.kind, CollectiveKind::HierarchicalAllreduce);
        assert!(c.predicted_s < cost_model::ring_allreduce(topo.inter, m, 8));
    }

    /// Flat topology: Hier-AR is excluded and HD-AR (ring β at tree α)
    /// dominates the α-β model for power-of-two N.
    #[test]
    fn flat_topology_prefers_halving_doubling() {
        let topo = Topology::flat(l(10.0, 1.0));
        let c = choose_dense_topo(topo, 4e8, 8);
        assert_eq!(c.kind, CollectiveKind::HalvingDoublingAllreduce);
    }

    #[test]
    fn choose_dense_topo_is_argmin() {
        check("dense topo selector minimizes", 200, |g| {
            let w = *g.choose(&[1usize, 2, 4]);
            let n = w * *g.choose(&[1usize, 2, 4]);
            if n < 2 {
                return Ok(());
            }
            let topo = Topology::two_level(
                l(g.f64_in(0.001, 1.0), g.f64_in(10.0, 200.0)),
                l(g.f64_in(0.1, 100.0), g.f64_in(0.3, 50.0)),
                w,
            );
            let m = g.f64_in(1e6, 4e9);
            let best = choose_dense_topo(topo, m, n);
            let mut costs = vec![
                cost_model::ring_allreduce(topo.inter, m, n),
                cost_model::tree_allreduce(topo.inter, m, n),
                cost_model::halving_doubling_allreduce(topo.inter, m, n),
            ];
            if !topo.is_flat() {
                costs.push(cost_model::hierarchical_allreduce(topo, m, n));
            }
            for c in costs {
                ensure(best.predicted_s <= c + 1e-15, format!("{:?} not minimal", best.kind))?;
            }
            Ok(())
        });
    }

    #[test]
    fn flavor_mapping() {
        assert_eq!(ar_flavor(CollectiveKind::ArTopkRing), Some(crate::artopk::ArFlavor::Ring));
        assert_eq!(ar_flavor(CollectiveKind::ArTopkTree), Some(crate::artopk::ArFlavor::Tree));
        assert_eq!(ar_flavor(CollectiveKind::AllgatherTopk), None);
    }
}
