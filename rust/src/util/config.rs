//! TOML-subset configuration parser (offline build: no `serde`/`toml`).
//!
//! Supported syntax — enough for real experiment configs, nothing exotic:
//!
//! ```toml
//! # comment
//! [section]            # and [nested.section]
//! key = "string"
//! n = 8
//! cr = 0.01            # floats, incl. scientific notation
//! enabled = true
//! crs = [0.1, 0.01, 0.001]
//! names = ["a", "b"]
//! ```
//!
//! Values are stored flat under `"section.key"`. Typed getters return
//! `anyhow::Error` with the offending key on type mismatch.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Bool(_) => "bool",
            Value::List(_) => "list",
        }
    }
}

/// Parsed configuration: flat `section.key -> Value` map.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Config::parse(&text)
    }

    /// Override/insert a value from a `key=value` CLI string.
    pub fn set_from_str(&mut self, key: &str, raw: &str) -> Result<()> {
        let v = parse_value(raw).or_else(|_| parse_value(&format!("\"{raw}\"")))?;
        self.values.insert(key.to_string(), v);
        Ok(())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    fn get(&self, key: &str) -> Result<&Value> {
        self.values
            .get(key)
            .ok_or_else(|| anyhow!("missing config key `{key}`"))
    }

    pub fn str(&self, key: &str) -> Result<&str> {
        match self.get(key)? {
            Value::Str(s) => Ok(s),
            v => bail!("`{key}`: expected string, got {}", v.type_name()),
        }
    }

    pub fn int(&self, key: &str) -> Result<i64> {
        match self.get(key)? {
            Value::Int(i) => Ok(*i),
            v => bail!("`{key}`: expected int, got {}", v.type_name()),
        }
    }

    pub fn float(&self, key: &str) -> Result<f64> {
        match self.get(key)? {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            v => bail!("`{key}`: expected float, got {}", v.type_name()),
        }
    }

    pub fn bool(&self, key: &str) -> Result<bool> {
        match self.get(key)? {
            Value::Bool(b) => Ok(*b),
            v => bail!("`{key}`: expected bool, got {}", v.type_name()),
        }
    }

    pub fn float_list(&self, key: &str) -> Result<Vec<f64>> {
        match self.get(key)? {
            Value::List(xs) => xs
                .iter()
                .map(|v| match v {
                    Value::Float(f) => Ok(*f),
                    Value::Int(i) => Ok(*i as f64),
                    v => bail!("`{key}`: expected float element, got {}", v.type_name()),
                })
                .collect(),
            v => bail!("`{key}`: expected list, got {}", v.type_name()),
        }
    }

    pub fn str_list(&self, key: &str) -> Result<Vec<String>> {
        match self.get(key)? {
            Value::List(xs) => xs
                .iter()
                .map(|v| match v {
                    Value::Str(s) => Ok(s.clone()),
                    v => bail!("`{key}`: expected string element, got {}", v.type_name()),
                })
                .collect(),
            v => bail!("`{key}`: expected list, got {}", v.type_name()),
        }
    }

    // Defaulted variants.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str(key).map(str::to_string).unwrap_or_else(|_| default.to_string())
    }
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        if self.contains(key) { self.int(key).unwrap_or(default) } else { default }
    }
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        if self.contains(key) { self.float(key).unwrap_or(default) } else { default }
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        if self.contains(key) { self.bool(key).unwrap_or(default) } else { default }
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("unterminated string: {s}");
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated list: {s}");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_list(inner)? {
            if !part.trim().is_empty() {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::List(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

/// Split a list body on commas, respecting quoted strings.
fn split_list(s: &str) -> Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        bail!("unterminated string in list");
    }
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
workers = 8
[net]
alpha_ms = 4.0
bw_gbps = 20       # bandwidth
schedule = "c1"
[compress]
crs = [0.1, 0.01, 0.001]
kind = "artopk-star"
enabled = true
names = ["a", "b,c"]
"#;

    #[test]
    fn parses_all_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.int("workers").unwrap(), 8);
        assert_eq!(c.float("net.alpha_ms").unwrap(), 4.0);
        assert_eq!(c.float("net.bw_gbps").unwrap(), 20.0); // int coerces
        assert_eq!(c.str("net.schedule").unwrap(), "c1");
        assert_eq!(c.float_list("compress.crs").unwrap(), vec![0.1, 0.01, 0.001]);
        assert!(c.bool("compress.enabled").unwrap());
        assert_eq!(
            c.str_list("compress.names").unwrap(),
            vec!["a".to_string(), "b,c".to_string()]
        );
    }

    #[test]
    fn type_errors_name_the_key() {
        let c = Config::parse(SAMPLE).unwrap();
        let err = c.int("net.schedule").unwrap_err().to_string();
        assert!(err.contains("net.schedule"), "{err}");
        assert!(c.str("nope").is_err());
    }

    #[test]
    fn defaults() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.int_or("workers", 4), 8);
        assert_eq!(c.int_or("missing", 4), 4);
        assert_eq!(c.str_or("missing", "x"), "x");
        assert!(!c.bool_or("missing", false));
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_from_str("workers", "16").unwrap();
        assert_eq!(c.int("workers").unwrap(), 16);
        c.set_from_str("net.schedule", "c2").unwrap();
        assert_eq!(c.str("net.schedule").unwrap(), "c2");
    }

    #[test]
    fn bad_syntax_is_reported_with_line() {
        let err = Config::parse("x ==").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
    }

    #[test]
    fn scientific_notation_floats() {
        let c = Config::parse("x = 1e-3\ny = 2.5e2").unwrap();
        assert_eq!(c.float("x").unwrap(), 1e-3);
        assert_eq!(c.float("y").unwrap(), 250.0);
    }
}
