//! Rendering for flexlint: the human console table and the
//! `LINT_REPORT.json` machine record (hand-rolled writer, same idiom as
//! `util::bench::write_json` — no serde in the tree).

use super::{RunResult, Workspace, RULE_TABLE};
use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// The `--list` output: one row per registered rule.
pub fn rule_list() -> String {
    let width = RULE_TABLE.iter().map(|r| r.name.len()).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(out, "flexlint rules ({}):", RULE_TABLE.len());
    for r in RULE_TABLE {
        let summary: String = r.summary.split_whitespace().collect::<Vec<_>>().join(" ");
        let _ = writeln!(out, "  {:width$}  {}", r.name, summary, width = width);
    }
    out
}

/// The console report: one block per finding plus a summary line.
pub fn human_table(ws: &Workspace, r: &RunResult) -> String {
    let mut out = String::new();
    for f in &r.findings {
        let _ = writeln!(out, "[{}] {}:{}", f.rule, f.file, f.line);
        if !f.excerpt.is_empty() {
            let _ = writeln!(out, "    {}", f.excerpt);
        }
        let msg: String = f.message.split_whitespace().collect::<Vec<_>>().join(" ");
        let _ = writeln!(out, "    -> {msg}");
    }
    let _ = writeln!(
        out,
        "flexlint: {} rule(s) over {} file(s) — {} finding(s), {} suppressed",
        r.rules_run.len(),
        ws.files.len(),
        r.findings.len(),
        r.suppressed
    );
    out
}

/// Write `LINT_REPORT.json`. The caller (verify.sh) removes any stale
/// report before the run and checks existence after, so a crashed run can
/// never be mistaken for a clean one.
pub fn write_report(path: &Path, ws: &Workspace, r: &RunResult) -> io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"schema\": \"flexlint-report-v1\",");
    let _ = writeln!(s, "  \"files_scanned\": {},", ws.files.len());
    let _ = writeln!(s, "  \"suppressed\": {},", r.suppressed);
    let _ = writeln!(
        s,
        "  \"rules_run\": [{}],",
        r.rules_run.iter().map(|n| json_str(n)).collect::<Vec<_>>().join(", ")
    );
    s.push_str("  \"findings\": [");
    for (i, f) in r.findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    {");
        let _ = write!(
            s,
            "\"rule\": {}, \"file\": {}, \"line\": {}, \"excerpt\": {}, \"message\": {}",
            json_str(f.rule),
            json_str(&f.file),
            f.line,
            json_str(&f.excerpt),
            json_str(&f.message)
        );
        s.push('}');
    }
    if !r.findings.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    fs::write(path, s)
}

/// Minimal JSON string escaper (mirrors `util::bench`'s private helper;
/// kept local so the analysis module stays dependency-free).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{run, Workspace};

    #[test]
    fn report_json_is_well_formed_and_escaped() {
        let src = "fn rank(v: &mut Vec<f64>) {\n    \
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let ws = Workspace::fixture(src);
        let r = run(&ws, Some("nan-partial-cmp"));
        assert_eq!(r.findings.len(), 1);

        let dir = std::env::temp_dir().join("flexlint_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("LINT_REPORT.json");
        write_report(&path, &ws, &r).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"schema\": \"flexlint-report-v1\""));
        assert!(text.contains("\"rule\": \"nan-partial-cmp\""));
        assert!(text.contains("\"files_scanned\": 1"));
        // The excerpt contains quotes-free source but the escaper must
        // round-trip arbitrary text: spot-check the escapes directly.
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn human_table_names_every_finding_and_totals() {
        let src = "fn rank(v: &mut Vec<f64>) {\n    \
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let ws = Workspace::fixture(src);
        let r = run(&ws, None);
        let table = human_table(&ws, &r);
        assert!(table.contains("[nan-partial-cmp] fixture.rs:2"));
        assert!(table.contains("finding(s)"));
        assert!(rule_list().contains("nan-partial-cmp"));
    }
}
