//! Ring allreduce: reduce-scatter followed by allgather, the
//! bandwidth-optimal collective (Table I row 2).
//!
//! Round structure: `2(N-1)` rounds, each worker sending one `M/N` chunk —
//! total `2(N-1)α + 2((N-1)/N)Mβ`, matching
//! [`cost_model::ring_allreduce`](crate::netsim::cost_model::ring_allreduce).

use crate::collectives::CommReport;
use crate::netsim::cost_model::LinkParams;

/// In-place SUM ring-allreduce over per-worker buffers (all same length).
/// After the call every buffer holds the elementwise sum.
pub fn ring_allreduce(bufs: &mut [Vec<f32>], link: LinkParams) -> CommReport {
    let n = bufs.len();
    assert!(n >= 1);
    let m = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == m), "buffer length mismatch");
    let mut report = CommReport::default();
    if n == 1 || m == 0 {
        return report;
    }

    // Chunk boundaries: chunk i covers [start(i), start(i+1)).
    let start = |i: usize| i * m / n;
    let chunk_range = |i: usize| start(i % n)..start(i % n + 1);
    let chunk_bytes = 4.0 * m as f64 / n as f64;

    // Reusable per-round scratch (perf: one allocation per call, not per
    // round — see EXPERIMENTS.md §Perf).
    let max_chunk = start(1).max(m - start(n - 1));
    let mut outgoing: Vec<Vec<f32>> = vec![Vec::with_capacity(max_chunk); n];

    // Phase 1: reduce-scatter. Round r: worker w sends chunk (w - r) mod n
    // to worker (w + 1) mod n, which accumulates it. After n-1 rounds worker
    // w owns the fully reduced chunk (w + 1) mod n.
    for r in 0..n - 1 {
        // Snapshot the outgoing chunks first (all sends happen in parallel).
        for w in 0..n {
            outgoing[w].clear();
            outgoing[w].extend_from_slice(&bufs[w][chunk_range(w + n - r % n + n)]);
        }
        for w in 0..n {
            let dst = (w + 1) % n;
            let rng = chunk_range(w + n - r % n + n);
            for (dv, sv) in bufs[dst][rng].iter_mut().zip(&outgoing[w]) {
                *dv += sv;
            }
        }
        report.add_round(link, chunk_bytes);
    }

    // Phase 2: allgather. Round r: worker w sends its owned (reduced) chunk
    // which then propagates around the ring.
    for r in 0..n - 1 {
        for w in 0..n {
            outgoing[w].clear();
            outgoing[w].extend_from_slice(&bufs[w][chunk_range(w + 1 + n - r % n + n)]);
        }
        for w in 0..n {
            let dst = (w + 1) % n;
            let rng = chunk_range(w + 1 + n - r % n + n);
            bufs[dst][rng.clone()].copy_from_slice(&outgoing[w]);
        }
        report.add_round(link, chunk_bytes);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;
    use crate::util::proptest::{all_close, check, ensure};
    use crate::util::rng::Rng;

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(2.0, 10.0)
    }

    #[test]
    fn sums_exactly() {
        let mut bufs = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        ring_allreduce(&mut bufs, link());
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0, 333.0, 444.0, 555.0]);
        }
    }

    #[test]
    fn time_matches_closed_form() {
        // Chunked model matches the Table I closed form exactly when n | m.
        let n = 8;
        let m = 8 * 1000;
        let mut bufs = vec![vec![1.0f32; m]; n];
        let r = ring_allreduce(&mut bufs, link());
        let want = cost_model::ring_allreduce(link(), 4.0 * m as f64, n);
        assert!(
            (r.seconds - want).abs() / want < 1e-9,
            "sim {} vs model {}",
            r.seconds,
            want
        );
        assert_eq!(r.rounds, 2 * (n as u32 - 1));
    }

    #[test]
    fn property_sum_any_n_m() {
        check("ring allreduce sums for any n,m", 60, |g| {
            let n = g.usize_in(1, 9);
            let m = g.usize_in(1, 200);
            let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(m, 1.0)).collect();
            let mut want = vec![0.0f32; m];
            for b in &bufs {
                for (w, v) in want.iter_mut().zip(b) {
                    *w += v;
                }
            }
            let mut got = bufs.clone();
            ring_allreduce(&mut got, link());
            for (w, b) in got.iter().enumerate() {
                all_close(b, &want, 1e-4).map_err(|e| format!("worker {w}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_worker_is_noop() {
        let mut bufs = vec![vec![1.0, 2.0]];
        let r = ring_allreduce(&mut bufs, link());
        assert_eq!(r.seconds, 0.0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn deterministic() {
        check("ring deterministic", 20, |g| {
            let n = g.usize_in(2, 6);
            let m = g.usize_in(1, 64);
            let bufs: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    let mut r = Rng::new(i as u64);
                    let mut v = vec![0.0; m];
                    r.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            let mut a = bufs.clone();
            let mut b = bufs;
            let ra = ring_allreduce(&mut a, link());
            let rb = ring_allreduce(&mut b, link());
            ensure(a == b && ra == rb, "nondeterministic")
        });
    }
}
