"""L2 model correctness: layouts, shapes, loss/grad sanity, SGD step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

TINY = M.TRANSFORMER_PRESETS["tiny"]
MLP = M.MLP_PRESETS["mlp"]


def _tokens(rng, cfg, batch=None):
    b = batch or cfg.batch
    return rng.integers(0, cfg.vocab, size=(b, cfg.seq + 1)).astype(np.int32)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------
def test_layout_offsets_are_contiguous():
    for layout in (M.transformer_layout(TINY), M.mlp_layout(MLP)):
        rows = M.layout_sizes(layout)
        off = 0
        for _, o, s in rows:
            assert o == off
            assert s > 0
            off += s
        assert off == M.param_count(layout)


def test_transformer_layout_param_count_formula():
    cfg = TINY
    d, v, t = cfg.dim, cfg.vocab, cfg.seq
    per_block = 4 * d + 3 * d * d + d * d + 8 * d * d  # ln + qkv + out + mlp
    want = v * d + t * d + cfg.layers * per_block + 2 * d + d * v
    assert M.param_count(M.transformer_layout(cfg)) == want


def test_unflatten_roundtrip():
    layout = M.mlp_layout(MLP)
    p = M.init_params(layout, seed=1)
    tree = M.unflatten(p, layout)
    rebuilt = jnp.concatenate([tree[n].reshape(-1) for n, _ in layout])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(p))


def test_init_params_deterministic():
    layout = M.transformer_layout(TINY)
    a = M.init_params(layout, seed=0)
    b = M.init_params(layout, seed=0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = M.init_params(layout, seed=1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# transformer forward/backward
# ---------------------------------------------------------------------------
def test_transformer_loss_near_uniform_at_init():
    rng = np.random.default_rng(0)
    layout = M.transformer_layout(TINY)
    p = M.init_params(layout, seed=0)
    loss = M.transformer_loss(TINY, p, jnp.array(_tokens(rng, TINY)))
    # Random init ~ uniform over vocab -> loss ~ ln(V).
    assert abs(float(loss) - np.log(TINY.vocab)) < 1.5


def test_transformer_grad_shapes_and_nonzero():
    rng = np.random.default_rng(1)
    layout = M.transformer_layout(TINY)
    p = M.init_params(layout, seed=0)
    f = M.grad_fn("transformer", TINY)
    loss, grads = f(p, jnp.array(_tokens(rng, TINY)))
    assert grads.shape == p.shape
    assert np.isfinite(float(loss))
    assert float(jnp.sum(jnp.abs(grads))) > 0


def test_transformer_causality():
    """Changing a future token must not change logits at earlier positions."""
    rng = np.random.default_rng(2)
    layout = M.transformer_layout(TINY)
    p = M.unflatten(M.init_params(layout, seed=0), layout)
    toks = _tokens(rng, TINY, batch=1)[:, :-1]
    la = M.transformer_logits(TINY, p, jnp.array(toks))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % TINY.vocab
    lb = M.transformer_logits(TINY, p, jnp.array(toks2))
    np.testing.assert_allclose(
        np.asarray(la[0, :-1]), np.asarray(lb[0, :-1]), rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(np.asarray(la[0, -1]), np.asarray(lb[0, -1]))


def test_transformer_eval_counts_bounded():
    rng = np.random.default_rng(3)
    p = M.init_params(M.transformer_layout(TINY), seed=0)
    loss, correct = M.eval_fn("transformer", TINY)(p, jnp.array(_tokens(rng, TINY)))
    total = TINY.batch * TINY.seq
    assert 0.0 <= float(correct) <= total


def test_transformer_learns_constant_sequence():
    """A few SGD steps on a repeated token must drive the loss down hard."""
    cfg = TINY
    p = M.init_params(M.transformer_layout(cfg), seed=0)
    toks = jnp.full((cfg.batch, cfg.seq + 1), 7, jnp.int32)
    f = jax.jit(M.grad_fn("transformer", cfg))
    first = None
    for _ in range(12):
        loss, g = f(p, toks)
        first = first if first is not None else float(loss)
        p = p - 0.5 * g
    assert float(loss) < first * 0.2, (first, float(loss))


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------
def _cluster_batch(rng, cfg):
    y = rng.integers(0, cfg.classes, size=cfg.batch).astype(np.int32)
    centers = rng.standard_normal((cfg.classes, cfg.features)).astype(np.float32) * 2
    x = centers[y] + rng.standard_normal((cfg.batch, cfg.features)).astype(np.float32) * 0.3
    return x, y


def test_mlp_grad_and_learning():
    rng = np.random.default_rng(0)
    p = M.init_params(M.mlp_layout(MLP), seed=0)
    x, y = _cluster_batch(rng, MLP)
    f = jax.jit(M.grad_fn("mlp", MLP))
    losses = []
    for _ in range(60):
        loss, g = f(p, jnp.array(x), jnp.array(y))
        losses.append(float(loss))
        p = p - 0.2 * g
    assert losses[-1] < losses[0] * 0.3


def test_mlp_eval_perfect_after_overfit():
    rng = np.random.default_rng(1)
    p = M.init_params(M.mlp_layout(MLP), seed=0)
    x, y = _cluster_batch(rng, MLP)
    f = jax.jit(M.grad_fn("mlp", MLP))
    for _ in range(150):
        _, g = f(p, jnp.array(x), jnp.array(y))
        p = p - 0.2 * g
    _, correct = M.eval_fn("mlp", MLP)(p, jnp.array(x), jnp.array(y))
    assert float(correct) >= 0.9 * MLP.batch


# ---------------------------------------------------------------------------
# sgd step graph
# ---------------------------------------------------------------------------
def test_sgd_step_matches_manual():
    rng = np.random.default_rng(5)
    p = rng.standard_normal(100).astype(np.float32)
    m = rng.standard_normal(100).astype(np.float32)
    g = rng.standard_normal(100).astype(np.float32)
    lr, mom, wd = 0.1, 0.9, 0.0005
    f = M.sgd_step_fn()
    p2, m2 = f(jnp.array(p), jnp.array(m), jnp.array(g), lr, mom, wd)
    gm = g + wd * p
    want_m = mom * m + gm
    want_p = p - lr * want_m
    np.testing.assert_allclose(np.asarray(m2), want_m, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p2), want_p, rtol=1e-5)


@pytest.mark.parametrize("preset", ["tiny", "small"])
def test_presets_resolve(preset):
    cfg = M.TRANSFORMER_PRESETS[preset]
    assert M.param_count(M.transformer_layout(cfg)) > 0
