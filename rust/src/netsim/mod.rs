//! α-β network simulator.
//!
//! The paper's testbed shapes a real 8-GPU cluster with linux `tc` (netem
//! latency + htb bandwidth). Here the *link* is simulated: every collective
//! really moves data between in-process worker buffers, and its wall-time is
//! charged from the same α-β cost algebra the paper validates against
//! hardware (Tables I/II/VI).
//!
//! * [`model`] — the [`NetworkModel`](model::NetworkModel) trait every
//!   environment implements, plus the [`NET_TABLE`](model::NET_TABLE)
//!   scenario registry (DESIGN.md §9).
//! * [`cost_model`] — closed-form collective costs (Table I, Eqn 4) and the
//!   switching heuristics (Eqn 5).
//! * [`schedule`] — piecewise (α, β) schedules incl. the paper's C1/C2
//!   (Fig 6).
//! * [`modifiers`] — composable environment wrappers: jitter, congestion
//!   episodes, diurnal load, link flapping, asymmetric degradation,
//!   two-level topology.
//! * [`trace`] — replay of measured (epoch, α, β) trace files (CSV/JSON).
//! * [`probe`] — the iperf/traceroute analogue: noisy observations of the
//!   current link, with change detection.

pub mod cost_model;
pub mod model;
pub mod modifiers;
pub mod probe;
pub mod schedule;
pub mod trace;

/// Virtual wall clock (seconds). The trainer advances it with compute,
/// compression and (simulated) communication time.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `seconds`. Negative or NaN advances are a cost-model
    /// bug: debug builds panic (loud during development and `cargo test`),
    /// release builds clamp the advance to zero — the old behaviour
    /// silently ran the clock BACKWARDS in release, corrupting every
    /// virtual-time comparison downstream.
    pub fn advance(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0, "negative/NaN time advance {seconds}");
        self.now += seconds.max(0.0); // NaN.max(0.0) == 0.0: NaN also clamps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    /// Regression (release profile): negative and NaN advances must not
    /// move the clock backwards (or poison it) — they clamp to zero.
    #[test]
    #[cfg(not(debug_assertions))]
    fn clock_never_runs_backwards() {
        let mut c = VirtualClock::new();
        c.advance(2.0);
        c.advance(-1.0);
        assert_eq!(c.now(), 2.0, "negative advance must clamp to zero");
        c.advance(f64::NAN);
        assert_eq!(c.now(), 2.0, "NaN advance must clamp to zero");
        c.advance(0.5);
        assert!((c.now() - 2.5).abs() < 1e-12, "clock keeps working after a clamp");
    }

    /// Regression (debug profile): a buggy cost model feeding a negative
    /// advance stays LOUD where developers and `cargo test` run.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "time advance")]
    fn clock_rejects_negative_advance_loudly_in_debug() {
        VirtualClock::new().advance(-1.0);
    }
}
