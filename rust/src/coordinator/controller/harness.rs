//! The engine-owned exploration harness: checkpointed candidate probing
//! as a service (DESIGN.md §10).
//!
//! The paper's §3-E controller measures each candidate CR for a few
//! iterations under checkpoint/restore so exploration never damages the
//! model. That loop used to live inside the MOO controller; it is now a
//! harness ANY [`Controller`](super::Controller) can invoke (via
//! [`ControlAction::RequestExploration`](super::ControlAction)), so the
//! three concerns it bundles stay in one place:
//!
//! * **checkpointing** — snapshot before the first candidate, restore
//!   after every candidate, so each starts from the same state and the
//!   committed timeline resumes exactly where it left off;
//! * **overhead accounting** — every explored step's simulated time is
//!   charged to `Trainer::explore_overhead_s` (reported separately, never
//!   on the restored virtual clock);
//! * **delivery semantics** — exploration steps are UNRECORDED: no
//!   metrics rows, no observer events, and `CommStrategy::observe` is not
//!   called, so a strategy's internal controllers never learn from a
//!   rolled-back timeline. Decisions *about* the exploration (the
//!   controller's follow-ups from
//!   [`Controller::on_exploration`](super::Controller::on_exploration))
//!   are applied right after the restore and stamped with the committed
//!   step counter — observers see them on the real timeline.

use crate::coordinator::trainer::Trainer;
use crate::moo::problem::CandidateProfile;
use crate::netsim::cost_model::LinkParams;

/// A controller's request for checkpointed candidate probing: run each
/// candidate CR for `iters` steps and measure (t_comp, t_sync, gain).
/// Candidates are probed in the given order (the paper walks the ladder
/// descending from `c_high`).
#[derive(Debug, Clone, PartialEq)]
pub struct ExplorationRequest {
    pub candidates: Vec<f64>,
    pub iters: u64,
}

/// What comes back: the measured per-candidate profiles, plus the `by` /
/// `reason` tags of the requesting decision (echoed verbatim so a
/// [`CompositeController`](super::CompositeController) can route the
/// result to the sub-controller that asked).
#[derive(Debug, Clone)]
pub struct ExplorationOutcome {
    pub by: &'static str,
    pub reason: &'static str,
    /// The probed inter link the candidates were costed at.
    pub probed: LinkParams,
    pub profiles: Vec<CandidateProfile>,
}

/// Engine-side exploration driver over a borrowed trainer. Created by the
/// engine's control phase; controllers never touch the trainer directly.
pub struct ExplorationHarness<'a> {
    trainer: &'a mut Trainer,
}

impl<'a> ExplorationHarness<'a> {
    pub(crate) fn new(trainer: &'a mut Trainer) -> Self {
        ExplorationHarness { trainer }
    }

    /// Probe every candidate CR for `req.iters` unrecorded steps under
    /// checkpoint/restore at the probed link; returns measured profiles
    /// (mean t_comp / t_sync / gain per candidate, gain clamped into
    /// `(0, 1]` for the MOO objectives). Restores the pre-exploration
    /// state and CR before returning; all explored step time lands in
    /// `explore_overhead_s`.
    pub(crate) fn probe_candidates(
        &mut self,
        req: &ExplorationRequest,
        probed: LinkParams,
    ) -> Vec<CandidateProfile> {
        let t = &mut *self.trainer;
        if req.candidates.is_empty() || req.iters == 0 {
            return Vec::new();
        }
        let ck = t.snapshot();
        let saved_cr = t.cur_cr;
        let mut out = Vec::new();
        let mut overhead = 0.0;
        for &cr in &req.candidates {
            t.cur_cr = cr;
            let (mut tc, mut ts, mut ga) = (0.0, 0.0, 0.0);
            for _ in 0..req.iters {
                let m = t.step_once(false, probed);
                tc += m.t_comp;
                ts += m.t_sync;
                ga += m.gain;
                overhead += m.t_step();
            }
            let k = req.iters as f64;
            out.push(CandidateProfile {
                cr,
                t_comp: tc / k,
                t_sync: ts / k,
                gain: (ga / k).clamp(1e-6, 1.0),
            });
            t.restore(&ck);
        }
        t.cur_cr = saved_cr;
        t.explore_overhead_s += overhead;
        out
    }
}
