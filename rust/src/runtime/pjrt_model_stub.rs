//! Stub [`PjrtModel`] for builds without the `pjrt` feature. Mirrors the
//! public API of `pjrt_model.rs`; [`PjrtModel::load`] always fails, so the
//! [`GradSource`] methods are unreachable by construction.

use crate::coordinator::worker::GradSource;
use crate::runtime::artifact::ModelArtifacts;
use crate::runtime::engine::Engine;
use crate::tensor::Layout;
use anyhow::{bail, Result};

const NO_PJRT: &str =
    "flexcomm was built without the `pjrt` feature; rebuild with `--features pjrt` \
     to execute AOT-lowered artifacts";

/// Stand-in for the PJRT-backed model (never constructible here).
pub struct PjrtModel {
    arts: ModelArtifacts,
}

impl PjrtModel {
    /// Always fails in non-`pjrt` builds.
    pub fn load(_engine: &Engine, _arts: ModelArtifacts, _seed: u64) -> Result<PjrtModel> {
        bail!("{NO_PJRT}")
    }

    pub fn sgd_step(
        &self,
        _params: &[f32],
        _momentum: &[f32],
        _grads: &[f32],
        _lr: f32,
        _mom: f32,
        _wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!("{NO_PJRT}")
    }

    pub fn ef_topk(
        &self,
        _g: &[f32],
        _residual: &[f32],
        _k: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f64, f64, f32)> {
        bail!("{NO_PJRT}")
    }

    pub fn has_ef_topk(&self) -> bool {
        false
    }

    pub fn artifacts(&self) -> &ModelArtifacts {
        &self.arts
    }
}

impl GradSource for PjrtModel {
    fn dim(&self) -> usize {
        unreachable!("stub PjrtModel cannot be constructed")
    }

    fn layout(&self) -> &Layout {
        unreachable!("stub PjrtModel cannot be constructed")
    }

    fn init_params(&mut self) -> Vec<f32> {
        unreachable!("stub PjrtModel cannot be constructed")
    }

    fn grad(
        &self,
        _params: &[f32],
        _worker: usize,
        _n_workers: usize,
        _step: u64,
    ) -> (f64, Vec<f32>) {
        unreachable!("stub PjrtModel cannot be constructed")
    }

    fn eval(&mut self, _params: &[f32]) -> (f64, f64) {
        unreachable!("stub PjrtModel cannot be constructed")
    }

    fn name(&self) -> String {
        unreachable!("stub PjrtModel cannot be constructed")
    }
}
