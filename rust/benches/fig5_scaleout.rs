//! Fig 5: scale-out communication cost of AG vs AR-Topk at CR 0.1 as N
//! grows 2..8(..32), on a 5ms / 1Gbps link (ResNet50-sized tensor).
//! Both the closed form and the real collective implementations.
//!
//!     cargo bench --bench fig5_scaleout

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::allgather_sparse;
use flexcomm::compress::{Compressor, EfState, TopK};
use flexcomm::netsim::cost_model::{self, LinkParams};
use flexcomm::tensor::Layout;
use flexcomm::util::rng::Rng;
use flexcomm::util::stats::sparkline;
use flexcomm::util::table::Table;

fn main() {
    let params = 25.6e6; // ResNet50
    let cr = 0.1;
    let l = LinkParams::from_ms_gbps(5.0, 1.0);
    let m = 4.0 * params;
    let sim_dim = 100_000;
    let scale = params / sim_dim as f64;
    let ls = LinkParams { alpha: l.alpha, beta: l.beta * scale };

    println!("Fig 5 — scale-out at CR 0.1, 5ms/1Gbps, ResNet50 tensor\n");
    let mut t = Table::new(["N", "AG model (ms)", "AG sim (ms)", "ART-Ring model (ms)", "ART-Ring sim (ms)"]);
    let mut ag_series = Vec::new();
    let mut art_series = Vec::new();
    for n in [2usize, 3, 4, 5, 6, 7, 8, 16, 32] {
        let mut rng = Rng::new(n as u64);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0; sim_dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        // Real ops.
        let layout = Layout::single(sim_dim);
        let mut tk = TopK::with_quickselect();
        let parts: Vec<_> = grads.iter().map(|g| tk.compress(g, cr, &layout)).collect();
        let (_, rep_ag) = allgather_sparse(&parts, sim_dim, ls);
        let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(sim_dim)).collect();
        let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
        let rep_art = art.exchange(&grads, &mut ef, cr, 0, ls).comm;

        let ag_model = cost_model::ag_topk(l, m, n, cr) * 1e3;
        let art_model = cost_model::art_ring(l, m, n, cr) * 1e3;
        ag_series.push(ag_model);
        art_series.push(art_model);
        t.row([
            n.to_string(),
            format!("{ag_model:.0}"),
            format!("{:.0}", rep_ag.seconds * 1e3),
            format!("{art_model:.0}"),
            format!("{:.0}", rep_art.seconds * 1e3),
        ]);
    }
    t.print();
    println!("\nAG       {}", sparkline(&ag_series));
    println!("ART-Ring {}", sparkline(&art_series));
    println!(
        "\nShape check (paper Fig 5): AG cost climbs steeply with N \
         (bandwidth O(MN)); ART-Ring inclines gently (ring β-term ~ \
         independent of N, broadcast grows as log N)."
    );
}
