//! The MOO-adaptive compression controller (§3-E), ported onto the
//! [`Controller`] seam (formerly `coordinator/adaptive.rs`'s
//! `AdaptiveState`, spliced into the trainer).
//!
//! Triggers, exactly as the paper specifies:
//! * **gain drift** ≥ `gain_threshold` (10%) — re-profile the candidate CR
//!   ladder: a [`RequestExploration`](super::ControlAction) decision makes
//!   the engine checkpoint, run each candidate for `probe_iters` steps
//!   recording (t_comp, t_sync, gain), restore; the profiles come back via
//!   [`Controller::on_exploration`], the MOO problem is rebuilt and solved
//!   (NSGA-II) for the knee-point `c_optimal`;
//! * **network change** (probe detects α or bandwidth drift) — keep the
//!   measured gain/comp profiles but re-predict each candidate's `t_sync`
//!   from the α-β cost model at the new link, re-solve.
//!
//! Behavior is pinned BITWISE against the pre-refactor implementation by
//! `moo_controller_reproduces_the_legacy_adaptive_run_bitwise` (below),
//! which drives a verbatim copy of the old `AdaptiveState` algorithm
//! against the engine directly and compares the full trajectory.

use super::{
    ControlAction, ControlCtx, ControlDecision, Controller, ExplorationOutcome,
    ExplorationRequest,
};
use crate::compress::GainTracker;
use crate::coordinator::selector;
use crate::moo::problem::{candidate_crs, CandidateProfile, CrProblem};

/// Adaptive-CR configuration (defaults = the paper's §3-E1 values). Also
/// the ladder-bounds source for the [`GravacController`](super::gravac)
/// registry build.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    pub c_low: f64,
    pub c_high: f64,
    /// Geometric step between candidate CRs.
    pub factor: f64,
    /// Iterations each candidate runs during exploration.
    pub probe_iters: u64,
    /// Relative gain-drift trigger (0.1 = 10%).
    pub gain_threshold: f64,
    /// NSGA-II seed.
    pub seed: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            c_low: 0.001,
            c_high: 0.1,
            factor: 3.0,
            probe_iters: 10,
            gain_threshold: 0.1,
            seed: 0,
        }
    }
}

/// The §3-E NSGA-II knee-point controller.
#[derive(Debug)]
pub struct MooController {
    pub cfg: AdaptiveConfig,
    /// Smoothed-gain drift trigger (GraVAC's gain heuristic, Fig 3).
    tracker: GainTracker,
    /// Last measured candidate profiles (refreshed on gain triggers).
    profiles: Option<Vec<CandidateProfile>>,
    /// Trigger tag of the exploration in flight.
    pending_reason: &'static str,
    /// How many explorations ran (observability/tests).
    pub explorations: u64,
    /// How many re-solves ran (gain + network triggers).
    pub resolves: u64,
}

impl MooController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        let tracker = GainTracker::new(cfg.gain_threshold);
        MooController {
            cfg,
            tracker,
            profiles: None,
            pending_reason: "warmup",
            explorations: 0,
            resolves: 0,
        }
    }

    /// Solve the MOO problem over the current profiles; the knee point
    /// (clamped to the ladder bounds) becomes the next CR.
    fn solve(&mut self, reason: &'static str) -> ControlDecision {
        let profiles = self.profiles.as_ref().expect("profiles measured");
        let c_opt = CrProblem::new(profiles.clone()).solve(self.cfg.seed);
        self.resolves += 1;
        ControlDecision {
            by: "moo",
            reason,
            action: ControlAction::SetCr(c_opt.clamp(self.cfg.c_low, self.cfg.c_high)),
        }
    }
}

impl Controller for MooController {
    fn name(&self) -> &'static str {
        "moo"
    }

    fn adapts_cr(&self) -> bool {
        true
    }

    /// The paper starts every adaptive run at the ladder's top (`c_high`).
    fn initial_cr(&self) -> Option<f64> {
        Some(self.cfg.c_high)
    }

    fn observe(&mut self, ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
        let gain_fired = self.tracker.record(ctx.metrics.gain);
        if !ctx.compressed {
            return Vec::new();
        }
        let need_explore = self.profiles.is_none() || gain_fired;
        if !need_explore && !ctx.net_changed {
            return Vec::new();
        }
        if need_explore {
            let reason = if self.profiles.is_none() { "warmup" } else { "gain-drift" };
            self.pending_reason = reason;
            return vec![ControlDecision {
                by: "moo",
                reason,
                action: ControlAction::RequestExploration(ExplorationRequest {
                    candidates: candidate_crs(self.cfg.c_low, self.cfg.c_high, self.cfg.factor),
                    iters: self.cfg.probe_iters,
                }),
            }];
        }
        // Network changed: re-predict t_sync at the new link only.
        if let Some(profiles) = &mut self.profiles {
            for p in profiles.iter_mut() {
                p.t_sync = selector::choose(ctx.probed, ctx.model_bytes, ctx.n_workers, p.cr)
                    .predicted_s;
            }
        }
        vec![self.solve("net-change")]
    }

    fn on_exploration(&mut self, res: &ExplorationOutcome) -> Vec<ControlDecision> {
        // A CR problem needs >= 2 measured candidates; a degenerate
        // harness result (empty/single — e.g. a foreign request echoed to
        // us by a composite) must not poison the stored profiles or panic
        // in CrProblem::new. Keep the previous profiles and decide
        // nothing.
        if res.profiles.len() < 2 {
            return Vec::new();
        }
        self.profiles = Some(res.profiles.clone());
        self.explorations += 1;
        // Accept the current gain level as the new drift anchor.
        self.tracker.rearm();
        vec![self.solve(self.pending_reason)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artopk::SelectionPolicy;
    use crate::compress::GainTracker;
    use crate::coordinator::controller::StaticController;
    use crate::coordinator::strategy::instantiate;
    use crate::coordinator::trainer::{CrControl, Strategy, Trainer, TrainConfig};
    use crate::coordinator::worker::ComputeModel;
    use crate::netsim::cost_model::LinkParams;
    use crate::netsim::schedule::NetSchedule;
    use crate::runtime::host_model::HostMlp;
    use crate::util::pool::ThreadPool;

    fn adaptive_cfg(schedule: NetSchedule, steps: u64) -> TrainConfig {
        TrainConfig {
            n_workers: 4,
            steps,
            steps_per_epoch: 25,
            lr: 0.3,
            momentum: 0.6,
            strategy: Strategy::Flexible { policy: SelectionPolicy::Star },
            cr: CrControl::Adaptive(AdaptiveConfig { probe_iters: 3, ..Default::default() }),
            net: Box::new(schedule),
            compute: ComputeModel::fixed(0.005),
            eval_every: 0,
            seed: 5,
            // Zero out MEASURED compression time so the MOO inputs — and
            // therefore the whole run — are deterministic (DESIGN.md §10).
            comp_scale: 0.0,
            ..Default::default()
        }
    }

    fn adaptive_trainer(schedule: NetSchedule, steps: u64) -> Trainer {
        Trainer::new(adaptive_cfg(schedule, steps), Box::new(HostMlp::default_preset(11)))
    }

    #[test]
    fn first_step_triggers_exploration_and_sets_cr() {
        let mut t = adaptive_trainer(NetSchedule::c2(4.0), 5);
        t.run();
        assert!(t.cur_cr() >= 0.001 && t.cur_cr() <= 0.1);
        assert!(t.explore_overhead_s() > 0.0, "exploration must cost time");
        // Main log only contains the recorded steps.
        assert_eq!(t.metrics().steps.len(), 5);
    }

    #[test]
    fn exploration_does_not_corrupt_training() {
        // With restore, adaptive training must still learn.
        let mut t = adaptive_trainer(NetSchedule::c2(8.0), 200);
        t.run();
        let acc = t.metrics().final_accuracy().unwrap();
        assert!(acc > 0.7, "adaptive accuracy {acc}");
    }

    #[test]
    fn network_change_triggers_resolve_without_new_exploration() {
        // C2 at short epochs -> several network phase changes within run.
        let mut t = adaptive_trainer(NetSchedule::c2(4.0), 100);
        t.run();
        let crs = t.metrics().crs_used();
        let distinct: std::collections::BTreeSet<u64> =
            crs.iter().map(|c| (c * 1e6) as u64).collect();
        assert!(distinct.len() >= 2, "adaptive CR never moved: {distinct:?}");
    }

    #[test]
    fn fixed_strategy_with_static_cr_never_adapts() {
        let cfg = TrainConfig {
            n_workers: 4,
            steps: 30,
            strategy: Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: crate::artopk::ArFlavor::Ring,
            },
            cr: CrControl::Static(0.02),
            compute: ComputeModel::fixed(0.005),
            seed: 2,
            ..Default::default()
        };
        let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(1)));
        t.run();
        assert!(t.metrics().crs_used().iter().all(|&c| (c - 0.02).abs() < 1e-12));
        assert_eq!(t.explore_overhead_s(), 0.0);
    }

    // -----------------------------------------------------------------------
    // The behavior pin (ISSUE 5 satellite): a VERBATIM copy of the
    // pre-refactor `AdaptiveState` (adaptive.rs as of PR 4) driven against
    // the engine directly, compared bitwise against the ported `moo`
    // controller on the C2 adaptive scenario. `comp_scale = 0` removes the
    // one timing-nondeterministic input (measured compression seconds), so
    // any trajectory difference is an algorithmic divergence, not noise.
    // -----------------------------------------------------------------------

    /// Pre-refactor controller state, copied verbatim (field-for-field,
    /// branch-for-branch) from the deleted `coordinator/adaptive.rs`.
    struct LegacyAdaptiveState {
        cfg: AdaptiveConfig,
        profiles: Option<Vec<CandidateProfile>>,
        explorations: u64,
    }

    impl LegacyAdaptiveState {
        fn new(cfg: AdaptiveConfig) -> Self {
            LegacyAdaptiveState { cfg, profiles: None, explorations: 0 }
        }

        /// Verbatim `AdaptiveState::maybe_adapt` (the old trainer-owned
        /// gain tracker is passed in, as the old trainer did implicitly).
        /// Kept character-for-character — lints are silenced rather than
        /// "fixing" the copy, which would defeat the pin.
        #[allow(clippy::nonminimal_bool)]
        fn maybe_adapt(
            &mut self,
            t: &mut Trainer,
            tracker: &mut GainTracker,
            net_changed: bool,
            gain_fired: bool,
            probed: LinkParams,
        ) {
            let need_explore = self.profiles.is_none() || gain_fired;
            if !(need_explore || net_changed) {
                return;
            }
            if need_explore {
                self.profiles = Some(self.explore(t, probed));
                self.explorations += 1;
                tracker.rearm();
            } else if let Some(profiles) = &mut self.profiles {
                for p in profiles.iter_mut() {
                    p.t_sync =
                        selector::choose(probed, t.model_bytes(), t.cfg().n_workers, p.cr)
                            .predicted_s;
                }
            }
            let profiles = self.profiles.as_ref().expect("profiles set");
            let c_opt = CrProblem::new(profiles.clone()).solve(self.cfg.seed);
            t.cur_cr = c_opt.clamp(self.cfg.c_low, self.cfg.c_high);
        }

        /// Verbatim `AdaptiveState::explore`.
        fn explore(&self, t: &mut Trainer, probed: LinkParams) -> Vec<CandidateProfile> {
            let ck = t.snapshot();
            let saved_cr = t.cur_cr;
            let mut out = Vec::new();
            let mut overhead = 0.0;
            for cr in candidate_crs(self.cfg.c_low, self.cfg.c_high, self.cfg.factor) {
                t.cur_cr = cr;
                let (mut tc, mut ts, mut ga) = (0.0, 0.0, 0.0);
                for _ in 0..self.cfg.probe_iters {
                    let m = t.step_once(false, probed);
                    tc += m.t_comp;
                    ts += m.t_sync;
                    ga += m.gain;
                    overhead += m.t_step();
                }
                let k = self.cfg.probe_iters as f64;
                out.push(CandidateProfile {
                    cr,
                    t_comp: tc / k,
                    t_sync: ts / k,
                    gain: (ga / k).clamp(1e-6, 1.0),
                });
                t.restore(&ck);
            }
            t.cur_cr = saved_cr;
            t.explore_overhead_s += overhead;
            out
        }
    }

    /// Drive the legacy algorithm exactly as the old
    /// `run_one_scheduled_step`/`run` did: probe → recorded step → gain
    /// tracking → maybe_adapt, against a trainer whose own controller is a
    /// no-op (so only the legacy copy steers it).
    fn legacy_run(cfg: TrainConfig, steps: u64) -> Trainer {
        let a = match &cfg.cr {
            CrControl::Adaptive(a) => a.clone(),
            _ => panic!("legacy pin needs an adaptive config"),
        };
        let pool = ThreadPool::auto(cfg.threads);
        let strategy = instantiate(cfg.strategy, cfg.n_workers, cfg.seed, pool.clone());
        let mut t = Trainer::with_parts(
            cfg,
            Box::new(HostMlp::default_preset(11)),
            strategy,
            Vec::new(),
            pool,
            Box::new(StaticController),
        );
        // The old trainer owned the gain tracker (threshold from the
        // adaptive config) and started at c_high.
        let mut tracker = GainTracker::new(a.gain_threshold);
        t.cur_cr = a.c_high;
        let mut legacy = LegacyAdaptiveState::new(a);
        for _ in 0..steps {
            let epoch = t.epoch();
            let (obs, net_changed) = t.probe.measure_and_detect(epoch);
            let m = t.step_once(true, obs.link());
            let gain_fired = tracker.record(m.gain);
            legacy.maybe_adapt(&mut t, &mut tracker, net_changed, gain_fired, obs.link());
        }
        assert!(legacy.explorations >= 1, "the pin scenario must explore");
        t
    }

    /// THE PIN: on the C2 adaptive scenario the ported `moo` controller
    /// reproduces the pre-refactor run bitwise — parameters, per-step
    /// loss/CR trajectory, simulated times and exploration overhead.
    #[test]
    fn moo_controller_reproduces_the_legacy_adaptive_run_bitwise() {
        let steps = 60;
        let legacy = legacy_run(adaptive_cfg(NetSchedule::c2(4.0), steps), steps);
        let mut ported = adaptive_trainer(NetSchedule::c2(4.0), steps);
        ported.run();

        assert_eq!(legacy.params().len(), ported.params().len());
        for (i, (a, b)) in legacy.params().iter().zip(ported.params()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "param {i}: {a} vs {b}");
        }
        assert_eq!(legacy.metrics().steps.len(), ported.metrics().steps.len());
        for (a, b) in legacy.metrics().steps.iter().zip(&ported.metrics().steps) {
            let s = a.step;
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {s}: loss");
            assert_eq!(a.cr.to_bits(), b.cr.to_bits(), "step {s}: cr");
            assert_eq!(a.t_sync.to_bits(), b.t_sync.to_bits(), "step {s}: t_sync");
            assert_eq!(a.t_compute.to_bits(), b.t_compute.to_bits(), "step {s}: t_compute");
            assert_eq!(a.gain.to_bits(), b.gain.to_bits(), "step {s}: gain");
            assert_eq!(a.collective, b.collective, "step {s}: collective");
        }
        assert_eq!(legacy.cur_cr().to_bits(), ported.cur_cr().to_bits(), "final cr");
        assert_eq!(
            legacy.explore_overhead_s().to_bits(),
            ported.explore_overhead_s().to_bits(),
            "exploration overhead accounting"
        );
    }
}
