//! GraVAC-style threshold-ladder CR controller (Tyagi & Swany, *GraVAC:
//! Adaptive Compression for Communication-Efficient Distributed DL
//! Training*, 2023).
//!
//! Where the paper's `moo` controller re-profiles the whole candidate
//! ladder under checkpoint/restore and re-solves an NSGA-II problem on
//! every trigger, GraVAC's insight is that the compression *gain* signal
//! alone is enough to steer the ratio: keep compressing harder while the
//! smoothed gain holds up, back off one rung the moment a descent
//! collapses it. No exploration, no checkpoints, no MOO solves — and
//! because the gain is a pure function of the simulated exchange, a
//! gravac run stays **bitwise thread-invariant** (DESIGN.md §7), which no
//! measured-time controller can promise.
//!
//! Walk rules (all judged on the EWMA-smoothed gain, once per
//! `patience`-step settle window):
//! * **descend** (`"ladder-descend"`): the current rung has settled and
//!   the rung below is not blocked → step the CR down one geometric rung.
//! * **collapse** (`"gain-collapse"`): the settled gain fell more than
//!   `gain_drop` below the rung above's settled gain → climb back up and
//!   block deeper rungs.
//! * **network change**: unblocks the ladder (the compute/communication
//!   trade moved, deeper rungs deserve a retrial). The CR itself is not
//!   touched — the next judgements re-walk the ladder.

use super::{ControlAction, ControlCtx, ControlDecision, Controller};
use crate::moo::problem::candidate_crs;
use crate::util::stats::Ewma;

/// GraVAC ladder configuration. The ladder itself is the same geometric
/// `candidate_crs(c_low, c_high, factor)` the MOO controller probes —
/// rung 0 is `c_high`, the last rung is `c_low`.
#[derive(Debug, Clone)]
pub struct GravacConfig {
    pub c_low: f64,
    pub c_high: f64,
    /// Geometric step between rungs.
    pub factor: f64,
    /// Relative smoothed-gain drop (vs the rung above) that aborts a
    /// descent (0.25 = a quarter of the signal lost).
    pub gain_drop: f64,
    /// Recorded steps to settle at a rung before judging it.
    pub patience: u64,
}

impl Default for GravacConfig {
    fn default() -> Self {
        GravacConfig { c_low: 0.001, c_high: 0.1, factor: 3.0, gain_drop: 0.25, patience: 8 }
    }
}

/// The threshold-ladder controller.
#[derive(Debug)]
pub struct GravacController {
    cfg: GravacConfig,
    /// Descending CRs, rung 0 = `c_high`.
    ladder: Vec<f64>,
    rung: usize,
    /// Settled (judged) smoothed gain per rung, refreshed at every
    /// judgement of that rung.
    judged: Vec<Option<f64>>,
    /// Rungs at and below this index are blocked after a collapse, until
    /// a network change unblocks them.
    blocked_from: Option<usize>,
    steps_at_rung: u64,
    ewma: Ewma,
    /// Ladder moves taken (observability/tests).
    pub moves: u64,
}

impl GravacController {
    pub fn new(cfg: GravacConfig) -> Self {
        let ladder = candidate_crs(cfg.c_low, cfg.c_high, cfg.factor);
        let judged = vec![None; ladder.len()];
        GravacController {
            cfg,
            ladder,
            rung: 0,
            judged,
            blocked_from: None,
            steps_at_rung: 0,
            ewma: Ewma::new(0.2),
            moves: 0,
        }
    }

    /// Current rung's CR (tests/observability).
    pub fn current_cr(&self) -> f64 {
        self.ladder[self.rung]
    }

    fn decide(&mut self, rung: usize, reason: &'static str) -> ControlDecision {
        self.rung = rung;
        self.steps_at_rung = 0;
        // Fresh smoothing window per rung: without the reset, ~alpha-
        // complement^patience of every judgement would still be the
        // PREVIOUS rung's gain, biasing collapse detection low near the
        // threshold and compounding down the ladder.
        self.ewma.reset();
        self.moves += 1;
        ControlDecision {
            by: "gravac",
            reason,
            action: ControlAction::SetCr(self.ladder[rung]),
        }
    }
}

impl Controller for GravacController {
    fn name(&self) -> &'static str {
        "gravac"
    }

    fn adapts_cr(&self) -> bool {
        true
    }

    /// Like the paper's controller, start at the ladder top (`c_high`).
    fn initial_cr(&self) -> Option<f64> {
        Some(self.cfg.c_high)
    }

    fn observe(&mut self, ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
        if !ctx.compressed {
            return Vec::new();
        }
        let smoothed = self.ewma.update(ctx.metrics.gain);
        self.steps_at_rung += 1;
        if ctx.net_changed {
            // The trade moved: deeper rungs deserve a retrial.
            self.blocked_from = None;
        }
        if self.steps_at_rung < self.cfg.patience {
            return Vec::new();
        }
        // Judgement point: at most one ladder move, then re-settle.
        self.steps_at_rung = 0;
        self.judged[self.rung] = Some(smoothed);
        let collapsed = self.rung > 0
            && self.judged[self.rung - 1]
                .is_some_and(|above| smoothed < above * (1.0 - self.cfg.gain_drop));
        if collapsed {
            // This rung costs too much signal: climb back, block
            // everything at and below it until the network moves.
            self.blocked_from = Some(self.rung);
            let up = self.rung - 1;
            return vec![self.decide(up, "gain-collapse")];
        }
        let next = self.rung + 1;
        let blocked = self.blocked_from.is_some_and(|b| next >= b);
        if next < self.ladder.len() && !blocked {
            return vec![self.decide(next, "ladder-descend")];
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveKind;
    use crate::coordinator::metrics::StepMetrics;
    use crate::netsim::cost_model::LinkParams;

    fn ctx(m: &StepMetrics, net_changed: bool) -> ControlCtx<'_> {
        ControlCtx {
            metrics: m,
            net_changed,
            probed: LinkParams::from_ms_gbps(4.0, 20.0),
            cur_cr: 0.1,
            model_bytes: 4e6,
            n_workers: 4,
            compressed: true,
            straggler_factor: 1.0,
            active_workers: 4,
        }
    }

    fn metrics_with_gain(step: u64, gain: f64) -> StepMetrics {
        StepMetrics {
            step,
            epoch: step as f64 / 10.0,
            loss: 0.5,
            t_compute: 0.01,
            t_comp: 0.0,
            t_sync: 0.02,
            collective: CollectiveKind::ArTopkRing,
            cr: 0.1,
            selected_rank: Some(0),
            gain,
            alpha_ms: 4.0,
            bw_gbps: 20.0,
        }
    }

    fn drive(c: &mut GravacController, steps: u64, gain: f64) -> Vec<ControlDecision> {
        let mut out = Vec::new();
        for s in 0..steps {
            let m = metrics_with_gain(s, gain);
            out.extend(c.observe(&ctx(&m, false)));
        }
        out
    }

    #[test]
    fn descends_the_ladder_while_gain_holds() {
        let mut c = GravacController::new(GravacConfig::default());
        assert_eq!(c.initial_cr(), Some(0.1));
        // Stable high gain: one descend per patience window until c_low.
        let rungs = c.ladder.len();
        let ds = drive(&mut c, 8 * rungs as u64, 0.9);
        assert_eq!(ds.len(), rungs - 1, "{ds:?}");
        assert!(ds.iter().all(|d| d.reason == "ladder-descend"));
        assert!((c.current_cr() - 0.001).abs() < 1e-12, "bottom rung reached");
        // At the bottom with stable gain: no further decisions.
        assert!(drive(&mut c, 20, 0.9).is_empty());
    }

    #[test]
    fn collapse_climbs_back_and_blocks_until_net_change() {
        let mut c = GravacController::new(GravacConfig::default());
        // Settle rung 0 at high gain, descend once.
        let ds = drive(&mut c, 8, 0.9);
        assert_eq!(ds.len(), 1);
        let rung1_cr = c.current_cr();
        // Rung 1 collapses the gain (< 0.9 * 0.75): climb back to rung 0.
        let ds = drive(&mut c, 8, 0.3);
        assert_eq!(ds.len(), 1, "{ds:?}");
        assert_eq!(ds[0].reason, "gain-collapse");
        assert_eq!(ds[0].action, ControlAction::SetCr(0.1));
        // Blocked: stable gain at rung 0 no longer descends...
        assert!(drive(&mut c, 24, 0.9).is_empty());
        // ...until the network changes, which unblocks the ladder.
        let m = metrics_with_gain(0, 0.9);
        let _ = c.observe(&ctx(&m, true));
        let ds = drive(&mut c, 8, 0.9);
        assert_eq!(ds.len(), 1, "net change must re-enable descents: {ds:?}");
        assert_eq!(ds[0].reason, "ladder-descend");
        assert_eq!(c.current_cr(), rung1_cr);
    }

    #[test]
    fn uncompressed_context_is_ignored() {
        let mut c = GravacController::new(GravacConfig::default());
        let m = metrics_with_gain(0, 0.9);
        for _ in 0..30 {
            let mut cx = ctx(&m, false);
            cx.compressed = false;
            assert!(c.observe(&cx).is_empty());
        }
        assert_eq!(c.moves, 0);
    }
}
