//! Shared experiment-harness plumbing: the standard "paper-proxy" training
//! configuration, diff-table assembly (Tables III/IV/V layout), and the
//! paper's model-size registry for cost experiments.
//!
//! Every `examples/table*`/`examples/fig*` binary builds on these helpers
//! so the rows they print line up with the paper's tables 1:1.

use crate::artopk::SelectionPolicy;
use crate::coordinator::controller::{AdaptiveConfig, CONTROLLER_TABLE};
use crate::coordinator::selector;
use crate::coordinator::session::{Session, TrainReport};
use crate::coordinator::trainer::{CrControl, Strategy, TrainConfig};
use crate::coordinator::worker::ComputeModel;
use crate::netsim::cost_model::{self, LinkParams, Topology};
use crate::netsim::model::{NetworkModel, NET_TABLE};
use crate::netsim::schedule::NetSchedule;
use crate::runtime::host_model::HostMlp;
use crate::util::table::{fmt_ms, Table};

/// The paper's four evaluation DNNs with their parameter counts — the `M`
/// in every cost experiment (Tables II/VI, Figs 1/5).
pub const PAPER_MODELS: [(&str, f64); 4] = [
    ("ResNet18", 11.7e6),
    ("ResNet50", 25.6e6),
    ("AlexNet", 61.1e6),
    ("ViT", 86.6e6),
];

/// Paper-measured compute times per step (Fig 1a, 8xV100, ms) — used to
/// parameterize the simulated `t_compute` so step-time tables have the
/// paper's compute:communication proportions.
pub const PAPER_COMPUTE_MS: [(&str, f64); 4] = [
    ("ResNet18", 30.0),
    ("ResNet50", 65.0),
    ("AlexNet", 25.0),
    ("ViT", 110.0),
];

/// Accelerator-vs-host compression throughput ratio: the paper compresses
/// on V100s; this host compresses on one CPU core. Top-k/threshold scans
/// are memory-bandwidth-bound, and a V100's ~900 GB/s HBM vs ~25-45 GB/s
/// single-core stream puts the ratio at 20-35x; we use the conservative
/// low end. Applied by proxy harnesses as comp_scale = msg_scale / this.
pub const GPU_COMPRESS_SPEEDUP: f64 = 20.0;

/// Intra-node link of the two-level topology presets: NVLink/PCIe-class
/// (10 µs, 100 Gbps) — effectively free next to any WAN/TCP inter link.
pub fn intra_nvlink() -> LinkParams {
    LinkParams::from_ms_gbps(0.01, 100.0)
}

/// Named cluster topologies for the per-topology crossover tables: the flat
/// single-link cluster every original experiment assumed, plus two-level
/// layouts (2 nodes × 4 ranks, 4 nodes × 2 ranks) sharing the same
/// bottleneck `inter` link. All presets keep 8 total ranks so rows are
/// directly comparable with the paper's N=8 tables.
pub fn topology_presets(inter: LinkParams) -> Vec<(&'static str, Topology)> {
    vec![
        ("flat 1x8", Topology::flat(inter)),
        ("2 nodes x4", Topology::two_level(intra_nvlink(), inter, 4)),
        ("4 nodes x2", Topology::two_level(intra_nvlink(), inter, 2)),
    ]
}

/// One row of the dense-collective crossover table: closed-form costs (ms)
/// of every dense allreduce on one topology, and the selector's pick.
#[derive(Debug, Clone)]
pub struct DenseCrossoverRow {
    pub topology: String,
    pub ring_ms: f64,
    pub tree_ms: f64,
    pub hd_ms: f64,
    /// None on flat topologies (the op degenerates to ring).
    pub hier_ms: Option<f64>,
    pub chosen: &'static str,
}

/// Dense AR crossover per topology for an `m_bytes` tensor on `n` ranks —
/// the data behind the "optimal collective flips with topology" claim
/// (Agarwal et al.; ISSUE 1 tentpole).
pub fn dense_crossover_rows(
    presets: &[(&str, Topology)],
    m_bytes: f64,
    n: usize,
) -> Vec<DenseCrossoverRow> {
    presets
        .iter()
        .map(|(name, topo)| {
            let l = topo.inter;
            let hier = if topo.is_flat() {
                None
            } else {
                Some(cost_model::hierarchical_allreduce(*topo, m_bytes, n) * 1e3)
            };
            DenseCrossoverRow {
                topology: name.to_string(),
                ring_ms: cost_model::ring_allreduce(l, m_bytes, n) * 1e3,
                tree_ms: cost_model::tree_allreduce(l, m_bytes, n) * 1e3,
                hd_ms: cost_model::halving_doubling_allreduce(l, m_bytes, n) * 1e3,
                hier_ms: hier,
                chosen: selector::choose_dense_topo(*topo, m_bytes, n).kind.name(),
            }
        })
        .collect()
}

/// The Eqn 5 AG-vs-AR decision across bottleneck-link qualities: compressed
/// collectives run rank-flat over the topology's inter link (the intra side
/// never carries the compressed exchange), so their crossover is a function
/// of that single link — sweep it to see the pick move. Returns
/// `(link label, cr, chosen collective)` per link × CR.
pub fn compressed_crossover(
    inter_links: &[(&str, LinkParams)],
    m_bytes: f64,
    n: usize,
    crs: &[f64],
) -> Vec<(String, f64, &'static str)> {
    let mut out = Vec::new();
    for (name, link) in inter_links {
        for &cr in crs {
            let chosen = cost_model::optimal_collective(*link, m_bytes, n, cr).name();
            out.push((name.to_string(), cr, chosen));
        }
    }
    out
}

/// One row of the scenario-registry sweep: how a network environment
/// ranges over a run, and which compressed collectives the Eqn 5 decider
/// picks across it — the "scenario diversity drives strategy diversity"
/// view (GraVAC-style evaluations sweep exactly this axis).
#[derive(Debug, Clone)]
pub struct ScenarioRow {
    /// Registry name.
    pub name: &'static str,
    /// Full identity ([`NetworkModel::describe`](crate::netsim::model::NetworkModel::describe)).
    pub describe: String,
    pub alpha_ms_range: (f64, f64),
    pub bw_gbps_range: (f64, f64),
    /// Distinct collectives chosen over the sampled epochs, in first-seen
    /// order.
    pub collectives: Vec<&'static str>,
}

/// Sweep every [`NET_TABLE`] scenario: sample each environment across
/// `total_epochs` and record the link range plus the Eqn 5 pick per
/// sample ([`cost_model::optimal_collective`]) for an `m_bytes` tensor on
/// `n` ranks at compression ratio `cr`.
pub fn scenario_rows(total_epochs: f64, m_bytes: f64, n: usize, cr: f64) -> Vec<ScenarioRow> {
    const SAMPLES: usize = 60;
    NET_TABLE
        .iter()
        .map(|s| {
            let model = (s.build)(total_epochs);
            let (mut a_lo, mut a_hi) = (f64::INFINITY, f64::NEG_INFINITY);
            let (mut b_lo, mut b_hi) = (f64::INFINITY, f64::NEG_INFINITY);
            let mut collectives: Vec<&'static str> = Vec::new();
            for i in 0..SAMPLES {
                let epoch = total_epochs * i as f64 / SAMPLES as f64;
                let l = model.link_at(epoch);
                a_lo = a_lo.min(l.alpha_ms());
                a_hi = a_hi.max(l.alpha_ms());
                b_lo = b_lo.min(l.bw_gbps());
                b_hi = b_hi.max(l.bw_gbps());
                let pick = cost_model::optimal_collective(l, m_bytes, n, cr).name();
                if !collectives.contains(&pick) {
                    collectives.push(pick);
                }
            }
            ScenarioRow {
                name: s.name,
                describe: model.describe(),
                alpha_ms_range: (a_lo, a_hi),
                bw_gbps_range: (b_lo, b_hi),
                collectives,
            }
        })
        .collect()
}

/// Print the [`scenario_rows`] sweep in table form.
pub fn print_scenario_sweep(total_epochs: f64, m_bytes: f64, n: usize, cr: f64) {
    let mut t = Table::new(["scenario", "alpha (ms)", "bw (Gbps)", "Eqn 5 picks"]);
    for r in scenario_rows(total_epochs, m_bytes, n, cr) {
        t.row([
            r.describe,
            format!("{:.1}-{:.1}", r.alpha_ms_range.0, r.alpha_ms_range.1),
            format!("{:.1}-{:.1}", r.bw_gbps_range.0, r.bw_gbps_range.1),
            r.collectives.join(", "),
        ]);
    }
    t.print();
}

/// One row of the controller-comparison sweep (ISSUE 5): which adaptation
/// policy, what it cost, what it reached.
#[derive(Debug, Clone)]
pub struct ControllerRow {
    /// Row label (`static cr=0.01`, `gravac`, `moo`, ...).
    pub label: String,
    /// Controller identity from the report.
    pub controller: String,
    pub best_acc: f64,
    pub final_cr: f64,
    /// Simulated cluster seconds for the whole run.
    pub virtual_time_s: f64,
    /// Simulated seconds burned in checkpointed exploration.
    pub explore_overhead_s: f64,
    /// Simulated seconds until the first eval reaching `target_acc`
    /// (`None` = never reached), INCLUDING the run's checkpointed
    /// exploration overhead — the GraVAC-style time-to-accuracy metric
    /// the controller comparison ranks by. A cluster really pays for
    /// exploration, so a metric that excluded it would systematically
    /// flatter exploring controllers in the very sweep built to compare
    /// them fairly.
    pub time_to_target_s: Option<f64>,
}

/// Simulated seconds until the first held-out eval with accuracy >=
/// `target`: the cumulative recorded `t_step` up to that eval PLUS the
/// run's exploration overhead. Per-step exploration attribution is not
/// recorded, so the WHOLE overhead is charged — exact for non-exploring
/// controllers (overhead 0) and an upper bound for exploring ones (the
/// `moo` warmup exploration fires on the first step, well before any
/// target is reached, so the bound is tight in practice).
pub fn time_to_accuracy(r: &TrainReport, target: f64, steps_per_epoch: u64) -> Option<f64> {
    let mut cum = Vec::with_capacity(r.metrics.steps.len());
    let mut acc_t = 0.0;
    for m in &r.metrics.steps {
        acc_t += m.t_step();
        cum.push(acc_t);
    }
    for &(epoch, _, acc) in &r.metrics.evals {
        if acc >= target {
            let idx = ((epoch * steps_per_epoch as f64).round() as usize).min(cum.len());
            let stepped = if idx == 0 { 0.0 } else { cum[idx - 1] };
            return Some(stepped + r.explore_overhead_s);
        }
    }
    None
}

/// The controller-comparison sweep: the SAME model (host MLP), network
/// scenario and strategy (`flexible`) under every adaptation policy —
/// static low CR, static high CR, plus every non-static
/// [`CONTROLLER_TABLE`] entry (gravac, moo, and any future registration
/// joins automatically). This is the experiment the control-plane seam
/// exists for: GraVAC and Agarwal et al. both show the winner is
/// workload/network-dependent, so the repo must be able to print this
/// table for any scenario.
pub fn controller_rows(
    scenario: &str,
    steps: u64,
    seed: u64,
    target_acc: f64,
) -> anyhow::Result<Vec<ControllerRow>> {
    let spe = (steps / 8).max(1);
    let mut runs: Vec<(String, CrControl, &str)> = vec![
        ("static cr=0.01".into(), CrControl::Static(0.01), "static"),
        ("static cr=0.10".into(), CrControl::Static(0.1), "static"),
    ];
    for e in CONTROLLER_TABLE.iter().filter(|e| e.name != "static") {
        // Short probe windows keep the sweep's exploration cost sane at
        // smoke step counts; bounds stay the paper's ladder.
        runs.push((
            e.name.to_string(),
            CrControl::Adaptive(AdaptiveConfig { probe_iters: 3, seed, ..Default::default() }),
            e.name,
        ));
    }
    let mut out = Vec::new();
    for (label, cr, spec) in runs {
        let cfg = TrainConfig {
            n_workers: 4,
            steps,
            steps_per_epoch: spe,
            lr: 0.3,
            momentum: 0.6,
            strategy: Strategy::Flexible { policy: SelectionPolicy::Star },
            cr,
            compute: ComputeModel::fixed(0.005),
            eval_every: spe,
            seed,
            ..Default::default()
        };
        let report = Session::from_config(cfg)
            .network_spec(scenario)
            .controller_spec(spec)
            .source(Box::new(HostMlp::default_preset(seed)))
            .build()?
            .run();
        out.push(ControllerRow {
            label,
            controller: report.controller.clone(),
            best_acc: report.best_accuracy().unwrap_or(f64::NAN),
            final_cr: report.final_cr,
            virtual_time_s: report.virtual_time_s,
            explore_overhead_s: report.explore_overhead_s,
            time_to_target_s: time_to_accuracy(&report, target_acc, spe),
        });
    }
    Ok(out)
}

/// Print the [`controller_rows`] sweep in the time-to-accuracy layout.
pub fn print_controller_sweep(scenario: &str, rows: &[ControllerRow], target_acc: f64) {
    println!(
        "\n== controller comparison on `{scenario}` (target acc {:.0}%) ==",
        target_acc * 100.0
    );
    let mut t = Table::new([
        "controller",
        "best acc",
        "final cr",
        "virtual time (s)",
        "explore (s)",
        "t-to-target (s)",
    ]);
    for r in rows {
        t.row([
            r.label.clone(),
            format!("{:.2}%", r.best_acc * 100.0),
            format!("{:.4}", r.final_cr),
            format!("{:.2}", r.virtual_time_s),
            format!("{:.2}", r.explore_overhead_s),
            r.time_to_target_s.map_or("-".to_string(), |s| format!("{s:.2}")),
        ]);
    }
    t.print();
}

/// Standard proxy-training config: 8 workers on a 4 ms / 20 Gbps link
/// (the Tables III/IV/V setting).
pub fn proxy_cfg(strategy: Strategy, cr: CrControl, steps: u64, seed: u64) -> TrainConfig {
    TrainConfig {
        n_workers: 8,
        steps,
        steps_per_epoch: steps / 10,
        lr: 0.2,
        momentum: 0.9,
        weight_decay: 0.0005,
        lr_decay: vec![(steps * 6 / 10, 0.1)],
        strategy,
        cr,
        net: Box::new(NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))),
        compute: ComputeModel::with_jitter(0.030, 0.05),
        probe_noise: 0.02,
        msg_scale: 1.0,
        comp_scale: 1.0,
        eval_every: (steps / 20).max(1),
        seed,
        threads: 0, // all cores; bitwise-identical to threads = 1 (static CR)
    }
}

/// Run one table row on the hard host-MLP proxy; returns the report for
/// further inspection (gain curves, rank densities, ...).
pub fn run_proxy(mut cfg: TrainConfig, seed: u64) -> TrainReport {
    cfg.seed = seed;
    let src = Box::new(HostMlp::hard_preset(seed));
    Session::from_config(cfg)
        .source(src)
        .build()
        .expect("proxy config valid")
        .run()
}

/// One row of a Tables III/IV/V-style comparison.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub method: String,
    pub t_step_ms: f64,
    pub accuracy: f64,
}

/// Print the paper's `Method | t_step | Acc | Diff` layout, with diff
/// computed against the first (baseline) row.
pub fn print_diff_table(title: &str, rows: &[DiffRow]) {
    println!("\n== {title} ==");
    assert!(!rows.is_empty());
    let base = rows[0].accuracy;
    let mut t = Table::new(["Method", "t_step (ms)", "Acc.", "Diff."]);
    for r in rows {
        t.row([
            r.method.clone(),
            fmt_ms(r.t_step_ms / 1e3),
            format!("{:.2}%", r.accuracy * 100.0),
            format!("{:+.2}%", (r.accuracy - base) * 100.0),
        ]);
    }
    t.print();
}

/// Row from a finished run.
pub fn diff_row(method: impl Into<String>, r: &TrainReport) -> DiffRow {
    let s = r.summary();
    DiffRow {
        method: method.into(),
        t_step_ms: s.mean_step_s * 1e3,
        accuracy: r.best_accuracy().unwrap_or(f64::NAN),
    }
}

/// Write a CSV file, creating parent dirs; returns the path for logging.
pub fn write_csv(path: &str, content: &str) -> anyhow::Result<String> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    Ok(path.to_string())
}

/// Render a labelled KDE as a terminal sparkline block (our "figure").
pub fn print_kde(label: &str, samples: &[f64], lo: f64, hi: f64) {
    let k = crate::util::stats::kde(samples, lo, hi, 60);
    println!("{label:<24} {}", crate::util::stats::sparkline(&k.density));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artopk::{ArFlavor, SelectionPolicy};

    #[test]
    fn proxy_cfg_matches_paper_setting() {
        let cfg = proxy_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            CrControl::Static(0.01),
            100,
            0,
        );
        assert_eq!(cfg.n_workers, 8);
        let l = cfg.net.link_at(0.0);
        assert!((l.alpha_ms() - 4.0).abs() < 1e-9);
        assert!((l.bw_gbps() - 20.0).abs() < 1e-9);
    }

    /// The registry sweep: every scenario yields sane link ranges, and the
    /// unpredictable ones move the Eqn 5 decision — the paper's premise
    /// (one fixed collective cannot be optimal across environments) in
    /// table form.
    #[test]
    fn scenario_sweep_covers_the_registry_and_moves_the_decision() {
        let rows = scenario_rows(50.0, 4.0 * 25.6e6, 8, 0.01);
        assert_eq!(rows.len(), NET_TABLE.len());
        let mut multi_pick = 0;
        for r in &rows {
            assert!(r.alpha_ms_range.0 > 0.0 && r.alpha_ms_range.1.is_finite(), "{r:?}");
            assert!(r.bw_gbps_range.0 > 0.0 && r.bw_gbps_range.1.is_finite(), "{r:?}");
            assert!(!r.collectives.is_empty(), "{r:?}");
            if r.collectives.len() >= 2 {
                multi_pick += 1;
            }
        }
        // C1/C2 swing between regimes, so the chosen collective must flip
        // within at least some scenarios.
        assert!(multi_pick >= 2, "{rows:?}");
        // Doesn't panic; eyeball-checked in examples.
        print_scenario_sweep(50.0, 4.0 * 25.6e6, 8, 0.01);
    }

    #[test]
    fn paper_registry_sane() {
        assert_eq!(PAPER_MODELS.len(), 4);
        assert!(PAPER_MODELS.windows(2).all(|w| w[0].1 < w[1].1));
    }

    #[test]
    fn topology_presets_share_the_bottleneck() {
        let inter = LinkParams::from_ms_gbps(10.0, 1.0);
        let presets = topology_presets(inter);
        assert_eq!(presets.len(), 3);
        assert!(presets[0].1.is_flat());
        for (_, t) in &presets {
            assert_eq!(t.inter, inter);
            assert_eq!(8 % t.workers_per_node, 0, "presets must tile N=8");
        }
    }

    /// The tentpole claim in table form: on a flat cluster HD-AR wins the
    /// dense crossover; make the inter link asymmetric-slow and the same
    /// model/N flips to Hier-AR.
    #[test]
    fn dense_crossover_flips_with_topology() {
        let presets = topology_presets(LinkParams::from_ms_gbps(10.0, 1.0));
        let rows = dense_crossover_rows(&presets, 4.0 * 25.6e6, 8);
        assert_eq!(rows[0].chosen, "HD-AR");
        assert_eq!(rows[0].hier_ms, None);
        for row in &rows[1..] {
            assert_eq!(row.chosen, "Hier-AR", "{}", row.topology);
            let hier = row.hier_ms.expect("two-level row has a Hier-AR cost");
            assert!(hier < row.ring_ms && hier < row.hd_ms);
        }
    }

    #[test]
    fn compressed_crossover_moves_with_link_quality() {
        let links = [
            ("lan", LinkParams::from_ms_gbps(1.0, 10.0)),
            ("wan", LinkParams::from_ms_gbps(50.0, 1.0)),
        ];
        let rows = compressed_crossover(&links, 4.0 * 25.6e6, 8, &[0.1, 0.001]);
        assert_eq!(rows.len(), 4);
        let pick = |name: &str, cr: f64| {
            rows.iter()
                .find(|(l, c, _)| l == name && *c == cr)
                .map(|(_, _, chosen)| *chosen)
                .unwrap()
        };
        // At CR 0.1 the AR flavour flips with the link (Eqn 5a): ring on
        // the low-latency LAN, tree on the high-latency WAN. Tiny CRs stay
        // with AG on both.
        assert_eq!(pick("lan", 0.1), "ART-Ring");
        assert_eq!(pick("wan", 0.1), "ART-Tree");
        assert_eq!(pick("lan", 0.001), "AG");
        assert_eq!(pick("wan", 0.001), "AG");
    }

    /// The controller sweep covers the whole registry (2 static rows +
    /// every non-static entry), runs end-to-end on a registry scenario,
    /// and produces sane numbers — a panicking or unregistered controller
    /// fails here (and in the verify-gate smoke) loudly.
    #[test]
    fn controller_sweep_covers_the_registry() {
        let rows = controller_rows("c2", 24, 7, 0.99).expect("sweep runs");
        let non_static = CONTROLLER_TABLE.iter().filter(|e| e.name != "static").count();
        assert_eq!(rows.len(), 2 + non_static, "{rows:?}");
        for r in &rows {
            assert!(r.best_acc.is_finite() && r.best_acc > 0.0, "{r:?}");
            assert!(r.virtual_time_s > 0.0, "{r:?}");
            assert!(r.final_cr > 0.0 && r.final_cr <= 1.0, "{r:?}");
        }
        // Static rows never explore; the moo row must have (it has no
        // profiles at step 0).
        assert_eq!(rows[0].explore_overhead_s, 0.0);
        let moo = rows.iter().find(|r| r.label == "moo").expect("moo row");
        assert!(moo.explore_overhead_s > 0.0, "{moo:?}");
        // Unreachable target -> no time-to-target; renders as '-'.
        assert!(rows.iter().all(|r| r.time_to_target_s.is_none()));
        print_controller_sweep("c2", &rows, 0.99);
    }

    #[test]
    fn time_to_accuracy_maps_evals_onto_the_step_clock() {
        let rows = controller_rows("c1", 16, 3, 0.0).expect("sweep runs");
        // Target 0 is reached at the FIRST eval: time-to-target equals
        // the cumulative step time up to that eval plus the exploration
        // overhead the controller burned — positive, at most the whole
        // run's simulated cost, and charging moo's checkpointed probing
        // (a non-exploring row's bound is the bare virtual time).
        for r in &rows {
            let t = r.time_to_target_s.expect("target 0 always reached");
            assert!(
                t > 0.0 && t <= r.virtual_time_s + r.explore_overhead_s + 1e-9,
                "{r:?}"
            );
            if r.explore_overhead_s > 0.0 {
                assert!(t > r.explore_overhead_s, "{r:?}");
            }
        }
    }

    #[test]
    fn diff_table_renders() {
        let rows = vec![
            DiffRow { method: "DenseSGD".into(), t_step_ms: 98.7, accuracy: 0.908 },
            DiffRow { method: "LWTopk 0.1".into(), t_step_ms: 62.0, accuracy: 0.9015 },
        ];
        // Shouldn't panic; eyeball-checked in examples.
        print_diff_table("smoke", &rows);
    }
}
