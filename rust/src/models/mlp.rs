//! A small first-party reverse-mode-autograd MLP ([`MlpSource`]) — the
//! repo's first [`GradSource`] that *actually learns* a nonlinear task.
//!
//! The learner is deliberately tiny (a few hundred parameters) but real:
//! a scalar tape ([`Tape`]) records the forward pass of a tanh MLP and a
//! single reverse sweep produces exact gradients, micrograd-style. Two
//! in-crate deterministic datasets exercise both head types:
//!
//! * **two-spirals** (softmax-CE head, 2 classes) — the classic
//!   interleaved-arms task; linearly inseparable, so above-chance
//!   accuracy proves the hidden layers are doing work.
//! * **noisy sine** (MSE head, 1 output) — regression on
//!   `0.8·sin(3u) + η`; "accuracy" is the fraction of held-out points
//!   predicted within a fixed tolerance band.
//!
//! Everything is a pure function of `(seed, worker, n_workers, step)` —
//! per-batch RNGs are derived with the same splitmix-style mixing as
//! [`SyntheticGrad`](crate::runtime::host_model::SyntheticGrad) — so EF
//! residuals, compressors and whole-run replay stay bitwise
//! deterministic (DESIGN.md §7). Internally the tape is f64; the
//! [`GradSource`] boundary is the crate-wide flat `Vec<f32>`.

use std::f64::consts::PI;

use crate::coordinator::worker::GradSource;
use crate::tensor::Layout;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// The tape: scalar reverse-mode autograd.
// ---------------------------------------------------------------------------

/// One tape node: its forward value plus up to two `(parent, ∂self/∂parent)`
/// edges recorded at forward time. Leaves have zero parents.
#[derive(Clone, Copy)]
struct Node {
    parents: [(u32, f64); 2],
    n_parents: u8,
    val: f64,
}

/// Append-only scalar tape. The forward pass pushes nodes in topological
/// order, so one reverse sweep over the vec ([`Tape::backward`]) is a full
/// reverse-mode gradient — no graph object, no recursion.
struct Tape {
    nodes: Vec<Node>,
}

impl Tape {
    fn with_capacity(n: usize) -> Tape {
        Tape { nodes: Vec::with_capacity(n) }
    }

    fn val(&self, i: usize) -> f64 {
        self.nodes[i].val
    }

    fn leaf(&mut self, val: f64) -> usize {
        self.nodes.push(Node { parents: [(0, 0.0); 2], n_parents: 0, val });
        self.nodes.len() - 1
    }

    fn unary(&mut self, p: usize, val: f64, dp: f64) -> usize {
        self.nodes.push(Node { parents: [(p as u32, dp), (0, 0.0)], n_parents: 1, val });
        self.nodes.len() - 1
    }

    fn binary(&mut self, a: usize, b: usize, val: f64, da: f64, db: f64) -> usize {
        self.nodes.push(Node {
            parents: [(a as u32, da), (b as u32, db)],
            n_parents: 2,
            val,
        });
        self.nodes.len() - 1
    }

    fn add(&mut self, a: usize, b: usize) -> usize {
        self.binary(a, b, self.nodes[a].val + self.nodes[b].val, 1.0, 1.0)
    }

    fn sub(&mut self, a: usize, b: usize) -> usize {
        self.binary(a, b, self.nodes[a].val - self.nodes[b].val, 1.0, -1.0)
    }

    fn mul(&mut self, a: usize, b: usize) -> usize {
        let (va, vb) = (self.nodes[a].val, self.nodes[b].val);
        self.binary(a, b, va * vb, vb, va)
    }

    fn tanh(&mut self, a: usize) -> usize {
        let t = self.nodes[a].val.tanh();
        self.unary(a, t, 1.0 - t * t)
    }

    fn exp(&mut self, a: usize) -> usize {
        let e = self.nodes[a].val.exp();
        self.unary(a, e, e)
    }

    fn ln(&mut self, a: usize) -> usize {
        let v = self.nodes[a].val;
        self.unary(a, v.ln(), 1.0 / v)
    }

    /// `a + c` for a constant `c` (no gradient flows into the constant).
    fn add_const(&mut self, a: usize, c: f64) -> usize {
        self.unary(a, self.nodes[a].val + c, 1.0)
    }

    /// Reverse sweep from `out` (seeded with ∂out/∂out = 1). Returns the
    /// adjoint of every node; callers read off the leaf slots.
    fn backward(&self, out: usize) -> Vec<f64> {
        let mut adj = vec![0.0f64; self.nodes.len()];
        adj[out] = 1.0;
        for i in (0..=out).rev() {
            let g = adj[i];
            if g == 0.0 {
                continue;
            }
            let n = &self.nodes[i];
            for k in 0..n.n_parents as usize {
                let (p, d) = n.parents[k];
                adj[p as usize] += g * d;
            }
        }
        adj
    }
}

// ---------------------------------------------------------------------------
// Datasets + heads.
// ---------------------------------------------------------------------------

/// Which loss head (and therefore which dataset family) the MLP runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Head {
    /// Softmax cross-entropy over `out` logits; targets are class ids.
    Softmax,
    /// Scalar MSE; targets are real values, "accuracy" = within-band rate.
    Mse,
}

/// Tolerance band for the MSE head's within-band "accuracy" (the sine
/// target lives in `[-0.8, 0.8]`, so a chance predictor scores near zero).
const MSE_ACC_BAND: f64 = 0.2;

/// A reverse-mode-autograd tanh MLP over a deterministic in-crate dataset.
///
/// Construct via [`MlpSource::two_spirals`] (classification) or
/// [`MlpSource::noisy_sine`] (regression); both are rows of
/// [`MODEL_TABLE`](crate::models::MODEL_TABLE).
pub struct MlpSource {
    /// Layer widths, input first: e.g. `[2, 24, 16, 2]`.
    sizes: Vec<usize>,
    head: Head,
    tag: &'static str,
    layout: Layout,
    seed: u64,
    /// Per-worker per-step minibatch size.
    batch: usize,
    /// Input noise std (spirals) / target noise std (sine).
    noise: f32,
    /// Held-out eval batch, built lazily: (inputs flat, targets).
    eval_cache: Option<(Vec<f32>, Vec<f32>)>,
}

impl MlpSource {
    fn new(
        sizes: Vec<usize>,
        head: Head,
        tag: &'static str,
        seed: u64,
        batch: usize,
        noise: f32,
    ) -> Self {
        assert!(sizes.len() >= 2, "need at least input and output layers");
        let mut layer_sizes: Vec<(String, usize)> = Vec::new();
        for i in 1..sizes.len() {
            layer_sizes.push((format!("fc{}.w", i - 1), sizes[i - 1] * sizes[i]));
            layer_sizes.push((format!("fc{}.b", i - 1), sizes[i]));
        }
        let refs: Vec<(&str, usize)> =
            layer_sizes.iter().map(|(n, s)| (n.as_str(), *s)).collect();
        let layout = Layout::from_sizes(&refs);
        MlpSource { sizes, head, tag, layout, seed, batch, noise, eval_cache: None }
    }

    /// Two interleaved spiral arms, softmax-CE head, sizes `[2, 24, 16, 2]`.
    pub fn two_spirals(seed: u64) -> Self {
        MlpSource::new(vec![2, 24, 16, 2], Head::Softmax, "mlp-spirals", seed, 16, 0.06)
    }

    /// Noisy sine regression, MSE head, sizes `[1, 16, 16, 1]`.
    pub fn noisy_sine(seed: u64) -> Self {
        MlpSource::new(vec![1, 16, 16, 1], Head::Mse, "mlp-sine", seed, 16, 0.05)
    }

    fn in_features(&self) -> usize {
        self.sizes[0]
    }

    fn out_features(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Draw one (input, target) pair. Softmax: a point on spiral arm `c`
    /// with Gaussian jitter, target = class id. MSE: `u ∈ [-1, 1]`,
    /// target = `0.8·sin(3u) + η`.
    fn sample(&self, rng: &mut Rng, x: &mut Vec<f32>) -> f32 {
        match self.head {
            Head::Softmax => {
                let c = rng.below(2);
                let t = 0.3 + 0.7 * rng.f64();
                let th = t * 2.0 * PI + c as f64 * PI;
                x.push((t * th.sin()) as f32 + rng.normal_f32(0.0, self.noise));
                x.push((t * th.cos()) as f32 + rng.normal_f32(0.0, self.noise));
                c as f32
            }
            Head::Mse => {
                let u = 2.0 * rng.f64() - 1.0;
                x.push(u as f32);
                (0.8 * (3.0 * u).sin()) as f32 + rng.normal_f32(0.0, self.noise)
            }
        }
    }

    /// Deterministic minibatch for `(worker, step)` — same splitmix-style
    /// seed derivation as `SyntheticGrad`, so replay is bitwise.
    fn batch_for(&self, worker: usize, step: u64, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xA076_1D64_78BD_642F),
        );
        let mut x = Vec::with_capacity(batch * self.in_features());
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let t = self.sample(&mut rng, &mut x);
            y.push(t);
        }
        (x, y)
    }

    /// Tape forward for one sample: returns the output-logit node ids.
    /// `params` leaves occupy tape slots `0..dim()` (pushed by the caller),
    /// so leaf index == flat parameter index.
    fn forward_tape(&self, tape: &mut Tape, x: &[f32]) -> Vec<usize> {
        let mut acts: Vec<usize> = x.iter().map(|&v| tape.leaf(v as f64)).collect();
        let mut off = 0usize;
        for li in 1..self.sizes.len() {
            let (din, dout) = (self.sizes[li - 1], self.sizes[li]);
            let w_off = off;
            let b_off = off + din * dout;
            let mut next = Vec::with_capacity(dout);
            for o in 0..dout {
                // acc = b[o] + Σ_i w[o*din+i] * a[i]
                let mut acc = b_off + o; // bias leaf
                for (i, &a) in acts.iter().enumerate() {
                    let prod = tape.mul(w_off + o * din + i, a);
                    acc = tape.add(acc, prod);
                }
                // tanh on hidden layers, identity on the output layer.
                next.push(if li + 1 < self.sizes.len() { tape.tanh(acc) } else { acc });
            }
            acts = next;
            off = b_off + dout;
        }
        acts
    }

    /// Per-sample loss node from the logits and target.
    fn loss_tape(&self, tape: &mut Tape, logits: &[usize], target: f32) -> usize {
        match self.head {
            Head::Softmax => {
                // Stable log-sum-exp: subtracting the max as a CONSTANT
                // leaves the gradient (softmax) unchanged.
                let m = logits
                    .iter()
                    .map(|&l| tape.val(l))
                    .fold(f64::NEG_INFINITY, f64::max);
                let mut sum = None;
                for &l in logits {
                    let shifted = tape.add_const(l, -m);
                    let e = tape.exp(shifted);
                    sum = Some(match sum {
                        None => e,
                        Some(s) => tape.add(s, e),
                    });
                }
                let lse = tape.ln(sum.unwrap());
                let lse = tape.add_const(lse, m);
                tape.sub(lse, logits[target as usize])
            }
            Head::Mse => {
                let t = tape.leaf(target as f64);
                let e = tape.sub(logits[0], t);
                tape.mul(e, e)
            }
        }
    }

    /// Plain (tape-free) forward for eval.
    fn forward_plain(&self, params: &[f32], x: &[f32]) -> Vec<f64> {
        let mut acts: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut off = 0usize;
        for li in 1..self.sizes.len() {
            let (din, dout) = (self.sizes[li - 1], self.sizes[li]);
            let w = &params[off..off + din * dout];
            let b = &params[off + din * dout..off + din * dout + dout];
            let mut next = Vec::with_capacity(dout);
            for o in 0..dout {
                let mut acc = b[o] as f64;
                for (i, &a) in acts.iter().enumerate() {
                    acc += w[o * din + i] as f64 * a;
                }
                next.push(if li + 1 < self.sizes.len() { acc.tanh() } else { acc });
            }
            acts = next;
            off += din * dout + dout;
        }
        acts
    }

    /// Held-out loss/accuracy on one sample's plain-forward outputs.
    fn score(&self, out: &[f64], target: f32) -> (f64, bool) {
        match self.head {
            Head::Softmax => {
                let m = out.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                let lse = m + out.iter().map(|&z| (z - m).exp()).sum::<f64>().ln();
                let loss = lse - out[target as usize];
                let pred = out
                    .iter()
                    .enumerate()
                    .max_by(|a, b| crate::tensor::nan_min_cmp(*a.1, *b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                (loss, pred == target as usize)
            }
            Head::Mse => {
                let e = out[0] - target as f64;
                (e * e, e.abs() < MSE_ACC_BAND)
            }
        }
    }
}

impl GradSource for MlpSource {
    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    fn init_params(&mut self) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ 0x51AB_D00D);
        let mut p = vec![0.0f32; self.dim()];
        let mut off = 0usize;
        for li in 1..self.sizes.len() {
            let (din, dout) = (self.sizes[li - 1], self.sizes[li]);
            // Xavier-ish for tanh; biases stay zero.
            let std = (1.0 / din as f64).sqrt() as f32;
            rng.fill_normal(&mut p[off..off + din * dout], std);
            off += din * dout + dout;
        }
        p
    }

    fn grad(
        &self,
        params: &[f32],
        worker: usize,
        _n_workers: usize,
        step: u64,
    ) -> (f64, Vec<f32>) {
        let (x, y) = self.batch_for(worker, step, self.batch);
        let dim = self.dim();
        // One tape per batch: parameter leaves first (leaf index == flat
        // parameter index), then every sample's forward + loss, summed.
        // ~2 nodes per weight per sample (mul + add) plus activations/head.
        let mut tape = Tape::with_capacity(dim * (1 + 3 * self.batch));
        for &p in params {
            tape.leaf(p as f64);
        }
        let mut total = None;
        for s in 0..self.batch {
            let xi = &x[s * self.in_features()..(s + 1) * self.in_features()];
            let logits = self.forward_tape(&mut tape, xi);
            let loss = self.loss_tape(&mut tape, &logits, y[s]);
            total = Some(match total {
                None => loss,
                Some(t) => tape.add(t, loss),
            });
        }
        let total = total.expect("batch >= 1");
        let adj = tape.backward(total);
        let inv_b = 1.0 / self.batch as f64;
        let grad: Vec<f32> = adj[..dim].iter().map(|&g| (g * inv_b) as f32).collect();
        (tape.val(total) * inv_b, grad)
    }

    fn eval(&mut self, params: &[f32]) -> (f64, f64) {
        const EVAL_N: usize = 256;
        if self.eval_cache.is_none() {
            // Worker-independent held-out draw (disjoint from any training
            // batch's (worker, step) seed by the usize::MAX/2 convention).
            self.eval_cache = Some(self.batch_for(usize::MAX / 2, u64::MAX / 2, EVAL_N));
        }
        let (x, y) = self.eval_cache.as_ref().unwrap();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for s in 0..EVAL_N {
            let xi = &x[s * self.in_features()..(s + 1) * self.in_features()];
            let out = self.forward_plain(params, xi);
            let (l, ok) = self.score(&out, y[s]);
            loss += l;
            correct += ok as usize;
        }
        (loss / EVAL_N as f64, correct as f64 / EVAL_N as f64)
    }

    fn name(&self) -> String {
        format!("{}{:?}", self.tag, self.sizes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Central finite differences agree with the tape gradient — the
    /// autograd correctness pin (satellite: gradcheck vs FD).
    fn gradcheck(mut src: MlpSource) {
        let params = src.init_params();
        let (_, g) = src.grad(&params, 0, 2, 3);
        let dim = src.dim();
        let eps = 1e-3f32;
        for &i in &[0usize, 5, 17, dim / 2, dim - 1] {
            let mut p = params.clone();
            p[i] = params[i] + eps;
            let (lp, _) = src.grad(&p, 0, 2, 3);
            p[i] = params[i] - eps;
            let (lm, _) = src.grad(&p, 0, 2, 3);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let tol = 2e-2 * (1.0 + fd.abs());
            assert!(
                (g[i] as f64 - fd).abs() < tol,
                "{}: param {i}: autograd {} vs fd {fd}",
                src.name(),
                g[i]
            );
        }
    }

    #[test]
    fn spirals_gradcheck_vs_finite_differences() {
        gradcheck(MlpSource::two_spirals(7));
    }

    #[test]
    fn sine_gradcheck_vs_finite_differences() {
        gradcheck(MlpSource::noisy_sine(11));
    }

    #[test]
    fn grads_deterministic_and_vary_by_worker_and_step() {
        let mut src = MlpSource::two_spirals(5);
        let p = src.init_params();
        let (l1, g1) = src.grad(&p, 1, 4, 9);
        let (l2, g2) = src.grad(&p, 1, 4, 9);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
        let (_, g3) = src.grad(&p, 2, 4, 9);
        let (_, g4) = src.grad(&p, 1, 4, 10);
        assert_ne!(g1, g3, "worker shards must differ");
        assert_ne!(g1, g4, "steps must differ");
    }

    /// Momentum SGD on the tape gradients learns the spirals well above
    /// the 50% chance floor — the "actually learns" pin for the
    /// classification head.
    #[test]
    fn spirals_learn_with_momentum_sgd() {
        let mut src = MlpSource::two_spirals(1);
        let mut p = src.init_params();
        let (loss0, acc0) = src.eval(&p);
        let mut m = vec![0.0f32; p.len()];
        for step in 0..500u64 {
            let (_, g) = src.grad(&p, 0, 1, step);
            for i in 0..p.len() {
                m[i] = 0.9 * m[i] + g[i];
                p[i] -= 0.3 * m[i];
            }
        }
        let (loss1, acc1) = src.eval(&p);
        assert!(loss1 < loss0 * 0.8, "loss {loss0} -> {loss1}");
        assert!(acc1 > 0.75 && acc1 > acc0, "accuracy {acc0} -> {acc1}");
    }

    /// The MSE head fits the sine to within the accuracy band on most of
    /// the held-out points.
    #[test]
    fn sine_learns_with_momentum_sgd() {
        let mut src = MlpSource::noisy_sine(2);
        let mut p = src.init_params();
        let (loss0, _) = src.eval(&p);
        let mut m = vec![0.0f32; p.len()];
        for step in 0..500u64 {
            let (_, g) = src.grad(&p, 0, 1, step);
            for i in 0..p.len() {
                m[i] = 0.9 * m[i] + g[i];
                p[i] -= 0.1 * m[i];
            }
        }
        let (loss1, acc1) = src.eval(&p);
        assert!(loss1 < loss0 * 0.5, "loss {loss0} -> {loss1}");
        assert!(acc1 > 0.5, "within-band accuracy {acc1}");
    }

    #[test]
    fn layout_covers_dim_and_names_layers() {
        let src = MlpSource::two_spirals(0);
        assert_eq!(src.layout().total(), src.dim());
        assert_eq!(src.layout().num_layers(), 6); // 3 layers x (w, b)
        assert_eq!(src.layout().layers[0].name, "fc0.w");
        // [2,24,16,2]: 2*24+24 + 24*16+16 + 16*2+2
        assert_eq!(src.dim(), 72 + 400 + 34);
    }
}
