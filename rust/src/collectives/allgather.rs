//! Allgather: recursive doubling (Table I row 5):
//! `α·log N + (N-1)Mβ` where M is the per-worker contribution.
//!
//! Two flavours: a dense concat used by VAR-Topk's variance exchange, and
//! the sparse (values + indices) gather that synchronizes Top-k compressed
//! gradients (the paper's AG baseline path).

use crate::collectives::{ceil_log2, CommReport};
use crate::compress::SparseGrad;
use crate::netsim::cost_model::LinkParams;

/// Charge the recursive-doubling rounds for per-worker contributions of
/// `part_bytes` (possibly ragged — e.g. MS-Topk layers with differing k).
///
/// Round `d` has each worker forward the up-to-`2^d` blocks it has
/// accumulated so far (a Bruck-style circular window of whole parts; the
/// final round forwards only the `n - 2^d` still-missing ones). The
/// synchronous round completes when the max-loaded worker finishes, so the
/// β charge is the **max window sum of actual part bytes** — not
/// `blocks × max part`, which overbilled every round whenever the parts
/// were uneven. For equal parts the two agree exactly: `⌈log N⌉` α-rounds
/// and `(N-1)·M` total β bytes, the Table I row 5 closed form.
fn charge_recursive_doubling(report: &mut CommReport, part_bytes: &[f64], link: LinkParams) {
    let n = part_bytes.len();
    if n <= 1 {
        return;
    }
    // Window sums are recomputed fresh per worker (O(n²·log n) overall):
    // at simulated cluster sizes (n <= 32 across the experiment suite)
    // that is a few thousand adds, and fresh summation keeps the charged
    // bytes bitwise-stable — a rolling add/subtract window would be O(n·
    // log n) but accumulate float drift into the simulated cost.
    let mut rounds_here = 0u32;
    let mut held = 1usize; // parts accumulated per worker so far
    while held < n {
        let send = held.min(n - held);
        let mut max_window = 0.0f64;
        for w in 0..n {
            let mut window = 0.0;
            for j in 0..send {
                window += part_bytes[(w + j) % n];
            }
            max_window = max_window.max(window);
        }
        report.add_round(link, max_window);
        rounds_here += 1;
        held += send;
    }
    debug_assert_eq!(rounds_here, ceil_log2(n));
}

/// Dense allgather: every worker contributes `parts[w]`; returns the
/// concatenation (identical on every worker) and the comm report.
///
/// Recursive-doubling round structure: in round d each worker forwards the
/// (up to `2^d`) parts it has accumulated so far, charged at the actual
/// accumulated bytes of the max-loaded worker — exact for ragged parts,
/// `2^d · M` for equal ones (see `charge_recursive_doubling`).
pub fn allgather_concat(parts: &[Vec<f32>], link: LinkParams) -> (Vec<f32>, CommReport) {
    let n = parts.len();
    assert!(n >= 1);
    let mut report = CommReport::default();
    let mut out = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
    for p in parts {
        out.extend_from_slice(p);
    }
    let part_bytes: Vec<f64> = parts.iter().map(|p| 4.0 * p.len() as f64).collect();
    charge_recursive_doubling(&mut report, &part_bytes, link);
    (out, report)
}

/// Sparse Top-k allgather (the AG compression path, §3-D): each worker
/// contributes `k` (index, value) pairs = `8k` bytes; every worker ends with
/// the elementwise SUM of all scattered contributions in a dense vector.
///
/// Cost: `α·log N + 2Mcβ(N-1)` with `Mc = 4k` value-bytes (indices double it).
pub fn allgather_sparse(
    parts: &[SparseGrad],
    dense_len: usize,
    link: LinkParams,
) -> (Vec<f32>, CommReport) {
    let n = parts.len();
    assert!(n >= 1);
    let mut report = CommReport::default();
    let mut dense = vec![0.0f32; dense_len];
    for p in parts {
        debug_assert_eq!(p.dense_len, dense_len);
        for (&i, &v) in p.indices.iter().zip(&p.values) {
            dense[i as usize] += v;
        }
    }
    // 8 bytes per kept entry: 4 value + 4 index.
    let part_bytes: Vec<f64> = parts.iter().map(|p| 8.0 * p.indices.len() as f64).collect();
    charge_recursive_doubling(&mut report, &part_bytes, link);
    (dense, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;
    use crate::util::proptest::{check, ensure};

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(1.0, 10.0)
    }

    #[test]
    fn concat_order_and_content() {
        let parts = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let (out, _) = allgather_concat(&parts, link());
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dense_time_matches_closed_form_pow2() {
        for n in [2usize, 4, 8, 16] {
            let m = 256;
            let parts = vec![vec![1.0f32; m]; n];
            let (_, r) = allgather_concat(&parts, link());
            let want = cost_model::allgather(link(), 4.0 * m as f64, n);
            assert!(
                (r.seconds - want).abs() / want < 1e-9,
                "n={n}: sim {} vs model {}",
                r.seconds,
                want
            );
        }
    }

    #[test]
    fn sparse_sums_overlapping_indices() {
        let a = SparseGrad { indices: vec![0, 3], values: vec![1.0, 2.0], dense_len: 5 };
        let b = SparseGrad { indices: vec![3, 4], values: vec![10.0, 20.0], dense_len: 5 };
        let (dense, _) = allgather_sparse(&[a, b], 5, link());
        assert_eq!(dense, vec![1.0, 0.0, 0.0, 12.0, 20.0]);
    }

    #[test]
    fn sparse_time_matches_ag_topk_cost() {
        // k entries per worker -> Mc = 4k bytes; cost formula uses 2*Mc.
        let n = 8;
        let dense_len = 100_000;
        let k = 1000;
        let parts: Vec<SparseGrad> = (0..n)
            .map(|w| SparseGrad {
                indices: (0..k as u32).collect(),
                values: vec![w as f32; k],
                dense_len,
            })
            .collect();
        let (_, r) = allgather_sparse(&parts, dense_len, link());
        let m = 4.0 * dense_len as f64;
        let c = k as f64 / dense_len as f64;
        let want = cost_model::ag_topk(link(), m, n, c);
        assert!(
            (r.seconds - want).abs() / want < 1e-9,
            "sim {} vs model {}",
            r.seconds,
            want
        );
    }

    #[test]
    fn property_sparse_equals_dense_scatter_sum() {
        check("sparse AG == scatter-add", 50, |g| {
            let n = g.usize_in(1, 6);
            let len = g.usize_in(4, 200);
            let mut want = vec![0.0f32; len];
            let mut parts = Vec::new();
            for _ in 0..n {
                let k = g.usize_in(0, len.min(16));
                let idx = g.rng.sample_indices(len, k);
                let vals = g.vec_normal(k, 1.0);
                for (&i, &v) in idx.iter().zip(&vals) {
                    want[i] += v;
                }
                parts.push(SparseGrad {
                    indices: idx.iter().map(|&i| i as u32).collect(),
                    values: vals,
                    dense_len: len,
                });
            }
            let (dense, _) = allgather_sparse(&parts, len, link());
            crate::util::proptest::all_close(&dense, &want, 1e-5)
        });
    }

    /// Ragged parts are billed at actual accumulated bytes per round (max
    /// window sum), pinned here against the closed form computed
    /// independently — and strictly below the old `blocks × max part`
    /// accounting.
    #[test]
    fn ragged_parts_match_closed_form_and_beat_max_billing() {
        // Uneven contributions, the MS-Topk differing-k shape.
        let lens = [5usize, 1, 3, 2, 8, 1];
        let n = lens.len();
        let parts: Vec<Vec<f32>> = lens.iter().map(|&k| vec![1.0f32; k]).collect();
        let (out, r) = allgather_concat(&parts, link());
        assert_eq!(out.len(), lens.iter().sum::<usize>());
        assert_eq!(r.rounds, 3); // ceil_log2(6)

        // Closed form: Σ_d [α + β · max_w Σ_{j<send_d} bytes[(w+j) mod n]]
        // with send_d = min(2^d, n - 2^d) = [1, 2, 2] for n = 6.
        let bytes: Vec<f64> = lens.iter().map(|&k| 4.0 * k as f64).collect();
        let mut want_secs = 0.0;
        let mut want_bytes = 0.0;
        for send in [1usize, 2, 2] {
            let max_window = (0..n)
                .map(|w| (0..send).map(|j| bytes[(w + j) % n]).sum::<f64>())
                .fold(0.0f64, f64::max);
            want_secs += link().alpha + max_window * link().beta;
            want_bytes += max_window;
        }
        assert!(
            (r.seconds - want_secs).abs() < 1e-12,
            "sim {} vs closed form {want_secs}",
            r.seconds
        );
        assert!((r.bytes_per_worker - want_bytes).abs() < 1e-9);

        // The old accounting billed every round at the max part size.
        let max_part = bytes.iter().cloned().fold(0.0f64, f64::max);
        let old_secs = 3.0 * link().alpha + (n as f64 - 1.0) * max_part * link().beta;
        assert!(
            r.seconds < old_secs,
            "ragged billing {} must undercut max-part billing {old_secs}",
            r.seconds
        );
    }

    /// Same fix on the sparse path: per-worker k differs, cost must track
    /// actual (8 bytes/entry) windows, not `(N-1) × max k`.
    #[test]
    fn sparse_ragged_k_costs_actual_bytes() {
        let dense_len = 1000;
        let ks = [100usize, 10, 50, 10];
        let parts: Vec<SparseGrad> = ks
            .iter()
            .map(|&k| SparseGrad {
                indices: (0..k as u32).collect(),
                values: vec![1.0; k],
                dense_len,
            })
            .collect();
        let (_, r) = allgather_sparse(&parts, dense_len, link());
        assert_eq!(r.rounds, 2);
        // n = 4: send windows [1, 2]; bytes = 8k.
        let b: Vec<f64> = ks.iter().map(|&k| 8.0 * k as f64).collect();
        let w1 = b.iter().cloned().fold(0.0f64, f64::max);
        let w2 = (0..4).map(|w| b[w] + b[(w + 1) % 4]).fold(0.0f64, f64::max);
        let want = 2.0 * link().alpha + (w1 + w2) * link().beta;
        assert!((r.seconds - want).abs() < 1e-12, "sim {} vs {want}", r.seconds);
        let even = cost_model::ag_topk(link(), 4.0 * dense_len as f64, 4, 0.1);
        assert!(r.seconds < even * 4.0, "sanity: same order as even-k cost {even}");
    }

    #[test]
    fn single_worker_no_comm() {
        let parts = vec![vec![1.0, 2.0]];
        let (out, r) = allgather_concat(&parts, link());
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(r.seconds, 0.0);
        ensure(r.rounds == 0, "rounds").unwrap();
    }
}
