//! First-party micro-bench harness (offline build: no `criterion`).
//!
//! `cargo bench` targets use `harness = false` and drive this module: each
//! benchmark warms up, then runs timed iterations until a wall-clock budget
//! or max-iteration cap is hit, and reports mean/p50/p95 per iteration.

// flexlint::allow-file(unsanctioned-clock): the bench harness measures wall time by definition
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark runner with a per-target time budget.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(800),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration, max_iters: usize) -> Self {
        Bencher { warmup, budget, max_iters, results: Vec::new() }
    }

    /// Quick-mode bencher honouring `FLEXCOMM_BENCH_FAST=1` (used in CI).
    pub fn from_env() -> Self {
        if std::env::var("FLEXCOMM_BENCH_FAST").is_ok() {
            Bencher::new(Duration::from_millis(10), Duration::from_millis(80), 200)
        } else {
            Bencher::default()
        }
    }

    /// Time `f` repeatedly; returns and records the measurement.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Timed runs.
        let mut samples: Vec<Duration> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.budget && samples.len() < self.max_iters {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len().max(1) as u32,
            p50: samples[samples.len() / 2],
            p95: samples[p95_idx],
        };
        println!(
            "bench {:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            m.name, m.mean, m.p50, m.p95, m.iters
        );
        self.results.push(m.clone());
        m
    }

    /// Prevent the optimizer from deleting a computed value.
    #[inline]
    pub fn black_box<T>(x: T) -> T {
        std::hint::black_box(x)
    }

    /// Serialize every recorded measurement to `path` as a single JSON
    /// document (hand-rolled — offline build, no `serde`). The shape is
    /// stable so regression tooling can diff runs:
    ///
    /// ```json
    /// {"bench": "hotpath", "measurements": [
    ///   {"name": "...", "iters": 12, "mean_secs": 1.0e-5,
    ///    "p50_secs": 1.0e-5, "p95_secs": 2.0e-5}, ...]}
    /// ```
    pub fn write_json(&self, bench: &str, path: &std::path::Path) -> std::io::Result<()> {
        let mut out = String::new();
        out.push_str(&format!("{{\"bench\": {},\n \"measurements\": [", json_str(bench)));
        for (i, m) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n  {{\"name\": {}, \"iters\": {}, \"mean_secs\": {:e}, \
                 \"p50_secs\": {:e}, \"p95_secs\": {:e}}}",
                json_str(&m.name),
                m.iters,
                m.mean.as_secs_f64(),
                m.p50.as_secs_f64(),
                m.p95.as_secs_f64()
            ));
        }
        out.push_str("\n]}\n");
        std::fs::write(path, out)
    }
}

/// Minimal JSON string encoder: quotes, backslashes and control bytes —
/// bench names are ASCII labels, but escape correctly anyway.
fn json_str(s: &str) -> String {
    let mut q = String::with_capacity(s.len() + 2);
    q.push('"');
    for c in s.chars() {
        match c {
            '"' => q.push_str("\\\""),
            '\\' => q.push_str("\\\\"),
            '\n' => q.push_str("\\n"),
            '\r' => q.push_str("\\r"),
            '\t' => q.push_str("\\t"),
            c if (c as u32) < 0x20 => q.push_str(&format!("\\u{:04x}", c as u32)),
            c => q.push(c),
        }
    }
    q.push('"');
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_measurement() {
        let mut b = Bencher::new(
            Duration::from_millis(1),
            Duration::from_millis(10),
            50,
        );
        let m = b.bench("noop-ish", || {
            let v: Vec<u32> = (0..100).collect();
            Bencher::black_box(v.iter().sum::<u32>());
        });
        assert!(m.iters > 0);
        assert!(m.mean > Duration::ZERO);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn write_json_emits_every_measurement() {
        let mut b = Bencher::new(
            Duration::from_millis(1),
            Duration::from_millis(5),
            20,
        );
        b.bench("stage \"a\"", || {
            Bencher::black_box((0..64).sum::<u32>());
        });
        b.bench("stage b", || {
            Bencher::black_box((0..64).product::<u64>());
        });
        let path = std::env::temp_dir().join("flexcomm_bench_json_test.json");
        b.write_json("hotpath", &path).expect("write json");
        let text = std::fs::read_to_string(&path).expect("read back");
        std::fs::remove_file(&path).ok();
        assert!(text.starts_with("{\"bench\": \"hotpath\""), "{text}");
        assert!(text.contains("\"name\": \"stage \\\"a\\\"\""), "{text}");
        assert!(text.contains("\"name\": \"stage b\""), "{text}");
        assert!(text.contains("\"mean_secs\": "), "{text}");
        assert_eq!(text.matches("\"iters\":").count(), 2, "{text}");
        assert!(text.trim_end().ends_with("]}"), "{text}");
    }

    #[test]
    fn json_str_escapes_specials() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\n\t\u{1}"), "\"x\\n\\t\\u0001\"");
    }
}
