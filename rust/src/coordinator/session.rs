//! The Session API: builder-validated construction of training runs
//! (DESIGN.md §8).
//!
//! [`Session::builder`] is THE construction path for a runnable trainer:
//! it validates the configuration into typed [`ConfigError`]s (what used
//! to be scattered `assert!`s and silent misconfigurations), instantiates
//! the configured [`CommStrategy`] from the strategy registry (or accepts
//! a custom one), resolves the control plane (a [`Controller`] object,
//! a `--controller` registry spec, or the [`CrControl`]-implied default —
//! DESIGN.md §10), attaches
//! [`TrainObserver`](crate::coordinator::observer::TrainObserver)s, and
//! hands back a [`Session`] whose `run()` returns a [`TrainReport`].
//! [`TrainConfig`] remains the serialized form —
//! [`Session::from_config`] seeds a builder from one.
//!
//! ```
//! use flexcomm::coordinator::session::Session;
//! use flexcomm::coordinator::trainer::Strategy;
//! use flexcomm::runtime::HostMlp;
//!
//! let report = Session::builder()
//!     .workers(4)
//!     .steps(5)
//!     .strategy(Strategy::parse("artopk-star").unwrap())
//!     .static_cr(0.05)
//!     .seed(7)
//!     .source(Box::new(HostMlp::default_preset(7)))
//!     .build()
//!     .unwrap()
//!     .run();
//! assert_eq!(report.metrics.steps.len(), 5);
//! ```

use crate::coordinator::controller::{
    self, AdaptiveConfig, Controller, ControllerError, DEFAULT_POLICY_WINDOWS,
};
use crate::coordinator::metrics::{MetricsLog, Summary};
use crate::coordinator::observer::TrainObserver;
use crate::coordinator::policy_switch::PolicySwitcher;
use crate::coordinator::strategy::{instantiate, CommStrategy};
use crate::coordinator::trainer::{CrControl, Strategy, TrainConfig, Trainer};
use crate::coordinator::worker::{ComputeModel, GradSource};
use crate::models::{self, ModelError};
use crate::netsim::model::{parse_spec, NetModelError, NetworkModel};
use crate::netsim::schedule::NetSchedule;
use crate::util::pool::ThreadPool;
use std::fmt;

/// A configuration the builder refused — every variant is a misconfig
/// that used to panic mid-construction or silently misbehave. Implements
/// [`std::error::Error`], so `?` converts it into `anyhow::Result`
/// contexts transparently.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// `workers(0)`: a cluster needs at least one worker.
    ZeroWorkers,
    /// `steps_per_epoch(0)`: epochs would be undefined (division by zero
    /// drives the network schedule).
    ZeroStepsPerEpoch,
    /// Static CR outside (0, 1].
    CrOutOfRange(f64),
    /// Adaptive CR ladder violating 0 < c_low < c_high <= 1 (strict:
    /// `candidate_crs` needs a non-degenerate range).
    AdaptiveCrBounds { c_low: f64, c_high: f64 },
    /// Adaptive ladder parameters the candidate generator/explorer cannot
    /// work with: the geometric step must exceed 1 and every candidate
    /// needs at least one probe iteration (both used to be `assert!`s
    /// that fired inside `build()` or mid-run at the first exploration).
    AdaptiveLadderParams { factor: f64, probe_iters: u64 },
    /// Two-level topology whose ranks-per-node does not divide the
    /// cluster size (was an `assert!` in the old `Trainer::new`).
    RaggedTopology { n_workers: usize, workers_per_node: usize },
    /// Adaptive CR control with an uncompressed strategy: there is no
    /// compression ratio to adapt.
    AdaptiveNeedsCompression { strategy: String },
    /// `build()` without a gradient source.
    MissingSource,
    /// The gradient source's `init_params()` length disagrees with its
    /// `dim()` — a broken [`GradSource`] impl (was a debug-only assert;
    /// in release it would index out of bounds or silently truncate
    /// updates mid-run).
    SourceDimMismatch { params_len: usize, dim: usize },
    /// The network environment was rejected: an unloadable/malformed
    /// trace, a bad modifier composition, or an unknown scenario spec
    /// (from [`SessionBuilder::network_spec`]).
    Network(NetModelError),
    /// The model axis was rejected: an unknown `--model` spec (from
    /// [`SessionBuilder::model_spec`]) — the error lists every
    /// [`MODEL_TABLE`](crate::models::MODEL_TABLE) name.
    Model(ModelError),
    /// The control plane was rejected: an unknown `--controller` spec,
    /// invalid STAR/VAR trial/commit windows, or a CR-adapting controller
    /// paired with an uncompressed strategy (DESIGN.md §10).
    Controller(ControllerError),
}

impl From<NetModelError> for ConfigError {
    fn from(e: NetModelError) -> Self {
        ConfigError::Network(e)
    }
}

impl From<ControllerError> for ConfigError {
    fn from(e: ControllerError) -> Self {
        ConfigError::Controller(e)
    }
}

impl From<ModelError> for ConfigError {
    fn from(e: ModelError) -> Self {
        ConfigError::Model(e)
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::ZeroWorkers => write!(f, "n_workers must be >= 1"),
            ConfigError::ZeroStepsPerEpoch => write!(f, "steps_per_epoch must be >= 1"),
            ConfigError::CrOutOfRange(c) => {
                write!(f, "compression ratio {c} outside (0, 1]")
            }
            ConfigError::AdaptiveCrBounds { c_low, c_high } => write!(
                f,
                "adaptive CR bounds must satisfy 0 < c_low < c_high <= 1 (got [{c_low}, {c_high}])"
            ),
            ConfigError::AdaptiveLadderParams { factor, probe_iters } => write!(
                f,
                "adaptive CR ladder needs factor > 1 and probe_iters >= 1 \
                 (got factor={factor}, probe_iters={probe_iters})"
            ),
            ConfigError::RaggedTopology { n_workers, workers_per_node } => write!(
                f,
                "n_workers {n_workers} not divisible by the schedule's \
                 workers_per_node {workers_per_node}"
            ),
            ConfigError::AdaptiveNeedsCompression { strategy } => write!(
                f,
                "adaptive CR control requires a compressed strategy ({strategy} is uncompressed)"
            ),
            ConfigError::MissingSource => {
                write!(f, "no gradient source: call .source(..) before .build()")
            }
            ConfigError::SourceDimMismatch { params_len, dim } => write!(
                f,
                "gradient source is inconsistent: init_params() produced {params_len} \
                 parameters but dim() reports {dim}"
            ),
            ConfigError::Network(e) => write!(f, "network environment rejected: {e}"),
            ConfigError::Model(e) => write!(f, "model rejected: {e}"),
            ConfigError::Controller(e) => write!(f, "controller rejected: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Fluent, validating constructor for a [`Session`]. Defaults mirror
/// `TrainConfig::default()`; every setter overrides one field, and
/// [`SessionBuilder::build`] validates the whole configuration at once.
#[derive(Default)]
pub struct SessionBuilder {
    cfg: TrainConfig,
    source: Option<Box<dyn GradSource>>,
    custom: Option<Box<dyn CommStrategy>>,
    observers: Vec<Box<dyn TrainObserver>>,
    /// Deferred `--net` spec: resolved at `build()` (it needs the run's
    /// total epoch count), overriding `cfg.net` when present.
    net_spec: Option<String>,
    /// Custom controller object (takes precedence over the spec).
    custom_controller: Option<Box<dyn Controller>>,
    /// Deferred `--controller` spec: resolved against
    /// [`CONTROLLER_TABLE`](crate::coordinator::controller::CONTROLLER_TABLE)
    /// at `build()`, overriding the [`CrControl`]-implied controller.
    controller_spec: Option<String>,
    /// STAR/VAR trial/commit windows for the `artopk-auto` composition.
    policy_windows: Option<(u64, u64)>,
    /// Deferred `--model` spec: resolved against
    /// [`MODEL_TABLE`](crate::models::MODEL_TABLE) at `build()` when no
    /// explicit [`SessionBuilder::source`] was given.
    model_spec: Option<String>,
    /// An externally-owned worker pool to run on (the sweep server's
    /// shared-pool seam); `None` = spawn one pool for this session.
    shared_pool: Option<ThreadPool>,
}

impl SessionBuilder {
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.n_workers = n;
        self
    }

    pub fn steps(mut self, steps: u64) -> Self {
        self.cfg.steps = steps;
        self
    }

    pub fn steps_per_epoch(mut self, spe: u64) -> Self {
        self.cfg.steps_per_epoch = spe;
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.lr = lr;
        self
    }

    pub fn momentum(mut self, mu: f32) -> Self {
        self.cfg.momentum = mu;
        self
    }

    pub fn weight_decay(mut self, wd: f32) -> Self {
        self.cfg.weight_decay = wd;
        self
    }

    /// `(step, factor)` learning-rate decay events.
    pub fn lr_decay(mut self, decay: Vec<(u64, f32)>) -> Self {
        self.cfg.lr_decay = decay;
        self
    }

    /// Pick a built-in strategy (the config surface; see
    /// [`Strategy::parse`] for names). For a strategy of your own, use
    /// [`SessionBuilder::comm_strategy`].
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// Plug in a custom [`CommStrategy`] object, bypassing the built-in
    /// registry — the seam that makes a new strategy a new file instead
    /// of trainer surgery. Takes precedence over
    /// [`SessionBuilder::strategy`].
    pub fn comm_strategy(mut self, strategy: Box<dyn CommStrategy>) -> Self {
        self.custom = Some(strategy);
        self
    }

    pub fn cr(mut self, cr: CrControl) -> Self {
        self.cfg.cr = cr;
        self
    }

    /// Fixed compression ratio in (0, 1].
    pub fn static_cr(self, cr: f64) -> Self {
        self.cr(CrControl::Static(cr))
    }

    /// MOO-adaptive compression ratio (§3-E) — shorthand for
    /// `cr(CrControl::Adaptive(..))`, which implies the `moo` controller
    /// unless [`SessionBuilder::controller`] /
    /// [`SessionBuilder::controller_spec`] override it.
    pub fn adaptive_cr(self, cfg: AdaptiveConfig) -> Self {
        self.cr(CrControl::Adaptive(cfg))
    }

    /// Plug in a custom [`Controller`] object (DESIGN.md §10), bypassing
    /// the [`CONTROLLER_TABLE`](crate::coordinator::controller::CONTROLLER_TABLE)
    /// registry — the seam that makes a new adaptation policy a drop-in
    /// object instead of trainer surgery. Takes precedence over
    /// [`SessionBuilder::controller_spec`] and the [`CrControl`]-implied
    /// default.
    pub fn controller(mut self, controller: Box<dyn Controller>) -> Self {
        self.custom_controller = Some(controller);
        self
    }

    /// Defer a `--controller`-style registry name (`static`, `moo`,
    /// `gravac`, ...) to `build()` — an unknown name surfaces as the
    /// typed [`ConfigError::Controller`] listing every registered
    /// controller.
    pub fn controller_spec(mut self, spec: &str) -> Self {
        self.controller_spec = Some(spec.to_string());
        self
    }

    /// STAR/VAR trial/commit windows for the policy-switch controller the
    /// builder composes with the `artopk-auto` strategy (defaults
    /// [`DEFAULT_POLICY_WINDOWS`]). Validated at `build()` — invalid
    /// windows are the typed
    /// [`ControllerError::BadPolicyWindows`], never a panic.
    pub fn policy_windows(mut self, trial_window: u64, commit_period: u64) -> Self {
        self.policy_windows = Some((trial_window, commit_period));
        self
    }

    /// Plug in the network environment — any [`NetworkModel`]: a
    /// [`NetSchedule`], a loaded
    /// [`TraceModel`](crate::netsim::trace::TraceModel)
    /// (`.network(TraceModel::load(path)?)`), or a
    /// [`modifiers`](crate::netsim::modifiers) composition.
    pub fn network(mut self, net: impl NetworkModel + 'static) -> Self {
        self.cfg.net = Box::new(net);
        self
    }

    /// Boxed-object form of [`SessionBuilder::network`] (registry output,
    /// [`parse_spec`] results).
    pub fn network_boxed(mut self, net: Box<dyn NetworkModel>) -> Self {
        self.cfg.net = net;
        self
    }

    /// Defer a `--net`-style spec (`<scenario name>` or `trace:<path>`)
    /// to `build()`, which resolves it against the scenario registry at
    /// the run's epoch count — a bad spec surfaces as the typed
    /// [`ConfigError::Network`] instead of a panic or a stringly error.
    pub fn network_spec(mut self, spec: &str) -> Self {
        self.net_spec = Some(spec.to_string());
        self
    }

    /// Convenience for the common piecewise-schedule case (delegates to
    /// [`SessionBuilder::network`]).
    pub fn schedule(self, schedule: NetSchedule) -> Self {
        self.network(schedule)
    }

    pub fn compute(mut self, compute: ComputeModel) -> Self {
        self.cfg.compute = compute;
        self
    }

    pub fn probe_noise(mut self, frac: f64) -> Self {
        self.cfg.probe_noise = frac;
        self
    }

    /// See [`TrainConfig::msg_scale`].
    pub fn msg_scale(mut self, scale: f64) -> Self {
        self.cfg.msg_scale = scale;
        self
    }

    /// See [`TrainConfig::comp_scale`].
    pub fn comp_scale(mut self, scale: f64) -> Self {
        self.cfg.comp_scale = scale;
        self
    }

    /// Evaluate every N steps (0 = only at the end).
    pub fn eval_every(mut self, every: u64) -> Self {
        self.cfg.eval_every = every;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Worker threads (0 = all cores; DESIGN.md §7).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Register a typed-event observer (repeatable; events fire in
    /// registration order).
    pub fn observer(mut self, observer: Box<dyn TrainObserver>) -> Self {
        self.observers.push(observer);
        self
    }

    /// The model backend producing per-worker gradients. Required unless
    /// [`SessionBuilder::model_spec`] names one; an explicit source takes
    /// precedence over the spec.
    pub fn source(mut self, source: Box<dyn GradSource>) -> Self {
        self.source = Some(source);
        self
    }

    /// Defer a `--model`-style registry name (`mlp`, `matreg`,
    /// `host-mlp`, `synthetic:<dim>`, ...) to `build()`, which resolves
    /// it against [`MODEL_TABLE`](crate::models::MODEL_TABLE) at the
    /// session seed — an unknown name surfaces as the typed
    /// [`ConfigError::Model`] listing every registered model.
    pub fn model_spec(mut self, spec: &str) -> Self {
        self.model_spec = Some(spec.to_string());
        self
    }

    /// Run this session on an externally-owned persistent [`ThreadPool`]
    /// instead of spawning its own. Pool handles clone cheaply and share
    /// the parked worker set; whole parallel regions are serialized across
    /// handles (DESIGN.md §7), so many concurrent sessions can share one
    /// pool — the sweep server's execution model. Chunking depends only on
    /// `(threads, n)`, so per-session results stay bitwise identical to a
    /// privately-owned pool of the same width.
    pub fn pool(mut self, pool: ThreadPool) -> Self {
        self.shared_pool = Some(pool);
        self
    }

    /// Validate the full configuration and assemble the [`Session`].
    /// Every rejection is a typed [`ConfigError`] (auto-converts into
    /// `anyhow::Result` contexts via `?`).
    pub fn build(self) -> Result<Session, ConfigError> {
        let SessionBuilder {
            mut cfg,
            source,
            custom,
            observers,
            net_spec,
            custom_controller,
            controller_spec,
            policy_windows,
            model_spec,
            shared_pool,
        } = self;
        if cfg.n_workers == 0 {
            return Err(ConfigError::ZeroWorkers);
        }
        if cfg.steps_per_epoch == 0 {
            return Err(ConfigError::ZeroStepsPerEpoch);
        }
        if let Some(spec) = net_spec {
            let epochs = cfg.steps as f64 / cfg.steps_per_epoch as f64;
            cfg.net = parse_spec(&spec, epochs.max(1.0))?;
        }
        match &cfg.cr {
            CrControl::Static(c) => {
                if !(*c > 0.0 && *c <= 1.0) {
                    return Err(ConfigError::CrOutOfRange(*c));
                }
            }
            CrControl::Adaptive(a) => {
                // Strict c_low < c_high: candidate_crs / the ladder
                // controllers assert a non-degenerate geometric range.
                if !(a.c_low > 0.0 && a.c_low < a.c_high && a.c_high <= 1.0) {
                    return Err(ConfigError::AdaptiveCrBounds {
                        c_low: a.c_low,
                        c_high: a.c_high,
                    });
                }
                if !(a.factor > 1.0) || a.probe_iters == 0 {
                    return Err(ConfigError::AdaptiveLadderParams {
                        factor: a.factor,
                        probe_iters: a.probe_iters,
                    });
                }
            }
        }
        let wpn = cfg.net.topology_at(0.0).workers_per_node;
        if wpn > 0 && cfg.n_workers % wpn != 0 {
            return Err(ConfigError::RaggedTopology {
                n_workers: cfg.n_workers,
                workers_per_node: wpn,
            });
        }
        // ONE persistent worker pool per session: spawned here (or handed
        // in via `.pool()` — the sweep server's shared-pool seam), handle
        // clones shared by the trainer and the strategy's operators, so
        // every parallel region in the run reuses the same parked workers
        // (DESIGN.md §7).
        let pool = shared_pool.unwrap_or_else(|| ThreadPool::auto(cfg.threads));
        let from_registry = custom.is_none();
        let strategy = match custom {
            Some(s) => s,
            None => instantiate(cfg.strategy, cfg.n_workers, cfg.seed, pool.clone()),
        };
        if matches!(cfg.cr, CrControl::Adaptive(_)) && !strategy.is_compressed() {
            return Err(ConfigError::AdaptiveNeedsCompression {
                strategy: strategy.name().to_string(),
            });
        }
        // The control plane (DESIGN.md §10): explicit object > registry
        // spec > the CrControl-implied default (Static -> no-op,
        // Adaptive -> moo). Windows are validated whenever set, so a bad
        // configuration is rejected even if the strategy never uses them.
        if let Some((t, c)) = policy_windows {
            PolicySwitcher::validate(t, c)?;
        }
        let primary: Box<dyn Controller> = match (custom_controller, controller_spec) {
            (Some(c), _) => c,
            (None, Some(spec)) => controller::build_controller(&spec, &cfg)?,
            (None, None) => controller::from_cr_control(&cfg),
        };
        if primary.adapts_cr() && !strategy.is_compressed() {
            return Err(ConfigError::Controller(ControllerError::NeedsCompression {
                controller: primary.name(),
                strategy: strategy.name().to_string(),
            }));
        }
        // `artopk-auto` = plain AR-Topk + the STAR/VAR trial/commit
        // controller composed alongside the CR controller (the stack
        // shape lives in controller::compose_for_strategy, shared with
        // the default path). Custom strategies compose their own control
        // stack explicitly.
        let controller: Box<dyn Controller> = if from_registry {
            controller::compose_for_strategy(
                primary,
                &cfg,
                policy_windows.unwrap_or(DEFAULT_POLICY_WINDOWS),
            )?
        } else {
            primary
        };
        // Model axis: an explicit `.source()` wins; otherwise resolve the
        // deferred `--model` spec against MODEL_TABLE at the session seed.
        let source = match (source, model_spec) {
            (Some(s), _) => s,
            (None, Some(spec)) => models::build_model(&spec, cfg.seed)?,
            (None, None) => return Err(ConfigError::MissingSource),
        };
        let trainer = Trainer::with_parts(cfg, source, strategy, observers, pool, controller);
        // init_params ran exactly once inside with_parts; check its output
        // against the declared dimension here, where a broken GradSource
        // impl becomes a typed error instead of a mid-run panic.
        if trainer.params.len() != trainer.source.dim() {
            return Err(ConfigError::SourceDimMismatch {
                params_len: trainer.params.len(),
                dim: trainer.source.dim(),
            });
        }
        Ok(Session { trainer })
    }
}

/// A validated, runnable training session.
pub struct Session {
    trainer: Trainer,
}

impl Session {
    /// Start a fresh builder (defaults = `TrainConfig::default()`).
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Seed a builder from a serialized [`TrainConfig`] (config files,
    /// experiment presets) — the same validation runs at `build()`.
    pub fn from_config(cfg: TrainConfig) -> SessionBuilder {
        SessionBuilder { cfg, ..SessionBuilder::default() }
    }

    /// Attach an observer AFTER validation — for observers with side
    /// effects on creation (e.g. [`CsvSink`](crate::coordinator::observer::CsvSink)
    /// truncates its target file), so a rejected config cannot clobber
    /// anything. Events fire after all builder-registered observers.
    pub fn observer(
        mut self,
        observer: Box<dyn TrainObserver>,
    ) -> Self {
        self.trainer.observers.push(observer);
        self
    }

    /// The configured network environment's full identity
    /// ([`NetworkModel::describe`]) — what the report and tagged CSV
    /// output carry.
    pub fn network_describe(&self) -> String {
        self.trainer.cfg.net.describe()
    }

    /// Run the configured number of steps and return the report.
    pub fn run(mut self) -> TrainReport {
        self.trainer.run();
        let Trainer {
            cfg,
            source,
            params,
            clock,
            metrics,
            explore_overhead_s,
            cur_cr,
            strategy,
            controller,
            ..
        } = self.trainer;
        TrainReport {
            model: source.name(),
            strategy: strategy.name().to_string(),
            network: cfg.net.describe(),
            controller: controller.name().to_string(),
            final_cr: if strategy.is_compressed() { cur_cr } else { 1.0 },
            virtual_time_s: clock.now(),
            explore_overhead_s,
            metrics,
            params,
            steps: cfg.steps,
        }
    }
}

/// Everything a finished run produced — what consumers used to scrape off
/// the trainer's public fields.
pub struct TrainReport {
    /// Per-step metrics + eval records of the whole run.
    pub metrics: MetricsLog,
    /// Final model parameters (identical on every simulated worker).
    pub params: Vec<f32>,
    /// Accumulated simulated cluster seconds (the virtual clock).
    pub virtual_time_s: f64,
    /// Simulated seconds spent in MOO candidate exploration (reported
    /// separately from the clock).
    pub explore_overhead_s: f64,
    /// CR in effect at the end (1.0 for uncompressed strategies).
    pub final_cr: f64,
    /// Gradient-source descriptor.
    pub model: String,
    /// Strategy display name.
    pub strategy: String,
    /// Network-scenario identity
    /// ([`NetworkModel::describe`]) — names the environment (base
    /// scenario + modifier chain, or `trace:<name>`) this run saw.
    pub network: String,
    /// Controller identity
    /// ([`Controller::name`](crate::coordinator::controller::Controller::name);
    /// `"composite"` for composed stacks like `artopk-auto`'s).
    pub controller: String,
    /// Configured step count.
    pub steps: u64,
}

impl TrainReport {
    /// Aggregate timing/loss view over the whole run.
    pub fn summary(&self) -> Summary {
        self.metrics.summary()
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.metrics.final_accuracy()
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.metrics.best_accuracy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::LinkParams;
    use crate::runtime::host_model::HostMlp;

    fn base_no_source() -> SessionBuilder {
        Session::builder()
            .workers(4)
            .steps(3)
            .steps_per_epoch(10)
            .seed(1)
            .compute(ComputeModel::fixed(0.01))
    }

    fn base() -> SessionBuilder {
        base_no_source().source(Box::new(HostMlp::default_preset(1)))
    }

    #[test]
    fn valid_config_builds_and_runs() {
        let report = base().static_cr(0.05).build().unwrap().run();
        assert_eq!(report.metrics.steps.len(), 3);
        assert_eq!(report.steps, 3);
        assert!(report.virtual_time_s > 0.0);
        // Final eval always recorded.
        assert!(report.final_accuracy().is_some());
    }

    #[test]
    fn zero_workers_is_a_typed_error() {
        assert_eq!(base().workers(0).build().err(), Some(ConfigError::ZeroWorkers));
    }

    #[test]
    fn zero_steps_per_epoch_is_a_typed_error() {
        assert_eq!(
            base().steps_per_epoch(0).build().err(),
            Some(ConfigError::ZeroStepsPerEpoch)
        );
    }

    #[test]
    fn cr_outside_unit_interval_is_a_typed_error() {
        for bad in [0.0, -0.1, 1.5, f64::NAN] {
            match base().static_cr(bad).build().err() {
                Some(ConfigError::CrOutOfRange(_)) => {}
                other => panic!("cr {bad}: expected CrOutOfRange, got {other:?}"),
            }
        }
        // Boundary: exactly 1.0 (dense nominal) is valid.
        assert!(base().static_cr(1.0).build().is_ok());
    }

    #[test]
    fn adaptive_bounds_validated() {
        let flex = || base().strategy(Strategy::parse("flexible").unwrap());
        let bad = AdaptiveConfig { c_low: 0.2, c_high: 0.1, ..Default::default() };
        assert_eq!(
            flex().adaptive_cr(bad).build().err(),
            Some(ConfigError::AdaptiveCrBounds { c_low: 0.2, c_high: 0.1 })
        );
        // Degenerate range: candidate_crs needs c_low < c_high STRICTLY —
        // accepting equality used to panic inside the ladder generator
        // (in build() for gravac, mid-run for moo) instead of erroring.
        let degenerate = AdaptiveConfig { c_low: 0.05, c_high: 0.05, ..Default::default() };
        assert!(matches!(
            flex().adaptive_cr(degenerate).build().err(),
            Some(ConfigError::AdaptiveCrBounds { .. })
        ));
        // Ladder parameters the explorer cannot work with: geometric
        // factor <= 1 (incl. NaN) and zero probe iterations both used to
        // be asserts that fired after validation had "passed".
        for cfg in [
            AdaptiveConfig { factor: 1.0, ..Default::default() },
            AdaptiveConfig { factor: f64::NAN, ..Default::default() },
            AdaptiveConfig { probe_iters: 0, ..Default::default() },
        ] {
            assert!(
                matches!(
                    flex().adaptive_cr(cfg.clone()).build().err(),
                    Some(ConfigError::AdaptiveLadderParams { .. })
                ),
                "{cfg:?}"
            );
        }
        // Boundary: the default ladder (and a just-valid factor) build.
        assert!(flex().adaptive_cr(AdaptiveConfig::default()).build().is_ok());
    }

    #[test]
    fn ragged_topology_is_a_typed_error_not_a_panic() {
        let sched = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))
            .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 4);
        assert_eq!(
            base().workers(6).schedule(sched).build().err(),
            Some(ConfigError::RaggedTopology { n_workers: 6, workers_per_node: 4 })
        );
    }

    #[test]
    fn adaptive_with_dense_is_a_typed_error() {
        let err = base()
            .strategy(Strategy::parse("dense-ring").unwrap())
            .adaptive_cr(AdaptiveConfig::default())
            .build()
            .err();
        assert!(
            matches!(err, Some(ConfigError::AdaptiveNeedsCompression { .. })),
            "{err:?}"
        );
    }

    #[test]
    fn missing_source_is_a_typed_error() {
        let err = Session::builder().workers(2).build().err();
        assert_eq!(err, Some(ConfigError::MissingSource));
    }

    /// A GradSource whose init_params() disagrees with dim() — formerly a
    /// debug-only assert that in release builds became an out-of-bounds
    /// index (or silent truncation) mid-run.
    struct BadDimSource {
        layout: crate::tensor::Layout,
    }

    impl crate::coordinator::worker::GradSource for BadDimSource {
        fn dim(&self) -> usize {
            10
        }
        fn layout(&self) -> &crate::tensor::Layout {
            &self.layout
        }
        fn init_params(&mut self) -> Vec<f32> {
            vec![0.0; 7] // wrong: dim() says 10
        }
        fn grad(&self, _p: &[f32], _w: usize, _n: usize, _s: u64) -> (f64, Vec<f32>) {
            (0.0, vec![0.0; 10])
        }
        fn eval(&mut self, _p: &[f32]) -> (f64, f64) {
            (0.0, 0.0)
        }
        fn name(&self) -> String {
            "bad-dim".into()
        }
    }

    #[test]
    fn inconsistent_source_is_a_typed_error() {
        let err = Session::builder()
            .workers(2)
            .source(Box::new(BadDimSource { layout: crate::tensor::Layout::single(10) }))
            .build()
            .err();
        assert_eq!(err, Some(ConfigError::SourceDimMismatch { params_len: 7, dim: 10 }));
    }

    #[test]
    fn errors_display_actionably() {
        let e = ConfigError::RaggedTopology { n_workers: 6, workers_per_node: 4 };
        let msg = e.to_string();
        assert!(msg.contains('6') && msg.contains('4'), "{msg}");
        // And convert into the anyhow world via `?`.
        fn through_anyhow() -> anyhow::Result<()> {
            Err(ConfigError::ZeroWorkers)?;
            Ok(())
        }
        assert!(through_anyhow().unwrap_err().to_string().contains("n_workers"));
    }

    #[test]
    fn network_spec_resolves_the_scenario_registry_at_build_time() {
        let report = base().static_cr(0.05).network_spec("c2-hostile").build().unwrap().run();
        assert_eq!(report.network, "c2+jitter(0.15)+congestion(0.2,8)");
        // And a plain model plugged in directly names itself too.
        let report = base()
            .static_cr(0.05)
            .network(NetSchedule::c1(10.0))
            .build()
            .unwrap()
            .run();
        assert_eq!(report.network, "c1");
    }

    #[test]
    fn bad_network_specs_are_typed_errors() {
        use crate::netsim::model::NetModelError;
        match base().network_spec("nope").build().err() {
            Some(ConfigError::Network(NetModelError::UnknownScenario { spec })) => {
                assert_eq!(spec, "nope")
            }
            other => panic!("expected UnknownScenario, got {other:?}"),
        }
        assert!(matches!(
            base().network_spec("trace:/nonexistent/trace.csv").build().err(),
            Some(ConfigError::Network(NetModelError::TraceIo { .. }))
        ));
        // NetModelError lifts into ConfigError via `?` (the builder path
        // custom compositions take).
        fn compose() -> Result<crate::netsim::modifiers::Jitter, ConfigError> {
            Ok(crate::netsim::modifiers::Jitter::wrap(NetSchedule::c1(10.0), 2.0, 0)?)
        }
        assert!(matches!(
            compose().err(),
            Some(ConfigError::Network(NetModelError::BadModifier { .. }))
        ));
    }

    #[test]
    fn controller_specs_resolve_the_registry_at_build_time() {
        // Every registered controller is constructible via the builder
        // with a compressed strategy (the ISSUE 5 acceptance surface).
        for name in controller::controller_names() {
            let report = base()
                .strategy(Strategy::parse("flexible").unwrap())
                .static_cr(0.05)
                .controller_spec(name)
                .build()
                .unwrap_or_else(|e| panic!("{name}: {e}"))
                .run();
            assert!(
                report.controller == name || report.controller == "composite",
                "{name} -> {}",
                report.controller
            );
        }
        match base().controller_spec("nope").build().err() {
            Some(ConfigError::Controller(ControllerError::UnknownController { spec })) => {
                assert_eq!(spec, "nope")
            }
            other => panic!("expected UnknownController, got {other:?}"),
        }
    }

    #[test]
    fn cr_adapting_controller_with_dense_strategy_is_a_typed_error() {
        for name in ["moo", "gravac"] {
            match base()
                .strategy(Strategy::parse("dense-ring").unwrap())
                .static_cr(1.0)
                .controller_spec(name)
                .build()
                .err()
            {
                Some(ConfigError::Controller(ControllerError::NeedsCompression {
                    controller,
                    ..
                })) => assert_eq!(controller, name),
                other => panic!("{name}: expected NeedsCompression, got {other:?}"),
            }
        }
    }

    #[test]
    fn policy_windows_validated_at_build() {
        // Boundary: (2, 2) is the smallest valid configuration.
        assert!(base()
            .strategy(Strategy::ArTopkAuto { flavor: crate::artopk::ArFlavor::Ring })
            .static_cr(0.05)
            .policy_windows(2, 2)
            .build()
            .is_ok());
        // Violations are typed errors even when no auto strategy uses
        // them — a bad window never panics (the old PolicySwitcher
        // assert) and never passes silently.
        for (t, c) in [(1u64, 10u64), (0, 0), (5, 4)] {
            assert_eq!(
                base().policy_windows(t, c).build().err(),
                Some(ConfigError::Controller(ControllerError::BadPolicyWindows {
                    trial_window: t,
                    commit_period: c
                })),
                "windows ({t}, {c})"
            );
        }
    }

    #[test]
    fn artopk_auto_composes_the_policy_controller() {
        let report = base()
            .strategy(Strategy::ArTopkAuto { flavor: crate::artopk::ArFlavor::Ring })
            .static_cr(0.05)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.strategy, "AR-Topk-auto");
        assert_eq!(report.controller, "composite");
    }

    /// A custom controller object drives the run through the same seam
    /// the built-ins use: here, a fixed CR schedule.
    #[test]
    fn custom_controller_object_steers_the_cr() {
        use crate::coordinator::controller::{ControlAction, ControlCtx, ControlDecision};
        struct HalveAt(u64);
        impl Controller for HalveAt {
            fn name(&self) -> &'static str {
                "halve-at"
            }
            fn adapts_cr(&self) -> bool {
                true
            }
            fn observe(&mut self, ctx: &ControlCtx<'_>) -> Vec<ControlDecision> {
                if ctx.metrics.step + 1 == self.0 {
                    vec![ControlDecision {
                        by: "halve-at",
                        reason: "schedule",
                        action: ControlAction::SetCr(ctx.cur_cr / 2.0),
                    }]
                } else {
                    Vec::new()
                }
            }
        }
        let report = base()
            .steps(6)
            .strategy(Strategy::parse("artopk-star").unwrap())
            .static_cr(0.08)
            .controller(Box::new(HalveAt(3)))
            .build()
            .unwrap()
            .run();
        assert_eq!(report.controller, "halve-at");
        let crs = report.metrics.crs_used();
        assert!(crs[..3].iter().all(|&c| (c - 0.08).abs() < 1e-12), "{crs:?}");
        assert!(crs[3..].iter().all(|&c| (c - 0.04).abs() < 1e-12), "{crs:?}");
        assert!((report.final_cr - 0.04).abs() < 1e-12);
    }

    /// `--model` specs resolve MODEL_TABLE at build time; unknown names
    /// are the typed [`ConfigError::Model`] listing every registered
    /// model, and an explicit `.source()` wins over the spec.
    #[test]
    fn model_specs_resolve_the_registry_at_build_time() {
        let report = base_no_source().model_spec("mlp").build().unwrap().run();
        assert!(report.model.starts_with("mlp-spirals"), "{}", report.model);
        match base_no_source().model_spec("nope").build().err() {
            Some(ConfigError::Model(ModelError::UnknownModel { spec })) => {
                assert_eq!(spec, "nope")
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        let msg = base_no_source().model_spec("nope").build().err().unwrap().to_string();
        assert!(msg.contains("mlp") && msg.contains("matreg"), "{msg}");
        // Explicit source takes precedence over the spec.
        let report = base().model_spec("matreg").build().unwrap().run();
        assert!(report.model.starts_with("host-mlp"), "{}", report.model);
    }

    /// The `.pool()` seam: a session on an externally-owned pool replays
    /// the privately-pooled run bitwise (same chunking contract), which is
    /// what lets the sweep server share one pool across many sessions.
    #[test]
    fn injected_shared_pool_is_bitwise_invisible() {
        let run = |pool: Option<ThreadPool>| {
            let mut b = base_no_source()
                .model_spec("matreg")
                .threads(2)
                .strategy(Strategy::parse("ag-topk").unwrap())
                .static_cr(0.1);
            if let Some(p) = pool {
                b = b.pool(p);
            }
            b.build().unwrap().run()
        };
        let shared = ThreadPool::auto(2);
        let a = run(None);
        let b = run(Some(shared.clone()));
        let c = run(Some(shared)); // pool reuse across sessions
        assert_eq!(a.params, b.params);
        assert_eq!(b.params, c.params);
        for (x, y) in a.metrics.steps.iter().zip(&b.metrics.steps) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.t_sync.to_bits(), y.t_sync.to_bits());
        }
    }

    #[test]
    fn from_config_roundtrips_the_serialized_form() {
        let cfg = TrainConfig {
            n_workers: 4,
            steps: 2,
            compute: ComputeModel::fixed(0.01),
            cr: CrControl::Static(0.05),
            strategy: Strategy::parse("ag-topk").unwrap(),
            seed: 3,
            ..Default::default()
        };
        let report = Session::from_config(cfg)
            .source(Box::new(HostMlp::default_preset(3)))
            .build()
            .unwrap()
            .run();
        assert_eq!(report.metrics.steps.len(), 2);
        assert_eq!(report.strategy, "AG-compress");
        assert!((report.final_cr - 0.05).abs() < 1e-12);
    }
}
