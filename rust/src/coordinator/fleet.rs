//! Event-driven fleet cost engine: price a full training run for
//! 1024–16384 workers WITHOUT per-worker dense state (DESIGN.md §11).
//!
//! The numeric [`Trainer`](crate::coordinator::trainer::Trainer) carries
//! O(n·dim) gradient/error-feedback state per worker, which caps honest
//! simulation at a few dozen workers — far below the fleet scales where
//! the paper's AG-vs-AR crossovers actually move. [`FleetSim`] drops the
//! numerics and keeps ONLY the cost events: per step it reads the elastic
//! membership ([`NetworkModel::active_workers_at`]), materializes the
//! per-worker link view ([`NetworkModel::worker_link_at`]) as one
//! TRANSIENT `Vec<LinkParams>` (O(n) f64 pairs, freed at step end),
//! prices the exchange with the heterogeneous collective argmin
//! ([`cheapest_hetero`](crate::collectives::cheapest_hetero)), and takes
//! the straggler-scaled critical-path compute time through the same
//! [`ComputeModel::step_time_stragglers`] primitive the trainer uses.
//!
//! Statistical efficiency is a *sampled proxy*: churn shrinks the
//! aggregated batch, so per-step progress is scaled by
//! `sqrt(active / n)` (gradient-noise-scale argument), while fleet-health
//! telemetry (straggler factors, slow-link share) is estimated from a
//! deterministic ≤[`SAMPLE_CAP`]-worker sample per step instead of an
//! exact fleet scan. The run's peak memory-shaped state is accounted in
//! f64 slots and hard-asserted O(n) — `model_bytes` enters only as a
//! scalar, so the bound is independent of model size by construction.

use crate::collectives::cheapest_hetero;
use crate::coordinator::worker::ComputeModel;
use crate::netsim::cost_model::LinkParams;
use crate::netsim::model::NetworkModel;
use crate::netsim::schedule::NetSchedule;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Per-step sample size for the statistical-efficiency / fleet-health
/// proxies: evenly spaced over the active fleet, deterministic.
pub const SAMPLE_CAP: usize = 64;

/// Fixed f64-slot budget for the report accumulators (everything that is
/// not the transient per-worker link view) — part of the O(n) accounting.
const FIXED_STATE_F64S: usize = 32;

/// Cost-only fleet run configuration. No gradient source, no parameter
/// vector: `model_bytes` is the one scalar through which model size
/// enters, so state can never scale with `dim`.
pub struct FleetConfig {
    /// Configured fleet size (churn can idle workers below this).
    pub n_workers: usize,
    pub steps: u64,
    pub steps_per_epoch: u64,
    /// Effective message bytes per exchange (`4 · dim · msg_scale`).
    pub model_bytes: f64,
    /// Compression ratio the priced strategy runs at (1.0 = dense).
    pub cr: f64,
    /// The network environment (per-worker hooks drive everything).
    pub net: Box<dyn NetworkModel>,
    pub compute: ComputeModel,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_workers: 4096,
            steps: 100,
            steps_per_epoch: 50,
            // ResNet-50-class message: 25.6M params * 4 bytes.
            model_bytes: 4.0 * 25.6e6,
            cr: 0.01,
            net: Box::new(NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))),
            compute: ComputeModel::fixed(0.005),
            seed: 0,
        }
    }
}

/// What a fleet run cost, and how healthy the fleet was while paying it.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub n_workers: usize,
    pub steps: u64,
    /// Total simulated seconds: compute + sync + catch-up.
    pub virtual_time_s: f64,
    /// Critical-path compute seconds (straggler-scaled max per step).
    pub compute_s: f64,
    /// Collective sync seconds (heterogeneous round-pattern pricing).
    pub comm_s: f64,
    /// Declared catch-up seconds charged on membership joins.
    pub catchup_s: f64,
    /// Membership edges observed between consecutive steps.
    pub membership_changes: u64,
    /// Smallest active fleet seen during the run.
    pub min_active: usize,
    /// Mean statistical-efficiency proxy over the run:
    /// `sqrt(active / n_workers)` per step, 1.0 for a full fleet.
    pub stat_efficiency: f64,
    /// `steps / stat_efficiency` — steps a full fleet would have needed
    /// for the same progress under the noise-scale proxy.
    pub est_steps_to_parity: f64,
    /// Sampled mean straggler factor over the run (1.0 = no tail).
    pub sampled_mean_straggler: f64,
    /// Worst sampled straggler factor over the run.
    pub sampled_max_straggler: f64,
    /// Sampled share of workers whose link is strictly slower than the
    /// backbone `link_at` view (heterogeneous-fleet fingerprint).
    pub slow_link_share: f64,
    /// Steps won per collective, by registry name (pricing argmin).
    pub collective_counts: Vec<(&'static str, u64)>,
    /// Peak memory-shaped state in f64 slots: the transient per-worker
    /// link view plus fixed accumulators. Hard-asserted ≤ `2n + 64` at
    /// the end of every run — O(n), never O(n·dim).
    pub peak_state_f64s: usize,
}

impl FleetReport {
    /// The collective that won the most steps.
    pub fn dominant_collective(&self) -> Option<&'static str> {
        self.collective_counts.iter().max_by_key(|(_, c)| *c).map(|(n, _)| *n)
    }
}

/// The event-driven fleet cost engine. See the module docs for the model;
/// [`FleetSim::run`] is deterministic for a given config (pure-function
/// network hooks + a dedicated seeded compute stream).
pub struct FleetSim {
    cfg: FleetConfig,
}

impl FleetSim {
    pub fn new(cfg: FleetConfig) -> Self {
        assert!(cfg.n_workers >= 1, "fleet of zero workers");
        assert!(cfg.steps_per_epoch >= 1, "steps_per_epoch must be >= 1");
        FleetSim { cfg }
    }

    pub fn run(&self) -> FleetReport {
        let cfg = &self.cfg;
        let n = cfg.n_workers;
        let mut compute_rng = Rng::new(cfg.seed ^ 0xC0317);
        let mut compute_s = 0.0;
        let mut comm_s = 0.0;
        let mut catchup_s = 0.0;
        let mut membership_changes = 0u64;
        let mut min_active = n;
        let mut eff_sum = 0.0;
        let mut straggler_sum = 0.0;
        let mut straggler_samples = 0u64;
        let mut straggler_max: f64 = 1.0;
        let mut slow_links = 0u64;
        let mut counts: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut last_active: Option<usize> = None;
        let mut peak_state = FIXED_STATE_F64S;

        for step in 0..cfg.steps {
            let epoch = step as f64 / cfg.steps_per_epoch as f64;
            let active = cfg.net.active_workers_at(epoch, n);
            min_active = min_active.min(active);

            // Membership edge: count it, and charge the environment's
            // declared catch-up cost when the fleet GREW.
            if let Some(prev) = last_active {
                if prev != active {
                    membership_changes += 1;
                    if active > prev {
                        catchup_s += cfg.net.catchup_cost_at(epoch, cfg.model_bytes);
                    }
                }
            }
            last_active = Some(active);

            // Critical-path compute: the same straggler-scaled primitive
            // the numeric trainer uses (§7 purity contract).
            compute_s += cfg.compute.step_time_stragglers(active, &mut compute_rng, |w| {
                cfg.net.straggler_factor(w, step)
            });

            // Per-worker cost event: ONE transient O(active) link view,
            // priced by the heterogeneous collective argmin.
            let links: Vec<LinkParams> =
                (0..active).map(|w| cfg.net.worker_link_at(w, epoch)).collect();
            peak_state = peak_state.max(FIXED_STATE_F64S + 2 * links.len());
            let topo = cfg.net.topology_at(epoch);
            let (op, cost) = cheapest_hetero(topo, &links, cfg.model_bytes, cfg.cr);
            *counts.entry(op.kind().name()).or_insert(0) += 1;
            comm_s += cost;

            // Sampled proxies: statistical efficiency from the membership
            // noise scale, fleet health from a ≤SAMPLE_CAP evenly spaced
            // worker sample (deterministic — no RNG).
            eff_sum += (active as f64 / n as f64).sqrt();
            let k = active.min(SAMPLE_CAP);
            let backbone = cfg.net.link_at(epoch);
            for i in 0..k {
                let w = i * active / k;
                let f = cfg.net.straggler_factor(w, step);
                straggler_sum += f;
                straggler_max = straggler_max.max(f);
                let l = links[w];
                if l.alpha > backbone.alpha || l.beta > backbone.beta {
                    slow_links += 1;
                }
            }
            straggler_samples += k as u64;
        }

        // The O(n)-not-O(n·dim) contract, enforced at every run: the
        // transient link view is 2 f64s per worker, everything else is a
        // fixed handful of accumulators.
        assert!(
            peak_state <= 2 * n + 2 * FIXED_STATE_F64S,
            "fleet state grew past O(n): {peak_state} f64s for n={n}"
        );

        let steps_f = (cfg.steps.max(1)) as f64;
        let stat_efficiency =
            if cfg.steps == 0 { 1.0 } else { eff_sum / steps_f };
        FleetReport {
            n_workers: n,
            steps: cfg.steps,
            virtual_time_s: compute_s + comm_s + catchup_s,
            compute_s,
            comm_s,
            catchup_s,
            membership_changes,
            min_active,
            stat_efficiency,
            est_steps_to_parity: steps_f / stat_efficiency,
            sampled_mean_straggler: if straggler_samples == 0 {
                1.0
            } else {
                straggler_sum / straggler_samples as f64
            },
            sampled_max_straggler: straggler_max,
            slow_link_share: if straggler_samples == 0 {
                0.0
            } else {
                slow_links as f64 / straggler_samples as f64
            },
            collective_counts: counts.into_iter().collect(),
            peak_state_f64s: peak_state,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::model::build_scenario;

    fn cfg_for(scenario: &str, n: usize, steps: u64) -> FleetConfig {
        FleetConfig {
            n_workers: n,
            steps,
            steps_per_epoch: steps.max(4) / 4,
            net: build_scenario(scenario, 2.0).unwrap(),
            ..Default::default()
        }
    }

    #[test]
    fn homogeneous_fleet_prices_like_the_closed_form() {
        let cfg = FleetConfig { n_workers: 1024, steps: 20, ..Default::default() };
        let report = FleetSim::new(cfg).run();
        // Static 4ms/20Gbps, fixed compute: every step costs the same.
        let per_step_comm = report.comm_s / 20.0;
        let links = vec![LinkParams::from_ms_gbps(4.0, 20.0); 1024];
        let topo = crate::netsim::cost_model::Topology::flat(links[0]);
        let (_, expect) = cheapest_hetero(topo, &links, 4.0 * 25.6e6, 0.01);
        assert!((per_step_comm - expect).abs() < 1e-12, "{per_step_comm} vs {expect}");
        assert!((report.compute_s - 20.0 * 0.005).abs() < 1e-12);
        assert_eq!(report.membership_changes, 0);
        assert_eq!(report.min_active, 1024);
        assert!((report.stat_efficiency - 1.0).abs() < 1e-12);
        assert!((report.sampled_mean_straggler - 1.0).abs() < 1e-12);
        assert_eq!(report.slow_link_share, 0.0);
        assert_eq!(report.collective_counts.iter().map(|(_, c)| c).sum::<u64>(), 20);
    }

    #[test]
    fn fleet_runs_4096_workers_with_o_n_state_independent_of_model_size() {
        for scenario in ["hetero", "straggler", "churn"] {
            let small = FleetSim::new(FleetConfig {
                model_bytes: 1e6,
                ..cfg_for(scenario, 4096, 40)
            })
            .run();
            let big = FleetSim::new(FleetConfig {
                model_bytes: 1e9,
                ..cfg_for(scenario, 4096, 40)
            })
            .run();
            assert!(small.virtual_time_s > 0.0 && big.virtual_time_s > small.virtual_time_s);
            // The O(n) contract: state never scales with model size.
            assert_eq!(small.peak_state_f64s, big.peak_state_f64s, "{scenario}");
            assert!(small.peak_state_f64s <= 2 * 4096 + 64, "{scenario}");
        }
    }

    #[test]
    fn churn_fleet_reports_membership_and_catchup() {
        let report = FleetSim::new(cfg_for("churn", 1024, 40)).run();
        // Registry churn at 2.0 epochs / spe 10: leave at step 5, leave at
        // step 10, rejoin at step 15 -> 3 edges, one join charge.
        assert_eq!(report.membership_changes, 3);
        assert!(report.catchup_s > 0.0);
        assert!(report.min_active < 1024);
        assert!(report.stat_efficiency < 1.0);
        assert!(report.est_steps_to_parity > 40.0);
    }

    #[test]
    fn hetero_fleet_sees_slow_links_and_straggler_fleet_sees_tails() {
        let hetero = FleetSim::new(cfg_for("hetero", 2048, 20)).run();
        assert!(
            hetero.slow_link_share > 0.05 && hetero.slow_link_share < 0.55,
            "sampled slow share {} must resemble the configured 0.25",
            hetero.slow_link_share
        );
        assert!((hetero.sampled_max_straggler - 1.0).abs() < 1e-12);
        // A heterogeneous fleet is strictly more expensive than the same
        // fleet on its backbone link alone.
        let flat = FleetSim::new(cfg_for("c1", 2048, 20)).run();
        assert!(hetero.comm_s > 0.0 && flat.comm_s > 0.0);

        let straggler = FleetSim::new(cfg_for("straggler", 2048, 20)).run();
        assert!(straggler.sampled_max_straggler > 1.5, "{}", straggler.sampled_max_straggler);
        assert!(straggler.sampled_mean_straggler > 1.0);
        assert!(straggler.compute_s > 20.0 * 0.005, "tails stretch the critical path");
        assert_eq!(straggler.slow_link_share, 0.0);
    }

    #[test]
    fn fleet_runs_are_deterministic() {
        let a = FleetSim::new(cfg_for("hetero", 1024, 16)).run();
        let b = FleetSim::new(cfg_for("hetero", 1024, 16)).run();
        assert_eq!(a, b);
        assert_eq!(a.dominant_collective(), b.dominant_collective());
    }
}
