//! L3 coordinator: the synchronous data-parallel training loop, the
//! Session API (builder-validated configs, pluggable communication
//! strategies, typed observer stream — DESIGN.md §8), collective selection
//! (Eqn 5), and the pluggable control plane (CR/collective/policy
//! controllers incl. the §3-E MOO controller — DESIGN.md §10).

pub mod checkpoint;
pub mod controller;
pub mod fleet;
pub mod metrics;
pub mod observer;
pub mod policy_switch;
pub mod selector;
pub mod session;
pub mod strategy;
pub mod sweep;
pub mod trainer;
pub mod worker;

pub use controller::{
    AdaptiveConfig, ControlAction, ControlCtx, ControlDecision, Controller,
    ControllerError, GravacConfig, CONTROLLER_TABLE,
};
pub use fleet::{FleetConfig, FleetReport, FleetSim};
pub use metrics::{MetricsLog, StepMetrics};
pub use observer::{
    CrChange, CsvSink, EvalRecord, MembershipChange, NetChange, ProgressPrinter,
    StrategySwitch, SwitchDimension, TrainObserver,
};
pub use session::{ConfigError, Session, SessionBuilder, TrainReport};
pub use strategy::{CommPlan, CommStrategy, ExchangeCtx, ExchangeOutcome, StepCtx};
pub use sweep::{SweepCell, SweepError, SweepObserver, SweepReport, SweepRow, SweepSpec};
pub use trainer::{Strategy, TrainConfig, Trainer};
pub use worker::{ComputeModel, GradSource};
