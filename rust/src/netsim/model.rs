//! The [`NetworkModel`] trait: pluggable network environments.
//!
//! The paper's premise is that the *best* communication strategy shifts
//! with network conditions — which means the network side must be as
//! pluggable as the strategy side. [`NetworkModel`] is the environment
//! counterpart of `CommStrategy`: the trainer, probe and selector read
//! link conditions ONLY through this trait, so a new environment (a
//! measured trace, a synthetic failure pattern, a diurnal WAN) is a new
//! impl — not `netsim/schedule.rs` surgery.
//!
//! Implementations shipped here:
//! * [`NetSchedule`](crate::netsim::schedule::NetSchedule) — piecewise
//!   schedules incl. the paper's C1/C2 (Fig 6).
//! * [`TraceModel`](crate::netsim::trace::TraceModel) — replays measured
//!   (epoch, α, β) traces from CSV/JSON files.
//! * The [`modifiers`](crate::netsim::modifiers) wrappers — jitter,
//!   congestion episodes, diurnal load, link flapping, asymmetric
//!   degradation, two-level topology — compose over any model.
//!
//! [`NET_TABLE`] is the scenario registry: one name table feeding CLI
//! parsing, `--help` text and error listings, exactly like the strategy
//! side's `STRATEGY_TABLE`.

use crate::netsim::cost_model::{LinkParams, Topology};
use crate::netsim::modifiers::{
    AsymmetricDegrade, Churn, CongestionEpisodes, Diurnal, Flapping, HeterogeneousLinks,
    Jitter, StragglerTail,
};
use crate::netsim::schedule::NetSchedule;
use crate::netsim::trace::TraceModel;
use std::fmt;

/// A (possibly time-varying) network environment: everything the trainer,
/// probe and cost model ever ask about the cluster's links.
///
/// Determinism contract: `link_at` and `topology_at` must be pure
/// functions of `(self, epoch)` — the same model at the same fractional
/// epoch always reports the same parameters, so experiments replay
/// exactly and threads=1 vs threads=N runs stay bitwise identical under
/// static CR control (DESIGN.md §7/§9).
pub trait NetworkModel: fmt::Debug + Send + Sync {
    /// Effective inter-node link parameters at a fractional epoch.
    fn link_at(&self, epoch: f64) -> LinkParams;

    /// Full cluster topology at a fractional epoch. Defaults to a flat
    /// single-link cluster riding [`NetworkModel::link_at`].
    fn topology_at(&self, epoch: f64) -> Topology {
        Topology::flat(self.link_at(epoch))
    }

    /// Effective link of ONE specific worker at a fractional epoch.
    ///
    /// Defaults to the fleet-shared [`NetworkModel::link_at`], so every
    /// pre-existing model is a homogeneous fleet and replays bitwise
    /// identically. Heterogeneous environments (fast/slow mixes) override
    /// this per worker id; like `link_at` it must be a pure function of
    /// `(self, worker, epoch)`.
    fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
        let _ = worker;
        self.link_at(epoch)
    }

    /// Multiplicative tail-latency factor (>= 1) on worker `worker`'s
    /// compute time at `step`. Defaults to 1 (no stragglers). Must be a
    /// pure function of `(self, worker, step)` — never of the thread
    /// schedule — so the §7 thread-invariance contract extends to
    /// straggler fleets.
    fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
        let _ = (worker, step);
        1.0
    }

    /// Live workers at a fractional epoch out of a configured fleet of
    /// `n`. Defaults to `n` (fixed membership). Implementations clamp to
    /// `[1, n]`: the numeric engine sizes per-worker state for `n` up
    /// front, so churn can idle workers but never mint new ones.
    fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
        let _ = epoch;
        n
    }

    /// Declared parameter catch-up cost (simulated seconds) charged when
    /// the engine observes a membership GROWTH at `epoch` — a joiner must
    /// stream the current `model_bytes` before it contributes. Defaults
    /// to free (no churn). Leave events declare no catch-up.
    fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
        let _ = (epoch, model_bytes);
        0.0
    }

    /// Short base name (registry/CLI identity of the underlying scenario).
    fn name(&self) -> &str;

    /// Full self-describing identity — base name plus every modifier in
    /// composition order (e.g. `c2+jitter(0.15)+congestion(0.2,8)`).
    /// This is the string metrics/CSV output carries, so two runs are
    /// comparable iff their `describe()` strings match.
    fn describe(&self) -> String {
        self.name().to_string()
    }

    /// Clone into a boxed trait object (`TrainConfig` must stay `Clone`).
    fn clone_model(&self) -> Box<dyn NetworkModel>;
}

impl Clone for Box<dyn NetworkModel> {
    fn clone(&self) -> Self {
        self.clone_model()
    }
}

/// A boxed model is itself a model, so registry/spec output composes
/// directly into the [`modifiers`](crate::netsim::modifiers) wrappers
/// (e.g. `Jitter::wrap(parse_spec("c2", 50.0)?, 0.05, seed)`).
impl NetworkModel for Box<dyn NetworkModel> {
    fn link_at(&self, epoch: f64) -> LinkParams {
        (**self).link_at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        (**self).topology_at(epoch)
    }

    fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
        (**self).worker_link_at(worker, epoch)
    }

    fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
        (**self).straggler_factor(worker, step)
    }

    fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
        (**self).active_workers_at(epoch, n)
    }

    fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
        (**self).catchup_cost_at(epoch, model_bytes)
    }

    fn name(&self) -> &str {
        (**self).name()
    }

    fn describe(&self) -> String {
        (**self).describe()
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        (**self).clone_model()
    }
}

/// A network environment the loader/composer refused. Every variant is a
/// misconfiguration that used to be an `assert!` (or a silent
/// mid-experiment panic); carried by
/// [`ConfigError::Network`](crate::coordinator::session::ConfigError) into
/// the Session builder's typed-error surface.
#[derive(Debug, Clone, PartialEq)]
pub enum NetModelError {
    /// Trace file could not be read.
    TraceIo { path: String, reason: String },
    /// Trace file line that did not parse.
    TraceParse { path: String, line: usize, reason: String },
    /// Trace file with no usable points.
    EmptyTrace { path: String },
    /// Trace points not strictly increasing in epoch.
    UnsortedTrace { path: String, line: usize },
    /// A modifier wrapper given out-of-range parameters.
    BadModifier { modifier: &'static str, reason: String },
    /// `--net` spec naming no registry scenario (lists the valid names).
    UnknownScenario { spec: String },
}

impl fmt::Display for NetModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetModelError::TraceIo { path, reason } => {
                write!(f, "trace `{path}`: {reason}")
            }
            NetModelError::TraceParse { path, line, reason } => {
                write!(f, "trace `{path}` line {line}: {reason}")
            }
            NetModelError::EmptyTrace { path } => {
                write!(f, "trace `{path}`: no trace points")
            }
            NetModelError::UnsortedTrace { path, line } => write!(
                f,
                "trace `{path}` line {line}: epochs must be strictly increasing"
            ),
            NetModelError::BadModifier { modifier, reason } => {
                write!(f, "network modifier `{modifier}`: {reason}")
            }
            NetModelError::UnknownScenario { spec } => write!(
                f,
                "unknown network scenario `{spec}` (valid: {}; or `trace:<path>` \
                 to replay a measured CSV/JSON trace)",
                scenario_names().collect::<Vec<_>>().join(", ")
            ),
        }
    }
}

impl std::error::Error for NetModelError {}

/// One scenario registry row: a name, a one-line summary (printed by
/// `--help`-style listings), and a constructor scaled to the run's total
/// epoch count (the paper's schedules are defined over 50 epochs and
/// stretch to the run length, Fig 6).
pub struct NetScenario {
    pub name: &'static str,
    pub summary: &'static str,
    pub build: fn(total_epochs: f64) -> Box<dyn NetworkModel>,
}

/// The one scenario-name table: CLI parsing ([`parse_spec`]), usage text
/// and preset error listings all read from here, so a new environment is
/// one new row (mirror of the strategy side's `STRATEGY_TABLE`).
pub const NET_TABLE: &[NetScenario] = &[
    NetScenario {
        name: "c1",
        summary: "paper Fig 6a: 4 phases, one big latency+bandwidth swing",
        build: |e| Box::new(NetSchedule::c1(e)),
    },
    NetScenario {
        name: "c2",
        summary: "paper Fig 6b: 5 phases, degrades then recovers",
        build: |e| Box::new(NetSchedule::c2(e)),
    },
    NetScenario {
        name: "c1-jitter",
        summary: "C1 with ±5% multiplicative link jitter",
        build: |e| {
            Box::new(
                Jitter::wrap(NetSchedule::c1(e), 0.05, 11).expect("registry params valid"),
            )
        },
    },
    NetScenario {
        name: "c2-congested",
        summary: "C2 with 15%-probability 8x bandwidth-collapse episodes",
        build: |e| {
            Box::new(
                CongestionEpisodes::wrap(NetSchedule::c2(e), 0.15, 8.0, 12)
                    .expect("registry params valid"),
            )
        },
    },
    NetScenario {
        name: "c2-hostile",
        summary: "C2 + 15% jitter + 20%-probability 8x congestion episodes",
        build: |e| {
            let jittered =
                Jitter::wrap(NetSchedule::c2(e), 0.15, 13).expect("registry params valid");
            Box::new(
                CongestionEpisodes::wrap(jittered, 0.2, 8.0, 14)
                    .expect("registry params valid"),
            )
        },
    },
    NetScenario {
        name: "diurnal",
        summary: "shared WAN day/night cycle: bandwidth swings ±50% sinusoidally",
        build: |e| {
            let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
            Box::new(
                Diurnal::wrap(base, 0.5, (e / 5.0).max(0.2)).expect("registry params valid"),
            )
        },
    },
    NetScenario {
        name: "flaky",
        summary: "link flaps: 30% of every cycle on a 16x-degraded backup path",
        build: |e| {
            let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
            Box::new(
                Flapping::wrap(base, (e / 10.0).max(0.1), 0.3, 16.0)
                    .expect("registry params valid"),
            )
        },
    },
    NetScenario {
        name: "asym",
        summary: "asymmetric degradation: 50x latency at full bandwidth (AG corner)",
        build: |_| {
            let base = NetSchedule::static_link(LinkParams::from_ms_gbps(1.0, 25.0));
            Box::new(AsymmetricDegrade::wrap(base, 50.0, 1.0).expect("registry params valid"))
        },
    },
    NetScenario {
        name: "straggler",
        summary: "10% per-(worker,step) chance of a compute tail up to 8x (Agarwal-style)",
        build: |_| {
            let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
            Box::new(StragglerTail::wrap(base, 0.1, 8.0, 21).expect("registry params valid"))
        },
    },
    NetScenario {
        name: "hetero",
        summary: "per-worker links: 25% of the fleet rides an 8x-degraded path",
        build: |_| {
            let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
            Box::new(
                HeterogeneousLinks::wrap(base, 0.25, 8.0, 22).expect("registry params valid"),
            )
        },
    },
    NetScenario {
        name: "churn",
        summary: "elastic fleet: -25% at 1/4-run, -12.5% at mid-run, rejoin at 3/4",
        build: |e| {
            let base = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
            let events = vec![(e * 0.25, -0.25), (e * 0.5, -0.125), (e * 0.75, 0.375)];
            Box::new(Churn::wrap(base, events, 1.0).expect("registry params valid"))
        },
    },
];

/// Every registered scenario name, in table order (usage/help text).
pub fn scenario_names() -> impl Iterator<Item = &'static str> {
    NET_TABLE.iter().map(|s| s.name)
}

/// Build a registry scenario by name, scaled to `total_epochs`.
pub fn build_scenario(
    name: &str,
    total_epochs: f64,
) -> Result<Box<dyn NetworkModel>, NetModelError> {
    match NET_TABLE.iter().find(|s| s.name == name) {
        Some(s) => Ok((s.build)(total_epochs)),
        None => Err(NetModelError::UnknownScenario { spec: name.to_string() }),
    }
}

/// Parse a `--net` spec: a registry scenario name, or `trace:<path>` to
/// replay a measured trace file. The error lists every valid name.
pub fn parse_spec(
    spec: &str,
    total_epochs: f64,
) -> Result<Box<dyn NetworkModel>, NetModelError> {
    match spec.strip_prefix("trace:") {
        Some(path) => Ok(Box::new(TraceModel::load(path)?)),
        None => build_scenario(spec, total_epochs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_build() {
        let mut seen = std::collections::BTreeSet::new();
        for s in NET_TABLE {
            assert!(seen.insert(s.name), "duplicate scenario name {}", s.name);
            let m = (s.build)(50.0);
            assert!(!m.describe().is_empty());
            for e in [0.0, 7.3, 25.0, 49.9, 80.0] {
                let l = m.link_at(e);
                assert!(l.alpha >= 0.0 && l.alpha.is_finite(), "{} α at {e}", s.name);
                assert!(l.beta > 0.0 && l.beta.is_finite(), "{} β at {e}", s.name);
                let t = m.topology_at(e);
                assert_eq!(t.inter, l, "{}: topology must ride link_at", s.name);
            }
        }
    }

    #[test]
    fn parse_spec_resolves_names_and_lists_them_on_error() {
        for s in NET_TABLE {
            assert!(parse_spec(s.name, 50.0).is_ok(), "{}", s.name);
        }
        let err = parse_spec("nope", 50.0).unwrap_err().to_string();
        assert!(err.contains("c1") && err.contains("flaky") && err.contains("trace:"), "{err}");
    }

    #[test]
    fn parse_spec_trace_prefix_reports_io_errors_typed() {
        let err = parse_spec("trace:/nonexistent/file.csv", 50.0).unwrap_err();
        assert!(matches!(err, NetModelError::TraceIo { .. }), "{err:?}");
    }

    #[test]
    fn boxed_models_clone_and_describe() {
        let m = build_scenario("c2-hostile", 50.0).unwrap();
        let c = m.clone();
        assert_eq!(m.describe(), c.describe());
        assert_eq!(m.name(), "c2");
        assert!(m.describe().contains("jitter") && m.describe().contains("congestion"));
        assert_eq!(m.link_at(17.7), c.link_at(17.7));
    }

    #[test]
    fn scenarios_are_deterministic_per_epoch() {
        for s in NET_TABLE {
            let (a, b) = ((s.build)(50.0), (s.build)(50.0));
            for e in [0.0, 3.14, 42.0] {
                let (la, lb) = (a.link_at(e), b.link_at(e));
                assert_eq!(la, lb, "{} at {e}", s.name);
            }
        }
    }

    /// The fleet hooks ship with homogeneous defaults: every scenario that
    /// does not opt into heterogeneity/churn must report per-worker links
    /// bitwise equal to the shared link, unit straggler factors and fixed
    /// membership — that is the "pre-existing trajectories are untouched"
    /// half of the ISSUE 7 contract. The three fleet scenarios must be
    /// deterministic per (worker, step/epoch) and clamp membership sanely.
    #[test]
    fn fleet_hooks_default_homogeneous_and_stay_deterministic() {
        let fleet = ["straggler", "hetero", "churn"];
        for s in NET_TABLE {
            let m = (s.build)(50.0);
            let twin = (s.build)(50.0);
            for e in [0.0, 12.5, 49.9] {
                for w in [0usize, 3, 17, 1023] {
                    assert_eq!(
                        m.worker_link_at(w, e),
                        twin.worker_link_at(w, e),
                        "{} worker {w} at {e}",
                        s.name
                    );
                    let f = m.straggler_factor(w, 7);
                    assert!(f >= 1.0 && f.is_finite(), "{} factor {f}", s.name);
                    assert_eq!(f, twin.straggler_factor(w, 7), "{}", s.name);
                    if !fleet.contains(&s.name) {
                        assert_eq!(m.worker_link_at(w, e), m.link_at(e), "{}", s.name);
                        assert_eq!(f, 1.0, "{}", s.name);
                    }
                }
                let n = m.active_workers_at(e, 1024);
                assert!((1..=1024).contains(&n), "{} active {n}", s.name);
                if !fleet.contains(&s.name) {
                    assert_eq!(n, 1024, "{}", s.name);
                    assert_eq!(m.catchup_cost_at(e, 1e8), 0.0, "{}", s.name);
                }
                assert!(m.catchup_cost_at(e, 1e8) >= 0.0, "{}", s.name);
            }
        }
        // The fleet rows actually move their respective hooks.
        let het = build_scenario("hetero", 50.0).unwrap();
        assert!((0..64).any(|w| het.worker_link_at(w, 1.0) != het.link_at(1.0)));
        let st = build_scenario("straggler", 50.0).unwrap();
        assert!((0..64).any(|w| st.straggler_factor(w, 3) > 1.0));
        let ch = build_scenario("churn", 50.0).unwrap();
        assert!(ch.active_workers_at(20.0, 1024) < 1024);
        assert_eq!(ch.active_workers_at(0.0, 1024), 1024);
    }
}
