//! Mini property-testing harness (offline build: no `proptest` crate).
//!
//! Usage:
//! ```ignore
//! check("ring allreduce averages", 200, |g| {
//!     let n = g.usize_in(2, 16);
//!     let xs = g.vec_f32(n, -1.0, 1.0);
//!     ...
//!     ensure(cond, "message")
//! });
//! ```
//!
//! Each case runs with a seed derived from a base seed (overridable with
//! `FLEXCOMM_PROP_SEED` for reproduction); failures panic with the exact
//! per-case seed so a single case replays via `FLEXCOMM_PROP_SEED=<seed>
//! FLEXCOMM_PROP_ONLY=1`.

use crate::util::rng::Rng;

/// Per-case input generator: a seeded RNG with convenience draws.
pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        self.rng.range_usize(lo, hi_inclusive + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, std);
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Property result: `Ok(())` passes, `Err(msg)` fails the case.
pub type PropResult = Result<(), String>;

/// Assert helper for properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are within `tol` (absolute + relative).
pub fn close(a: f64, b: f64, tol: f64) -> PropResult {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("expected {a} ≈ {b} (tol {tol})"))
    }
}

/// Assert two f32 slices are elementwise close.
pub fn all_close(a: &[f32], b: &[f32], tol: f32) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0_f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing test)
/// with the reproducing seed on first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base: u64 = std::env::var("FLEXCOMM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_F00D);
    let only_one = std::env::var("FLEXCOMM_PROP_ONLY").is_ok();
    let total = if only_one { 1 } else { cases };
    for case in 0..total {
        let seed = base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case)
            .wrapping_add(fxhash(name));
        let mut g = Gen { rng: Rng::new(seed) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` failed on case {case}/{cases}: {msg}\n\
                 reproduce with FLEXCOMM_PROP_SEED={seed} FLEXCOMM_PROP_ONLY=1"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            count += 1;
            let n = g.usize_in(1, 10);
            ensure((1..=10).contains(&n), "range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            ensure(x < 0.0, format!("x={x} not negative"))
        });
    }

    #[test]
    fn close_and_all_close() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1.0, 2.0, 1e-6).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }
}
