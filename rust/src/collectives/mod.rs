//! Communication collectives over in-process worker buffers.
//!
//! Every op REALLY moves/reduces the data (numerics are exact, not mocked)
//! and returns the wall-time a cluster of N single-GPU nodes on the
//! simulated link would have spent, derived from the op's round structure:
//! each round costs `α + bytes_sent_per_worker · β`, charged against the
//! link that round actually crosses (the two-level
//! [`hierarchical_allreduce`] mixes intra- and inter-node rounds). For
//! power-of-two N the totals equal the closed forms in
//! [`crate::netsim::cost_model`] — that equivalence is what the unit tests
//! pin down (the paper validates the same algebra on hardware in Tables
//! II/VI). Round structures per op are documented in DESIGN.md §4.
//!
//! The ops are also exposed uniformly through the [`Collective`] /
//! [`DenseCollective`] traits and their [`registry`]: the trainer's dense
//! path and the topology-aware selector dispatch through the table instead
//! of per-flavor matches, so a new collective plugs in at one seam (a
//! `CollectiveKind`, an impl, a registry row).

pub mod allgather;
pub mod broadcast;
pub mod halving_doubling;
pub mod hierarchical;
pub mod ps;
pub mod ring_allreduce;
pub mod tree_allreduce;

pub use allgather::{allgather_concat, allgather_sparse};
pub use broadcast::broadcast;
pub use halving_doubling::halving_doubling_allreduce;
pub use hierarchical::hierarchical_allreduce;
pub use ps::ps_exchange;
pub use ring_allreduce::ring_allreduce;
pub use tree_allreduce::tree_allreduce;

use crate::netsim::cost_model::{self, LinkParams, Topology};

/// Simulated time + traffic accounting for one collective call.
///
/// Accumulated round by round (crate-internal `add_round`): each
/// latency-bearing round contributes `α + bytes·β` simulated seconds on the
/// link it crosses, `bytes` to the per-worker egress, and one to `rounds`.
/// Reports from sub-phases that run on different links (e.g. the
/// hierarchical op's intra-reduce and inter-ring) compose with
/// [`CommReport::merge`] — seconds and rounds add, so the totals stay
/// comparable with the closed-form α-β costs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommReport {
    /// Simulated wall-clock seconds for the whole op.
    pub seconds: f64,
    /// Total bytes a single worker put on the wire (per-worker egress; for
    /// ops whose per-round sends are uneven this is the max-loaded worker,
    /// the one the synchronous step waits for).
    pub bytes_per_worker: f64,
    /// Number of latency-bearing rounds (α charges).
    pub rounds: u32,
}

impl CommReport {
    pub(crate) fn add_round(&mut self, link: LinkParams, bytes: f64) {
        self.seconds += link.alpha + bytes * link.beta;
        self.bytes_per_worker += bytes;
        self.rounds += 1;
    }

    pub fn merge(&mut self, other: CommReport) {
        self.seconds += other.seconds;
        self.bytes_per_worker += other.bytes_per_worker;
        self.rounds += other.rounds;
    }
}

/// Which collective a training step used (for the Fig 8 density plots and
/// the metrics log).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    RingAllreduce,
    TreeAllreduce,
    /// Recursive halving-doubling (Rabenseifner) dense allreduce.
    HalvingDoublingAllreduce,
    /// Two-level intra-reduce / inter-ring / intra-broadcast allreduce.
    HierarchicalAllreduce,
    AllgatherTopk,
    ArTopkRing,
    ArTopkTree,
    PsStar,
    /// A strategy outside the built-in registry (plugged in through
    /// `SessionBuilder::comm_strategy`): the label is the metrics identity
    /// it reports under. Custom kinds have no registry row — [`dense_op`]
    /// returns `None` and the auto-selectors never consider them.
    Custom(&'static str),
}

impl CollectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::RingAllreduce => "Ring-AR",
            CollectiveKind::TreeAllreduce => "Tree-AR",
            CollectiveKind::HalvingDoublingAllreduce => "HD-AR",
            CollectiveKind::HierarchicalAllreduce => "Hier-AR",
            CollectiveKind::AllgatherTopk => "AG",
            CollectiveKind::ArTopkRing => "ART-Ring",
            CollectiveKind::ArTopkTree => "ART-Tree",
            CollectiveKind::PsStar => "PS",
            CollectiveKind::Custom(label) => label,
        }
    }
}

pub(crate) fn ceil_log2(n: usize) -> u32 {
    assert!(n >= 1);
    usize::BITS - (n - 1).leading_zeros()
}

// ---------------------------------------------------------------------------
// The Collective trait + registry (ISSUE 2 tentpole): one seam unifying the
// eight collectives behind trait objects, so selector choices, metrics
// `CollectiveKind`s and future collectives plug in at a single table instead
// of nested matches in the trainer.
// ---------------------------------------------------------------------------

/// A collective viewed uniformly: its metrics identity ([`CollectiveKind`])
/// and its closed-form α-β cost prediction. All eight [`CollectiveKind`]s
/// implement this (see [`registry`]); the five dense allreduces additionally
/// implement [`DenseCollective`] with a real data-moving execution.
pub trait Collective: Send + Sync {
    /// Metrics/selector identity of this op.
    fn kind(&self) -> CollectiveKind;

    /// Short display name (the [`CollectiveKind::name`]).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Predicted seconds for one full-model exchange of `m_bytes` over
    /// `topo` with `n` ranks at compression ratio `cr` (dense ops ignore
    /// `cr`; flat ops price the bottleneck `topo.inter` link). The
    /// hierarchical op requires `topo.workers_per_node` to divide `n`, the
    /// same precondition as its execution.
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, cr: f64) -> f64;

    /// Whether the dense auto-selectors may pick this op for an `n`-rank
    /// cluster on `topo`: the PS star is a scale-out strawman (never
    /// auto-picked) and the hierarchical op needs a two-level topology
    /// whose `workers_per_node` divides `n` (its `predict`/`run`
    /// precondition — gating here keeps direct selector callers from
    /// tripping the divisibility assert).
    fn auto_candidate(&self, topo: Topology, n: usize) -> bool {
        let _ = (topo, n);
        true
    }

    /// Predicted seconds for one exchange over a HETEROGENEOUS fleet: one
    /// link per worker (`links.len()` ranks), each round priced by the
    /// slowest participant of that round's pattern (ISSUE 7 cost layer).
    ///
    /// The default prices the fleet's componentwise-slowest link with the
    /// homogeneous closed form — exact when all links coincide (the fast
    /// path the pattern-aware overrides also take), conservative
    /// otherwise. Ring/HD/hierarchical and the compressed trio
    /// (AG-Topk, ART-Ring, ART-Tree) override with true per-round pattern
    /// costs; ops whose pattern is not yet modelled per-round (tree, PS)
    /// inherit the conservative default.
    fn predict_hetero(&self, topo: Topology, links: &[LinkParams], m_bytes: f64, cr: f64) -> f64 {
        let slow = cost_model::slowest_link(links);
        let t = Topology { inter: slow, ..topo };
        self.predict(t, m_bytes, links.len(), cr)
    }
}

/// A dense in-place SUM allreduce: really moves/reduces the per-worker
/// buffers and reports the simulated time (same contract as the free
/// functions it wraps — the registry tests pin the equivalence).
pub trait DenseCollective: Collective {
    fn run(&self, bufs: &mut [Vec<f32>], topo: Topology) -> CommReport;
}

/// [`ring_allreduce`] over the bottleneck (inter) link.
pub struct RingAllreduceOp;
/// [`tree_allreduce`] over the bottleneck (inter) link.
pub struct TreeAllreduceOp;
/// [`halving_doubling_allreduce`] over the bottleneck (inter) link.
pub struct HalvingDoublingOp;
/// [`hierarchical_allreduce`] over the full two-level topology.
pub struct HierarchicalOp;
/// [`ps_exchange`] with rank 0 as the star center.
pub struct PsStarOp;
/// Cost surface of the sparse [`allgather_sparse`] AG-Topk path (its data
/// path is bespoke — the AG-compress strategy’s `ag_exchange` — so it is cost-only here).
pub struct AllgatherTopkOp;
/// Cost surface of AR-Topk with ring reduction (Eqn 4a; executed by
/// [`crate::artopk::ArTopk`]).
pub struct ArTopkRingOp;
/// Cost surface of AR-Topk with tree reduction (Eqn 4b; executed by
/// [`crate::artopk::ArTopk`]).
pub struct ArTopkTreeOp;

impl Collective for RingAllreduceOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::RingAllreduce
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, _cr: f64) -> f64 {
        cost_model::ring_allreduce(topo.inter, m_bytes, n)
    }
    fn predict_hetero(&self, _topo: Topology, links: &[LinkParams], m_bytes: f64, _cr: f64) -> f64 {
        cost_model::hetero_ring_allreduce(links, m_bytes)
    }
}

impl DenseCollective for RingAllreduceOp {
    fn run(&self, bufs: &mut [Vec<f32>], topo: Topology) -> CommReport {
        ring_allreduce(bufs, topo.inter)
    }
}

impl Collective for TreeAllreduceOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::TreeAllreduce
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, _cr: f64) -> f64 {
        cost_model::tree_allreduce(topo.inter, m_bytes, n)
    }
}

impl DenseCollective for TreeAllreduceOp {
    fn run(&self, bufs: &mut [Vec<f32>], topo: Topology) -> CommReport {
        tree_allreduce(bufs, topo.inter)
    }
}

impl Collective for HalvingDoublingOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::HalvingDoublingAllreduce
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, _cr: f64) -> f64 {
        cost_model::halving_doubling_allreduce(topo.inter, m_bytes, n)
    }
    fn predict_hetero(&self, _topo: Topology, links: &[LinkParams], m_bytes: f64, _cr: f64) -> f64 {
        cost_model::hetero_halving_doubling_allreduce(links, m_bytes)
    }
}

impl DenseCollective for HalvingDoublingOp {
    fn run(&self, bufs: &mut [Vec<f32>], topo: Topology) -> CommReport {
        halving_doubling_allreduce(bufs, topo.inter)
    }
}

impl Collective for HierarchicalOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::HierarchicalAllreduce
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, _cr: f64) -> f64 {
        cost_model::hierarchical_allreduce(topo, m_bytes, n)
    }
    fn auto_candidate(&self, topo: Topology, n: usize) -> bool {
        !topo.is_flat() && n % topo.workers_per_node.max(1) == 0
    }
    fn predict_hetero(&self, topo: Topology, links: &[LinkParams], m_bytes: f64, _cr: f64) -> f64 {
        cost_model::hetero_hierarchical_allreduce(topo, links, m_bytes)
    }
}

impl DenseCollective for HierarchicalOp {
    fn run(&self, bufs: &mut [Vec<f32>], topo: Topology) -> CommReport {
        hierarchical_allreduce(bufs, topo)
    }
}

impl Collective for PsStarOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::PsStar
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, _cr: f64) -> f64 {
        cost_model::ps_star(topo.inter, m_bytes, n)
    }
    fn auto_candidate(&self, _topo: Topology, _n: usize) -> bool {
        false // O(MN) strawman: selectable explicitly, never auto-picked
    }
}

impl DenseCollective for PsStarOp {
    fn run(&self, bufs: &mut [Vec<f32>], topo: Topology) -> CommReport {
        ps_exchange(bufs, 0, topo.inter)
    }
}

impl Collective for AllgatherTopkOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::AllgatherTopk
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, cr: f64) -> f64 {
        cost_model::ag_topk(topo.inter, m_bytes, n, cr)
    }
    fn predict_hetero(&self, _topo: Topology, links: &[LinkParams], m_bytes: f64, cr: f64) -> f64 {
        cost_model::hetero_ag_topk(links, m_bytes, cr)
    }
}

impl Collective for ArTopkRingOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::ArTopkRing
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, cr: f64) -> f64 {
        cost_model::art_ring(topo.inter, m_bytes, n, cr)
    }
    fn predict_hetero(&self, _topo: Topology, links: &[LinkParams], m_bytes: f64, cr: f64) -> f64 {
        cost_model::hetero_art_ring(links, m_bytes, cr)
    }
}

impl Collective for ArTopkTreeOp {
    fn kind(&self) -> CollectiveKind {
        CollectiveKind::ArTopkTree
    }
    fn predict(&self, topo: Topology, m_bytes: f64, n: usize, cr: f64) -> f64 {
        cost_model::art_tree(topo.inter, m_bytes, n, cr)
    }
    fn predict_hetero(&self, _topo: Topology, links: &[LinkParams], m_bytes: f64, cr: f64) -> f64 {
        cost_model::hetero_art_tree(links, m_bytes, cr)
    }
}

static DENSE_OPS: [&(dyn DenseCollective); 5] = [
    // Registry order is the selector's tie-break order (strict argmin
    // keeps the earliest candidate).
    &RingAllreduceOp,
    &TreeAllreduceOp,
    &HalvingDoublingOp,
    &HierarchicalOp,
    &PsStarOp,
];

static ALL_OPS: [&(dyn Collective); 8] = [
    &RingAllreduceOp,
    &TreeAllreduceOp,
    &HalvingDoublingOp,
    &HierarchicalOp,
    &PsStarOp,
    &AllgatherTopkOp,
    &ArTopkRingOp,
    &ArTopkTreeOp,
];

/// The five executable dense allreduces, in selector tie-break order.
pub fn dense_registry() -> &'static [&'static dyn DenseCollective] {
    &DENSE_OPS
}

/// Every collective's cost/identity surface (all eight [`CollectiveKind`]s).
pub fn registry() -> &'static [&'static dyn Collective] {
    &ALL_OPS
}

/// Executable dense op for `kind` (None for the compressed kinds, whose
/// data paths live in the AG-compress strategy’s `ag_exchange` / [`crate::artopk::ArTopk`]).
pub fn dense_op(kind: CollectiveKind) -> Option<&'static dyn DenseCollective> {
    dense_registry().iter().copied().find(|op| op.kind() == kind)
}

/// Cost/identity surface for `kind` — total over the BUILT-IN kinds.
/// Panics on [`CollectiveKind::Custom`], which by definition has no
/// registry row (callers gate on it; see `CommPlan::priced`).
pub fn collective(kind: CollectiveKind) -> &'static dyn Collective {
    registry()
        .iter()
        .copied()
        .find(|op| op.kind() == kind)
        .expect("every built-in CollectiveKind is registered")
}

/// Cheapest registered collective for a heterogeneous fleet of
/// `links.len()` workers: the fleet-scale argmin `FleetSim` prices every
/// round with. Considers every [`registry`] op whose
/// [`Collective::auto_candidate`] admits `(topo, n)` — the same gate the
/// homogeneous selectors use — scoring by [`Collective::predict_hetero`].
/// Registry order breaks ties (strict argmin), mirroring
/// `choose_dense_topo`. Panics on an empty fleet.
pub fn cheapest_hetero(
    topo: Topology,
    links: &[LinkParams],
    m_bytes: f64,
    cr: f64,
) -> (&'static dyn Collective, f64) {
    assert!(!links.is_empty(), "cheapest_hetero over an empty fleet");
    let n = links.len();
    let mut best: Option<(&'static dyn Collective, f64)> = None;
    for op in registry() {
        if !op.auto_candidate(topo, n) {
            continue;
        }
        let cost = op.predict_hetero(topo, links, m_bytes, cr);
        if best.map_or(true, |(_, b)| cost < b) {
            best = Some((*op, cost));
        }
    }
    best.expect("ring/tree/HD are unconditional candidates")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::{self, Topology};

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn report_accumulates() {
        let l = LinkParams::from_ms_gbps(1.0, 8.0); // beta = 1e-9 s/B
        let mut r = CommReport::default();
        r.add_round(l, 1e6);
        assert!((r.seconds - (1e-3 + 1e-3)).abs() < 1e-12);
        assert_eq!(r.rounds, 1);
        let mut r2 = CommReport::default();
        r2.add_round(l, 2e6);
        r.merge(r2);
        assert_eq!(r.rounds, 2);
        assert!((r.bytes_per_worker - 3e6).abs() < 1e-6);
    }

    #[test]
    fn merge_spans_links() {
        // Rounds on different links keep their own α/β — the hierarchical
        // op's accounting depends on this.
        let fast = LinkParams::from_ms_gbps(0.01, 100.0);
        let slow = LinkParams::from_ms_gbps(10.0, 1.0);
        let mut r = CommReport::default();
        r.add_round(fast, 1e6);
        let mut s = CommReport::default();
        s.add_round(slow, 1e6);
        r.merge(s);
        let want = (0.01e-3 + 1e6 * 8.0 / 100e9) + (10e-3 + 1e6 * 8e-9);
        assert!((r.seconds - want).abs() < 1e-12);
        assert_eq!(r.rounds, 2);
    }

    /// Round counts of every allreduce against the closed-form α-terms,
    /// pinned for power-of-two and non-power-of-two N.
    #[test]
    fn round_counts_match_closed_forms() {
        let l = LinkParams::from_ms_gbps(1.0, 10.0);
        for n in [2usize, 4, 7, 8, 12, 16] {
            let m = 16 * 15; // divisible by every participant count used
            let mk = || vec![vec![1.0f32; m]; n];
            let ring = ring_allreduce(&mut mk(), l);
            assert_eq!(ring.rounds, 2 * (n as u32 - 1), "ring n={n}");
            let tree = tree_allreduce(&mut mk(), l);
            assert_eq!(tree.rounds, 2 * ceil_log2(n), "tree n={n}");
            let hd = halving_doubling_allreduce(&mut mk(), l);
            let np = cost_model::prev_pow2(n) as u32;
            let fold = if np as usize == n { 0 } else { 2 };
            assert_eq!(hd.rounds, 2 * np.trailing_zeros() + fold, "hd n={n}");
        }
    }

    /// The registry is total over `CollectiveKind` and the dense subset is
    /// exactly the five executable allreduces.
    #[test]
    fn registry_is_total_over_collective_kinds() {
        let kinds = [
            CollectiveKind::RingAllreduce,
            CollectiveKind::TreeAllreduce,
            CollectiveKind::HalvingDoublingAllreduce,
            CollectiveKind::HierarchicalAllreduce,
            CollectiveKind::PsStar,
            CollectiveKind::AllgatherTopk,
            CollectiveKind::ArTopkRing,
            CollectiveKind::ArTopkTree,
        ];
        assert_eq!(registry().len(), kinds.len());
        for kind in kinds {
            let op = collective(kind);
            assert_eq!(op.kind(), kind);
            assert_eq!(op.name(), kind.name());
        }
        assert_eq!(dense_registry().len(), 5);
        assert!(dense_op(CollectiveKind::RingAllreduce).is_some());
        assert!(dense_op(CollectiveKind::PsStar).is_some());
        assert!(dense_op(CollectiveKind::AllgatherTopk).is_none());
        assert!(dense_op(CollectiveKind::ArTopkRing).is_none());
    }

    /// Trait-object execution is the same op as the free functions: same
    /// reduced data, same CommReport.
    #[test]
    fn registry_ops_match_free_functions() {
        let topo = Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(5.0, 2.0),
            2,
        );
        let mk = || -> Vec<Vec<f32>> { (0..4).map(|w| vec![w as f32 + 1.0; 24]).collect() };
        for op in dense_registry() {
            let mut via_trait = mk();
            let r1 = op.run(&mut via_trait, topo);
            let mut direct = mk();
            let r2 = match op.kind() {
                CollectiveKind::RingAllreduce => ring_allreduce(&mut direct, topo.inter),
                CollectiveKind::TreeAllreduce => tree_allreduce(&mut direct, topo.inter),
                CollectiveKind::HalvingDoublingAllreduce => {
                    halving_doubling_allreduce(&mut direct, topo.inter)
                }
                CollectiveKind::HierarchicalAllreduce => {
                    hierarchical_allreduce(&mut direct, topo)
                }
                CollectiveKind::PsStar => ps_exchange(&mut direct, 0, topo.inter),
                k => unreachable!("not a dense op: {k:?}"),
            };
            assert_eq!(via_trait, direct, "{} data", op.name());
            assert_eq!(r1, r2, "{} report", op.name());
        }
    }

    /// `predict` is exactly the closed-form cost of the matching op.
    #[test]
    fn registry_predict_matches_closed_forms() {
        let topo = Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(4.0, 20.0),
            4,
        );
        let (m, n, cr) = (4e8, 8usize, 0.01);
        let want = [
            (CollectiveKind::RingAllreduce, cost_model::ring_allreduce(topo.inter, m, n)),
            (CollectiveKind::TreeAllreduce, cost_model::tree_allreduce(topo.inter, m, n)),
            (
                CollectiveKind::HalvingDoublingAllreduce,
                cost_model::halving_doubling_allreduce(topo.inter, m, n),
            ),
            (
                CollectiveKind::HierarchicalAllreduce,
                cost_model::hierarchical_allreduce(topo, m, n),
            ),
            (CollectiveKind::PsStar, cost_model::ps_star(topo.inter, m, n)),
            (CollectiveKind::AllgatherTopk, cost_model::ag_topk(topo.inter, m, n, cr)),
            (CollectiveKind::ArTopkRing, cost_model::art_ring(topo.inter, m, n, cr)),
            (CollectiveKind::ArTopkTree, cost_model::art_tree(topo.inter, m, n, cr)),
        ];
        for (kind, cost) in want {
            let got = collective(kind).predict(topo, m, n, cr);
            assert!(
                (got - cost).abs() <= 1e-15 * cost.abs().max(1.0),
                "{kind:?}: predict {got} vs closed form {cost}"
            );
        }
    }

    /// Auto-candidate flags: PS never; hierarchical only on two-level
    /// topologies whose ranks-per-node divide the cluster; all else always.
    #[test]
    fn auto_candidate_flags() {
        let flat = Topology::flat(LinkParams::from_ms_gbps(4.0, 20.0));
        let two = Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(4.0, 20.0),
            4,
        );
        for op in dense_registry() {
            match op.kind() {
                CollectiveKind::PsStar => {
                    assert!(!op.auto_candidate(flat, 8) && !op.auto_candidate(two, 8));
                }
                CollectiveKind::HierarchicalAllreduce => {
                    assert!(!op.auto_candidate(flat, 8));
                    assert!(op.auto_candidate(two, 8));
                    // Ragged cluster: predict would assert, so the gate
                    // must exclude it (direct selector callers).
                    assert!(!op.auto_candidate(two, 6));
                }
                _ => {
                    assert!(op.auto_candidate(flat, 8) && op.auto_candidate(two, 6));
                }
            }
        }
    }

    /// Direct selector use with a ragged (non-dividing) topology must fall
    /// back to the flat candidates instead of panicking in Hier predict.
    #[test]
    fn choose_dense_topo_skips_ragged_hierarchical() {
        let two = Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(10.0, 1.0),
            3,
        );
        let c = crate::coordinator::selector::choose_dense_topo(two, 4e8, 8);
        assert_ne!(c.kind, CollectiveKind::HierarchicalAllreduce);
        assert!(c.predicted_s.is_finite());
    }

    /// `predict_hetero` on a coincident-link fleet equals `predict` with
    /// that link BITWISE for every registered op — the homogeneous fast
    /// path the ISSUE 7 determinism pins ride on — and the pattern-aware
    /// overrides really price per-round (a one-worker degrade moves ring
    /// and HD, and moves them differently from the conservative default).
    #[test]
    fn predict_hetero_fast_path_and_pattern_overrides() {
        let inter = LinkParams::from_ms_gbps(4.0, 20.0);
        let topo = Topology::two_level(LinkParams::from_ms_gbps(0.01, 100.0), inter, 4);
        let (m, n, cr) = (4e8, 8usize, 0.01);
        let links = vec![inter; n];
        for op in registry() {
            if !op.auto_candidate(topo, n) && op.kind() != CollectiveKind::PsStar {
                continue;
            }
            let hom = op.predict(topo, m, n, cr);
            let het = op.predict_hetero(topo, &links, m, cr);
            assert_eq!(hom.to_bits(), het.to_bits(), "{} fast path", op.name());
        }
        // Degrade one worker: per-round ring cost stretches every round by
        // the slow worker, matching the cost_model entry point exactly.
        let mut degraded = links.clone();
        degraded[3] = LinkParams::from_ms_gbps(40.0, 2.0);
        let ring = collective(CollectiveKind::RingAllreduce);
        assert_eq!(
            ring.predict_hetero(topo, &degraded, m, cr).to_bits(),
            cost_model::hetero_ring_allreduce(&degraded, m).to_bits()
        );
        let hd = collective(CollectiveKind::HalvingDoublingAllreduce);
        assert_eq!(
            hd.predict_hetero(topo, &degraded, m, cr).to_bits(),
            cost_model::hetero_halving_doubling_allreduce(&degraded, m).to_bits()
        );
        let hier = collective(CollectiveKind::HierarchicalAllreduce);
        assert_eq!(
            hier.predict_hetero(topo, &degraded, m, cr).to_bits(),
            cost_model::hetero_hierarchical_allreduce(topo, &degraded, m).to_bits()
        );
        // The compressed trio prices per-round too (ISSUE 8): same
        // cost_model entry points, and the degraded fleet costs strictly
        // more than the homogeneous prediction for each of the three.
        let trio = [
            (
                CollectiveKind::AllgatherTopk,
                cost_model::hetero_ag_topk(&degraded, m, cr),
            ),
            (CollectiveKind::ArTopkRing, cost_model::hetero_art_ring(&degraded, m, cr)),
            (CollectiveKind::ArTopkTree, cost_model::hetero_art_tree(&degraded, m, cr)),
        ];
        for (kind, want) in trio {
            let op = collective(kind);
            assert_eq!(
                op.predict_hetero(topo, &degraded, m, cr).to_bits(),
                want.to_bits(),
                "{} hetero entry point",
                op.name()
            );
            assert!(
                op.predict_hetero(topo, &degraded, m, cr) > op.predict(topo, m, n, cr),
                "a straggling link must cost {} something",
                op.name()
            );
        }
        assert!(
            ring.predict_hetero(topo, &degraded, m, cr) > ring.predict(topo, m, n, cr),
            "a straggling link must cost the ring something"
        );
    }

    /// The fleet argmin honors auto-candidate gates and really minimizes.
    #[test]
    fn cheapest_hetero_is_a_gated_argmin() {
        let inter = LinkParams::from_ms_gbps(4.0, 20.0);
        let flat = Topology::flat(inter);
        let mut links = vec![inter; 8];
        links[2] = LinkParams::from_ms_gbps(32.0, 2.5);
        let (op, cost) = cheapest_hetero(flat, &links, 4e8, 0.01);
        assert!(cost.is_finite() && cost > 0.0);
        assert_ne!(op.kind(), CollectiveKind::PsStar, "strawman never auto-picked");
        assert_ne!(op.kind(), CollectiveKind::HierarchicalAllreduce, "flat topo");
        for other in registry() {
            if other.auto_candidate(flat, links.len()) {
                assert!(
                    cost <= other.predict_hetero(flat, &links, 4e8, 0.01),
                    "{} beat the chosen {}",
                    other.name(),
                    op.name()
                );
            }
        }
    }

    #[test]
    fn hierarchical_round_counts_pow2_and_not() {
        let topo = |w| {
            Topology::two_level(
                LinkParams::from_ms_gbps(0.01, 100.0),
                LinkParams::from_ms_gbps(5.0, 2.0),
                w,
            )
        };
        // (w, nodes): power-of-two and non-power-of-two node groups.
        for (w, nodes) in [(4usize, 2usize), (2, 3), (3, 2), (1, 4)] {
            let n = w * nodes;
            let mut bufs = vec![vec![1.0f32; 60]; n];
            let r = hierarchical_allreduce(&mut bufs, topo(w));
            let want = if w == 1 {
                2 * (nodes as u32 - 1) // degenerate flat ring
            } else {
                2 * ceil_log2(w) + 2 * (nodes as u32 - 1)
            };
            assert_eq!(r.rounds, want, "w={w} nodes={nodes}");
        }
    }
}
