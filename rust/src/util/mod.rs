//! Zero-dependency substrates: PRNG, stats/KDE, config, CLI, property-test
//! harness, table printer, micro-bench timer.
//!
//! The offline build vendors only `xla` + `anyhow`, so the facilities that a
//! networked project would pull from `rand`/`serde`/`clap`/`proptest`/
//! `criterion` are implemented here, first-party and tested.

pub mod bench;
pub mod cli;
pub mod config;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
