//! Time-varying network schedules — the "unpredictable network" half of the
//! paper's title.
//!
//! The paper drives `tc` from a background process to emulate latency and
//! bandwidth that change over epochs (Fig 6, configurations C1/C2) and
//! attributes real-world variability to congestion, QoS priorities,
//! resource sharing and scheduling (§2-C2). [`NetSchedule`] reproduces all
//! of these as composable layers over a base piecewise schedule.

use crate::netsim::cost_model::{LinkParams, Topology};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Canonical (α, 1/β) levels used by the paper's C1/C2 configurations.
pub mod levels {
    pub const ALPHA_LOW_MS: f64 = 1.0;
    pub const ALPHA_MOD_MS: f64 = 10.0;
    pub const ALPHA_HIGH_MS: f64 = 50.0;
    pub const BW_LOW_GBPS: f64 = 1.0;
    pub const BW_MOD_GBPS: f64 = 10.0;
    pub const BW_HIGH_GBPS: f64 = 25.0;
}

/// One piece of a piecewise-constant schedule: applies from `from_epoch`
/// (inclusive) until the next breakpoint.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub from_epoch: f64,
    pub link: LinkParams,
}

/// A network schedule: maps training progress (fractional epoch) to link
/// parameters, with optional jitter and congestion-episode overlays, and an
/// optional two-level topology overlay (`with_topology`). The schedule (and
/// its jitter/congestion) drives the *inter-node* link — the WAN/TCP side
/// the paper shapes with `tc`; the intra-node link is in-machine hardware
/// and stays fixed.
#[derive(Debug, Clone)]
pub struct NetSchedule {
    pub name: String,
    phases: Vec<Phase>,
    /// Multiplicative observation-free jitter applied to α and 1/β
    /// (fraction, e.g. 0.05 = ±5%). Deterministic per epoch-bucket.
    jitter_frac: f64,
    /// Congestion episodes: probability per epoch-bucket that effective
    /// bandwidth collapses by `congestion_factor`.
    congestion_prob: f64,
    congestion_factor: f64,
    seed: u64,
    /// Fixed intra-node link of the two-level topology overlay (None =
    /// flat cluster; see [`NetSchedule::with_topology`]).
    intra: Option<LinkParams>,
    workers_per_node: usize,
}

impl NetSchedule {
    pub fn static_link(link: LinkParams) -> Self {
        NetSchedule {
            name: "static".into(),
            phases: vec![Phase { from_epoch: 0.0, link }],
            jitter_frac: 0.0,
            congestion_prob: 0.0,
            congestion_factor: 1.0,
            seed: 0,
            intra: None,
            workers_per_node: 1,
        }
    }

    pub fn piecewise(name: &str, phases: Vec<Phase>) -> Self {
        assert!(!phases.is_empty());
        assert!(
            phases.windows(2).all(|w| w[0].from_epoch < w[1].from_epoch),
            "phases must be sorted by from_epoch"
        );
        NetSchedule {
            name: name.into(),
            phases,
            jitter_frac: 0.0,
            congestion_prob: 0.0,
            congestion_factor: 1.0,
            seed: 0,
            intra: None,
            workers_per_node: 1,
        }
    }

    /// Paper configuration C1 (Fig 6a), scaled to `total_epochs`
    /// (50 in the paper; ResNet50 runs 100 => every phase stretches 2x).
    ///
    /// C1: (low-α, high-bw) epochs 1-12, (low, low) 13-24,
    ///     (high, low) 25-36, (high, high) 37+.
    ///
    /// ```
    /// use flexcomm::netsim::schedule::NetSchedule;
    /// let c1 = NetSchedule::c1(50.0);
    /// assert_eq!(c1.at(0.0).bw_gbps().round(), 25.0);   // (low α, high bw)
    /// assert_eq!(c1.at(30.0).alpha_ms().round(), 50.0); // (high α, low bw)
    /// assert_eq!(c1.phases().len(), 4);
    /// ```
    pub fn c1(total_epochs: f64) -> Self {
        use levels::*;
        let s = total_epochs / 50.0;
        NetSchedule::piecewise(
            "c1",
            vec![
                Phase { from_epoch: 0.0, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_HIGH_GBPS) },
                Phase { from_epoch: 12.0 * s, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_LOW_GBPS) },
                Phase { from_epoch: 24.0 * s, link: LinkParams::from_ms_gbps(ALPHA_HIGH_MS, BW_LOW_GBPS) },
                Phase { from_epoch: 36.0 * s, link: LinkParams::from_ms_gbps(ALPHA_HIGH_MS, BW_HIGH_GBPS) },
            ],
        )
    }

    /// Paper configuration C2 (Fig 6b), scaled to `total_epochs`.
    ///
    /// C2: (low, high) 0-11, (moderate, moderate) 12-19, (high, low) 20-27,
    ///     (moderate, moderate) 28-35, (low, high) 36+.
    ///
    /// ```
    /// use flexcomm::netsim::schedule::NetSchedule;
    /// let c2 = NetSchedule::c2(50.0);
    /// assert_eq!(c2.at(22.0).bw_gbps().round(), 1.0);   // (high α, low bw)
    /// assert_eq!(c2.at(45.0).alpha_ms().round(), 1.0);  // recovers by the end
    /// assert!(c2.phases().len() > NetSchedule::c1(50.0).phases().len());
    /// ```
    pub fn c2(total_epochs: f64) -> Self {
        use levels::*;
        let s = total_epochs / 50.0;
        NetSchedule::piecewise(
            "c2",
            vec![
                Phase { from_epoch: 0.0, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_HIGH_GBPS) },
                Phase { from_epoch: 12.0 * s, link: LinkParams::from_ms_gbps(ALPHA_MOD_MS, BW_MOD_GBPS) },
                Phase { from_epoch: 20.0 * s, link: LinkParams::from_ms_gbps(ALPHA_HIGH_MS, BW_LOW_GBPS) },
                Phase { from_epoch: 28.0 * s, link: LinkParams::from_ms_gbps(ALPHA_MOD_MS, BW_MOD_GBPS) },
                Phase { from_epoch: 36.0 * s, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_HIGH_GBPS) },
            ],
        )
    }

    /// Valid [`NetSchedule::preset`] names, in lookup order ("static" is
    /// not a preset — it takes explicit link parameters).
    pub const PRESETS: &'static [&'static str] = &["c1", "c2"];

    /// Look up a named preset; the error lists every valid name.
    pub fn preset(name: &str, total_epochs: f64) -> Result<Self> {
        match name {
            "c1" => Ok(Self::c1(total_epochs)),
            "c2" => Ok(Self::c2(total_epochs)),
            _ => bail!(
                "unknown schedule preset `{name}` (valid: {}; or `static` with explicit \
                 link parameters)",
                Self::PRESETS.join(", ")
            ),
        }
    }

    /// Overlay multiplicative jitter (±`frac`) on α and bandwidth,
    /// deterministic per 0.1-epoch bucket.
    pub fn with_jitter(mut self, frac: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&frac));
        self.jitter_frac = frac;
        self.seed = seed;
        self
    }

    /// Overlay congestion episodes: with probability `prob` per 0.1-epoch
    /// bucket, bandwidth is divided by `factor` (>= 1).
    pub fn with_congestion(mut self, prob: f64, factor: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&prob) && factor >= 1.0);
        self.congestion_prob = prob;
        self.congestion_factor = factor;
        self.seed = seed;
        self
    }

    /// Overlay a two-level topology: `workers_per_node` ranks share the
    /// fixed `intra` link, and the scheduled (possibly jittered/congested)
    /// link becomes the *inter-node* link. See
    /// [`Topology`](crate::netsim::cost_model::Topology).
    ///
    /// ```
    /// use flexcomm::netsim::cost_model::LinkParams;
    /// use flexcomm::netsim::schedule::NetSchedule;
    /// let s = NetSchedule::c2(50.0)
    ///     .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 4);
    /// let t = s.topology_at(0.0);
    /// assert_eq!(t.workers_per_node, 4);
    /// assert_eq!(t.inter, s.at(0.0)); // schedule drives the inter link
    /// assert_eq!(t.nodes(8), 2);
    /// ```
    pub fn with_topology(mut self, intra: LinkParams, workers_per_node: usize) -> Self {
        assert!(workers_per_node >= 1, "workers_per_node must be >= 1");
        self.intra = Some(intra);
        self.workers_per_node = workers_per_node;
        self
    }

    /// Ranks per node of the topology overlay (1 = flat).
    pub fn workers_per_node(&self) -> usize {
        self.workers_per_node
    }

    /// Full topology at a fractional epoch: the (overlaid) scheduled link
    /// as the inter-node side, the fixed intra link if configured.
    pub fn topology_at(&self, epoch: f64) -> Topology {
        let inter = self.at(epoch);
        match self.intra {
            Some(intra) if self.workers_per_node > 1 => {
                Topology::two_level(intra, inter, self.workers_per_node)
            }
            _ => Topology::flat(inter),
        }
    }

    /// Base (overlay-free) link parameters at a fractional epoch.
    pub fn base_at(&self, epoch: f64) -> LinkParams {
        let mut link = self.phases[0].link;
        for p in &self.phases {
            if epoch >= p.from_epoch {
                link = p.link;
            } else {
                break;
            }
        }
        link
    }

    /// Effective link parameters at a fractional epoch, overlays applied.
    /// Deterministic: the same (schedule, seed, epoch) always yields the
    /// same parameters, so experiments replay exactly.
    pub fn at(&self, epoch: f64) -> LinkParams {
        let mut link = self.base_at(epoch);
        if self.jitter_frac == 0.0 && self.congestion_prob == 0.0 {
            return link;
        }
        // Derive a per-bucket RNG: same bucket -> same perturbation.
        let bucket = (epoch * 10.0).floor() as u64;
        let mut rng = Rng::new(self.seed ^ bucket.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if self.jitter_frac > 0.0 {
            let ja = 1.0 + self.jitter_frac * (2.0 * rng.f64() - 1.0);
            let jb = 1.0 + self.jitter_frac * (2.0 * rng.f64() - 1.0);
            link.alpha *= ja;
            link.beta /= jb; // jitter bandwidth, not beta, symmetrically
        }
        if self.congestion_prob > 0.0 && rng.f64() < self.congestion_prob {
            link.beta *= self.congestion_factor;
        }
        link
    }

    /// Breakpoints (for harnesses that print the Fig 6 schedule).
    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c1_matches_fig6a() {
        let s = NetSchedule::c1(50.0);
        let at = |e: f64| {
            let l = s.at(e);
            (l.alpha_ms().round(), l.bw_gbps().round())
        };
        assert_eq!(at(0.0), (1.0, 25.0));
        assert_eq!(at(11.9), (1.0, 25.0));
        assert_eq!(at(12.1), (1.0, 1.0));
        assert_eq!(at(25.0), (50.0, 1.0));
        assert_eq!(at(40.0), (50.0, 25.0));
    }

    #[test]
    fn c2_matches_fig6b_and_changes_more_often() {
        let c1 = NetSchedule::c1(50.0);
        let c2 = NetSchedule::c2(50.0);
        assert_eq!(c2.phases().len(), 5);
        assert!(c2.phases().len() > c1.phases().len());
        let l = c2.at(22.0);
        assert_eq!(l.alpha_ms().round(), 50.0);
        assert_eq!(l.bw_gbps().round(), 1.0);
        let l = c2.at(30.0);
        assert_eq!(l.alpha_ms().round(), 10.0);
    }

    #[test]
    fn resnet50_scaling_stretches_2x() {
        let s = NetSchedule::c1(100.0);
        // C1 for ResNet50 applies (low, high) through epoch 1-24.
        assert_eq!(s.at(20.0).bw_gbps().round(), 25.0);
        assert_eq!(s.at(25.0).bw_gbps().round(), 1.0);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let s = NetSchedule::c1(50.0).with_jitter(0.1, 7);
        let a = s.at(3.14);
        let b = s.at(3.14);
        assert_eq!(a, b, "same epoch must give same link");
        let base = s.base_at(3.14);
        assert!((a.alpha / base.alpha - 1.0).abs() <= 0.1 + 1e-9);
        let ratio = base.beta / a.beta;
        assert!((ratio - 1.0).abs() <= 0.1 + 1e-9);
    }

    #[test]
    fn congestion_reduces_bandwidth_sometimes() {
        let s = NetSchedule::static_link(LinkParams::from_ms_gbps(1.0, 10.0))
            .with_congestion(0.5, 10.0, 3);
        let mut congested = 0;
        let mut free = 0;
        for i in 0..200 {
            let l = s.at(i as f64 * 0.1);
            if l.bw_gbps() < 2.0 {
                congested += 1;
            } else {
                free += 1;
            }
        }
        assert!(congested > 30, "{congested}");
        assert!(free > 30, "{free}");
    }

    #[test]
    fn preset_lookup() {
        for name in NetSchedule::PRESETS {
            assert!(NetSchedule::preset(name, 50.0).is_ok(), "{name}");
        }
        let err = NetSchedule::preset("nope", 50.0).unwrap_err().to_string();
        assert!(err.contains("c1") && err.contains("c2"), "{err}");
    }

    #[test]
    fn topology_defaults_to_flat() {
        let s = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
        let t = s.topology_at(1.0);
        assert!(t.is_flat());
        assert_eq!(t.inter, s.at(1.0));
        assert_eq!(s.workers_per_node(), 1);
    }

    #[test]
    fn topology_overlay_tracks_schedule_on_inter_only() {
        let intra = LinkParams::from_ms_gbps(0.01, 100.0);
        let s = NetSchedule::c1(50.0).with_topology(intra, 4).with_jitter(0.1, 9);
        for epoch in [0.0, 13.0, 26.0, 40.0] {
            let t = s.topology_at(epoch);
            assert_eq!(t.workers_per_node, 4);
            // The inter side follows the (jittered) schedule...
            assert_eq!(t.inter, s.at(epoch));
            // ...while the intra link stays the fixed in-machine hardware.
            assert_eq!(t.intra, intra);
        }
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_phases_rejected() {
        NetSchedule::piecewise(
            "bad",
            vec![
                Phase { from_epoch: 5.0, link: LinkParams::from_ms_gbps(1.0, 1.0) },
                Phase { from_epoch: 1.0, link: LinkParams::from_ms_gbps(1.0, 1.0) },
            ],
        );
    }
}
