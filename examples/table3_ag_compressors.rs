//! Table III + Fig 3: step-time, accuracy and diff (w.r.t. DenseSGD) for
//! LWTopk/MSTopk at CRs {0.1, 0.01, 0.001} via Allgather on a 4ms/20Gbps
//! link; compression gain curves per (compressor, CR).
//!
//!     cargo run --release --example table3_ag_compressors -- [--steps 600]
//!         [--models ResNet18,ViT|all] [--emit-gain]
//!
//! Proxy substitution (DESIGN.md §3): the host-MLP trains on synthetic
//! clusters while simulated message sizes are scaled to the paper model's
//! parameter count (`msg_scale`), so step-time magnitudes correspond to
//! the paper's and accuracy ordering reflects real error-feedback SGD.

use anyhow::Result;
use flexcomm::compress::CompressorKind;
use flexcomm::coordinator::trainer::{CrControl, DenseFlavor, Strategy};
use flexcomm::experiments::{
    diff_row, print_diff_table, proxy_cfg, run_proxy, write_csv, GPU_COMPRESS_SPEEDUP,
    PAPER_COMPUTE_MS, PAPER_MODELS,
};
use flexcomm::util::cli::Args;

const PROXY_PARAMS: f64 = 53_664.0; // HostMlp::hard_preset dimension

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 600)?;
    let emit_gain = args.flag("emit-gain");
    let want = args.str_or("models", "ResNet18,ViT");
    let crs = [0.1, 0.01, 0.001];

    let mut gain_csv = String::from("model,method,cr,step,gain\n");
    for (model, params) in PAPER_MODELS {
        if want != "all" && !want.split(',').any(|m| m == model) {
            continue;
        }
        let msg_scale = 4.0 * params / (4.0 * PROXY_PARAMS);
        let compute_ms = PAPER_COMPUTE_MS.iter().find(|(m, _)| *m == model).unwrap().1;
        let mut mk = |strategy, cr: f64, seed| {
            let mut cfg = proxy_cfg(strategy, CrControl::Static(cr), steps, seed);
            cfg.msg_scale = msg_scale;
            cfg.comp_scale = msg_scale / GPU_COMPRESS_SPEEDUP;
            cfg.compute = flexcomm::coordinator::worker::ComputeModel::with_jitter(
                compute_ms * 1e-3,
                0.05,
            );
            run_proxy(cfg, seed)
        };

        let mut rows = Vec::new();
        let dense = mk(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 1);
        rows.push(diff_row("DenseSGD", &dense));
        for (kind, label) in [
            (CompressorKind::LwTopk, "LWTopk"),
            (CompressorKind::MsTopk, "MSTopk"),
        ] {
            for &cr in &crs {
                let t = mk(Strategy::AgCompress { kind }, cr, 1);
                rows.push(diff_row(format!("{label} {cr}"), &t));
                if emit_gain {
                    for (i, m) in t.metrics.steps.iter().enumerate() {
                        if i % 10 == 0 {
                            gain_csv.push_str(&format!(
                                "{model},{label},{cr},{},{:.5}\n",
                                m.step, m.gain
                            ));
                        }
                    }
                }
            }
        }
        print_diff_table(
            &format!("Table III — {model} (proxy, 4ms/20Gbps, AG for compressed)"),
            &rows,
        );
    }
    if emit_gain {
        let p = write_csv("results/fig3_gain.csv", &gain_csv)?;
        println!("\nFig 3 gain curves -> {p}");
    }
    Ok(())
}
