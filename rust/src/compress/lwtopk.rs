//! Layerwise Top-k (LWTopk [20], §2-C3): top `k%` PER LAYER, so every layer
//! contributes in proportion to its size.
//!
//! The paper's critique (and why AR-Topk compresses the fused tensor
//! instead): with skewed gradients, a fixed per-layer quota drops critical
//! updates that cluster in a few layers. The accuracy gap in Table V
//! follows from exactly this behaviour.

use crate::compress::{k_for, Compressor, SparseGrad};
use crate::compress::topk::topk_indices_select;
use crate::tensor::Layout;

/// Layerwise exact top-k compressor.
#[derive(Debug, Clone, Default)]
pub struct LwTopk;

impl LwTopk {
    pub fn new() -> Self {
        LwTopk
    }
}

impl Compressor for LwTopk {
    fn name(&self) -> &'static str {
        "lwtopk"
    }

    fn compress(&mut self, g: &[f32], cr: f64, layout: &Layout) -> SparseGrad {
        assert_eq!(
            layout.total(),
            g.len(),
            "layout total {} != gradient len {}",
            layout.total(),
            g.len()
        );
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for layer in &layout.layers {
            let seg = &g[layer.offset..layer.offset + layer.size];
            let k = k_for(cr, seg.len());
            for local in topk_indices_select(seg, k) {
                let global = (layer.offset + local as usize) as u32;
                indices.push(global);
                values.push(seg[local as usize]);
            }
        }
        SparseGrad { indices, values, dense_len: g.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::TopK;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn per_layer_quota() {
        let layout = Layout::from_sizes(&[("a", 10), ("b", 10)]);
        // All large values live in layer a; LWTopk must still pick from b.
        let mut g = vec![0.0f32; 20];
        for i in 0..10 {
            g[i] = 100.0 + i as f32;
        }
        for i in 10..20 {
            g[i] = 0.001 * i as f32;
        }
        let s = LwTopk::new().compress(&g, 0.2, &layout);
        assert_eq!(s.k(), 4); // 2 per layer
        let from_b = s.indices.iter().filter(|&&i| i >= 10).count();
        assert_eq!(from_b, 2, "layer b must contribute its quota");
    }

    #[test]
    fn fused_topk_beats_lwtopk_on_skewed_gradients() {
        // The paper's argument for fused compression: when critical mass
        // clusters in one layer, fused top-k keeps more of the energy.
        let layout = Layout::from_sizes(&[("hot", 50), ("cold", 50)]);
        let mut g = vec![0.01f32; 100];
        for i in 0..50 {
            g[i] = 1.0 + i as f32 * 0.1;
        }
        let lw = LwTopk::new().compress(&g, 0.2, &layout);
        let fused = TopK::new().compress(&g, 0.2, &layout);
        assert!(fused.sq_norm() > lw.sq_norm());
    }

    #[test]
    fn indices_global_and_sorted_within_layer() {
        check("lwtopk indices valid", 60, |gen| {
            let l1 = gen.usize_in(1, 50);
            let l2 = gen.usize_in(1, 50);
            let layout = Layout::from_sizes(&[("x", l1), ("y", l2)]);
            let g = gen.vec_normal(l1 + l2, 1.0);
            let cr = gen.f64_in(0.01, 0.9);
            let s = LwTopk::new().compress(&g, cr, &layout);
            for (&i, &v) in s.indices.iter().zip(&s.values) {
                ensure((i as usize) < g.len(), "index out of range")?;
                ensure(v == g[i as usize], "value mismatch")?;
            }
            // No duplicates.
            let mut sorted = s.indices.clone();
            sorted.sort_unstable();
            sorted.dedup();
            ensure(sorted.len() == s.indices.len(), "duplicate indices")
        });
    }

    #[test]
    fn k_matches_per_layer_sum() {
        let layout = Layout::from_sizes(&[("a", 100), ("b", 1000), ("c", 17)]);
        let g = vec![1.0f32; 1117];
        let s = LwTopk::new().compress(&g, 0.01, &layout);
        // ceil(1)+ceil(10)+ceil(0.17) = 1 + 10 + 1
        assert_eq!(s.k(), 12);
    }

    #[test]
    #[should_panic(expected = "layout total")]
    fn layout_mismatch_panics() {
        let layout = Layout::single(5);
        LwTopk::new().compress(&[1.0; 6], 0.5, &layout);
    }
}
