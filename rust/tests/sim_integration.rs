//! Simulator-level integration tests: whole training runs across
//! strategies, schedules and policies with cross-cutting invariants —
//! no PJRT needed (host model), so these also guard refactors fast.
//! Everything drives the public Session API (builder + report + observer
//! stream; DESIGN.md §8).

use flexcomm::artopk::{ArFlavor, SelectionPolicy};
use flexcomm::compress::CompressorKind;
use flexcomm::coordinator::controller::AdaptiveConfig;
use flexcomm::coordinator::observer::{StrategySwitch, SwitchDimension, TrainObserver};
use flexcomm::coordinator::session::{Session, TrainReport};
use flexcomm::coordinator::trainer::{
    CrControl, DenseFlavor, Strategy, TrainConfig,
};
use flexcomm::coordinator::worker::ComputeModel;
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::netsim::modifiers::{CongestionEpisodes, Jitter};
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::runtime::HostMlp;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn base_cfg(strategy: Strategy, cr: CrControl, steps: u64) -> TrainConfig {
    TrainConfig {
        n_workers: 4,
        steps,
        steps_per_epoch: 25,
        lr: 0.3,
        momentum: 0.6,
        weight_decay: 0.0,
        strategy,
        cr,
        net: Box::new(NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))),
        compute: ComputeModel::fixed(0.005),
        eval_every: 25,
        seed: 21,
        ..Default::default()
    }
}

fn run(cfg: TrainConfig) -> TrainReport {
    Session::from_config(cfg)
        .source(Box::new(HostMlp::default_preset(21)))
        .build()
        .expect("valid config")
        .run()
}

/// Every strategy must actually learn the task.
#[test]
fn all_strategies_learn() {
    let strategies = [
        ("dense-ring", Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0),
        ("dense-tree", Strategy::DenseSgd { flavor: DenseFlavor::Tree }, 1.0),
        ("dense-ps", Strategy::DenseSgd { flavor: DenseFlavor::Ps }, 1.0),
        ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05),
        ("ag-lwtopk", Strategy::AgCompress { kind: CompressorKind::LwTopk }, 0.05),
        ("ag-mstopk", Strategy::AgCompress { kind: CompressorKind::MsTopk }, 0.05),
        (
            "artopk-star",
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            0.05,
        ),
        (
            "artopk-var-tree",
            Strategy::ArTopkFixed { policy: SelectionPolicy::Var, flavor: ArFlavor::Tree },
            0.05,
        ),
        ("flexible", Strategy::Flexible { policy: SelectionPolicy::Star }, 0.05),
    ];
    for (name, s, cr) in strategies {
        let r = run(base_cfg(s, CrControl::Static(cr), 200));
        let acc = r.best_accuracy().unwrap();
        assert!(acc > 0.70, "{name}: accuracy {acc}");
        let first = r.metrics.steps.first().unwrap().loss;
        let last = r.metrics.steps.last().unwrap().loss;
        assert!(last < first, "{name}: loss {first} -> {last}");
    }
}

/// Error-feedback compression at moderate CR must track DenseSGD closely
/// (the paper's statistical-efficiency claim), and random-k must be worse
/// than top-k at equal CR (why AR-Topk exists at all). Uses the hard task
/// so the ceiling doesn't mask differences.
#[test]
fn statistical_efficiency_ordering() {
    let run_hard = |strategy, cr: f64| {
        let cfg = base_cfg(strategy, CrControl::Static(cr), 250);
        Session::from_config(cfg)
            .source(Box::new(HostMlp::hard_preset(21)))
            .build()
            .expect("valid config")
            .run()
    };
    let dense = run_hard(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0);
    let topk = run_hard(Strategy::AgCompress { kind: CompressorKind::TopK }, 0.01);
    let randk = run_hard(Strategy::AgCompress { kind: CompressorKind::RandomK }, 0.01);
    let a_dense = dense.best_accuracy().unwrap();
    let a_topk = topk.best_accuracy().unwrap();
    let a_rand = randk.best_accuracy().unwrap();
    // Dense >= topk (small tolerance) and topk's retained-energy (gain)
    // dwarfs randomk's — the structural reason its convergence is worse.
    assert!(a_dense >= a_topk - 0.03, "dense {a_dense} vs topk {a_topk}");
    assert!(a_topk >= a_rand - 0.01, "topk {a_topk} vs randomk {a_rand}");
    let g_topk = topk.summary().mean_gain;
    let g_rand = randk.summary().mean_gain;
    assert!(g_topk > 2.0 * g_rand, "gain topk {g_topk} vs randomk {g_rand}");
}

/// Lower CR must lower the mean gain (paper Fig 3's premise).
#[test]
fn gain_monotone_in_cr() {
    let mut gains = Vec::new();
    for cr in [0.2, 0.02, 0.002] {
        let r = run(base_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            CrControl::Static(cr),
            60,
        ));
        gains.push(r.summary().mean_gain);
    }
    assert!(gains[0] > gains[1] && gains[1] > gains[2], "{gains:?}");
}

/// Identical seeds => bit-identical metrics (full-system determinism).
#[test]
fn whole_run_determinism() {
    let mk = || {
        run(base_cfg(
            Strategy::Flexible { policy: SelectionPolicy::Star },
            CrControl::Static(0.02),
            80,
        ))
    };
    let a = mk();
    let b = mk();
    // t_comp is MEASURED wall time (legitimately noisy); everything else
    // must be bit-identical.
    assert_eq!(a.params, b.params);
    for (x, y) in a.metrics.steps.iter().zip(&b.metrics.steps) {
        assert_eq!(x.loss, y.loss);
        assert_eq!(x.t_sync, y.t_sync);
        assert_eq!(x.t_compute, y.t_compute);
        assert_eq!(x.collective, y.collective);
        assert_eq!(x.cr, y.cr);
        assert_eq!(x.gain, y.gain);
    }
}

/// VAR-Topk under non-iid shards: selection density must be skewed (the
/// Fig 4b phenomenon) while STAR stays uniform.
#[test]
fn var_density_skews_under_noniid() {
    let mk = |policy| {
        let cfg = base_cfg(
            Strategy::ArTopkFixed { policy, flavor: ArFlavor::Ring },
            CrControl::Static(0.02),
            240,
        );
        let mut src = HostMlp::default_preset(5);
        src.skew = 1.0; // fully non-iid class shards
        let r = Session::from_config(cfg)
            .source(Box::new(src))
            .build()
            .expect("valid config")
            .run();
        let ranks = r.metrics.selected_ranks();
        let mut counts = [0usize; 4];
        for rank in ranks {
            counts[rank as usize] += 1;
        }
        counts
    };
    let star = mk(SelectionPolicy::Star);
    let var = mk(SelectionPolicy::Var);
    let spread = |c: &[usize; 4]| {
        let max = *c.iter().max().unwrap() as f64;
        let min = *c.iter().min().unwrap() as f64;
        max / min.max(1.0)
    };
    assert!(spread(&star) < 1.1, "STAR must be uniform: {star:?}");
    assert!(spread(&var) > spread(&star), "VAR must skew: {var:?} vs {star:?}");
}

/// The adaptive controller must keep CR in bounds and stay numerically
/// sound across a network schedule WITH jitter + congestion modifier
/// wrappers (failure-ish injection: the probe sees noisy, congested
/// links). Migrated from the old in-schedule `with_jitter`/`with_congestion`
/// overlays to the composable wrappers (distinct seeds per overlay).
#[test]
fn adaptive_survives_hostile_network() {
    let mut cfg = base_cfg(
        Strategy::Flexible { policy: SelectionPolicy::Star },
        CrControl::Adaptive(AdaptiveConfig { probe_iters: 3, ..Default::default() }),
        150,
    );
    cfg.net = Box::new(
        CongestionEpisodes::wrap(
            Jitter::wrap(NetSchedule::c2(6.0), 0.15, 13).unwrap(),
            0.2,
            8.0,
            14,
        )
        .unwrap(),
    );
    cfg.probe_noise = 0.10;
    let r = run(cfg);
    for m in &r.metrics.steps {
        assert!(m.cr >= 0.001 - 1e-12 && m.cr <= 0.1 + 1e-12, "cr {}", m.cr);
        assert!(m.loss.is_finite());
        assert!(m.t_sync >= 0.0 && m.t_sync.is_finite());
    }
    assert!(r.best_accuracy().unwrap() > 0.6);
}

/// Counts strategy-switch events off the typed observer stream (what used
/// to require reaching into `trainer.policy_switcher`).
struct SwitchCounter {
    policy_commits: Arc<AtomicU64>,
    collective_switches: Arc<AtomicU64>,
}

impl TrainObserver for SwitchCounter {
    fn on_strategy_switch(&mut self, s: &StrategySwitch) {
        match s.dimension {
            SwitchDimension::SelectionPolicy => {
                self.policy_commits.fetch_add(1, Ordering::Relaxed);
            }
            SwitchDimension::Collective => {
                self.collective_switches.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// The §5 future-work extension: auto STAR/VAR switching must trial both
/// policies, commit to one (visible as a typed observer event), and still
/// learn. Post ISSUE 5 the trial/commit logic is a `PolicySwitchController`
/// composed alongside the CR controller — the strategy itself is a plain
/// AR-Topk — so the same behavior now arrives via the control plane.
#[test]
fn artopk_auto_switches_and_learns() {
    let commits = Arc::new(AtomicU64::new(0));
    let switches = Arc::new(AtomicU64::new(0));
    let cfg = base_cfg(
        Strategy::ArTopkAuto { flavor: ArFlavor::Ring },
        CrControl::Static(0.05),
        200,
    );
    let r = Session::from_config(cfg)
        .observer(Box::new(SwitchCounter {
            policy_commits: commits.clone(),
            collective_switches: switches.clone(),
        }))
        .source(Box::new(HostMlp::default_preset(21)))
        .build()
        .expect("valid config")
        .run();
    assert_eq!(r.strategy, "AR-Topk-auto");
    assert_eq!(r.controller, "composite", "policy switching is a composed controller");
    assert!(
        commits.load(Ordering::Relaxed) >= 1,
        "must complete at least one trial->commit cycle"
    );
    assert!(r.best_accuracy().unwrap() > 0.7);
    // Both policies appear during trials: rank sequence has round-robin
    // stretches (STAR) — committed stretches may be either.
    let ranks = r.metrics.selected_ranks();
    assert_eq!(ranks.len(), 200);
}

/// Topology tentpole, end to end: the same training run on a flat vs a
/// two-level (fast-intra/slow-inter) cluster. TopoAuto must settle on
/// Hier-AR under the two-level overlay, cut sync time vs the flat ring,
/// and converge identically well (dense exchanges are exact sums).
#[test]
fn topo_auto_learns_and_cuts_sync_on_two_level_cluster() {
    let slow_inter = LinkParams::from_ms_gbps(10.0, 1.0);
    let flat = {
        let mut cfg = base_cfg(
            Strategy::DenseSgd { flavor: DenseFlavor::Ring },
            CrControl::Static(1.0),
            200,
        );
        cfg.net = Box::new(NetSchedule::static_link(slow_inter));
        run(cfg)
    };
    let topo = {
        let mut cfg = base_cfg(
            Strategy::DenseSgd { flavor: DenseFlavor::TopoAuto },
            CrControl::Static(1.0),
            200,
        );
        cfg.net = Box::new(
            NetSchedule::static_link(slow_inter)
                .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 2),
        );
        run(cfg)
    };
    assert!(topo
        .metrics
        .collectives_used()
        .iter()
        .all(|c| c.name() == "Hier-AR"));
    let s_flat = flat.summary().mean_sync_s;
    let s_topo = topo.summary().mean_sync_s;
    assert!(s_topo < s_flat, "two-level sync {s_topo} vs flat ring {s_flat}");
    assert!(topo.best_accuracy().unwrap() > 0.7);
}

/// Sanity: a 1-worker cluster degenerates to plain SGD with zero comm.
#[test]
fn single_worker_no_communication() {
    let mut cfg = base_cfg(
        Strategy::DenseSgd { flavor: DenseFlavor::Ring },
        CrControl::Static(1.0),
        50,
    );
    cfg.n_workers = 1;
    let r = run(cfg);
    assert!(r.metrics.steps.iter().all(|m| m.t_sync == 0.0));
    assert!(r.best_accuracy().unwrap() > 0.7);
}

/// Eqn 3 bookkeeping: recorded step time decomposes exactly.
#[test]
fn step_time_decomposition() {
    let r = run(base_cfg(
        Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
        CrControl::Static(0.05),
        40,
    ));
    for m in &r.metrics.steps {
        assert!((m.t_step() - (m.t_compute + m.t_comp + m.t_sync)).abs() < 1e-15);
        assert!(m.t_compute > 0.0);
    }
}
