//! The synchronous data-parallel training loop (Eqn 1/3) with flexible
//! compression-communication (the paper's full system).
//!
//! Per step: every worker computes a gradient (PJRT artifact or host
//! model), the chosen strategy compresses + exchanges it (real data
//! movement, simulated α-β time), and the shared parameters take a
//! momentum-SGD step. The [`super::adaptive`] controller may retune the CR
//! (MOO/NSGA-II) and the collective (Eqn 5) as the probed network drifts.

use crate::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use crate::collectives::{allgather_sparse, dense_op, CollectiveKind, CommReport};
use crate::compress::{gain::gain, Compressor, CompressorKind, EfState, GainTracker};
use crate::coordinator::adaptive::{AdaptiveConfig, AdaptiveState};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::{MetricsLog, StepMetrics};
use crate::coordinator::selector;
use crate::coordinator::worker::{ComputeModel, GradSource};
use crate::netsim::cost_model::Topology;
use crate::netsim::probe::Probe;
use crate::netsim::schedule::NetSchedule;
use crate::netsim::VirtualClock;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use std::time::Instant;

/// Dense allreduce flavour for the DenseSGD baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseFlavor {
    Ring,
    Tree,
    /// Recursive halving-doubling (Rabenseifner): ring's β at tree's α.
    HalvingDoubling,
    /// Two-level intra-reduce / inter-ring / intra-broadcast over the
    /// schedule's [`Topology`] (falls back to ring on flat clusters).
    Hierarchical,
    /// Parameter-server star (scale-out strawman).
    Ps,
    /// Pick ring/tree per step from the probed link (the paper's original
    /// two-way dense choice).
    Auto,
    /// Pick the cheapest of {ring, tree, HD, hierarchical} per step from
    /// the probed link and the schedule's topology
    /// ([`selector::choose_dense_topo`]).
    TopoAuto,
}

/// Compression-communication strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// No compression; dense allreduce (the paper's DenseSGD baseline).
    DenseSgd { flavor: DenseFlavor },
    /// Compress with `kind`, synchronize via Allgather (LW/MS-Topk path).
    AgCompress { kind: CompressorKind },
    /// AR-Topk with a fixed AR flavour (§3-A/B).
    ArTopkFixed { policy: SelectionPolicy, flavor: ArFlavor },
    /// Full flexible strategy: pick AG vs ART-Ring vs ART-Tree per step by
    /// Eqn 5 on the probed link (§3-D).
    Flexible { policy: SelectionPolicy },
    /// AR-Topk that AUTO-switches STAR<->VAR from observed loss improvement
    /// (the paper's §5 future work), with the Eqn 5 ring/tree choice.
    ArTopkAuto { flavor: ArFlavor },
}

impl Strategy {
    pub fn is_compressed(&self) -> bool {
        !matches!(self, Strategy::DenseSgd { .. })
    }
}

/// Compression-ratio control.
#[derive(Debug, Clone)]
pub enum CrControl {
    Static(f64),
    /// MOO-adaptive (§3-E): candidate exploration + NSGA-II knee point.
    Adaptive(AdaptiveConfig),
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub n_workers: usize,
    pub steps: u64,
    pub steps_per_epoch: u64,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// `(step, factor)` learning-rate decay events.
    pub lr_decay: Vec<(u64, f32)>,
    pub strategy: Strategy,
    pub cr: CrControl,
    pub schedule: NetSchedule,
    pub compute: ComputeModel,
    /// Probe observation noise fraction.
    pub probe_noise: f64,
    /// Message-size scale for SIMULATED communication/compression time:
    /// proxy-model experiments set this to `paper_params / proxy_params`
    /// so step-time tables carry the paper's message magnitudes while the
    /// numerics stay real (DESIGN.md §3). 1.0 = honest proxy size.
    pub msg_scale: f64,
    /// Multiplier on MEASURED compression time. Proxy experiments use
    /// `msg_scale / GPU_COMPRESS_SPEEDUP`: compression is O(G) so it
    /// extrapolates linearly in size, divided by the accelerator-vs-CPU
    /// throughput ratio (experiments::GPU_COMPRESS_SPEEDUP). 1.0 = honest
    /// measured time on this host.
    pub comp_scale: f64,
    /// Evaluate every N steps (0 = only at the end).
    pub eval_every: u64,
    pub seed: u64,
    /// Worker threads for per-worker gradient computation and compression
    /// (CLI `--threads`): 0 = available hardware parallelism, 1 = fully
    /// sequential. With static CR control, numerics are bitwise identical
    /// for every value — only measured wall time changes (DESIGN.md §7).
    /// MOO-adaptive runs ([`CrControl::Adaptive`]) feed MEASURED
    /// compression time into CR selection and so were never run-to-run
    /// bitwise reproducible, with or without threads.
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_workers: 8,
            steps: 200,
            steps_per_epoch: 50,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 0.0,
            lr_decay: Vec::new(),
            strategy: Strategy::DenseSgd { flavor: DenseFlavor::Ring },
            cr: CrControl::Static(0.01),
            schedule: NetSchedule::static_link(
                crate::netsim::cost_model::LinkParams::from_ms_gbps(4.0, 20.0),
            ),
            compute: ComputeModel::fixed(0.02),
            probe_noise: 0.02,
            msg_scale: 1.0,
            comp_scale: 1.0,
            eval_every: 0,
            seed: 0,
            threads: 0,
        }
    }
}

/// The coordinator-side trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
    source: Box<dyn GradSource>,
    pub params: Vec<f32>,
    momentum_buf: Vec<f32>,
    ef: Vec<EfState>,
    /// One compressor per worker (same seed — Random-k then draws the
    /// SAME indices on every worker each step, the AR-compatible shared
    /// sequence its module docs describe), so the AG path compresses all
    /// workers concurrently without sharing mutable state.
    compressors: Vec<Box<dyn Compressor>>,
    artopk_op: ArTopk,
    /// Execution engine for the per-worker hot path (DESIGN.md §7).
    pool: ThreadPool,
    probe: Probe,
    pub clock: VirtualClock,
    pub metrics: MetricsLog,
    rng: Rng,
    step: u64,
    pub cur_cr: f64,
    pub gain_tracker: GainTracker,
    adaptive: Option<AdaptiveState>,
    lr_cur: f32,
    /// Simulated seconds spent in candidate exploration (kept out of the
    /// restored clock, reported separately).
    pub explore_overhead_s: f64,
    /// STAR/VAR auto-switcher (ArTopkAuto strategy only).
    pub policy_switcher: Option<crate::coordinator::policy_switch::PolicySwitcher>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig, mut source: Box<dyn GradSource>) -> Self {
        let params = source.init_params();
        let dim = source.dim();
        assert_eq!(params.len(), dim);
        let n = cfg.n_workers;
        assert!(
            n % cfg.schedule.workers_per_node() == 0,
            "n_workers {n} not divisible by the schedule's workers_per_node {}",
            cfg.schedule.workers_per_node()
        );
        let (cur_cr, adaptive, gain_threshold) = match &cfg.cr {
            CrControl::Static(c) => (*c, None, 0.1),
            CrControl::Adaptive(a) => {
                (a.c_high, Some(AdaptiveState::new(a.clone())), a.gain_threshold)
            }
        };
        let compressors: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| match cfg.strategy {
                Strategy::AgCompress { kind } => kind.build(cfg.seed),
                _ => CompressorKind::TopK.build(cfg.seed),
            })
            .collect();
        let pool = ThreadPool::auto(cfg.threads);
        let (policy, flavor) = match cfg.strategy {
            Strategy::ArTopkFixed { policy, flavor } => (policy, flavor),
            Strategy::Flexible { policy } => (policy, ArFlavor::Ring),
            Strategy::ArTopkAuto { flavor } => (SelectionPolicy::Star, flavor),
            _ => (SelectionPolicy::Star, ArFlavor::Ring),
        };
        let probe = Probe::new(cfg.schedule.clone(), cfg.probe_noise, cfg.seed ^ 0xBEEF);
        let policy_switcher = match cfg.strategy {
            Strategy::ArTopkAuto { .. } => Some(
                crate::coordinator::policy_switch::PolicySwitcher::new(10, 50),
            ),
            _ => None,
        };
        Trainer {
            policy_switcher,
            momentum_buf: vec![0.0; dim],
            ef: (0..n).map(|_| EfState::new(dim)).collect(),
            compressors,
            artopk_op: ArTopk::new(policy, flavor).with_pool(pool),
            pool,
            probe,
            clock: VirtualClock::new(),
            metrics: MetricsLog::default(),
            rng: Rng::new(cfg.seed ^ 0x7EA1),
            step: 0,
            cur_cr,
            gain_tracker: GainTracker::new(gain_threshold),
            adaptive,
            lr_cur: cfg.lr,
            explore_overhead_s: 0.0,
            params,
            cfg,
            source,
        }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    pub fn epoch(&self) -> f64 {
        self.step as f64 / self.cfg.steps_per_epoch as f64
    }

    pub fn source_name(&self) -> String {
        self.source.name()
    }

    /// Effective message bytes (selector + cost predictions): the flat
    /// gradient size scaled by `msg_scale`.
    pub fn model_bytes(&self) -> f64 {
        4.0 * self.source.dim() as f64 * self.cfg.msg_scale
    }

    /// Scale the topology's links so β-terms charge `msg_scale`-times the
    /// actual bytes (equivalent to a msg_scale-times bigger message; α
    /// unchanged) — see [`Topology::scale_beta`].
    fn scaled_topo(&self, t: Topology) -> Topology {
        t.scale_beta(self.cfg.msg_scale)
    }

    /// Run the configured number of steps (with eval + adaptation hooks).
    pub fn run(&mut self) {
        while self.step < self.cfg.steps {
            self.run_one_scheduled_step();
        }
        // Final eval.
        let (loss, acc) = self.source.eval(&self.params);
        self.metrics.record_eval(self.epoch(), loss, acc);
    }

    /// One public step incl. probe-driven adaptation + periodic eval.
    pub fn run_one_scheduled_step(&mut self) {
        let epoch = self.epoch();
        let (obs, net_changed) = self.probe.measure_and_detect(epoch);
        let m = self.step_once(true, obs.link());
        let gain_fired = self.gain_tracker.record(m.gain);
        if self.adaptive.is_some() && self.cfg.strategy.is_compressed() {
            self.maybe_adapt(net_changed, gain_fired, obs.link());
        }
        if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0 {
            let (loss, acc) = self.source.eval(&self.params);
            self.metrics.record_eval(self.epoch(), loss, acc);
        }
    }

    /// Execute exactly one training step at the current CR/strategy.
    /// `record` controls whether it lands in the main metrics log.
    /// Returns the step's metrics either way.
    pub fn step_once(
        &mut self,
        record: bool,
        probed: crate::netsim::cost_model::LinkParams,
    ) -> StepMetrics {
        let n = self.cfg.n_workers;
        let epoch = self.epoch();
        // True data-movement topology (β scaled by msg_scale) and the
        // selector's view of it: the probe observes the inter link, the
        // intra link is known in-machine hardware.
        let base_topo = self.cfg.schedule.topology_at(epoch);
        let true_topo = self.scaled_topo(base_topo);
        let probed_topo = Topology { inter: probed, ..base_topo };
        let t_compute = self.cfg.compute.step_time(n, &mut self.rng);

        // Per-worker gradients (real computation — PJRT or host backprop),
        // concurrent across TrainConfig::threads. Each worker's shard is an
        // independent pure function of (params, worker, step), so results
        // are bitwise identical for any thread count.
        let per_worker = {
            let src: &dyn GradSource = &*self.source;
            let params = &self.params;
            let step = self.step;
            self.pool.map(n, |w| src.grad(params, w, n, step))
        };
        let mut losses = Vec::with_capacity(n);
        let mut grads = Vec::with_capacity(n);
        for (loss, g) in per_worker {
            losses.push(loss);
            grads.push(g);
        }
        let loss = losses.iter().sum::<f64>() / n as f64;

        // Exchange. Measured compression time is rescaled by comp_scale
        // (see TrainConfig::comp_scale); honest at comp_scale = 1.
        let (update, comm, t_comp, collective, selected, step_gain) =
            self.exchange(&grads, true_topo, probed_topo);
        let t_comp = t_comp * self.cfg.comp_scale;

        // Momentum-SGD update (identical params on every worker).
        self.apply_lr_decay();
        let lr = self.lr_cur;
        let mu = self.cfg.momentum;
        let wd = self.cfg.weight_decay;
        for i in 0..self.params.len() {
            let g = update[i] + wd * self.params[i];
            self.momentum_buf[i] = mu * self.momentum_buf[i] + g;
            self.params[i] -= lr * self.momentum_buf[i];
        }

        let m = StepMetrics {
            step: self.step,
            epoch,
            loss,
            t_compute,
            t_comp,
            t_sync: comm.seconds,
            collective,
            cr: if self.cfg.strategy.is_compressed() { self.cur_cr } else { 1.0 },
            selected_rank: selected,
            gain: step_gain,
            alpha_ms: probed.alpha_ms(),
            bw_gbps: probed.bw_gbps(),
        };
        self.clock.advance(m.t_step());
        if let Some(sw) = &mut self.policy_switcher {
            sw.observe(m.loss);
        }
        if record {
            self.metrics.record(m.clone());
        }
        self.step += 1;
        m
    }

    /// Compress + communicate per the strategy. `true_topo` carries the
    /// msg_scale-adjusted links the data actually moves over (its inter
    /// side is the old `true_link`); `probed_topo` is the selector's noisy
    /// view. Returns (mean update, comm report, measured t_comp,
    /// collective, selected rank, gain).
    fn exchange(
        &mut self,
        grads: &[Vec<f32>],
        true_topo: Topology,
        probed_topo: Topology,
    ) -> (Vec<f32>, CommReport, f64, CollectiveKind, Option<usize>, f64) {
        let n = self.cfg.n_workers;
        let true_link = true_topo.inter;
        let probed = probed_topo.inter;

        match self.cfg.strategy {
            Strategy::DenseSgd { flavor } => {
                // Table dispatch through the Collective registry: resolve
                // the flavor (fixed or selector-chosen) to a kind, run the
                // registered op. Selector choices, metrics kinds and future
                // collectives all plug in at this one seam.
                let kind = self.dense_kind(flavor, probed_topo);
                let op = dense_op(kind).expect("dense kind registered");
                let mut bufs = grads.to_vec();
                let report = op.run(&mut bufs, true_topo);
                let mut update = bufs.into_iter().next().unwrap();
                crate::tensor::scale(&mut update, 1.0 / n as f32);
                (update, report, 0.0, kind, None, 1.0)
            }

            Strategy::AgCompress { .. } => {
                self.ag_exchange(grads, true_link, CollectiveKind::AllgatherTopk)
            }

            Strategy::ArTopkFixed { flavor, .. } => {
                self.artopk_op.flavor = flavor;
                self.art_exchange(grads, true_link)
            }

            Strategy::Flexible { .. } => {
                let choice = selector::choose(probed, self.model_bytes(), n, self.cur_cr);
                match selector::ar_flavor(choice.kind) {
                    Some(f) => {
                        self.artopk_op.flavor = f;
                        self.art_exchange(grads, true_link)
                    }
                    None => self.ag_exchange(grads, true_link, CollectiveKind::AllgatherTopk),
                }
            }

            Strategy::ArTopkAuto { flavor } => {
                let policy = self
                    .policy_switcher
                    .as_ref()
                    .expect("switcher set for ArTopkAuto")
                    .current();
                self.artopk_op.policy = policy;
                self.artopk_op.flavor = flavor;
                self.art_exchange(grads, true_link)
            }
        }
    }

    /// Resolve a dense flavor (fixed or selector-driven) to the collective
    /// kind the registry will execute.
    fn dense_kind(&self, flavor: DenseFlavor, probed_topo: Topology) -> CollectiveKind {
        let n = self.cfg.n_workers;
        match flavor {
            DenseFlavor::Ring => CollectiveKind::RingAllreduce,
            DenseFlavor::Tree => CollectiveKind::TreeAllreduce,
            DenseFlavor::HalvingDoubling => CollectiveKind::HalvingDoublingAllreduce,
            DenseFlavor::Hierarchical => CollectiveKind::HierarchicalAllreduce,
            DenseFlavor::Ps => CollectiveKind::PsStar,
            DenseFlavor::Auto => {
                selector::choose_dense(probed_topo.inter, self.model_bytes(), n)
            }
            DenseFlavor::TopoAuto => {
                selector::choose_dense_topo(probed_topo, self.model_bytes(), n).kind
            }
        }
    }

    /// AG path: error-feed + compress every worker's gradient concurrently
    /// across the pool (each worker owns its EfState and compressor — no
    /// shared mutable state), then allgather. `t_comp` is the max of the
    /// per-worker durations MEASURED INSIDE the concurrently-running tasks
    /// — the critical-path worker a synchronous cluster step waits for,
    /// independent of this host's core count while the pool is not
    /// oversubscribed (DESIGN.md §7).
    fn ag_exchange(
        &mut self,
        grads: &[Vec<f32>],
        true_link: crate::netsim::cost_model::LinkParams,
        kind: CollectiveKind,
    ) -> (Vec<f32>, CommReport, f64, CollectiveKind, Option<usize>, f64) {
        let n = self.cfg.n_workers;
        let dim = self.source.dim();
        let layout = self.source.layout().clone();
        let cr = self.cur_cr;
        let mut lanes: Vec<(&mut EfState, &mut Box<dyn Compressor>)> =
            self.ef.iter_mut().zip(self.compressors.iter_mut()).collect();
        let results = self.pool.map_mut(&mut lanes, |w, lane| {
            let (ef, comp) = lane;
            let t0 = Instant::now();
            let g_e = ef.error_fed(&grads[w]);
            let sparse = comp.compress(&g_e, cr, &layout);
            let mut dt = t0.elapsed().as_secs_f64();
            // Gain bookkeeping is metrics-only — keep its O(G) pass OFF
            // the billed compression path (a cluster wouldn't run it).
            let e_sq = crate::tensor::sq_norm(&g_e);
            let g = gain(sparse.sq_norm(), e_sq);
            let t1 = Instant::now();
            ef.update(g_e, &sparse);
            dt += t1.elapsed().as_secs_f64();
            (sparse, g, dt)
        });
        drop(lanes);
        let mut parts = Vec::with_capacity(n);
        let mut gain_acc = 0.0f64;
        let mut t_comp = 0.0f64;
        for (sparse, g, dt) in results {
            gain_acc += g;
            t_comp = t_comp.max(dt);
            parts.push(sparse);
        }
        let (mut dense, report) = allgather_sparse(&parts, dim, true_link);
        crate::tensor::scale(&mut dense, 1.0 / n as f32);
        (dense, report, t_comp, kind, None, gain_acc / n as f64)
    }

    /// AR-Topk path (Alg 1).
    fn art_exchange(
        &mut self,
        grads: &[Vec<f32>],
        true_link: crate::netsim::cost_model::LinkParams,
    ) -> (Vec<f32>, CommReport, f64, CollectiveKind, Option<usize>, f64) {
        let n = self.cfg.n_workers;
        let kind = match self.artopk_op.flavor {
            ArFlavor::Ring => CollectiveKind::ArTopkRing,
            ArFlavor::Tree => CollectiveKind::ArTopkTree,
        };
        let res = self
            .artopk_op
            .exchange(grads, &mut self.ef, self.cur_cr, self.step, true_link);
        // Critical-path compression time (parallel workers): see §Perf.
        let t_comp = res.comp_wall_s;
        let mut update = res.update.to_dense();
        crate::tensor::scale(&mut update, 1.0 / n as f32);
        let g = res
            .gain_terms
            .iter()
            .map(|&(c, e)| gain(c, e))
            .sum::<f64>()
            / n as f64;
        (update, res.comm, t_comp, kind, Some(res.selected), g)
    }

    fn apply_lr_decay(&mut self) {
        let mut lr = self.cfg.lr;
        for &(at, factor) in &self.cfg.lr_decay {
            if self.step >= at {
                lr *= factor;
            }
        }
        self.lr_cur = lr;
    }

    // -- checkpoint/restore (used by the MOO exploration) ------------------

    pub fn snapshot(&self) -> Checkpoint {
        Checkpoint {
            params: self.params.clone(),
            momentum: self.momentum_buf.clone(),
            residuals: self.ef.iter().map(|e| e.residual.clone()).collect(),
            step: self.step,
            clock: self.clock.now(),
        }
    }

    pub fn restore(&mut self, ck: &Checkpoint) {
        self.params = ck.params.clone();
        self.momentum_buf = ck.momentum.clone();
        for (e, r) in self.ef.iter_mut().zip(&ck.residuals) {
            e.residual = r.clone();
        }
        self.step = ck.step;
        self.clock = VirtualClock::new();
        self.clock.advance(ck.clock);
    }

    /// Delegate to the adaptive controller (split out to keep borrows
    /// simple — the controller re-enters `step_once` during exploration).
    fn maybe_adapt(
        &mut self,
        net_changed: bool,
        gain_fired: bool,
        probed: crate::netsim::cost_model::LinkParams,
    ) {
        let mut state = self.adaptive.take().expect("adaptive state");
        state.maybe_adapt(self, net_changed, gain_fired, probed);
        self.adaptive = Some(state);
    }

    pub fn eval_now(&mut self) -> (f64, f64) {
        self.source.eval(&self.params)
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::LinkParams;
    use crate::runtime::host_model::HostMlp;

    fn quick_cfg(strategy: Strategy, cr: f64, steps: u64) -> TrainConfig {
        TrainConfig {
            n_workers: 4,
            steps,
            steps_per_epoch: 20,
            lr: 0.3,
            momentum: 0.6,
            weight_decay: 0.0,
            strategy,
            cr: CrControl::Static(cr),
            compute: ComputeModel::fixed(0.01),
            eval_every: 0,
            seed: 42,
            ..Default::default()
        }
    }

    fn train(strategy: Strategy, cr: f64, steps: u64) -> Trainer {
        let cfg = quick_cfg(strategy, cr, steps);
        let src = Box::new(HostMlp::default_preset(7));
        let mut t = Trainer::new(cfg, src);
        t.run();
        t
    }

    #[test]
    fn dense_sgd_learns() {
        let t = train(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 120);
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.8, "dense accuracy {acc}");
        let s = t.metrics.summary();
        assert!(s.final_loss < 0.5, "loss {}", s.final_loss);
        assert_eq!(s.mean_comp_s, 0.0);
    }

    #[test]
    fn ag_topk_learns_with_error_feedback() {
        let t = train(Strategy::AgCompress { kind: CompressorKind::TopK }, 0.05, 250);
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.75, "AG topk accuracy {acc}");
        assert!(t.metrics.summary().mean_gain < 1.0);
    }

    #[test]
    fn artopk_star_learns() {
        let t = train(
            Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            },
            0.05,
            250,
        );
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.75, "STAR accuracy {acc}");
        // Round-robin rank density (Fig 4 shape).
        let ranks = t.metrics.selected_ranks();
        assert_eq!(ranks.len(), 250);
        for r in 0..4 {
            let count = ranks.iter().filter(|&&x| x as usize == r).count();
            assert!((count as i64 - 62).abs() <= 2, "rank {r} count {count}");
        }
    }

    #[test]
    fn compressed_steps_are_faster_than_dense_on_slow_net() {
        let slow = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 0.05));
        let mk = |s: Strategy, cr| {
            let mut cfg = quick_cfg(s, cr, 20);
            cfg.schedule = slow.clone();
            let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(1)));
            t.run();
            t.metrics.summary().mean_step_s
        };
        let dense = mk(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0);
        let comp = mk(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            0.01,
        );
        assert!(comp < dense, "compressed {comp} vs dense {dense}");
    }

    #[test]
    fn flexible_switches_collectives_when_link_crosses_eqn5_boundary() {
        // 2M params at CR 0.1, N=4: Eqn 5b threshold α/β ≈ Mc·0.417 ≈ 3.3e5.
        // Phase A (0.1 ms, 1 Gbps): α/β = 1.25e4  -> ART-Ring.
        // Phase B (100 ms, 25 Gbps): α/β = 3.1e8  -> AG.
        use crate::netsim::schedule::Phase;
        let sched = NetSchedule::piecewise(
            "boundary",
            vec![
                Phase { from_epoch: 0.0, link: LinkParams::from_ms_gbps(0.1, 1.0) },
                Phase { from_epoch: 2.0, link: LinkParams::from_ms_gbps(100.0, 25.0) },
            ],
        );
        let mut cfg = quick_cfg(Strategy::Flexible { policy: SelectionPolicy::Star }, 0.1, 80);
        cfg.schedule = sched;
        cfg.steps_per_epoch = 20;
        let src = Box::new(crate::runtime::host_model::SyntheticGrad::new(2_000_000, 3));
        let mut t = Trainer::new(cfg, src);
        t.run();
        let used: Vec<&str> = t.metrics.collectives_used().iter().map(|c| c.name()).collect();
        assert!(used[..30].iter().all(|&c| c == "ART-Ring"), "phase A: {:?}", &used[..5]);
        assert!(used[50..].iter().all(|&c| c == "AG"), "phase B: {:?}", &used[75..]);
    }

    #[test]
    fn halving_doubling_dense_learns_like_ring() {
        let ring = train(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 120);
        let hd = train(Strategy::DenseSgd { flavor: DenseFlavor::HalvingDoubling }, 1.0, 120);
        // Identical numerics (both are exact sums), cheaper sync.
        let a_ring = ring.metrics.final_accuracy().unwrap();
        let a_hd = hd.metrics.final_accuracy().unwrap();
        assert!(a_hd > 0.8, "HD accuracy {a_hd} (ring {a_ring})");
        assert!(
            hd.metrics.summary().mean_sync_s < ring.metrics.summary().mean_sync_s,
            "HD must beat ring on the default latency-bearing link"
        );
        assert!(hd
            .metrics
            .collectives_used()
            .iter()
            .all(|c| *c == CollectiveKind::HalvingDoublingAllreduce));
    }

    #[test]
    fn topo_auto_picks_hierarchical_on_asymmetric_cluster() {
        use crate::netsim::cost_model::LinkParams;
        // 2 nodes x 2 ranks: NVLink-class intra, congested 10ms/1Gbps inter.
        let sched = NetSchedule::static_link(LinkParams::from_ms_gbps(10.0, 1.0))
            .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 2);
        let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::TopoAuto }, 1.0, 30);
        cfg.schedule = sched;
        let src = Box::new(crate::runtime::host_model::SyntheticGrad::new(2_000_000, 5));
        let mut t = Trainer::new(cfg, src);
        t.run();
        let used = t.metrics.collectives_used();
        assert!(
            used.iter().all(|c| *c == CollectiveKind::HierarchicalAllreduce),
            "expected Hier-AR everywhere, got {:?}",
            used.first()
        );
    }

    #[test]
    fn hierarchical_flavor_falls_back_to_ring_on_flat_cluster() {
        let t = train(Strategy::DenseSgd { flavor: DenseFlavor::Hierarchical }, 1.0, 20);
        // Flat schedule (workers_per_node = 1): the op degenerates to ring
        // but is still reported as the hierarchical flavour.
        assert!(t
            .metrics
            .collectives_used()
            .iter()
            .all(|c| *c == CollectiveKind::HierarchicalAllreduce));
        assert!(t.metrics.summary().mean_sync_s > 0.0);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn mismatched_topology_rejected() {
        use crate::netsim::cost_model::LinkParams;
        let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 1);
        cfg.n_workers = 6;
        cfg.schedule = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))
            .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 4);
        Trainer::new(cfg, Box::new(HostMlp::default_preset(1)));
    }

    #[test]
    fn lr_decay_applies() {
        let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 10);
        cfg.lr = 1.0;
        cfg.lr_decay = vec![(5, 0.1)];
        let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(2)));
        t.run();
        assert!((t.lr_cur - 0.1).abs() < 1e-6);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let cfg = quick_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            0.05,
            0,
        );
        let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(3)));
        let link = LinkParams::from_ms_gbps(4.0, 20.0);
        for _ in 0..5 {
            t.step_once(false, link);
        }
        let ck = t.snapshot();
        let params_at_ck = t.params.clone();
        for _ in 0..5 {
            t.step_once(false, link);
        }
        assert_ne!(t.params, params_at_ck);
        t.restore(&ck);
        assert_eq!(t.params, params_at_ck);
        assert_eq!(t.step_count(), 5);
    }

    #[test]
    fn clock_accumulates_step_times() {
        let t = train(Strategy::DenseSgd { flavor: DenseFlavor::Tree }, 1.0, 10);
        let total: f64 = t.metrics.steps.iter().map(|m| m.t_step()).sum();
        assert!((t.clock.now() - total).abs() < 1e-9);
    }

    /// Wraps a real model but poisons one worker's gradient with NaN at a
    /// chosen step — the exploding-loss regression fixture.
    struct NanAt {
        inner: HostMlp,
        at_step: u64,
        at_worker: usize,
    }

    impl crate::coordinator::worker::GradSource for NanAt {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn layout(&self) -> &crate::tensor::Layout {
            self.inner.layout()
        }
        fn init_params(&mut self) -> Vec<f32> {
            self.inner.init_params()
        }
        fn grad(
            &self,
            params: &[f32],
            worker: usize,
            n_workers: usize,
            step: u64,
        ) -> (f64, Vec<f32>) {
            let (loss, mut g) = self.inner.grad(params, worker, n_workers, step);
            if step == self.at_step && worker == self.at_worker {
                g.iter_mut().for_each(|v| *v = f32::NAN);
                return (f64::NAN, g);
            }
            (loss, g)
        }
        fn eval(&mut self, params: &[f32]) -> (f64, f64) {
            self.inner.eval(params)
        }
        fn name(&self) -> String {
            format!("nan-at-{}@{}", self.at_worker, self.at_step)
        }
    }

    /// A NaN gradient mid-run (exploding loss) must not panic the trainer:
    /// the poisoned step surfaces as a NaN loss in the metrics (the
    /// diagnosable state), VAR selection avoids the poisoned worker, and
    /// subsequent steps still execute. Regression for the
    /// `partial_cmp(..).unwrap()` panic at the old artopk.rs:158.
    #[test]
    fn trains_through_a_nan_step_without_panicking() {
        let cfg = quick_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Var, flavor: ArFlavor::Ring },
            0.05,
            0,
        );
        let src = NanAt { inner: HostMlp::default_preset(7), at_step: 2, at_worker: 1 };
        let mut t = Trainer::new(cfg, Box::new(src));
        let link = LinkParams::from_ms_gbps(4.0, 20.0);
        let mut steps = Vec::new();
        for _ in 0..5 {
            steps.push(t.step_once(false, link));
        }
        assert!(steps[0].loss.is_finite() && steps[1].loss.is_finite());
        assert!(steps[2].loss.is_nan(), "the poisoned step must be visible");
        assert_ne!(
            steps[2].selected_rank,
            Some(1),
            "VAR must not broadcast the NaN worker's indices"
        );
        // The run keeps stepping (no panic) even though params now carry
        // NaNs at the exchanged coordinates.
        assert_eq!(t.step_count(), 5);
    }

    /// `threads` plumbing: any explicit value yields a working trainer and
    /// 0 resolves to the host parallelism (determinism across thread
    /// counts is pinned end-to-end in rust/tests/determinism.rs).
    #[test]
    fn explicit_thread_counts_train() {
        for threads in [1usize, 2, 7] {
            let mut cfg = quick_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Ring }, 1.0, 5);
            cfg.threads = threads;
            let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(7)));
            t.run();
            assert_eq!(t.metrics.steps.len(), 5, "threads={threads}");
        }
    }
}
