//! Real-workload learners + the one model-name table.
//!
//! Every built-in [`GradSource`] is a row of [`MODEL_TABLE`] — the same
//! single-registry pattern as
//! [`STRATEGY_TABLE`](crate::coordinator::strategy::STRATEGY_TABLE),
//! [`NET_TABLE`](crate::netsim::model::NET_TABLE) and
//! [`CONTROLLER_TABLE`](crate::coordinator::controller::CONTROLLER_TABLE):
//! CLI parsing (`--model`), the sweep server's model axis, `--help` text
//! and error messages all read from here, so a new learner is one new row.
//!
//! The learners themselves live in the submodules: [`mlp::MlpSource`]
//! (first-party reverse-mode autograd, two-spirals / noisy-sine) and
//! [`regression::MatrixRegressionSource`] (NNUE-style closed-form matrix
//! regression with bitwise JSON checkpoints). Both speak the flat-`Vec`
//! [`GradSource`] contract, so EF residuals, every compressor and Session
//! checkpoints work on them unchanged.

pub mod mlp;
pub mod regression;

pub use mlp::MlpSource;
pub use regression::{MatRegCheckpoint, MatrixRegressionSource};

use crate::coordinator::worker::GradSource;
use crate::runtime::host_model::{HostMlp, SyntheticGrad};

/// One registered model: its CLI name, a one-line summary for `--help`,
/// a seed-parameterized constructor, and the per-model defaults the sweep
/// server reads — a suggested learning rate (`lr_hint`; parameter scales
/// differ wildly between learners, one global default diverges some and
/// stalls others) and the accuracy a parameter-free guesser scores
/// (`chance_acc`; the sweep smoke gate's "demonstrably above chance"
/// floor).
pub struct ModelEntry {
    pub name: &'static str,
    pub summary: &'static str,
    /// Momentum-SGD learning rate this learner is known to converge under.
    pub lr_hint: f32,
    /// Top-1 accuracy of random guessing on this learner's eval metric.
    pub chance_acc: f64,
    pub build: fn(seed: u64) -> Box<dyn GradSource>,
}

/// The one model-name table (see module docs). `synthetic:<dim>` is the
/// only spec handled outside the table (it carries a parameter), exactly
/// as `trace:<path>` is for [`NET_TABLE`](crate::netsim::model::NET_TABLE).
pub const MODEL_TABLE: &[ModelEntry] = &[
    ModelEntry {
        name: "mlp",
        summary: "two-spirals tanh MLP, softmax-CE head (tape autograd)",
        lr_hint: 0.3,
        chance_acc: 0.5, // 2 balanced classes
        build: |seed| Box::new(MlpSource::two_spirals(seed)),
    },
    ModelEntry {
        name: "mlp-sine",
        summary: "noisy-sine tanh MLP, MSE head (tape autograd)",
        lr_hint: 0.1,
        // Within-band regression accuracy: a constant-zero predictor is
        // inside the +/-0.2 band for roughly a third of the sine's range.
        chance_acc: 0.35,
        build: |seed| Box::new(MlpSource::noisy_sine(seed)),
    },
    ModelEntry {
        name: "matreg",
        summary: "NNUE-style CReLU matrix regression, JSON checkpoints",
        lr_hint: 0.05,
        chance_acc: 0.1, // +/-0.1 band around a ~unit-scale teacher output
        build: |seed| Box::new(MatrixRegressionSource::default_preset(seed)),
    },
    ModelEntry {
        name: "host-mlp",
        summary: "Gaussian-clusters hand-backprop MLP (64->256->128->16)",
        lr_hint: 0.3,
        chance_acc: 1.0 / 16.0, // 16 balanced clusters
        build: |seed| Box::new(HostMlp::default_preset(seed)),
    },
];

/// The registry's suggested learning rate for a model spec (the sweep
/// server's per-cell default; `synthetic:<dim>` and unknown specs fall
/// back to a conservative 0.1 — validation rejects unknowns elsewhere).
pub fn lr_hint(spec: &str) -> f32 {
    MODEL_TABLE.iter().find(|e| e.name == spec).map_or(0.1, |e| e.lr_hint)
}

/// Random-guess accuracy for a model spec (the sweep smoke gate's floor;
/// specs outside the table score 0.0, i.e. any accuracy passes).
pub fn chance_acc(spec: &str) -> f64 {
    MODEL_TABLE.iter().find(|e| e.name == spec).map_or(0.0, |e| e.chance_acc)
}

/// Typed model-axis errors ([`ConfigError::Model`](crate::coordinator::session::ConfigError)
/// wraps this). The unknown-spec message lists every valid name, matching
/// the `NET_TABLE` error style.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelError {
    UnknownModel { spec: String },
    /// Checkpoint (de)serialization failures ([`MatRegCheckpoint`]).
    Checkpoint { msg: String },
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::UnknownModel { spec } => write!(
                f,
                "unknown model `{spec}` (valid: {}; or `synthetic:<dim>` for a cost-only source)",
                model_names().collect::<Vec<_>>().join(", ")
            ),
            ModelError::Checkpoint { msg } => write!(f, "checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for ModelError {}

/// Every valid model name, in table order (CLI help text).
pub fn model_names() -> impl Iterator<Item = &'static str> {
    MODEL_TABLE.iter().map(|e| e.name)
}

/// Resolve a model spec to a constructed [`GradSource`]: a [`MODEL_TABLE`]
/// name, or `synthetic:<dim>` for the cost-only synthetic source.
pub fn build_model(spec: &str, seed: u64) -> Result<Box<dyn GradSource>, ModelError> {
    if let Some(dim) = spec.strip_prefix("synthetic:") {
        let dim: usize = dim
            .parse()
            .map_err(|_| ModelError::UnknownModel { spec: spec.to_string() })?;
        if dim == 0 {
            return Err(ModelError::UnknownModel { spec: spec.to_string() });
        }
        return Ok(Box::new(SyntheticGrad::new(dim, seed)));
    }
    match MODEL_TABLE.iter().find(|e| e.name == spec) {
        Some(e) => Ok((e.build)(seed)),
        None => Err(ModelError::UnknownModel { spec: spec.to_string() }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every table row constructs, self-reports a consistent dim/layout,
    /// and produces a deterministic gradient.
    #[test]
    fn table_rows_construct_and_are_consistent() {
        for e in MODEL_TABLE {
            let mut m = (e.build)(5);
            let p = m.init_params();
            assert_eq!(p.len(), m.dim(), "{}", e.name);
            assert_eq!(m.layout().total(), m.dim(), "{}", e.name);
            let (l1, g1) = m.grad(&p, 0, 2, 1);
            let (l2, g2) = m.grad(&p, 0, 2, 1);
            assert_eq!(l1.to_bits(), l2.to_bits(), "{}", e.name);
            assert_eq!(g1, g2, "{}", e.name);
            assert_eq!(g1.len(), m.dim(), "{}", e.name);
        }
    }

    #[test]
    fn build_model_resolves_names_and_synthetic() {
        for e in MODEL_TABLE {
            assert!(build_model(e.name, 0).unwrap().dim() > 0, "{}", e.name);
        }
        assert_eq!(build_model("synthetic:1000", 0).unwrap().dim(), 1000);
        assert!(build_model("synthetic:0", 0).is_err());
        assert!(build_model("synthetic:abc", 0).is_err());
    }

    /// Per-model defaults read by the sweep server: every row's lr hint
    /// is usable and its chance floor is a proper probability.
    #[test]
    fn table_hints_are_sane() {
        for e in MODEL_TABLE {
            assert!(e.lr_hint > 0.0 && e.lr_hint <= 1.0, "{}", e.name);
            assert!(e.chance_acc >= 0.0 && e.chance_acc < 1.0, "{}", e.name);
            assert_eq!(lr_hint(e.name), e.lr_hint, "{}", e.name);
            assert_eq!(chance_acc(e.name), e.chance_acc, "{}", e.name);
        }
        assert_eq!(lr_hint("synthetic:100"), 0.1);
        assert_eq!(chance_acc("synthetic:100"), 0.0);
    }

    /// The unknown-model error lists every valid name plus the synthetic
    /// hint — the NET_TABLE error style (satellite: listing parse errors).
    #[test]
    fn unknown_model_error_lists_the_table() {
        let err = build_model("nope", 0).unwrap_err();
        let msg = err.to_string();
        for e in MODEL_TABLE {
            assert!(msg.contains(e.name), "{msg}");
        }
        assert!(msg.contains("synthetic:<dim>"), "{msg}");
        assert!(matches!(err, ModelError::UnknownModel { .. }));
    }
}
