//! Parameter-server (star topology) exchange (Table I row 1):
//! `2α + 2(N-1)Mβ` — the server's link carries `(N-1)M` in each direction.
//!
//! Implemented as the DenseSGD baseline the paper contrasts with
//! decentralized AR; O(MN) bandwidth makes it the scale-out strawman.

use crate::collectives::CommReport;
use crate::netsim::cost_model::LinkParams;

/// PS exchange with `server` as the star center: gathers all buffers,
/// sums them, and pushes the sum back. After the call every buffer holds
/// the elementwise sum.
pub fn ps_exchange(bufs: &mut [Vec<f32>], server: usize, link: LinkParams) -> CommReport {
    let n = bufs.len();
    assert!(server < n, "server {server} out of range for n={n}");
    let m = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == m), "buffer length mismatch");
    let mut report = CommReport::default();
    if n == 1 || m == 0 {
        return report;
    }
    let m_bytes = 4.0 * m as f64;

    // Gather: the server's ingress carries (N-1)·M bytes in one round.
    let mut sum = bufs[server].clone();
    for (w, b) in bufs.iter().enumerate() {
        if w != server {
            for (s, v) in sum.iter_mut().zip(b) {
                *s += v;
            }
        }
    }
    report.add_round(link, (n as f64 - 1.0) * m_bytes);

    // Scatter: egress carries (N-1)·M bytes back.
    for b in bufs.iter_mut() {
        b.copy_from_slice(&sum);
    }
    report.add_round(link, (n as f64 - 1.0) * m_bytes);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(1.0, 10.0)
    }

    #[test]
    fn sums_exactly() {
        let mut bufs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        ps_exchange(&mut bufs, 0, link());
        for b in &bufs {
            assert_eq!(b, &vec![9.0, 12.0]);
        }
    }

    #[test]
    fn time_matches_closed_form() {
        let n = 8;
        let m = 1000;
        let mut bufs = vec![vec![1.0f32; m]; n];
        let r = ps_exchange(&mut bufs, 0, link());
        let want = cost_model::ps_star(link(), 4.0 * m as f64, n);
        assert!(
            (r.seconds - want).abs() / want < 1e-9,
            "sim {} vs model {}",
            r.seconds,
            want
        );
        assert_eq!(r.rounds, 2);
    }

    #[test]
    fn ps_scales_worse_than_ring_in_bandwidth() {
        let l = LinkParams::from_ms_gbps(0.1, 1.0);
        let m = 100_000;
        let mut a = vec![vec![1.0f32; m]; 16];
        let mut b = vec![vec![1.0f32; m]; 16];
        let ps = ps_exchange(&mut a, 0, l);
        let ring = crate::collectives::ring_allreduce(&mut b, l);
        assert!(ps.seconds > 5.0 * ring.seconds);
    }
}
