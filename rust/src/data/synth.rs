//! Synthetic dataset generators.
//!
//! * [`ClusterDataset`] — Gaussian class clusters for the MLP classifier
//!   (accuracy is measurable, so the Tables III/IV/V harnesses get a real
//!   top-1 number).
//! * [`MarkovCorpus`] — first-order Markov token streams for the
//!   transformer LM (next-token accuracy has a learnable ceiling).

use crate::util::rng::Rng;

/// Gaussian-cluster classification data.
///
/// `classes` centers drawn N(0, sep²·I); samples are center + N(0, noise²).
/// Worker shards can be i.i.d. or skewed (each worker over-samples a
/// subset of classes — the paper's unbalanced federated setting).
#[derive(Debug, Clone)]
pub struct ClusterDataset {
    pub features: usize,
    pub classes: usize,
    centers: Vec<Vec<f32>>,
    noise: f32,
    seed: u64,
}

impl ClusterDataset {
    pub fn new(features: usize, classes: usize, sep: f32, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1A5_5E5);
        let centers = (0..classes)
            .map(|_| {
                let mut c = vec![0.0f32; features];
                rng.fill_normal(&mut c, sep);
                c
            })
            .collect();
        ClusterDataset { features, classes, centers, noise, seed }
    }

    /// Draw a batch for `worker` at `step`. `skew` in [0,1]: 0 = i.i.d.;
    /// 1 = worker sees only its own class subset.
    pub fn batch(
        &self,
        worker: usize,
        n_workers: usize,
        step: u64,
        batch: usize,
        skew: f64,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xA076_1D64_78BD_642F),
        );
        let mut x = Vec::with_capacity(batch * self.features);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let class = if rng.f64() < skew {
                // Biased: classes assigned round-robin to workers.
                let mine: Vec<usize> = (0..self.classes)
                    .filter(|c| c % n_workers.max(1) == worker % n_workers.max(1))
                    .collect();
                if mine.is_empty() {
                    rng.below(self.classes)
                } else {
                    mine[rng.below(mine.len())]
                }
            } else {
                rng.below(self.classes)
            };
            y.push(class as i32);
            for f in 0..self.features {
                x.push(self.centers[class][f] + rng.normal_f32(0.0, self.noise));
            }
        }
        (x, y)
    }

    /// A held-out evaluation batch (worker-independent).
    pub fn eval_batch(&self, batch: usize) -> (Vec<f32>, Vec<i32>) {
        self.batch(usize::MAX / 2, 1, u64::MAX / 2, batch, 0.0)
    }
}

/// First-order Markov token corpus with a skewed transition matrix.
///
/// Each token has `branch` likely successors (one dominant), so a
/// well-trained LM's next-token accuracy approaches the dominant-successor
/// probability — a real learnability ceiling to train against.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    pub vocab: usize,
    /// transitions[t] = (successor ids, cumulative weights)
    succ: Vec<Vec<usize>>,
    dominant_p: f64,
    seed: u64,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, branch: usize, dominant_p: f64, seed: u64) -> Self {
        assert!(branch >= 1 && vocab >= branch);
        assert!((0.0..=1.0).contains(&dominant_p));
        let mut rng = Rng::new(seed ^ 0x3A5C_0FFE);
        let succ = (0..vocab)
            .map(|_| {
                let mut s: Vec<usize> = Vec::with_capacity(branch);
                while s.len() < branch {
                    let c = rng.below(vocab);
                    if !s.contains(&c) {
                        s.push(c);
                    }
                }
                s
            })
            .collect();
        MarkovCorpus { vocab, succ, dominant_p, seed }
    }

    fn next_token(&self, cur: usize, rng: &mut Rng) -> usize {
        let succ = &self.succ[cur];
        if rng.f64() < self.dominant_p {
            succ[0]
        } else if succ.len() > 1 {
            succ[1 + rng.below(succ.len() - 1)]
        } else {
            succ[0]
        }
    }

    /// Sequence batch [batch, seq+1] (i32, flattened row-major) for a
    /// worker/step — the layout the `<model>_grad` artifact consumes.
    pub fn batch(&self, worker: usize, step: u64, batch: usize, seq: usize) -> Vec<i32> {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x2545_F491_4F6C_DD1D)
                ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let mut cur = rng.below(self.vocab);
            out.push(cur as i32);
            for _ in 0..seq {
                cur = self.next_token(cur, &mut rng);
                out.push(cur as i32);
            }
        }
        out
    }

    /// The Bayes-optimal next-token accuracy (predict the dominant
    /// successor): equals `dominant_p` + residual mass on ties.
    pub fn accuracy_ceiling(&self) -> f64 {
        self.dominant_p.max(1.0 - self.dominant_p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_batches_deterministic_and_shaped() {
        let ds = ClusterDataset::new(8, 4, 2.0, 0.2, 1);
        let (x1, y1) = ds.batch(0, 4, 7, 16, 0.0);
        let (x2, y2) = ds.batch(0, 4, 7, 16, 0.0);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_eq!(x1.len(), 16 * 8);
        assert_eq!(y1.len(), 16);
        assert!(y1.iter().all(|&y| (0..4).contains(&y)));
        // Different steps and workers differ.
        let (x3, _) = ds.batch(0, 4, 8, 16, 0.0);
        assert_ne!(x1, x3);
        let (x4, _) = ds.batch(1, 4, 7, 16, 0.0);
        assert_ne!(x1, x4);
    }

    #[test]
    fn skew_biases_class_distribution() {
        let ds = ClusterDataset::new(4, 8, 2.0, 0.1, 2);
        let (_, y) = ds.batch(0, 4, 0, 400, 1.0);
        // Worker 0 of 4 with 8 classes sees only classes {0, 4}.
        assert!(y.iter().all(|&c| c == 0 || c == 4), "saw {:?}", &y[..8]);
        let (_, y_iid) = ds.batch(0, 4, 0, 400, 0.0);
        let distinct: std::collections::HashSet<i32> = y_iid.iter().copied().collect();
        assert!(distinct.len() >= 6);
    }

    #[test]
    fn nearest_center_classifies_cluster_data() {
        // The task must be learnable: nearest-center achieves high accuracy.
        let ds = ClusterDataset::new(16, 8, 2.0, 0.3, 3);
        let (x, y) = ds.eval_batch(200);
        let mut correct = 0;
        for (i, &label) in y.iter().enumerate() {
            let sample = &x[i * 16..(i + 1) * 16];
            let mut best = 0;
            let mut best_d = f32::MAX;
            for (c, center) in ds.centers.iter().enumerate() {
                let d: f32 = sample
                    .iter()
                    .zip(center)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            correct += (best as i32 == label) as usize;
        }
        assert!(correct >= 190, "cluster task not separable: {correct}/200");
    }

    #[test]
    fn markov_batches_shaped_and_learnable() {
        let mc = MarkovCorpus::new(64, 4, 0.8, 5);
        let toks = mc.batch(0, 0, 4, 32);
        assert_eq!(toks.len(), 4 * 33);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        // Dominant successor appears ~80% of the time.
        let mut dom = 0;
        let mut total = 0;
        for b in 0..4 {
            for s in 0..32 {
                let cur = toks[b * 33 + s] as usize;
                let nxt = toks[b * 33 + s + 1] as usize;
                total += 1;
                dom += (nxt == mc.succ[cur][0]) as usize;
            }
        }
        let frac = dom as f64 / total as f64;
        assert!((frac - 0.8).abs() < 0.1, "dominant fraction {frac}");
        assert_eq!(mc.accuracy_ceiling(), 0.8);
    }

    #[test]
    fn markov_deterministic() {
        let mc = MarkovCorpus::new(32, 3, 0.7, 9);
        assert_eq!(mc.batch(1, 2, 2, 8), mc.batch(1, 2, 2, 8));
        assert_ne!(mc.batch(1, 2, 2, 8), mc.batch(1, 3, 2, 8));
    }
}
