//! flexlint over the REAL tree — the acceptance gate for the lint pass.
//!
//! Three contracts, in increasing strictness:
//!
//!  1. The shipped `rust/src/**` lints CLEAN: zero unsuppressed findings
//!     across every registered rule. This is the same scan `verify.sh`
//!     runs via the `flexlint` binary, so a regression fails `cargo test`
//!     even on machines that skip the binary stage.
//!  2. Injecting any rule's positive fixture into the workspace turns the
//!     scan red again — i.e. the clean result in (1) is earned, not the
//!     product of a rule that stopped firing.
//!  3. Every `RULE_TABLE` row is reachable from the CLI `--rule` filter
//!     and running with that filter executes exactly that one rule.

use std::path::Path;

use flexcomm::analysis::{
    parse_rule_filter, run, scan::SourceFile, Workspace, FIXTURE_BINDINGS, RULE_TABLE,
};

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
}

fn load_tree() -> Workspace {
    Workspace::load(&src_root()).expect("workspace loads")
}

#[test]
fn shipped_tree_lints_clean_under_every_rule() {
    let ws = load_tree();
    let r = run(&ws, None);
    assert_eq!(
        r.rules_run.len(),
        RULE_TABLE.len(),
        "an unfiltered run must execute every registered rule"
    );
    assert!(
        r.findings.is_empty(),
        "shipped tree has {} unsuppressed finding(s):\n{}",
        r.findings.len(),
        r.findings
            .iter()
            .map(|f| format!("  [{}] {}:{} — {}", f.rule, f.file, f.line, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    // The audited-allow inventory is small and deliberate; if suppression
    // count hits zero the allows rotted (or the rules stopped firing where
    // the allows sit), and if it balloons someone is silencing instead of
    // fixing. Keep a loose band rather than a brittle exact pin.
    assert!(r.suppressed >= 1, "expected at least one audited allow in the tree");
    assert!(
        r.suppressed <= 40,
        "{} suppressed findings — audit the allow inventory, this smells like silencing",
        r.suppressed
    );
}

#[test]
fn injected_positive_fixture_turns_the_tree_red() {
    for rule in RULE_TABLE {
        let mut ws = load_tree();
        // The fixture rides alongside every real file, named `fixture.rs`
        // so the fixture registry bindings resolve (registry-coverage
        // attributes its findings to the enum's own file).
        ws.files.push(SourceFile::parse("fixture.rs", rule.fires_on));
        ws.bindings = FIXTURE_BINDINGS;
        let r = run(&ws, Some(rule.name));
        let hits: Vec<_> = r.findings.iter().filter(|f| f.file == "fixture.rs").collect();
        assert!(
            !hits.is_empty(),
            "rule `{}` stayed silent on its own positive fixture when injected \
             into the real tree",
            rule.name
        );
        assert!(
            hits.iter().all(|f| f.rule == rule.name),
            "rule `{}`: injected-fixture findings attributed to a different rule",
            rule.name
        );
    }
}

#[test]
fn every_rule_is_cli_reachable_and_filter_runs_exactly_one() {
    let ws = load_tree();
    for rule in RULE_TABLE {
        let canonical =
            parse_rule_filter(rule.name).expect("every registered rule parses as a filter");
        assert_eq!(canonical, rule.name);
        let r = run(&ws, Some(canonical));
        assert_eq!(
            r.rules_run,
            vec![rule.name],
            "--rule {} must execute exactly that rule",
            rule.name
        );
    }
    let err = parse_rule_filter("no-such-rule").expect_err("unknown rule is a typed error");
    assert!(
        err.contains("no-such-rule"),
        "error should echo the bad name for the CLI user: {err}"
    );
}

#[test]
fn fixture_suite_and_self_scan_agree_on_rule_count() {
    // `--self-test` in the binary and the in-crate fixture suite both walk
    // RULE_TABLE; this pins the table non-empty and its floor from ISSUE.md.
    assert!(
        RULE_TABLE.len() >= 6,
        "RULE_TABLE shrank below the documented minimum of 6 rules"
    );
    let mut names: Vec<_> = RULE_TABLE.iter().map(|r| r.name).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), RULE_TABLE.len(), "duplicate rule names in RULE_TABLE");
}
