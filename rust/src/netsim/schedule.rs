//! Piecewise network schedules — the paper's C1/C2 configurations.
//!
//! The paper drives `tc` from a background process to emulate latency and
//! bandwidth that change over epochs (Fig 6, configurations C1/C2).
//! [`NetSchedule`] reproduces those as a piecewise-constant
//! [`NetworkModel`]; the §2-C2 variability sources (congestion, QoS
//! priorities, resource sharing, scheduling) are composable wrappers in
//! [`modifiers`](crate::netsim::modifiers) — e.g.
//! `Jitter::wrap(NetSchedule::c2(50.0), 0.05, seed)` — and measured
//! traces replay via [`TraceModel`](crate::netsim::trace::TraceModel).

use crate::netsim::cost_model::{LinkParams, Topology};
use crate::netsim::model::NetworkModel;
use anyhow::{bail, Result};

/// Canonical (α, 1/β) levels used by the paper's C1/C2 configurations.
pub mod levels {
    pub const ALPHA_LOW_MS: f64 = 1.0;
    pub const ALPHA_MOD_MS: f64 = 10.0;
    pub const ALPHA_HIGH_MS: f64 = 50.0;
    pub const BW_LOW_GBPS: f64 = 1.0;
    pub const BW_MOD_GBPS: f64 = 10.0;
    pub const BW_HIGH_GBPS: f64 = 25.0;
}

/// One piece of a piecewise-constant schedule: applies from `from_epoch`
/// (inclusive) until the next breakpoint.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub from_epoch: f64,
    pub link: LinkParams,
}

/// A piecewise-constant network schedule with an optional two-level
/// topology overlay (`with_topology`). The schedule drives the
/// *inter-node* link — the WAN/TCP side the paper shapes with `tc`; the
/// intra-node link is in-machine hardware and stays fixed. Stochastic
/// overlays (jitter, congestion, ...) are separate
/// [`modifiers`](crate::netsim::modifiers) wrappers.
#[derive(Debug, Clone)]
pub struct NetSchedule {
    name: String,
    phases: Vec<Phase>,
    /// Fixed intra-node link of the two-level topology overlay (None =
    /// flat cluster; see [`NetSchedule::with_topology`]).
    intra: Option<LinkParams>,
    workers_per_node: usize,
}

impl NetSchedule {
    pub fn static_link(link: LinkParams) -> Self {
        NetSchedule {
            name: "static".into(),
            phases: vec![Phase { from_epoch: 0.0, link }],
            intra: None,
            workers_per_node: 1,
        }
    }

    pub fn piecewise(name: &str, phases: Vec<Phase>) -> Self {
        assert!(!phases.is_empty(), "a schedule needs at least one phase");
        assert!(
            phases.windows(2).all(|w| w[0].from_epoch < w[1].from_epoch),
            "phases must be sorted by from_epoch"
        );
        NetSchedule { name: name.into(), phases, intra: None, workers_per_node: 1 }
    }

    /// Paper configuration C1 (Fig 6a), scaled to `total_epochs`
    /// (50 in the paper; ResNet50 runs 100 => every phase stretches 2x).
    ///
    /// C1: (low-α, high-bw) epochs 1-12, (low, low) 13-24,
    ///     (high, low) 25-36, (high, high) 37+.
    ///
    /// ```
    /// use flexcomm::netsim::schedule::NetSchedule;
    /// let c1 = NetSchedule::c1(50.0);
    /// assert_eq!(c1.at(0.0).bw_gbps().round(), 25.0);   // (low α, high bw)
    /// assert_eq!(c1.at(30.0).alpha_ms().round(), 50.0); // (high α, low bw)
    /// assert_eq!(c1.phases().len(), 4);
    /// ```
    pub fn c1(total_epochs: f64) -> Self {
        use levels::*;
        let s = total_epochs / 50.0;
        NetSchedule::piecewise(
            "c1",
            vec![
                Phase { from_epoch: 0.0, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_HIGH_GBPS) },
                Phase { from_epoch: 12.0 * s, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_LOW_GBPS) },
                Phase { from_epoch: 24.0 * s, link: LinkParams::from_ms_gbps(ALPHA_HIGH_MS, BW_LOW_GBPS) },
                Phase { from_epoch: 36.0 * s, link: LinkParams::from_ms_gbps(ALPHA_HIGH_MS, BW_HIGH_GBPS) },
            ],
        )
    }

    /// Paper configuration C2 (Fig 6b), scaled to `total_epochs`.
    ///
    /// C2: (low, high) 0-11, (moderate, moderate) 12-19, (high, low) 20-27,
    ///     (moderate, moderate) 28-35, (low, high) 36+.
    ///
    /// ```
    /// use flexcomm::netsim::schedule::NetSchedule;
    /// let c2 = NetSchedule::c2(50.0);
    /// assert_eq!(c2.at(22.0).bw_gbps().round(), 1.0);   // (high α, low bw)
    /// assert_eq!(c2.at(45.0).alpha_ms().round(), 1.0);  // recovers by the end
    /// assert!(c2.phases().len() > NetSchedule::c1(50.0).phases().len());
    /// ```
    pub fn c2(total_epochs: f64) -> Self {
        use levels::*;
        let s = total_epochs / 50.0;
        NetSchedule::piecewise(
            "c2",
            vec![
                Phase { from_epoch: 0.0, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_HIGH_GBPS) },
                Phase { from_epoch: 12.0 * s, link: LinkParams::from_ms_gbps(ALPHA_MOD_MS, BW_MOD_GBPS) },
                Phase { from_epoch: 20.0 * s, link: LinkParams::from_ms_gbps(ALPHA_HIGH_MS, BW_LOW_GBPS) },
                Phase { from_epoch: 28.0 * s, link: LinkParams::from_ms_gbps(ALPHA_MOD_MS, BW_MOD_GBPS) },
                Phase { from_epoch: 36.0 * s, link: LinkParams::from_ms_gbps(ALPHA_LOW_MS, BW_HIGH_GBPS) },
            ],
        )
    }

    /// Valid [`NetSchedule::preset`] names, in lookup order ("static" is
    /// not a preset — it takes explicit link parameters).
    pub const PRESETS: &'static [&'static str] = &["c1", "c2"];

    /// Look up a named bare-schedule preset. The error lists every valid
    /// name — including the full scenario registry
    /// ([`NET_TABLE`](crate::netsim::model::NET_TABLE)), whose composite
    /// entries (jittered/congested/diurnal/... variants) are built via
    /// [`parse_spec`](crate::netsim::model::parse_spec) because they are
    /// not plain `NetSchedule`s.
    pub fn preset(name: &str, total_epochs: f64) -> Result<Self> {
        match name {
            "c1" => Ok(Self::c1(total_epochs)),
            "c2" => Ok(Self::c2(total_epochs)),
            _ => bail!(
                "unknown schedule preset `{name}` (bare presets: {}; or `static` with \
                 explicit link parameters; full scenario registry incl. composites: {})",
                Self::PRESETS.join(", "),
                crate::netsim::model::scenario_names().collect::<Vec<_>>().join(", ")
            ),
        }
    }

    /// Overlay a two-level topology: `workers_per_node` ranks share the
    /// fixed `intra` link, and the scheduled link becomes the *inter-node*
    /// link. See [`Topology`](crate::netsim::cost_model::Topology); for
    /// non-schedule models use
    /// [`TwoLevel`](crate::netsim::modifiers::TwoLevel).
    ///
    /// ```
    /// use flexcomm::netsim::cost_model::LinkParams;
    /// use flexcomm::netsim::schedule::NetSchedule;
    /// let s = NetSchedule::c2(50.0)
    ///     .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 4);
    /// let t = s.topology_at(0.0);
    /// assert_eq!(t.workers_per_node, 4);
    /// assert_eq!(t.inter, s.at(0.0)); // schedule drives the inter link
    /// assert_eq!(t.nodes(8), 2);
    /// ```
    pub fn with_topology(mut self, intra: LinkParams, workers_per_node: usize) -> Self {
        assert!(workers_per_node >= 1, "workers_per_node must be >= 1");
        self.intra = Some(intra);
        self.workers_per_node = workers_per_node;
        self
    }

    /// Ranks per node of the topology overlay (1 = flat).
    pub fn workers_per_node(&self) -> usize {
        self.workers_per_node
    }

    /// Full topology at a fractional epoch: the scheduled link as the
    /// inter-node side, the fixed intra link if configured.
    pub fn topology_at(&self, epoch: f64) -> Topology {
        let inter = self.at(epoch);
        match self.intra {
            Some(intra) if self.workers_per_node > 1 => {
                Topology::two_level(intra, inter, self.workers_per_node)
            }
            _ => Topology::flat(inter),
        }
    }

    /// Link parameters at a fractional epoch: the phase whose breakpoint
    /// was most recently passed. Epochs before the first breakpoint
    /// report the first phase; epochs beyond the last hold the last.
    pub fn at(&self, epoch: f64) -> LinkParams {
        let mut link = self.phases[0].link;
        for p in &self.phases {
            if epoch >= p.from_epoch {
                link = p.link;
            } else {
                break;
            }
        }
        link
    }

    /// Alias of [`NetSchedule::at`], kept from the era when `at` also
    /// applied jitter/congestion overlays (those are now
    /// [`modifiers`](crate::netsim::modifiers) wrappers, so the "base"
    /// and effective links of a bare schedule coincide).
    pub fn base_at(&self, epoch: f64) -> LinkParams {
        self.at(epoch)
    }

    /// Schedule name (also the [`NetworkModel::name`] identity).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Breakpoints (for harnesses that print the Fig 6 schedule).
    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }
}

impl NetworkModel for NetSchedule {
    fn link_at(&self, epoch: f64) -> LinkParams {
        self.at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        NetSchedule::topology_at(self, epoch)
    }

    fn name(&self) -> &str {
        NetSchedule::name(self)
    }

    fn describe(&self) -> String {
        if self.workers_per_node > 1 {
            format!("{}+2level(x{})", self.name, self.workers_per_node)
        } else {
            self.name.clone()
        }
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c1_matches_fig6a() {
        let s = NetSchedule::c1(50.0);
        let at = |e: f64| {
            let l = s.at(e);
            (l.alpha_ms().round(), l.bw_gbps().round())
        };
        assert_eq!(at(0.0), (1.0, 25.0));
        assert_eq!(at(11.9), (1.0, 25.0));
        assert_eq!(at(12.1), (1.0, 1.0));
        assert_eq!(at(25.0), (50.0, 1.0));
        assert_eq!(at(40.0), (50.0, 25.0));
    }

    #[test]
    fn c2_matches_fig6b_and_changes_more_often() {
        let c1 = NetSchedule::c1(50.0);
        let c2 = NetSchedule::c2(50.0);
        assert_eq!(c2.phases().len(), 5);
        assert!(c2.phases().len() > c1.phases().len());
        let l = c2.at(22.0);
        assert_eq!(l.alpha_ms().round(), 50.0);
        assert_eq!(l.bw_gbps().round(), 1.0);
        let l = c2.at(30.0);
        assert_eq!(l.alpha_ms().round(), 10.0);
    }

    #[test]
    fn resnet50_scaling_stretches_2x() {
        let s = NetSchedule::c1(100.0);
        // C1 for ResNet50 applies (low, high) through epoch 1-24.
        assert_eq!(s.at(20.0).bw_gbps().round(), 25.0);
        assert_eq!(s.at(25.0).bw_gbps().round(), 1.0);
    }

    /// Edge cases of the phase lookup: before the first breakpoint, on a
    /// breakpoint, far beyond the last breakpoint — `at` and `base_at`
    /// agree everywhere (overlays moved to the modifier wrappers).
    #[test]
    fn at_holds_first_and_last_phase_outside_the_breakpoints() {
        let s = NetSchedule::c1(50.0);
        for e in [-5.0, 0.0, 12.0, 36.0, 50.0, 1e5, f64::INFINITY] {
            assert_eq!(s.at(e), s.base_at(e), "at/base_at must agree at {e}");
        }
        assert_eq!(s.at(-5.0), s.at(0.0), "pre-history holds the first phase");
        assert_eq!(s.at(1e5), s.at(36.0), "post-history holds the last phase");
        assert_eq!(s.at(f64::INFINITY), s.at(36.0));
        // On an exact breakpoint the NEW phase applies (from_epoch incl.).
        assert_eq!(s.at(12.0).bw_gbps().round(), 1.0);
    }

    #[test]
    fn preset_lookup() {
        for name in NetSchedule::PRESETS {
            assert!(NetSchedule::preset(name, 50.0).is_ok(), "{name}");
        }
        let err = NetSchedule::preset("nope", 50.0).unwrap_err().to_string();
        assert!(err.contains("c1") && err.contains("c2"), "{err}");
        // The error lists the FULL scenario registry, not just the bare
        // presets (single name table, mirroring STRATEGY_TABLE).
        assert!(err.contains("c2-hostile") && err.contains("diurnal"), "{err}");
    }

    #[test]
    fn topology_defaults_to_flat() {
        let s = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0));
        let t = s.topology_at(1.0);
        assert!(t.is_flat());
        assert_eq!(t.inter, s.at(1.0));
        assert_eq!(s.workers_per_node(), 1);
    }

    #[test]
    fn network_model_impl_matches_the_inherent_api() {
        let s = NetSchedule::c2(50.0).with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 4);
        let m: &dyn NetworkModel = &s;
        for e in [0.0, 13.0, 22.0, 45.0] {
            assert_eq!(m.link_at(e), s.at(e));
            assert_eq!(m.topology_at(e), s.topology_at(e));
        }
        assert_eq!(m.name(), "c2");
        assert_eq!(m.describe(), "c2+2level(x4)");
        let cloned = m.clone_model();
        assert_eq!(cloned.link_at(22.0), s.at(22.0));
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_phases_rejected() {
        NetSchedule::piecewise(
            "bad",
            vec![
                Phase { from_epoch: 5.0, link: LinkParams::from_ms_gbps(1.0, 1.0) },
                Phase { from_epoch: 1.0, link: LinkParams::from_ms_gbps(1.0, 1.0) },
            ],
        );
    }

    #[test]
    #[should_panic(expected = "at least one phase")]
    fn empty_phase_list_rejected() {
        NetSchedule::piecewise("empty", Vec::new());
    }
}
