//! Integration: the AOT-lowered L2/L1 artifacts execute correctly via PJRT
//! from rust — the full python-compile -> rust-runtime loop.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use flexcomm::compress::{k_for, MsTopk};
use flexcomm::coordinator::session::Session;
use flexcomm::coordinator::trainer::{CrControl, DenseFlavor, Strategy, TrainConfig};
use flexcomm::coordinator::worker::{ComputeModel, GradSource};
use flexcomm::runtime::{find_artifacts_dir, Engine, ModelArtifacts, PjrtModel};
use flexcomm::util::rng::Rng;

fn engine() -> Engine {
    Engine::cpu().expect("PJRT CPU client")
}

fn load_model(name: &str) -> PjrtModel {
    let dir = find_artifacts_dir().expect("artifacts dir (run `make artifacts`)");
    let arts = ModelArtifacts::load(&dir, name).expect("artifact manifest");
    PjrtModel::load(&engine(), arts, 42).expect("compiling artifacts")
}

#[test]
fn mlp_grad_artifact_runs_and_matches_init_loss() {
    let mut m = load_model("mlp");
    let params = m.init_params();
    assert_eq!(params.len(), m.dim());
    let (loss, grads) = m.grad(&params, 0, 4, 0);
    // Random init over 16 classes: loss ~ ln(16) = 2.77.
    assert!((loss - (16.0f64).ln()).abs() < 0.7, "init loss {loss}");
    assert_eq!(grads.len(), m.dim());
    let nonzero = grads.iter().filter(|&&g| g != 0.0).count();
    assert!(nonzero > grads.len() / 2, "grads mostly zero: {nonzero}");
}

#[test]
fn transformer_tiny_grad_artifact_runs() {
    let mut m = load_model("tiny");
    let params = m.init_params();
    let (loss, grads) = m.grad(&params, 0, 4, 0);
    // Vocab 256 -> ln(256) = 5.55 at random init.
    assert!((loss - (256.0f64).ln()).abs() < 1.5, "init loss {loss}");
    assert_eq!(grads.len(), m.dim());
    // The Pallas-matmul MLP blocks must receive gradient.
    let layout = m.layout().clone();
    let fc = layout
        .layers
        .iter()
        .find(|l| l.name == "block0.mlp.fc")
        .expect("mlp.fc layer in layout");
    let seg = &grads[fc.offset..fc.offset + fc.size];
    assert!(seg.iter().any(|&g| g != 0.0), "no grad through Pallas matmul");
}

#[test]
fn sgd_step_artifact_matches_rust_formula() {
    let m = load_model("mlp");
    let dim = m.dim();
    let mut rng = Rng::new(1);
    let mut params = vec![0.0f32; dim];
    let mut mom = vec![0.0f32; dim];
    let mut grads = vec![0.0f32; dim];
    rng.fill_normal(&mut params, 1.0);
    rng.fill_normal(&mut mom, 0.5);
    rng.fill_normal(&mut grads, 0.1);
    let (lr, mu, wd) = (0.1f32, 0.9f32, 0.0005f32);
    let (p2, m2) = m.sgd_step(&params, &mom, &grads, lr, mu, wd).unwrap();
    for i in (0..dim).step_by(977) {
        let g = grads[i] + wd * params[i];
        let want_m = mu * mom[i] + g;
        let want_p = params[i] - lr * want_m;
        assert!((m2[i] - want_m).abs() < 1e-5, "mom[{i}]");
        assert!((p2[i] - want_p).abs() < 1e-5, "param[{i}]");
    }
}

#[test]
fn ef_topk_artifact_matches_rust_mstopk() {
    // The L1 Pallas kernels (threshold bisection + fused EF-compress) and
    // the rust MsTopk implement the same algorithm; pin them together.
    let m = load_model("mlp");
    let dim = m.dim();
    assert!(m.has_ef_topk());
    let mut rng = Rng::new(3);
    let mut g = vec![0.0f32; dim];
    let mut r = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);
    rng.fill_normal(&mut r, 0.3);
    let cr = 0.01;
    let k = k_for(cr, dim);

    let (gc, res, nc, ne, tau) = m.ef_topk(&g, &r, k as f32).unwrap();

    // Rust-side reference.
    let g_e: Vec<f32> = g.iter().zip(&r).map(|(a, b)| a + b).collect();
    let rust_tau = MsTopk::new(25).estimate_threshold(&g_e, k);
    assert!(
        (tau - rust_tau).abs() <= 2e-3 * (1.0 + rust_tau.abs()),
        "tau {tau} vs rust {rust_tau}"
    );

    // Kept count ~ k; support = |g_e| >= tau; g_c + res == g_e.
    let kept = gc.iter().filter(|&&v| v != 0.0).count();
    assert!(
        (kept as i64 - k as i64).abs() <= (k as i64 / 20).max(2),
        "kept {kept} vs k {k}"
    );
    for i in (0..dim).step_by(499) {
        assert!((gc[i] + res[i] - g_e[i]).abs() < 1e-5, "mass at {i}");
    }
    // Gain terms.
    let e_sq: f64 = g_e.iter().map(|&v| (v as f64).powi(2)).sum();
    assert!((ne - e_sq).abs() / e_sq < 1e-3, "||g_e||² {ne} vs {e_sq}");
    let c_sq: f64 = gc.iter().map(|&v| (v as f64).powi(2)).sum();
    assert!((nc - c_sq).abs() / c_sq.max(1e-9) < 1e-3);
    assert!(nc <= ne * (1.0 + 1e-6));
}

#[test]
fn pjrt_mlp_trains_end_to_end_dense() {
    let model = load_model("mlp");
    let cfg = TrainConfig {
        n_workers: 4,
        steps: 60,
        steps_per_epoch: 20,
        lr: 0.3,
        momentum: 0.6,
        weight_decay: 0.0,
        strategy: Strategy::DenseSgd { flavor: DenseFlavor::Ring },
        cr: CrControl::Static(1.0),
        compute: ComputeModel::fixed(0.01),
        eval_every: 0,
        seed: 9,
        ..Default::default()
    };
    let r = Session::from_config(cfg)
        .source(Box::new(model))
        .build()
        .expect("valid config")
        .run();
    let first = r.metrics.steps.first().unwrap().loss;
    let last = r.metrics.steps.last().unwrap().loss;
    assert!(last < first * 0.6, "PJRT dense training: {first} -> {last}");
    let acc = r.final_accuracy().unwrap();
    assert!(acc > 0.5, "accuracy {acc}");
}

#[test]
fn pjrt_mlp_trains_with_artopk() {
    use flexcomm::artopk::{ArFlavor, SelectionPolicy};
    let model = load_model("mlp");
    let cfg = TrainConfig {
        n_workers: 4,
        steps: 80,
        steps_per_epoch: 20,
        lr: 0.3,
        momentum: 0.6,
        strategy: Strategy::ArTopkFixed {
            policy: SelectionPolicy::Star,
            flavor: ArFlavor::Ring,
        },
        cr: CrControl::Static(0.05),
        compute: ComputeModel::fixed(0.01),
        seed: 10,
        ..Default::default()
    };
    let r = Session::from_config(cfg)
        .source(Box::new(model))
        .build()
        .expect("valid config")
        .run();
    let first = r.metrics.steps.first().unwrap().loss;
    let last = r.metrics.steps.last().unwrap().loss;
    assert!(last < first * 0.7, "PJRT AR-Topk training: {first} -> {last}");
}
