//! Shared experiment-harness plumbing: the standard "paper-proxy" training
//! configuration, diff-table assembly (Tables III/IV/V layout), and the
//! paper's model-size registry for cost experiments.
//!
//! Every `examples/table*`/`examples/fig*` binary builds on these helpers
//! so the rows they print line up with the paper's tables 1:1.

use crate::coordinator::trainer::{CrControl, Strategy, TrainConfig, Trainer};
use crate::coordinator::worker::ComputeModel;
use crate::netsim::cost_model::LinkParams;
use crate::netsim::schedule::NetSchedule;
use crate::runtime::host_model::HostMlp;
use crate::util::table::{fmt_ms, Table};

/// The paper's four evaluation DNNs with their parameter counts — the `M`
/// in every cost experiment (Tables II/VI, Figs 1/5).
pub const PAPER_MODELS: [(&str, f64); 4] = [
    ("ResNet18", 11.7e6),
    ("ResNet50", 25.6e6),
    ("AlexNet", 61.1e6),
    ("ViT", 86.6e6),
];

/// Paper-measured compute times per step (Fig 1a, 8xV100, ms) — used to
/// parameterize the simulated `t_compute` so step-time tables have the
/// paper's compute:communication proportions.
pub const PAPER_COMPUTE_MS: [(&str, f64); 4] = [
    ("ResNet18", 30.0),
    ("ResNet50", 65.0),
    ("AlexNet", 25.0),
    ("ViT", 110.0),
];

/// Accelerator-vs-host compression throughput ratio: the paper compresses
/// on V100s; this host compresses on one CPU core. Top-k/threshold scans
/// are memory-bandwidth-bound, and a V100's ~900 GB/s HBM vs ~25-45 GB/s
/// single-core stream puts the ratio at 20-35x; we use the conservative
/// low end. Applied by proxy harnesses as comp_scale = msg_scale / this.
pub const GPU_COMPRESS_SPEEDUP: f64 = 20.0;

/// Standard proxy-training config: 8 workers on a 4 ms / 20 Gbps link
/// (the Tables III/IV/V setting).
pub fn proxy_cfg(strategy: Strategy, cr: CrControl, steps: u64, seed: u64) -> TrainConfig {
    TrainConfig {
        n_workers: 8,
        steps,
        steps_per_epoch: steps / 10,
        lr: 0.2,
        momentum: 0.9,
        weight_decay: 0.0005,
        lr_decay: vec![(steps * 6 / 10, 0.1)],
        strategy,
        cr,
        schedule: NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0)),
        compute: ComputeModel::with_jitter(0.030, 0.05),
        probe_noise: 0.02,
        msg_scale: 1.0,
        comp_scale: 1.0,
        eval_every: (steps / 20).max(1),
        seed,
    }
}

/// Run one table row on the hard host-MLP proxy; returns the trainer for
/// further inspection (gain curves, rank densities, ...).
pub fn run_proxy(mut cfg: TrainConfig, seed: u64) -> Trainer {
    cfg.seed = seed;
    let src = Box::new(HostMlp::hard_preset(seed));
    let mut t = Trainer::new(cfg, src);
    t.run();
    t
}

/// One row of a Tables III/IV/V-style comparison.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub method: String,
    pub t_step_ms: f64,
    pub accuracy: f64,
}

/// Print the paper's `Method | t_step | Acc | Diff` layout, with diff
/// computed against the first (baseline) row.
pub fn print_diff_table(title: &str, rows: &[DiffRow]) {
    println!("\n== {title} ==");
    assert!(!rows.is_empty());
    let base = rows[0].accuracy;
    let mut t = Table::new(["Method", "t_step (ms)", "Acc.", "Diff."]);
    for r in rows {
        t.row([
            r.method.clone(),
            fmt_ms(r.t_step_ms / 1e3),
            format!("{:.2}%", r.accuracy * 100.0),
            format!("{:+.2}%", (r.accuracy - base) * 100.0),
        ]);
    }
    t.print();
}

/// Row from a finished trainer.
pub fn diff_row(method: impl Into<String>, t: &Trainer) -> DiffRow {
    let s = t.metrics.summary();
    DiffRow {
        method: method.into(),
        t_step_ms: s.mean_step_s * 1e3,
        accuracy: t.metrics.best_accuracy().unwrap_or(f64::NAN),
    }
}

/// Write a CSV file, creating parent dirs; returns the path for logging.
pub fn write_csv(path: &str, content: &str) -> anyhow::Result<String> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    Ok(path.to_string())
}

/// Render a labelled KDE as a terminal sparkline block (our "figure").
pub fn print_kde(label: &str, samples: &[f64], lo: f64, hi: f64) {
    let k = crate::util::stats::kde(samples, lo, hi, 60);
    println!("{label:<24} {}", crate::util::stats::sparkline(&k.density));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artopk::{ArFlavor, SelectionPolicy};

    #[test]
    fn proxy_cfg_matches_paper_setting() {
        let cfg = proxy_cfg(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            CrControl::Static(0.01),
            100,
            0,
        );
        assert_eq!(cfg.n_workers, 8);
        let l = cfg.schedule.at(0.0);
        assert!((l.alpha_ms() - 4.0).abs() < 1e-9);
        assert!((l.bw_gbps() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn paper_registry_sane() {
        assert_eq!(PAPER_MODELS.len(), 4);
        assert!(PAPER_MODELS.windows(2).all(|w| w[0].1 < w[1].1));
    }

    #[test]
    fn diff_table_renders() {
        let rows = vec![
            DiffRow { method: "DenseSGD".into(), t_step_ms: 98.7, accuracy: 0.908 },
            DiffRow { method: "LWTopk 0.1".into(), t_step_ms: 62.0, accuracy: 0.9015 },
        ];
        // Shouldn't panic; eyeball-checked in examples.
        print_diff_table("smoke", &rows);
    }
}
