//! MSTopk [21]: multi-round threshold-estimation Top-k over the fused
//! tensor (§2-C3). Bisection on a magnitude threshold with a configurable
//! round count (the paper evaluates 25) — each round scans the tensor, so
//! compression cost is ~`rounds × O(G)`, visibly higher than heap Top-k
//! (Fig 2 regenerates from these real timings).
//!
//! This is the same algorithm as the L1 Pallas kernel pair
//! `topk_threshold.py` + `ef_compress.py`; `python/tests` pins the kernels
//! to the jnp oracle, and `rust/tests/pjrt_roundtrip.rs` pins THIS
//! implementation to the kernels through the exported `ef_topk` artifact.

use crate::compress::{k_for, Compressor, SparseGrad};
use crate::tensor::{kernels, Layout};

/// Threshold-estimation Top-k.
#[derive(Debug, Clone)]
pub struct MsTopk {
    pub rounds: u32,
}

impl MsTopk {
    pub fn new(rounds: u32) -> Self {
        assert!(rounds >= 1);
        MsTopk { rounds }
    }

    /// Bisect tau with `count(|g| > tau) ~ k`; returns the LOWER bound of
    /// the final bracket (errs toward keeping slightly more than k, like
    /// the Pallas kernel).
    pub fn estimate_threshold(&self, g: &[f32], k: usize) -> f32 {
        // Chunked kernels, bitwise-equal to the old sequential fold/count
        // (max over magnitudes is order-insensitive; the count is integer)
        // — the pjrt_roundtrip.rs artifact pin is untouched.
        let mut hi = kernels::abs_max(g);
        let mut lo = 0.0f32;
        if hi == 0.0 {
            return 0.0;
        }
        for _ in 0..self.rounds {
            let mid = 0.5 * (lo + hi);
            let count = kernels::threshold_count(g, mid);
            if count > k {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Collect entries with `|g| >= tau`; if bisection resolution leaves
    /// more than `cap` candidates, keep the LARGEST `cap` of them (a cheap
    /// quickselect over the small candidate set — not the full tensor).
    fn collect(&self, g: &[f32], tau: f32, cap: usize) -> SparseGrad {
        let mut cand: Vec<(u32, f32)> = Vec::new();
        for (i, &v) in g.iter().enumerate() {
            if v.abs() >= tau && v.abs() > 0.0 {
                cand.push((i as u32, v));
            }
        }
        if cand.len() > cap {
            cand.select_nth_unstable_by(cap - 1, |a, b| {
                // Total order (NaN can't pass the >= tau filter, but
                // unwrap_or(Equal) is non-transitive and select_nth may
                // panic on inconsistent comparators).
                crate::tensor::nan_min_cmp_f32(b.1.abs(), a.1.abs())
                    .then_with(|| a.0.cmp(&b.0))
            });
            cand.truncate(cap);
            cand.sort_unstable_by_key(|&(i, _)| i);
        }
        SparseGrad {
            indices: cand.iter().map(|&(i, _)| i).collect(),
            values: cand.iter().map(|&(_, v)| v).collect(),
            dense_len: g.len(),
        }
    }
}

impl Compressor for MsTopk {
    fn name(&self) -> &'static str {
        "mstopk"
    }

    fn compress(&mut self, g: &[f32], cr: f64, _layout: &Layout) -> SparseGrad {
        let k = k_for(cr, g.len());
        let tau = self.estimate_threshold(g, k);
        // Keep a little headroom over k: bisection resolution means the
        // exact count at tau can exceed k slightly; cap at 1.05k like the
        // paper's implementation tolerates approximate k.
        let cap = (k + (k / 20).max(2)).min(g.len());
        self.collect(g, tau, cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::topk_indices;
    use crate::util::proptest::{check, ensure};

    /// A NaN-poisoned gradient must not panic the selection path (NaN
    /// fails the `>= tau` filter, and the quickselect comparator is a
    /// total order now), and the output must be NaN-free + deterministic.
    #[test]
    fn nan_gradient_does_not_panic_and_is_deterministic() {
        let mut g: Vec<f32> = (1..=500).map(|i| i as f32 / 500.0).collect();
        g[7] = f32::NAN;
        g[311] = f32::NAN;
        let mut ms = MsTopk::new(25);
        let a = ms.compress(&g, 0.05, &Layout::single(g.len()));
        assert!(a.values.iter().all(|v| !v.is_nan()), "NaN must be filtered");
        let b = ms.compress(&g, 0.05, &Layout::single(g.len()));
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn threshold_brackets_k() {
        let g: Vec<f32> = (1..=1000).map(|i| i as f32 / 1000.0).collect();
        let ms = MsTopk::new(25);
        let tau = ms.estimate_threshold(&g, 100);
        let kept = g.iter().filter(|&&v| v.abs() >= tau).count();
        assert!((95..=106).contains(&kept), "kept {kept}");
    }

    #[test]
    fn approximates_exact_topk_energy() {
        check("mstopk ~ exact topk energy", 40, |gen| {
            let n = gen.usize_in(200, 3000);
            let g = gen.vec_normal(n, 1.0);
            let cr = *gen.choose(&[0.1, 0.05, 0.01]);
            let k = k_for(cr, n);
            let s = MsTopk::new(25).compress(&g, cr, &Layout::single(n));
            ensure(
                (s.k() as f64 - k as f64).abs() <= (0.06 * k as f64).max(2.0),
                format!("k deviates: got {} want {k}", s.k()),
            )?;
            // Reduction rewired through the crate lane-split policy (was
            // a sequential .map().sum(); the 0.9-factor bound is far
            // above the low-bit policy drift).
            let exact = kernels::sq_norm_gather_lanes(&g, &topk_indices(&g, k));
            ensure(
                s.sq_norm() >= 0.9 * exact,
                format!("energy {} < 0.9 * exact {exact}", s.sq_norm()),
            )
        });
    }

    #[test]
    fn zero_gradient_compresses_empty() {
        let g = vec![0.0f32; 100];
        let s = MsTopk::new(25).compress(&g, 0.1, &Layout::single(100));
        assert_eq!(s.k(), 0);
    }

    #[test]
    fn more_rounds_tighter_count() {
        let mut gen = crate::util::proptest::Gen { rng: crate::util::rng::Rng::new(5) };
        let g = gen.vec_normal(5000, 1.0);
        let k = 250;
        let coarse = MsTopk::new(4);
        let fine = MsTopk::new(30);
        let ct = |ms: &MsTopk| {
            let tau = ms.estimate_threshold(&g, k);
            g.iter().filter(|&&v| v.abs() >= tau).count() as i64
        };
        let coarse_err = (ct(&coarse) - k as i64).abs();
        let fine_err = (ct(&fine) - k as i64).abs();
        assert!(fine_err <= coarse_err, "fine {fine_err} coarse {coarse_err}");
        assert!(fine_err <= 3);
    }
}
