//! Source model for `flexlint`: a hand-rolled, line-oriented scanner over
//! Rust source (offline build: no `syn`, no proc-macro machinery).
//!
//! Every file is modelled three ways, all LENGTH-PRESERVING (stripped
//! characters become spaces, newlines survive), so byte offsets map 1:1
//! between representations and findings can always name a real line:
//!
//! * `raw` — the text as written.
//! * `nocomment` — comments blanked, string/char literals intact (registry
//!   tables are scanned here, because their rows ARE string names).
//! * `code` — comments blanked AND literal *contents* blanked (rules scan
//!   here, so a doc comment or an embedded fixture string mentioning
//!   `partial_cmp().unwrap()` can never fire a finding).
//!
//! On top of the stripped text the scanner extracts:
//! * [`Allow`] suppressions from line comments (`allow(<rule>): <reason>`
//!   behind the `flexlint::` marker, plus the file-level `allow-file`
//!   form — see [`crate::analysis`] for the policy), and
//! * [`FnSpan`]s — `fn` item boundaries by brace matching over `code`,
//!   used by the function-scoped rules (take/put-back, silent asserts,
//!   per-worker rng paths).
//!
//! Known limitations (documented in DESIGN.md §13): block comments cannot
//! carry allows, macro definition bodies are scanned as ordinary code, and
//! closures do not open their own span (they belong to the innermost `fn`).

/// One suppression annotation parsed from a line comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// Rule name inside the parens (may be unknown — that is a finding).
    pub rule: String,
    /// Mandatory audit reason after the colon; `None` is itself a finding
    /// and never suppresses anything.
    pub reason: Option<String>,
    /// The `allow-file(...)` variant: applies to the whole file.
    pub file_level: bool,
    /// 1-indexed line the annotation sits on.
    pub line: usize,
}

/// One `fn` item with a body, located by brace matching.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Signature text (stripped `code` rep) from `fn` to the body `{`.
    pub header: String,
    /// 1-indexed line of the `fn` keyword.
    pub start: usize,
    /// 1-indexed line of the closing `}`.
    pub end: usize,
    /// Byte range of the body (between the braces) in the joined text.
    pub body_range: (usize, usize),
}

/// One scanned file: stripped representations + extracted structure.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated.
    pub rel: String,
    pub raw: String,
    pub nocomment: String,
    pub code: String,
    /// Byte offset of each line start in the (length-preserved) text.
    pub line_starts: Vec<usize>,
    pub allows: Vec<Allow>,
    pub fns: Vec<FnSpan>,
}

impl SourceFile {
    /// Build the model from source text. `rel` is the display path.
    pub fn parse(rel: &str, raw: &str) -> SourceFile {
        let (nocomment, code, comments) = strip(raw);
        let line_starts = line_starts(raw);
        let allows = parse_allows(&comments, &line_starts);
        let fns = fn_spans(&code, &line_starts);
        SourceFile {
            rel: rel.to_string(),
            raw: raw.to_string(),
            nocomment,
            code,
            line_starts,
            allows,
            fns,
        }
    }

    /// 1-indexed line containing byte `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i, // insertion point = lines fully before offset
        }
    }

    /// The raw text of 1-indexed `line`, trimmed (finding excerpts).
    pub fn raw_line(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e.saturating_sub(1))
            .unwrap_or(self.raw.len());
        self.raw[start..end.max(start)].trim()
    }

    /// Innermost `fn` span whose body contains byte `offset`.
    pub fn fn_at(&self, offset: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| offset >= f.body_range.0 && offset < f.body_range.1)
            .min_by_key(|f| f.body_range.1 - f.body_range.0)
    }
}

/// Byte offsets of line starts (first line starts at 0).
fn line_starts(text: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in text.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

/// The stripping state machine. Returns `(nocomment, code, comments)`,
/// each the same byte length as `raw`:
/// * `nocomment`: comment bytes → spaces;
/// * `code`: comment bytes AND string/char literal contents → spaces;
/// * `comments`: everything EXCEPT comment text → spaces (allow parsing).
fn strip(raw: &str) -> (String, String, String) {
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let bytes = raw.as_bytes();
    let n = bytes.len();
    let mut nocomment = vec![b' '; n];
    let mut code = vec![b' '; n];
    let mut comments = vec![b' '; n];
    let mut st = St::Code;
    let mut i = 0;
    // Copy a byte into the representations that keep it. Multi-byte UTF-8
    // sequences pass through byte-by-byte (states never switch mid-char:
    // every delimiter is ASCII).
    while i < n {
        let b = bytes[i];
        match st {
            St::Code => {
                if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
                    st = St::LineComment;
                    i += 2;
                    continue;
                }
                if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    st = St::BlockComment(1);
                    i += 2;
                    continue;
                }
                if b == b'"' {
                    nocomment[i] = b;
                    code[i] = b;
                    st = St::Str;
                    i += 1;
                    continue;
                }
                // Raw (and byte/raw-byte) strings: r"", r#""#, br"", ...
                if (b == b'r' || b == b'b') && !ident_char(prev_byte(bytes, i)) {
                    if let Some((hashes, skip)) = raw_str_open(bytes, i) {
                        for j in i..i + skip {
                            nocomment[j] = bytes[j];
                            code[j] = bytes[j];
                        }
                        st = St::RawStr(hashes);
                        i += skip;
                        continue;
                    }
                }
                if b == b'\'' {
                    // Lifetime (`'a`, `'static`) vs char literal: a
                    // lifetime's ident is NOT followed by a closing quote.
                    let mut j = i + 1;
                    while j < n && ident_char(bytes[j]) {
                        j += 1;
                    }
                    let is_lifetime = j > i + 1 && (j >= n || bytes[j] != b'\'');
                    if !is_lifetime {
                        nocomment[i] = b;
                        code[i] = b;
                        st = St::Char;
                        i += 1;
                        continue;
                    }
                }
                if b == b'\n' {
                    nocomment[i] = b;
                    code[i] = b;
                    comments[i] = b;
                } else {
                    nocomment[i] = b;
                    code[i] = b;
                }
                i += 1;
            }
            St::LineComment => {
                if b == b'\n' {
                    nocomment[i] = b;
                    code[i] = b;
                    comments[i] = b;
                    st = St::Code;
                } else {
                    comments[i] = b;
                }
                i += 1;
            }
            St::BlockComment(depth) => {
                if b == b'\n' {
                    nocomment[i] = b;
                    code[i] = b;
                    comments[i] = b;
                    i += 1;
                } else if b == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    i += 2;
                } else if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if b == b'\\' && i + 1 < n {
                    nocomment[i] = b;
                    nocomment[i + 1] = bytes[i + 1];
                    i += 2;
                } else {
                    if b == b'\n' || b == b'"' {
                        nocomment[i] = b;
                        code[i] = if b == b'\n' { b } else { b'"' };
                        if b == b'"' {
                            st = St::Code;
                        }
                    } else {
                        nocomment[i] = b;
                    }
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if b == b'"' && raw_str_close(bytes, i, hashes) {
                    for j in i..(i + 1 + hashes as usize).min(n) {
                        nocomment[j] = bytes[j];
                        code[j] = bytes[j];
                    }
                    st = St::Code;
                    i += 1 + hashes as usize;
                } else {
                    nocomment[i] = b;
                    if b == b'\n' {
                        code[i] = b;
                    }
                    i += 1;
                }
            }
            St::Char => {
                if b == b'\\' && i + 1 < n {
                    nocomment[i] = b;
                    nocomment[i + 1] = bytes[i + 1];
                    i += 2;
                } else {
                    nocomment[i] = b;
                    if b == b'\'' {
                        code[i] = b;
                        st = St::Code;
                    } else if b == b'\n' {
                        code[i] = b;
                        comments[i] = b;
                        // Unterminated char on one line: bail to Code so a
                        // stray quote can't swallow the rest of the file.
                        st = St::Code;
                    }
                    i += 1;
                }
            }
        }
    }
    // The buffers only ever hold ASCII substitutions or original bytes at
    // original positions, so they remain valid UTF-8.
    (
        String::from_utf8(nocomment).expect("stripped text stays utf-8"),
        String::from_utf8(code).expect("stripped text stays utf-8"),
        String::from_utf8(comments).expect("stripped text stays utf-8"),
    )
}

fn prev_byte(bytes: &[u8], i: usize) -> u8 {
    if i == 0 {
        b' '
    } else {
        bytes[i - 1]
    }
}

fn ident_char(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// If `bytes[i..]` opens a raw string (`r`/`br` + hashes + `"`), return
/// `(hash_count, bytes_consumed_through_quote)`.
fn raw_str_open(bytes: &[u8], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if bytes[j] == b'b' {
        j += 1;
        if j >= bytes.len() || bytes[j] != b'r' {
            return None;
        }
    }
    if bytes[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while j < bytes.len() && bytes[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < bytes.len() && bytes[j] == b'"' {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// True when the `"` at `i` closes a raw string opened with `hashes` `#`s
/// (i.e. exactly `hashes` `#` bytes follow; too few remaining bytes fail).
fn raw_str_close(bytes: &[u8], i: usize, hashes: u32) -> bool {
    let need = hashes as usize;
    bytes[i + 1..].iter().take(need).filter(|&&b| b == b'#').count() == need
}

/// Parse `allow(<rule>): <reason>` / `allow-file(..)` annotations (the
/// `MARK`-prefixed forms) out of the comments-only text. A missing
/// reason is recorded as `reason: None` (the `malformed-allow` rule
/// fires on it). The marker is spelled out only inside `MARK` below so
/// the scanner cannot flag its own documentation.
fn parse_allows(comments: &str, line_starts: &[usize]) -> Vec<Allow> {
    const MARK: &str = "flexlint::allow";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = comments[from..].find(MARK) {
        let at = from + pos;
        let mut j = at + MARK.len();
        let rest = &comments[j..];
        let file_level = rest.starts_with("-file");
        if file_level {
            j += "-file".len();
        }
        let line = match line_starts.binary_search(&at) {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        // Expect `(<rule>)` immediately (no spaces: the annotation is a
        // fixed token, not prose).
        let after = &comments[j..];
        let parsed = after.strip_prefix('(').and_then(|r| {
            r.find(')').map(|close| (r[..close].trim().to_string(), j + 1 + close + 1))
        });
        match parsed {
            Some((rule, after_paren)) => {
                // Reason: `: non-empty text` on the same line.
                let tail = &comments[after_paren..];
                let eol = tail.find('\n').unwrap_or(tail.len());
                let same_line = &tail[..eol];
                let reason = same_line.strip_prefix(':').map(str::trim).and_then(|r| {
                    if r.is_empty() {
                        None
                    } else {
                        Some(r.to_string())
                    }
                });
                out.push(Allow { rule, reason, file_level, line });
                from = after_paren;
            }
            None => {
                // A marker with no parens at all: record it as a
                // malformed (rule-less) annotation rather than ignoring it.
                out.push(Allow {
                    rule: String::new(),
                    reason: None,
                    file_level,
                    line,
                });
                from = j;
            }
        }
    }
    out
}

/// Locate every `fn` item WITH a body by brace matching over `code`.
fn fn_spans(code: &str, line_starts: &[usize]) -> Vec<FnSpan> {
    let bytes = code.as_bytes();
    let n = bytes.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < n {
        // Word-boundary `fn`.
        if &code[i..i + 2] == "fn"
            && !ident_char(prev_byte(bytes, i))
            && i + 2 < n
            && !ident_char(bytes[i + 2])
        {
            let mut j = i + 2;
            while j < n && (bytes[j] as char).is_whitespace() {
                j += 1;
            }
            let name_start = j;
            while j < n && ident_char(bytes[j]) {
                j += 1;
            }
            let name = code[name_start..j].to_string();
            if name.is_empty() {
                i += 2;
                continue;
            }
            // Scan to the body `{`; a `;` at paren/bracket depth 0 first
            // means a bodyless trait/extern declaration. `<`/`>` generics
            // are NOT tracked as depth (comparison operators would skew
            // it); braces inside generic bounds don't occur in this crate.
            let mut depth = 0i32;
            let mut body_open = None;
            while j < n {
                match bytes[j] {
                    b'(' | b'[' => depth += 1,
                    b')' | b']' => depth -= 1,
                    b';' if depth == 0 => break,
                    b'{' if depth == 0 => {
                        body_open = Some(j);
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            if let Some(open) = body_open {
                let mut braces = 1i32;
                let mut k = open + 1;
                while k < n && braces > 0 {
                    match bytes[k] {
                        b'{' => braces += 1,
                        b'}' => braces -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                let close = k.saturating_sub(1);
                let line = |off: usize| match line_starts.binary_search(&off) {
                    Ok(x) => x + 1,
                    Err(x) => x,
                };
                out.push(FnSpan {
                    name,
                    header: code[i..open].to_string(),
                    start: line(i),
                    end: line(close),
                    body_range: (open + 1, close),
                });
                // Continue INSIDE the body so nested fns are found too.
                i = open + 1;
                continue;
            }
            i = j.max(i + 2);
            continue;
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked_in_code() {
        let src = "let x = \"partial_cmp().unwrap()\"; // Instant::now()\nlet y = 1;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.code.contains("partial_cmp"));
        assert!(!f.code.contains("Instant::now"));
        assert!(f.code.contains("let x ="));
        assert!(f.code.contains("let y = 1;"));
        // nocomment keeps the string but drops the comment.
        assert!(f.nocomment.contains("partial_cmp"));
        assert!(!f.nocomment.contains("Instant::now"));
        assert_eq!(f.code.len(), src.len(), "length-preserving");
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let src = "let r = r#\"Instant::now() \"quoted\"\"#;\nlet c = '\\n';\nfn f<'a>(x: &'a str) -> &'a str { x }\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.code.contains("Instant::now"));
        assert!(f.code.contains("fn f<'a>"), "lifetimes survive: {}", f.code);
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "f");
    }

    #[test]
    fn block_comments_nest_and_blank() {
        let src = "/* outer /* Instant::now() */ still comment */ let z = 3;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.code.contains("Instant::now"));
        assert!(f.code.contains("let z = 3;"));
    }

    #[test]
    fn allow_parsing_and_malformed_forms() {
        let src = "\
// flexlint::allow(nan-partial-cmp): audited, this is the policy home\n\
let a = 1;\n\
// flexlint::allow(shared-rng)\n\
// flexlint::allow-file(unsanctioned-clock): bench harness measures time\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.allows.len(), 3);
        assert_eq!(f.allows[0].rule, "nan-partial-cmp");
        assert_eq!(f.allows[0].line, 1);
        assert!(f.allows[0].reason.is_some() && !f.allows[0].file_level);
        assert_eq!(f.allows[1].rule, "shared-rng");
        assert!(f.allows[1].reason.is_none(), "bare allow has no reason");
        assert!(f.allows[2].file_level);
        assert_eq!(f.allows[2].line, 4);
    }

    #[test]
    fn fn_spans_cover_bodies_and_skip_trait_decls() {
        let src = "\
trait T {\n\
    fn no_body(&self) -> u32;\n\
}\n\
fn outer(worker: usize) -> u32 {\n\
    fn inner() -> u32 { 7 }\n\
    inner() + worker as u32\n\
}\n";
        let f = SourceFile::parse("t.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner"]);
        let outer = &f.fns[0];
        assert!(outer.header.contains("worker"));
        assert_eq!((outer.start, outer.end), (4, 7));
        // Innermost-span resolution: a byte inside `inner` maps to inner.
        let off = src.find("{ 7 }").unwrap() + 2;
        assert_eq!(f.fn_at(off).unwrap().name, "inner");
    }

    #[test]
    fn line_mapping_is_exact() {
        let src = "a\nbb\nccc\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.line_of(0), 1);
        assert_eq!(f.line_of(2), 2);
        assert_eq!(f.line_of(5), 3);
        assert_eq!(f.raw_line(2), "bb");
    }
}
