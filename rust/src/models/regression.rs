//! [`MatrixRegressionSource`] — an NNUE-style fixed-size matrix regression
//! learner with hand-rolled closed-form gradients and a JSON-serializable
//! checkpoint.
//!
//! The model is the classic efficiently-updatable shape: one dense input
//! matrix into a clipped-ReLU (`clamp(·, 0, 1)`) hidden band, then a
//! scalar linear head. Targets come from a *teacher* network of the same
//! shape (frozen, drawn from the seed) plus small Gaussian noise, so the
//! task is exactly realizable and the loss floor is the noise power —
//! a clean target for the accuracy-vs-CR pareto measurements the sweep
//! server produces.
//!
//! Gradients are written out by hand (no tape): the CReLU derivative is
//! the indicator of the open band `(0, 1)`, everything else is the chain
//! rule on two matmuls. Checkpoints ([`MatRegCheckpoint`]) serialize
//! parameters AND gradients to JSON using Rust's shortest-roundtrip float
//! formatting, so `save → load` is **bitwise** lossless for every finite
//! f32 — pinned by the round-trip test below.

use crate::coordinator::worker::GradSource;
use crate::models::ModelError;
use crate::tensor::Layout;
use crate::util::rng::Rng;

/// Within-band tolerance for the regression "accuracy": the fraction of
/// held-out points predicted within ±0.1 of the teacher target.
const ACC_BAND: f64 = 0.1;

/// Teacher-target observation noise (std) — the realizable loss floor.
const TARGET_NOISE: f32 = 0.02;

/// NNUE-style `x → clamp(W1·x + b1, 0, 1) → w2·h + b2` regression.
pub struct MatrixRegressionSource {
    input: usize,
    hidden: usize,
    layout: Layout,
    seed: u64,
    batch: usize,
    /// Frozen teacher parameters (same flat layout as the student).
    teacher: Vec<f32>,
    eval_cache: Option<(Vec<f32>, Vec<f32>)>,
}

impl MatrixRegressionSource {
    /// The registry preset: 8 features into a 16-wide CReLU band.
    pub fn default_preset(seed: u64) -> Self {
        MatrixRegressionSource::new(8, 16, seed, 32)
    }

    pub fn new(input: usize, hidden: usize, seed: u64, batch: usize) -> Self {
        let layout = Layout::from_sizes(&[
            ("w1", input * hidden),
            ("b1", hidden),
            ("w2", hidden),
            ("b2", 1),
        ]);
        let dim = layout.total();
        // The teacher is a fixed random net of the same shape: w1 spread
        // wide enough that the CReLU band actually clips, b1 centered in
        // the band, a small head.
        let mut rng = Rng::new(seed ^ 0x7EAC_4E2);
        let mut teacher = vec![0.0f32; dim];
        rng.fill_normal(&mut teacher[..input * hidden], 0.6);
        for j in 0..hidden {
            teacher[input * hidden + j] = rng.normal_f32(0.5, 0.1);
        }
        let w2_off = input * hidden + hidden;
        let w2_std = (1.0 / hidden as f64).sqrt() as f32;
        rng.fill_normal(&mut teacher[w2_off..w2_off + hidden], w2_std);
        teacher[dim - 1] = 0.0;
        MatrixRegressionSource {
            input,
            hidden,
            layout,
            seed,
            batch,
            teacher,
            eval_cache: None,
        }
    }

    /// Forward pass; when `h_out` is given, the post-CReLU hidden vector
    /// and pre-activations are written for the backward pass.
    fn forward(
        &self,
        params: &[f32],
        x: &[f32],
        mut pre_h: Option<(&mut [f64], &mut [f64])>,
    ) -> f64 {
        let (inp, hid) = (self.input, self.hidden);
        let w2_off = inp * hid + hid;
        let mut y = params[w2_off + hid] as f64; // b2
        for j in 0..hid {
            let mut pre = params[inp * hid + j] as f64; // b1[j]
            for i in 0..inp {
                pre += params[j * inp + i] as f64 * x[i] as f64;
            }
            let h = pre.clamp(0.0, 1.0);
            if let Some((pres, hs)) = pre_h.as_mut() {
                pres[j] = pre;
                hs[j] = h;
            }
            y += params[w2_off + j] as f64 * h;
        }
        y
    }

    /// Deterministic `(inputs, teacher targets)` batch for `(worker, step)`
    /// — same splitmix-style derivation as the other sources.
    fn batch_for(&self, worker: usize, step: u64, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xA076_1D64_78BD_642F),
        );
        let mut x = Vec::with_capacity(batch * self.input);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let s0 = x.len();
            for _ in 0..self.input {
                x.push(rng.normal_f32(0.0, 1.0));
            }
            let t = self.forward(&self.teacher, &x[s0..], None);
            y.push(t as f32 + rng.normal_f32(0.0, TARGET_NOISE));
        }
        (x, y)
    }

    /// Bundle `(params, grads)` at `step` into a serializable checkpoint.
    /// `grad` is `&self`-pure, so the caller owns both vectors — the source
    /// never caches them.
    pub fn checkpoint(&self, step: u64, params: &[f32], grads: &[f32]) -> MatRegCheckpoint {
        MatRegCheckpoint {
            model: GradSource::name(self),
            step,
            params: params.to_vec(),
            grads: grads.to_vec(),
        }
    }
}

impl GradSource for MatrixRegressionSource {
    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    fn init_params(&mut self) -> Vec<f32> {
        let (inp, hid) = (self.input, self.hidden);
        let mut rng = Rng::new(self.seed ^ 0x57CD_E47);
        let mut p = vec![0.0f32; self.dim()];
        rng.fill_normal(&mut p[..inp * hid], 0.3);
        for j in 0..hid {
            // Start inside the CReLU band so gradients flow from step 0.
            p[inp * hid + j] = rng.normal_f32(0.5, 0.05);
        }
        let w2_off = inp * hid + hid;
        rng.fill_normal(&mut p[w2_off..w2_off + hid], 0.1);
        p
    }

    fn grad(
        &self,
        params: &[f32],
        worker: usize,
        _n_workers: usize,
        step: u64,
    ) -> (f64, Vec<f32>) {
        let (inp, hid) = (self.input, self.hidden);
        let w2_off = inp * hid + hid;
        let (x, y) = self.batch_for(worker, step, self.batch);
        let mut g = vec![0.0f64; self.dim()];
        let mut pre = vec![0.0f64; hid];
        let mut h = vec![0.0f64; hid];
        let mut loss = 0.0f64;
        for s in 0..self.batch {
            let xi = &x[s * inp..(s + 1) * inp];
            let pred = self.forward(params, xi, Some((&mut pre, &mut h)));
            let e = pred - y[s] as f64;
            loss += e * e;
            let dy = 2.0 * e;
            g[self.dim() - 1] += dy; // b2
            for j in 0..hid {
                g[w2_off + j] += dy * h[j];
                // CReLU subgradient: the open band (0, 1) passes, the
                // clipped rails block.
                if pre[j] > 0.0 && pre[j] < 1.0 {
                    let dpre = dy * params[w2_off + j] as f64;
                    g[inp * hid + j] += dpre; // b1[j]
                    for i in 0..inp {
                        g[j * inp + i] += dpre * xi[i] as f64;
                    }
                }
            }
        }
        let inv_b = 1.0 / self.batch as f64;
        (loss * inv_b, g.iter().map(|&v| (v * inv_b) as f32).collect())
    }

    fn eval(&mut self, params: &[f32]) -> (f64, f64) {
        const EVAL_N: usize = 256;
        if self.eval_cache.is_none() {
            self.eval_cache = Some(self.batch_for(usize::MAX / 2, u64::MAX / 2, EVAL_N));
        }
        let (x, y) = self.eval_cache.as_ref().unwrap();
        let mut loss = 0.0f64;
        let mut within = 0usize;
        for s in 0..EVAL_N {
            let pred = self.forward(params, &x[s * self.input..(s + 1) * self.input], None);
            let e = pred - y[s] as f64;
            loss += e * e;
            within += (e.abs() < ACC_BAND) as usize;
        }
        (loss / EVAL_N as f64, within as f64 / EVAL_N as f64)
    }

    fn name(&self) -> String {
        format!("matreg[{}x{}]", self.input, self.hidden)
    }
}

// ---------------------------------------------------------------------------
// Checkpoint: hand-rolled JSON (the repo has no serde — DESIGN.md §6), with
// shortest-roundtrip float formatting so finite f32s survive bitwise.
// ---------------------------------------------------------------------------

/// A `(model, step, params, grads)` snapshot. `to_json`/`from_json` are
/// exact inverses on finite values: Rust's `{}` formatting of an `f32` is
/// the shortest string that parses back to the identical bits.
#[derive(Debug, Clone, PartialEq)]
pub struct MatRegCheckpoint {
    pub model: String,
    pub step: u64,
    pub params: Vec<f32>,
    pub grads: Vec<f32>,
}

impl MatRegCheckpoint {
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(32 + 12 * (self.params.len() + self.grads.len()));
        s.push_str("{\"model\":\"");
        // The model tag is internal ASCII (`matreg[8x16]`) — escape the
        // JSON delimiters anyway so a hand-edited tag cannot corrupt the
        // file.
        for c in self.model.chars() {
            match c {
                '"' => s.push_str("\\\""),
                '\\' => s.push_str("\\\\"),
                c => s.push(c),
            }
        }
        s.push_str("\",\"step\":");
        s.push_str(&self.step.to_string());
        push_f32_array(&mut s, ",\"params\":[", &self.params);
        push_f32_array(&mut s, ",\"grads\":[", &self.grads);
        s.push('}');
        s
    }

    pub fn from_json(text: &str) -> Result<Self, ModelError> {
        let model = parse_string_field(text, "model")?;
        let step_raw = field_value(text, "step")?;
        let step: u64 = step_raw
            .trim()
            .parse()
            .map_err(|_| bad(format!("step `{step_raw}` is not a u64")))?;
        Ok(MatRegCheckpoint {
            model,
            step,
            params: parse_f32_array(text, "params")?,
            grads: parse_f32_array(text, "grads")?,
        })
    }

    pub fn save(&self, path: &str) -> Result<(), ModelError> {
        std::fs::write(path, self.to_json())
            .map_err(|e| bad(format!("write {path}: {e}")))
    }

    pub fn load(path: &str) -> Result<Self, ModelError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| bad(format!("read {path}: {e}")))?;
        MatRegCheckpoint::from_json(&text)
    }
}

fn bad(msg: String) -> ModelError {
    ModelError::Checkpoint { msg }
}

fn push_f32_array(s: &mut String, prefix: &str, vals: &[f32]) {
    use std::fmt::Write;
    s.push_str(prefix);
    for (i, v) in vals.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        // `{}` on f32 is shortest-roundtrip; non-finite values print as
        // `NaN`/`inf`/`-inf`, which `f32::from_str` also accepts (strictly
        // that is beyond JSON, but this is a first-party format).
        let _ = write!(s, "{v}");
    }
    s.push(']');
}

/// The raw text after `"key":` up to the next top-level delimiter.
fn field_value<'a>(text: &'a str, key: &str) -> Result<&'a str, ModelError> {
    let pat = format!("\"{key}\":");
    let at = text
        .find(&pat)
        .ok_or_else(|| bad(format!("missing field `{key}`")))?;
    let rest = &text[at + pat.len()..];
    let end = rest.find([',', '}', ']']).unwrap_or(rest.len());
    Ok(&rest[..end])
}

fn parse_string_field(text: &str, key: &str) -> Result<String, ModelError> {
    // Scan to the closing unescaped quote directly — the value may contain
    // `]`/`}` (the model tag does: `matreg[8x16]`), so the delimiter-based
    // `field_value` scan would truncate it.
    let pat = format!("\"{key}\":\"");
    let at = text
        .find(&pat)
        .ok_or_else(|| bad(format!("missing string field `{key}`")))?;
    let rest = &text[at + pat.len()..];
    let mut out = String::new();
    let mut escaped = false;
    for c in rest.chars() {
        if escaped {
            out.push(c);
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            return Ok(out);
        } else {
            out.push(c);
        }
    }
    Err(bad(format!("unterminated string field `{key}`")))
}

fn parse_f32_array(text: &str, key: &str) -> Result<Vec<f32>, ModelError> {
    let pat = format!("\"{key}\":[");
    let at = text
        .find(&pat)
        .ok_or_else(|| bad(format!("missing array field `{key}`")))?;
    let rest = &text[at + pat.len()..];
    let end = rest
        .find(']')
        .ok_or_else(|| bad(format!("unterminated array `{key}`")))?;
    let body = &rest[..end];
    if body.trim().is_empty() {
        return Ok(Vec::new());
    }
    body.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<f32>()
                .map_err(|_| bad(format!("`{key}` element `{tok}` is not an f32")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradcheck_vs_finite_differences() {
        let mut src = MatrixRegressionSource::default_preset(3);
        let params = src.init_params();
        let (_, g) = src.grad(&params, 0, 2, 5);
        let dim = src.dim();
        let eps = 1e-3f32;
        for &i in &[0usize, 7, 40, dim / 2, dim - 2, dim - 1] {
            let mut p = params.clone();
            p[i] = params[i] + eps;
            let (lp, _) = src.grad(&p, 0, 2, 5);
            p[i] = params[i] - eps;
            let (lm, _) = src.grad(&p, 0, 2, 5);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let tol = 2e-2 * (1.0 + fd.abs());
            assert!(
                (g[i] as f64 - fd).abs() < tol,
                "param {i}: closed-form {} vs fd {fd}",
                g[i]
            );
        }
    }

    #[test]
    fn grads_deterministic_and_vary_by_worker_and_step() {
        let mut src = MatrixRegressionSource::default_preset(9);
        let p = src.init_params();
        let (l1, g1) = src.grad(&p, 0, 4, 2);
        let (l2, g2) = src.grad(&p, 0, 4, 2);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
        assert_ne!(g1, src.grad(&p, 1, 4, 2).1);
        assert_ne!(g1, src.grad(&p, 0, 4, 3).1);
    }

    /// The task is realizable (teacher of the same shape), so momentum SGD
    /// drives the loss toward the noise floor and the within-band accuracy
    /// well above its untrained level.
    #[test]
    fn learns_toward_the_teacher() {
        let mut src = MatrixRegressionSource::default_preset(1);
        let mut p = src.init_params();
        let (loss0, acc0) = src.eval(&p);
        let mut m = vec![0.0f32; p.len()];
        for step in 0..400u64 {
            let (_, g) = src.grad(&p, 0, 1, step);
            for i in 0..p.len() {
                m[i] = 0.9 * m[i] + g[i];
                p[i] -= 0.05 * m[i];
            }
        }
        let (loss1, acc1) = src.eval(&p);
        assert!(loss1 < loss0 * 0.5, "loss {loss0} -> {loss1}");
        assert!(acc1 > acc0 && acc1 > 0.3, "band accuracy {acc0} -> {acc1}");
    }

    /// save → load is BITWISE lossless for params and grads — the
    /// shortest-roundtrip formatting contract.
    #[test]
    fn checkpoint_json_roundtrip_is_bitwise() {
        let mut src = MatrixRegressionSource::default_preset(4);
        let params = src.init_params();
        let (_, grads) = src.grad(&params, 2, 4, 17);
        let ck = src.checkpoint(17, &params, &grads);
        let back = MatRegCheckpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.model, ck.model);
        assert_eq!(back.step, 17);
        assert_eq!(back.params.len(), ck.params.len());
        for (a, b) in ck.params.iter().zip(&back.params) {
            assert_eq!(a.to_bits(), b.to_bits(), "params not bitwise");
        }
        for (a, b) in ck.grads.iter().zip(&back.grads) {
            assert_eq!(a.to_bits(), b.to_bits(), "grads not bitwise");
        }
        // Awkward but finite values survive too.
        let odd = MatRegCheckpoint {
            model: "m".into(),
            step: 0,
            params: vec![f32::MIN_POSITIVE, -0.0, 1e-38, 3.4e38],
            grads: vec![],
        };
        let back = MatRegCheckpoint::from_json(&odd.to_json()).unwrap();
        for (a, b) in odd.params.iter().zip(&back.params) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn checkpoint_file_roundtrip_and_errors() {
        let dir = std::env::temp_dir().join("flexcomm_matreg_ck");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        let path = path.to_str().unwrap();
        let mut src = MatrixRegressionSource::default_preset(8);
        let params = src.init_params();
        let (_, grads) = src.grad(&params, 0, 1, 0);
        src.checkpoint(3, &params, &grads).save(path).unwrap();
        let back = MatRegCheckpoint::load(path).unwrap();
        assert_eq!(back.step, 3);
        assert_eq!(back.params, params);
        // Typed errors carry what went wrong.
        let err = MatRegCheckpoint::from_json("{}").unwrap_err();
        assert!(err.to_string().contains("model"), "{err}");
        let err = MatRegCheckpoint::from_json(
            "{\"model\":\"m\",\"step\":1,\"params\":[x],\"grads\":[]}",
        )
        .unwrap_err();
        assert!(err.to_string().contains("params"), "{err}");
    }
}
