//! Artifact registry: locates `artifacts/`, parses model manifests
//! (`<name>_meta.txt`), and names the per-preset HLO files.

use crate::tensor::Layout;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$FLEXCOMM_ARTIFACTS`, else walk up from
/// the current directory looking for `artifacts/`.
pub fn find_artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("FLEXCOMM_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.is_dir() {
            return Ok(p);
        }
        bail!("FLEXCOMM_ARTIFACTS={} is not a directory", p.display());
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return Ok(cand);
        }
        if !cur.pop() {
            bail!(
                "artifacts/ not found (run `make artifacts` at the repo root, \
                 or set FLEXCOMM_ARTIFACTS)"
            );
        }
    }
}

/// Parsed `<name>_meta.txt` manifest + derived paths.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub name: String,
    pub dir: PathBuf,
    pub meta: BTreeMap<String, String>,
    pub layout: Layout,
}

impl ModelArtifacts {
    pub fn load(dir: &Path, name: &str) -> Result<ModelArtifacts> {
        let meta_path = dir.join(format!("{name}_meta.txt"));
        let meta_text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("{} (run `make artifacts`?)", meta_path.display()))?;
        let mut meta = BTreeMap::new();
        for line in meta_text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                meta.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let layout = Layout::load(
            dir.join(format!("{name}_layout.txt"))
                .to_str()
                .context("path utf8")?,
        )?;
        Ok(ModelArtifacts { name: name.to_string(), dir: dir.to_path_buf(), meta, layout })
    }

    pub fn kind(&self) -> &str {
        self.meta.get("kind").map(|s| s.as_str()).unwrap_or("unknown")
    }

    pub fn param_count(&self) -> Result<usize> {
        let p: usize = self
            .meta
            .get("param_count")
            .context("meta missing param_count")?
            .parse()?;
        anyhow::ensure!(p == self.layout.total(), "meta/layout param count mismatch");
        Ok(p)
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        Ok(self
            .meta
            .get(key)
            .with_context(|| format!("meta missing `{key}`"))?
            .parse()?)
    }

    pub fn grad_path(&self) -> PathBuf {
        self.dir.join(format!("{}_grad.hlo.txt", self.name))
    }

    pub fn eval_path(&self) -> PathBuf {
        self.dir.join(format!("{}_eval.hlo.txt", self.name))
    }

    pub fn step_path(&self) -> PathBuf {
        self.dir.join(format!("{}_step.hlo.txt", self.name))
    }

    pub fn init_path(&self) -> PathBuf {
        self.dir.join(format!("{}_init.f32", self.name))
    }

    pub fn ef_topk_path(&self) -> Result<PathBuf> {
        Ok(self.dir.join(format!("ef_topk_{}.hlo.txt", self.param_count()?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("toy_meta.txt"),
            "kind=mlp\nparam_count=15\nbatch=4\n",
        )
        .unwrap();
        std::fs::write(dir.join("toy_layout.txt"), "a 0 10\nb 10 5\n").unwrap();
    }

    #[test]
    fn load_meta_and_layout() {
        let dir = std::env::temp_dir().join("flexcomm_artifact_test");
        write_fixture(&dir);
        let m = ModelArtifacts::load(&dir, "toy").unwrap();
        assert_eq!(m.kind(), "mlp");
        assert_eq!(m.param_count().unwrap(), 15);
        assert_eq!(m.meta_usize("batch").unwrap(), 4);
        assert!(m.grad_path().ends_with("toy_grad.hlo.txt"));
        assert!(m.ef_topk_path().unwrap().ends_with("ef_topk_15.hlo.txt"));
    }

    #[test]
    fn mismatched_counts_rejected() {
        let dir = std::env::temp_dir().join("flexcomm_artifact_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad_meta.txt"), "kind=mlp\nparam_count=99\n").unwrap();
        std::fs::write(dir.join("bad_layout.txt"), "a 0 10\n").unwrap();
        let m = ModelArtifacts::load(&dir, "bad").unwrap();
        assert!(m.param_count().is_err());
    }

    #[test]
    fn missing_meta_is_actionable() {
        let dir = std::env::temp_dir().join("flexcomm_artifact_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let err = ModelArtifacts::load(&dir, "ghost").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
