//! Pareto utilities: dominance, front extraction, knee-point selection.

/// `a` dominates `b` iff a <= b in every objective and < in at least one
/// (all objectives minimized).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated members of `objs`.
pub fn pareto_front(objs: &[Vec<f64>]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| !objs.iter().enumerate().any(|(j, o)| j != i && dominates(o, &objs[i])))
        .collect()
}

/// Knee point of a front: normalize every objective to [0, 1] over the
/// front, then pick the member closest (L2) to the ideal origin. This is
/// the "knee-point or pareto-front" compromise the paper picks its
/// `c_optimal` from.
pub fn knee_point(objs: &[Vec<f64>], front: &[usize]) -> usize {
    assert!(!front.is_empty());
    let dims = objs[front[0]].len();
    let mut lo = vec![f64::INFINITY; dims];
    let mut hi = vec![f64::NEG_INFINITY; dims];
    for &i in front {
        for d in 0..dims {
            lo[d] = lo[d].min(objs[i][d]);
            hi[d] = hi[d].max(objs[i][d]);
        }
    }
    let mut best = front[0];
    let mut best_dist = f64::INFINITY;
    for &i in front {
        let mut dist = 0.0;
        for d in 0..dims {
            let range = hi[d] - lo[d];
            let z = if range > 0.0 { (objs[i][d] - lo[d]) / range } else { 0.0 };
            dist += z * z;
        }
        if dist < best_dist {
            best_dist = dist;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    #[test]
    fn front_extraction() {
        let objs = vec![
            vec![1.0, 5.0], // front
            vec![2.0, 4.0], // front
            vec![3.0, 3.0], // front
            vec![3.0, 5.0], // dominated by [1,5]? no: 1<3,5=5 -> dominated
            vec![2.5, 4.5], // dominated by [2,4]
        ];
        let f = pareto_front(&objs);
        assert_eq!(f, vec![0, 1, 2]);
    }

    #[test]
    fn knee_prefers_balanced_point() {
        let objs = vec![
            vec![0.0, 1.0],
            vec![0.2, 0.2], // balanced knee
            vec![1.0, 0.0],
        ];
        let f = pareto_front(&objs);
        assert_eq!(knee_point(&objs, &f), 1);
    }

    #[test]
    fn front_members_are_mutually_nondominated() {
        check("pareto front mutual nondominance", 60, |g| {
            let n = g.usize_in(1, 40);
            let objs: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0)])
                .collect();
            let front = pareto_front(&objs);
            ensure(!front.is_empty(), "front empty")?;
            for &i in &front {
                for &j in &front {
                    if i != j {
                        ensure(!dominates(&objs[i], &objs[j]), "front member dominated")?;
                    }
                }
            }
            // Every non-front member is dominated by someone.
            for i in 0..n {
                if !front.contains(&i) {
                    ensure(
                        objs.iter().any(|o| dominates(o, &objs[i])),
                        "non-front member not dominated",
                    )?;
                }
            }
            Ok(())
        });
    }
}
