//! Binomial-tree allreduce: reduce to rank 0, then broadcast back
//! (Table I row 3): `2α·log N + 2·log N·Mβ` for power-of-two N.

use crate::collectives::{ceil_log2, CommReport};
use crate::netsim::cost_model::LinkParams;

/// In-place SUM tree-allreduce. After the call every buffer holds the sum.
pub fn tree_allreduce(bufs: &mut [Vec<f32>], link: LinkParams) -> CommReport {
    let n = bufs.len();
    assert!(n >= 1);
    let m = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == m), "buffer length mismatch");
    let mut report = CommReport::default();
    if n == 1 || m == 0 {
        return report;
    }
    let bytes = 4.0 * m as f64;
    let rounds = ceil_log2(n);

    // Reduce phase: at round d, ranks with bit d set send to (rank - 2^d).
    for d in 0..rounds {
        let step = 1usize << d;
        let mut any = false;
        for w in (0..n).rev() {
            if w & step != 0 && w & (step - 1) == 0 {
                let dst = w - step;
                let (lo, hi) = bufs.split_at_mut(w);
                for (dv, sv) in lo[dst].iter_mut().zip(&hi[0]) {
                    *dv += sv;
                }
                any = true;
            }
        }
        if any {
            report.add_round(link, bytes);
        }
    }

    // Broadcast phase: mirror of the reduce (highest bit first).
    for d in (0..rounds).rev() {
        let step = 1usize << d;
        let mut any = false;
        for w in 0..n {
            if w & step != 0 && w & (step - 1) == 0 {
                let src = w - step;
                let (lo, hi) = bufs.split_at_mut(w);
                hi[0].copy_from_slice(&lo[src]);
                any = true;
            }
        }
        if any {
            report.add_round(link, bytes);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;
    use crate::util::proptest::{all_close, check};

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(1.0, 10.0)
    }

    #[test]
    fn sums_exactly_pow2() {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|w| vec![w as f32 + 1.0; 3]).collect();
        tree_allreduce(&mut bufs, link());
        for b in &bufs {
            assert_eq!(b, &vec![10.0, 10.0, 10.0]);
        }
    }

    #[test]
    fn time_matches_closed_form_pow2() {
        for n in [2usize, 4, 8, 16] {
            let m = 1024;
            let mut bufs = vec![vec![1.0f32; m]; n];
            let r = tree_allreduce(&mut bufs, link());
            let want = cost_model::tree_allreduce(link(), 4.0 * m as f64, n);
            assert!(
                (r.seconds - want).abs() / want < 1e-9,
                "n={n}: sim {} vs model {}",
                r.seconds,
                want
            );
        }
    }

    #[test]
    fn property_sum_any_n() {
        check("tree allreduce sums", 60, |g| {
            let n = g.usize_in(1, 13);
            let m = g.usize_in(1, 128);
            let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(m, 1.0)).collect();
            let mut want = vec![0.0f32; m];
            for b in &bufs {
                for (w, v) in want.iter_mut().zip(b) {
                    *w += v;
                }
            }
            let mut got = bufs;
            tree_allreduce(&mut got, link());
            for (w, b) in got.iter().enumerate() {
                all_close(b, &want, 1e-4).map_err(|e| format!("worker {w}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn tree_beats_ring_on_high_latency() {
        // The paper's motivation for ART-Tree: fewer latency-bearing rounds.
        let slow = LinkParams::from_ms_gbps(100.0, 10.0);
        let m = 1000;
        let mut a = vec![vec![1.0f32; m]; 8];
        let mut b = vec![vec![1.0f32; m]; 8];
        let tr = tree_allreduce(&mut a, slow);
        let rr = crate::collectives::ring_allreduce(&mut b, slow);
        assert!(tr.seconds < rr.seconds);
        assert!(tr.rounds < rr.rounds);
    }
}
