//! Table VI: communication cost of AG vs ART-Ring vs ART-Tree for the
//! paper's four models, CRs {0.1, 0.01, 0.001}, α=1ms, 1/β in {10,5,1}
//! Gbps, N=8 — the decision table behind the Eqn 5 selector.
//!
//! Costs are VALIDATED two ways: the closed form (Eqn 4 / §3-D), and the
//! actual collective implementations run on proportionally-sized tensors
//! with the simulated link — they must agree (and do; the ✓ column).
//!
//!     cargo bench --bench table6_collective_cost

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::{allgather_sparse, hierarchical_allreduce};
use flexcomm::compress::{Compressor, EfState, TopK};
use flexcomm::experiments::{self, PAPER_MODELS};
use flexcomm::netsim::cost_model::{self, LinkParams};
use flexcomm::tensor::Layout;
use flexcomm::util::rng::Rng;
use flexcomm::util::table::Table;

/// Run the real AR-Topk/AG exchanges at a scaled-down tensor and check the
/// simulated seconds match the closed form scaled back up.
fn validate(l: LinkParams, params: f64, n: usize, cr: f64) -> bool {
    let sim_dim = 200_000.min(params as usize);
    let scale = params / sim_dim as f64;
    let ls = LinkParams { alpha: l.alpha, beta: l.beta * scale };
    let mut rng = Rng::new(9);
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; sim_dim];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let m = 4.0 * params;

    // ART-Ring through the real Alg 1 implementation.
    let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(sim_dim)).collect();
    let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
    let got = art.exchange(&grads, &mut ef, cr, 0, ls).comm.seconds;
    let want = cost_model::art_ring(l, m, n, cr);
    let ok_ring = (got - want).abs() / want < 0.02;

    // AG through the real sparse allgather.
    let layout = Layout::single(sim_dim);
    let mut tk = TopK::with_quickselect();
    let parts: Vec<_> = grads.iter().map(|g| tk.compress(g, cr, &layout)).collect();
    let (_, rep) = allgather_sparse(&parts, sim_dim, ls);
    let want_ag = cost_model::ag_topk(l, m, n, cr);
    // Exact k vs ceil variance: tolerance 2%.
    let ok_ag = (rep.seconds - want_ag).abs() / want_ag < 0.02;
    ok_ring && ok_ag
}

fn main() {
    let n = 8;
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    println!("Table VI — communication cost (ms), α=1ms, N=8\n");
    let mut t = Table::new([
        "Model", "(α,1/β)", "CR", "AG", "ART-Ring", "ART-Tree", "chosen", "sim✓",
    ]);
    for (model, params) in PAPER_MODELS {
        let m = 4.0 * params;
        for bw in [10.0, 5.0, 1.0] {
            let l = LinkParams::from_ms_gbps(1.0, bw);
            for cr in [0.1, 0.01, 0.001] {
                let ag = cost_model::ag_topk(l, m, n, cr) * 1e3;
                let ring = cost_model::art_ring(l, m, n, cr) * 1e3;
                let tree = cost_model::art_tree(l, m, n, cr) * 1e3;
                let chosen = cost_model::optimal_collective(l, m, n, cr).name();
                let check = if fast && cr != 0.1 {
                    "-".to_string() // fast mode validates one CR per cell
                } else if validate(l, params, n, cr) {
                    "✓".to_string()
                } else {
                    "MISMATCH".to_string()
                };
                t.row([
                    model.to_string(),
                    format!("(1,{bw:.0})"),
                    format!("{cr}"),
                    format!("{ag:.2}"),
                    format!("{ring:.2}"),
                    format!("{tree:.2}"),
                    chosen.to_string(),
                    check,
                ]);
            }
        }
    }
    t.print();
    println!(
        "\nPaper anchors: ResNet18 (1,10): AG0.1=54 Ring=35 Tree=43.2; \
         AG0.001=3.28 Ring=16.7 Tree=9. ViT (1,1): AG0.01=601.8 Ring=222.8 \
         Tree=385.2.\nShape: ART-Ring wins at CR 0.1 / low bandwidth / big \
         models; AG wins at tiny CRs with decent bandwidth."
    );

    // Dense crossover per topology: the decision the Eqn 5 selector cannot
    // see on a flat model — validated against the real hierarchical op.
    println!("\nDense AR cost (ms) per topology — N=8, inter=(10ms, 1Gbps)");
    let mut td =
        Table::new(["Model", "Topology", "Ring-AR", "Tree-AR", "HD-AR", "Hier-AR", "chosen", "sim✓"]);
    let inter = LinkParams::from_ms_gbps(10.0, 1.0);
    let presets = experiments::topology_presets(inter);
    for (model, params) in PAPER_MODELS {
        let m = 4.0 * params;
        for row in experiments::dense_crossover_rows(&presets, m, n) {
            let topo = presets.iter().find(|(pn, _)| *pn == row.topology).unwrap().1;
            let check = match row.hier_ms {
                None => "-".to_string(),
                Some(want_ms) => {
                    // Run the real two-level op on a scaled tensor and
                    // compare against the closed form (see `validate`).
                    let sim_dim = 100_000;
                    let scale = params / sim_dim as f64;
                    let ts = topo.scale_beta(scale);
                    let mut bufs = vec![vec![1.0f32; sim_dim]; n];
                    let got = hierarchical_allreduce(&mut bufs, ts).seconds * 1e3;
                    if (got - want_ms).abs() / want_ms < 0.02 {
                        "✓".to_string()
                    } else {
                        "MISMATCH".to_string()
                    }
                }
            };
            td.row([
                model.to_string(),
                row.topology,
                format!("{:.1}", row.ring_ms),
                format!("{:.1}", row.tree_ms),
                format!("{:.1}", row.hd_ms),
                row.hier_ms.map(|h| format!("{h:.1}")).unwrap_or_else(|| "-".into()),
                row.chosen.to_string(),
                check,
            ]);
        }
    }
    td.print();

    // The Eqn 5 AG-vs-AR pick across bottleneck-link qualities: compressed
    // exchanges ride the inter link only, so their crossover moves with it
    // (not with the intra layout) — swept here instead of per-preset.
    println!("\nEqn 5 pick per bottleneck link — ResNet50, N=8");
    let links = [
        ("lan (1ms, 10G)", LinkParams::from_ms_gbps(1.0, 10.0)),
        ("metro (10ms, 5G)", LinkParams::from_ms_gbps(10.0, 5.0)),
        ("wan (50ms, 1G)", LinkParams::from_ms_gbps(50.0, 1.0)),
    ];
    let mut tc = Table::new(["Bottleneck", "CR", "chosen"]);
    for (name, cr, chosen) in
        experiments::compressed_crossover(&links, 4.0 * 25.6e6, n, &[0.1, 0.01, 0.001])
    {
        tc.row([name, format!("{cr}"), chosen.to_string()]);
    }
    tc.print();
    println!(
        "Shape: two-level layouts flip the dense optimum to Hier-AR; the \
         compressed AG/ART pick is a function of the bottleneck link alone \
         and flips ring->tree as latency grows."
    );
}
