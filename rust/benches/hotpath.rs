//! Perf-pass micro-benches for the L3 hot paths (EXPERIMENTS.md §Perf):
//! Top-k selection (heap vs quickselect), MSTopk threshold rounds, ring
//! allreduce arithmetic, sparse allgather scatter, EF bookkeeping, and the
//! threaded worker engine (grad+compress stage, threads=1 vs N — the
//! ISSUE 2 acceptance bench; also run in smoke mode by scripts/verify.sh,
//! which hard-fails if the parallel stage is not bitwise-identical to the
//! serial one).
//!
//!     cargo bench --bench hotpath
//!     FLEXCOMM_BENCH_FAST=1 cargo bench --bench hotpath   (CI smoke mode)

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::ring_allreduce;
use flexcomm::compress::topk::{topk_indices, topk_indices_select};
use flexcomm::compress::{Compressor, EfState, MsTopk, SparseGrad, TopK};
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::tensor::Layout;
use flexcomm::util::bench::Bencher;
use flexcomm::util::pool::ThreadPool;
use flexcomm::util::rng::Rng;

/// Reference implementation of the PRE-persistent-pool execution engine:
/// spawn a fresh scoped thread per worker per region, exactly the chunking
/// the persistent pool uses (`workers = threads.min(n)`, contiguous ceil
/// chunks, results by item index). Kept here, bench-local, so the
/// spawn-vs-park stage measures the real historical alternative and the
/// bitwise assert pins the persistent pool to the same outputs.
fn scoped_map<R, F>(threads: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let workers = threads.min(n).max(1);
    let chunk = (n + workers - 1) / workers;
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (w, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, slot) in slice.iter_mut().enumerate() {
                    *slot = Some(f(w * chunk + j));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("every slot filled")).collect()
}

fn main() {
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    let dim: usize = if fast { 200_000 } else { 4_000_000 };
    let mut rng = Rng::new(0);
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);
    let k = dim / 100;
    let mut b = Bencher::from_env();

    // Top-k selection: the paper's max-heap vs quickselect.
    b.bench(&format!("topk heap        G={dim} k={k}"), || {
        Bencher::black_box(topk_indices(&g, k));
    });
    b.bench(&format!("topk quickselect G={dim} k={k}"), || {
        Bencher::black_box(topk_indices_select(&g, k));
    });

    // MSTopk threshold rounds.
    for rounds in [10u32, 25] {
        let mut ms = MsTopk::new(rounds);
        b.bench(&format!("mstopk rounds={rounds} G={dim}"), || {
            Bencher::black_box(ms.compress(&g, 0.01, &Layout::single(dim)));
        });
    }

    // Ring allreduce arithmetic (data path, 8 workers).
    let n = 8;
    let bufs: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let link = LinkParams::from_ms_gbps(1.0, 10.0);
    b.bench(&format!("ring_allreduce data n={n} m={}", dim / 4), || {
        let mut bb = bufs.clone();
        Bencher::black_box(ring_allreduce(&mut bb, link));
    });

    // Full AR-Topk exchange (compress + residuals + reduce).
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(100 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
    b.bench(&format!("artopk exchange n={n} G={} cr=0.01", dim / 4), || {
        let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(dim / 4)).collect();
        Bencher::black_box(art.exchange(&grads, &mut ef, 0.01, 0, link));
    });

    // EF bookkeeping alone.
    let mut ef = EfState::new(dim);
    let sparse = flexcomm::compress::SparseGrad {
        indices: (0..k as u32).collect(),
        values: vec![1.0; k],
        dense_len: dim,
    };
    b.bench(&format!("error-feedback update G={dim}"), || {
        let ge = ef.error_fed(&g);
        ef.update(Bencher::black_box(ge), &sparse);
    });

    // ------------------------------------------------------------------
    // Threaded worker engine: the grad+compress stage of a 4-worker step
    // (per worker: O(G) gradient transform + error-feed + top-k select),
    // threads=1 vs all cores. ISSUE 2 acceptance: >=1.5x on a >=4-core
    // host. The outputs must be bitwise identical — that part is a hard
    // check, valid on any core count.
    // ------------------------------------------------------------------
    let nw = 4;
    let wdim = dim / 4;
    let wk = wdim / 100;
    let base: Vec<Vec<f32>> = (0..nw)
        .map(|i| {
            let mut v = vec![0.0; wdim];
            Rng::new(1000 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let residual = vec![0.01f32; wdim];
    let stage = |pool: &ThreadPool| -> Vec<Vec<u32>> {
        pool.map(nw, |w| {
            // "grad": a deterministic O(G) per-worker transform standing in
            // for backprop, then the AG-path compress (EF + selection).
            let g_w: Vec<f32> = base[w].iter().map(|&v| v * 1.000123 + 0.1).collect();
            let g_e: Vec<f32> = g_w.iter().zip(&residual).map(|(a, r)| a + r).collect();
            topk_indices_select(&g_e, wk)
        })
    };
    let serial = ThreadPool::serial();
    let threaded = ThreadPool::auto(0);
    assert_eq!(
        stage(&serial),
        stage(&threaded),
        "threaded grad+compress stage must be bitwise-identical to serial"
    );
    let m1 = b.bench(&format!("grad+compress stage n={nw} threads=1"), || {
        Bencher::black_box(stage(&serial));
    });
    let mn = b.bench(
        &format!("grad+compress stage n={nw} threads={}", threaded.threads()),
        || {
            Bencher::black_box(stage(&threaded));
        },
    );
    let speedup = m1.mean_secs() / mn.mean_secs();
    println!(
        "grad+compress stage speedup: {speedup:.2}x with {} threads on {} cores \
         (target >=1.5x on >=4 cores)",
        threaded.threads(),
        ThreadPool::available()
    );

    // Pooled AR-Topk (VAR computes every worker's top-k, so it parallelizes).
    let mut art_var =
        ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring).with_pool(threaded.clone());
    b.bench(&format!("artopk VAR exchange n={nw} threads={}", threaded.threads()), || {
        let mut ef: Vec<EfState> = (0..nw).map(|_| EfState::new(wdim)).collect();
        Bencher::black_box(art_var.exchange(&base, &mut ef, 0.01, 0, link));
    });

    // ------------------------------------------------------------------
    // Spawn-vs-park (ISSUE 6 tentpole): many TINY regions, where thread
    // spawn/join cost dominates the old per-region scoped engine. The
    // persistent pool parks its workers between regions, so the per-region
    // cost is one condvar wake instead of `threads` spawns + joins.
    // Outputs are pinned bitwise against both the scoped reference and a
    // serial run; the >=1.5x speedup is a soft assert (unmeasurable on
    // single-core hosts, where the persistent pool runs regions inline).
    // ------------------------------------------------------------------
    let regions = if fast { 50 } else { 400 };
    let tiny = &base; // nw small per-worker slices, reused as tiny tasks
    let tiny_work = |w: usize| -> f32 {
        let s: f32 = tiny[w].iter().take(512).sum();
        s * 1.000123
    };
    let park_run = |pool: &ThreadPool| -> Vec<f32> {
        let mut acc = vec![0.0f32; nw];
        for _ in 0..regions {
            let r = pool.map(nw, tiny_work);
            for (a, v) in acc.iter_mut().zip(&r) {
                *a += v;
            }
        }
        acc
    };
    let spawn_run = || -> Vec<f32> {
        let mut acc = vec![0.0f32; nw];
        for _ in 0..regions {
            let r = scoped_map(threaded.threads(), nw, tiny_work);
            for (a, v) in acc.iter_mut().zip(&r) {
                *a += v;
            }
        }
        acc
    };
    let park_out = park_run(&threaded);
    assert_eq!(
        park_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        spawn_run().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "persistent pool must be bitwise-identical to the scoped-spawn engine"
    );
    assert_eq!(
        park_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        park_run(&serial).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "persistent pool must be bitwise-identical to a serial run"
    );
    let m_spawn = b.bench(&format!("spawn-per-region {regions} tiny regions"), || {
        Bencher::black_box(spawn_run());
    });
    let m_park = b.bench(&format!("parked-pool      {regions} tiny regions"), || {
        Bencher::black_box(park_run(&threaded));
    });
    let park_speedup = m_spawn.mean_secs() / m_park.mean_secs();
    if park_speedup >= 1.5 {
        println!("spawn-vs-park speedup: {park_speedup:.2}x (target >=1.5x: OK)");
    } else {
        println!(
            "WARNING: spawn-vs-park speedup {park_speedup:.2}x below the 1.5x target \
             on this host ({} cores) — soft assert, bitwise equality held",
            ThreadPool::available()
        );
    }

    // ------------------------------------------------------------------
    // Fresh-vs-arena: one AG-path compress step (error-feed + top-k select
    // + residual update), allocating fresh buffers each step vs reusing
    // the per-worker arenas (`error_fed_into` / `compress_into` /
    // `update_swap`). The two cycles are pinned bitwise over several
    // steps before timing; steady-state allocation is what differs.
    // ------------------------------------------------------------------
    let layout = Layout::single(wdim);
    let cr = 0.01;
    {
        // Bitwise pin: run both cycles side by side for 5 steps.
        let mut ef_fresh = EfState::new(wdim);
        let mut ef_arena = EfState::new(wdim);
        let mut c_fresh = TopK::with_quickselect();
        let mut c_arena = TopK::with_quickselect();
        let mut g_e = Vec::new();
        let mut part = SparseGrad::default();
        for step in 0..5 {
            let g_s = &base[step % nw];
            let ge_fresh = ef_fresh.error_fed(g_s);
            let sp = c_fresh.compress(&ge_fresh, cr, &layout);
            ef_fresh.update(ge_fresh, &sp);
            ef_arena.error_fed_into(g_s, &mut g_e);
            c_arena.compress_into(&g_e, cr, &layout, &mut part);
            ef_arena.update_swap(&mut g_e, &part);
            assert_eq!(sp.indices, part.indices, "step {step}: arena indices");
            assert_eq!(
                sp.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                part.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {step}: arena values"
            );
            assert_eq!(
                ef_fresh.residual.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ef_arena.residual.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {step}: arena residual"
            );
        }
    }
    let mut ef_fresh = EfState::new(wdim);
    let mut c_fresh = TopK::with_quickselect();
    let m_fresh = b.bench(&format!("compress step fresh-alloc G={wdim}"), || {
        let ge = ef_fresh.error_fed(&base[0]);
        let sp = c_fresh.compress(&ge, cr, &layout);
        ef_fresh.update(Bencher::black_box(ge), &sp);
    });
    let mut ef_arena = EfState::new(wdim);
    let mut c_arena = TopK::with_quickselect();
    let mut g_e = Vec::new();
    let mut part = SparseGrad::default();
    let m_arena = b.bench(&format!("compress step arena-reuse G={wdim}"), || {
        ef_arena.error_fed_into(&base[0], &mut g_e);
        c_arena.compress_into(&g_e, cr, &layout, &mut part);
        ef_arena.update_swap(&mut g_e, Bencher::black_box(&part));
    });
    println!(
        "fresh-vs-arena compress step: {:.2}x (allocation savings; informational)",
        m_fresh.mean_secs() / m_arena.mean_secs()
    );

    // Machine-readable record for the regression harness: verify.sh fails
    // if this file is missing after the smoke-mode bench stage.
    let json_path = std::path::Path::new("BENCH_hotpath.json");
    b.write_json("hotpath", json_path).expect("write BENCH_hotpath.json");
    println!(
        "\n{} measurements recorded (see EXPERIMENTS.md §Perf); wrote {}.",
        b.results.len(),
        json_path.display()
    );
}
