//! Communication collectives over in-process worker buffers.
//!
//! Every op REALLY moves/reduces the data (numerics are exact, not mocked)
//! and returns the wall-time a cluster of N single-GPU nodes on the
//! simulated link would have spent, derived from the op's round structure:
//! each round costs `α + bytes_sent_per_worker · β`, charged against the
//! link that round actually crosses (the two-level
//! [`hierarchical_allreduce`] mixes intra- and inter-node rounds). For
//! power-of-two N the totals equal the closed forms in
//! [`crate::netsim::cost_model`] — that equivalence is what the unit tests
//! pin down (the paper validates the same algebra on hardware in Tables
//! II/VI). Round structures per op are documented in DESIGN.md §4.

pub mod allgather;
pub mod broadcast;
pub mod halving_doubling;
pub mod hierarchical;
pub mod ps;
pub mod ring_allreduce;
pub mod tree_allreduce;

pub use allgather::{allgather_concat, allgather_sparse};
pub use broadcast::broadcast;
pub use halving_doubling::halving_doubling_allreduce;
pub use hierarchical::hierarchical_allreduce;
pub use ps::ps_exchange;
pub use ring_allreduce::ring_allreduce;
pub use tree_allreduce::tree_allreduce;

use crate::netsim::cost_model::LinkParams;

/// Simulated time + traffic accounting for one collective call.
///
/// Accumulated round by round (crate-internal `add_round`): each
/// latency-bearing round contributes `α + bytes·β` simulated seconds on the
/// link it crosses, `bytes` to the per-worker egress, and one to `rounds`.
/// Reports from sub-phases that run on different links (e.g. the
/// hierarchical op's intra-reduce and inter-ring) compose with
/// [`CommReport::merge`] — seconds and rounds add, so the totals stay
/// comparable with the closed-form α-β costs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommReport {
    /// Simulated wall-clock seconds for the whole op.
    pub seconds: f64,
    /// Total bytes a single worker put on the wire (per-worker egress; for
    /// ops whose per-round sends are uneven this is the max-loaded worker,
    /// the one the synchronous step waits for).
    pub bytes_per_worker: f64,
    /// Number of latency-bearing rounds (α charges).
    pub rounds: u32,
}

impl CommReport {
    pub(crate) fn add_round(&mut self, link: LinkParams, bytes: f64) {
        self.seconds += link.alpha + bytes * link.beta;
        self.bytes_per_worker += bytes;
        self.rounds += 1;
    }

    pub fn merge(&mut self, other: CommReport) {
        self.seconds += other.seconds;
        self.bytes_per_worker += other.bytes_per_worker;
        self.rounds += other.rounds;
    }
}

/// Which collective a training step used (for the Fig 8 density plots and
/// the metrics log).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    RingAllreduce,
    TreeAllreduce,
    /// Recursive halving-doubling (Rabenseifner) dense allreduce.
    HalvingDoublingAllreduce,
    /// Two-level intra-reduce / inter-ring / intra-broadcast allreduce.
    HierarchicalAllreduce,
    AllgatherTopk,
    ArTopkRing,
    ArTopkTree,
    PsStar,
}

impl CollectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::RingAllreduce => "Ring-AR",
            CollectiveKind::TreeAllreduce => "Tree-AR",
            CollectiveKind::HalvingDoublingAllreduce => "HD-AR",
            CollectiveKind::HierarchicalAllreduce => "Hier-AR",
            CollectiveKind::AllgatherTopk => "AG",
            CollectiveKind::ArTopkRing => "ART-Ring",
            CollectiveKind::ArTopkTree => "ART-Tree",
            CollectiveKind::PsStar => "PS",
        }
    }
}

pub(crate) fn ceil_log2(n: usize) -> u32 {
    assert!(n >= 1);
    usize::BITS - (n - 1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::{self, Topology};

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn report_accumulates() {
        let l = LinkParams::from_ms_gbps(1.0, 8.0); // beta = 1e-9 s/B
        let mut r = CommReport::default();
        r.add_round(l, 1e6);
        assert!((r.seconds - (1e-3 + 1e-3)).abs() < 1e-12);
        assert_eq!(r.rounds, 1);
        let mut r2 = CommReport::default();
        r2.add_round(l, 2e6);
        r.merge(r2);
        assert_eq!(r.rounds, 2);
        assert!((r.bytes_per_worker - 3e6).abs() < 1e-6);
    }

    #[test]
    fn merge_spans_links() {
        // Rounds on different links keep their own α/β — the hierarchical
        // op's accounting depends on this.
        let fast = LinkParams::from_ms_gbps(0.01, 100.0);
        let slow = LinkParams::from_ms_gbps(10.0, 1.0);
        let mut r = CommReport::default();
        r.add_round(fast, 1e6);
        let mut s = CommReport::default();
        s.add_round(slow, 1e6);
        r.merge(s);
        let want = (0.01e-3 + 1e6 * 8.0 / 100e9) + (10e-3 + 1e6 * 8e-9);
        assert!((r.seconds - want).abs() < 1e-12);
        assert_eq!(r.rounds, 2);
    }

    /// Round counts of every allreduce against the closed-form α-terms,
    /// pinned for power-of-two and non-power-of-two N.
    #[test]
    fn round_counts_match_closed_forms() {
        let l = LinkParams::from_ms_gbps(1.0, 10.0);
        for n in [2usize, 4, 7, 8, 12, 16] {
            let m = 16 * 15; // divisible by every participant count used
            let mk = || vec![vec![1.0f32; m]; n];
            let ring = ring_allreduce(&mut mk(), l);
            assert_eq!(ring.rounds, 2 * (n as u32 - 1), "ring n={n}");
            let tree = tree_allreduce(&mut mk(), l);
            assert_eq!(tree.rounds, 2 * ceil_log2(n), "tree n={n}");
            let hd = halving_doubling_allreduce(&mut mk(), l);
            let np = cost_model::prev_pow2(n) as u32;
            let fold = if np as usize == n { 0 } else { 2 };
            assert_eq!(hd.rounds, 2 * np.trailing_zeros() + fold, "hd n={n}");
        }
    }

    #[test]
    fn hierarchical_round_counts_pow2_and_not() {
        let topo = |w| {
            Topology::two_level(
                LinkParams::from_ms_gbps(0.01, 100.0),
                LinkParams::from_ms_gbps(5.0, 2.0),
                w,
            )
        };
        // (w, nodes): power-of-two and non-power-of-two node groups.
        for (w, nodes) in [(4usize, 2usize), (2, 3), (3, 2), (1, 4)] {
            let n = w * nodes;
            let mut bufs = vec![vec![1.0f32; 60]; n];
            let r = hierarchical_allreduce(&mut bufs, topo(w));
            let want = if w == 1 {
                2 * (nodes as u32 - 1) // degenerate flat ring
            } else {
                2 * ceil_log2(w) + 2 * (nodes as u32 - 1)
            };
            assert_eq!(r.rounds, want, "w={w} nodes={nodes}");
        }
    }
}
