//! Worker abstractions: the gradient source interface every model backend
//! implements, and the per-worker compute-time model.

use crate::tensor::Layout;
use crate::util::rng::Rng;

/// A model backend that produces per-worker gradients.
///
/// Implementations: [`crate::runtime::host_model::HostMlp`] (pure-rust
/// backprop, fast simulator-only experiments),
/// [`crate::runtime::host_model::SyntheticGrad`] (cost-only experiments at
/// paper-scale tensor sizes), and [`crate::runtime::pjrt_model::PjrtModel`]
/// (the real L2 artifact executed via PJRT — the production path).
///
/// `Send + Sync` and a `&self` [`GradSource::grad`] so the trainer's
/// execution engine can compute the N per-worker gradients concurrently
/// (DESIGN.md §7). `grad` must be a pure function of
/// `(params, worker, n_workers, step)` — that purity is also what makes
/// whole runs replay bit-identically from a seed.
pub trait GradSource: Send + Sync {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;

    /// Layer layout (for LWTopk and bucketing).
    fn layout(&self) -> &Layout;

    /// Initial parameter vector.
    fn init_params(&mut self) -> Vec<f32>;

    /// Compute (loss, gradient) for `worker`'s shard at `step`. Called
    /// concurrently from worker threads — `&self`, deterministic.
    fn grad(
        &self,
        params: &[f32],
        worker: usize,
        n_workers: usize,
        step: u64,
    ) -> (f64, Vec<f32>);

    /// Held-out evaluation: (loss, top-1 accuracy in [0,1]).
    fn eval(&mut self, params: &[f32]) -> (f64, f64);

    /// Short descriptor for logs.
    fn name(&self) -> String;
}

/// Per-step compute-time model for the simulated cluster.
///
/// The paper's `t_compute` is a property of the model/GPU (Fig 1a); the
/// simulated workers draw `base · (1 + jitter)` with an optional straggler
/// tail — the synchronous step waits for the max.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Mean per-step forward+backward seconds.
    pub base: f64,
    /// Uniform jitter fraction (±).
    pub jitter: f64,
    /// Probability a worker straggles this step.
    pub straggler_prob: f64,
    /// Multiplier applied to a straggler's compute time.
    pub straggler_slowdown: f64,
}

impl ComputeModel {
    pub fn fixed(base: f64) -> Self {
        ComputeModel { base, jitter: 0.0, straggler_prob: 0.0, straggler_slowdown: 1.0 }
    }

    pub fn with_jitter(base: f64, jitter: f64) -> Self {
        ComputeModel { base, jitter, straggler_prob: 0.0, straggler_slowdown: 1.0 }
    }

    /// Synchronous-step compute time: max over the N workers' draws.
    pub fn step_time(&self, n_workers: usize, rng: &mut Rng) -> f64 {
        self.step_time_stragglers(n_workers, rng, |_| 1.0)
    }

    /// [`ComputeModel::step_time`] with an environment-supplied per-worker
    /// slowdown factor (the [`NetworkModel::straggler_factor`](crate::netsim::model::NetworkModel::straggler_factor)
    /// hook): each worker's draw is multiplied by `factor(worker)` before
    /// the synchronous max. `factor = |_| 1.0` reproduces `step_time`
    /// bitwise — the draw order is identical and `t * 1.0 == t` exactly.
    pub fn step_time_stragglers(
        &self,
        n_workers: usize,
        rng: &mut Rng,
        factor: impl Fn(usize) -> f64,
    ) -> f64 {
        let mut worst: f64 = 0.0;
        for w in 0..n_workers.max(1) {
            let mut t = self.base * (1.0 + self.jitter * (2.0 * rng.f64() - 1.0));
            if self.straggler_prob > 0.0 && rng.f64() < self.straggler_prob {
                t *= self.straggler_slowdown;
            }
            worst = worst.max(t * factor(w));
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_model_is_exact() {
        let mut rng = Rng::new(0);
        let m = ComputeModel::fixed(0.03);
        for _ in 0..10 {
            assert_eq!(m.step_time(8, &mut rng), 0.03);
        }
    }

    #[test]
    fn jitter_bounded_and_max_grows_with_n() {
        let mut rng = Rng::new(1);
        let m = ComputeModel::with_jitter(0.1, 0.2);
        let mut one = 0.0;
        let mut eight = 0.0;
        for _ in 0..200 {
            one += m.step_time(1, &mut rng);
            eight += m.step_time(8, &mut rng);
        }
        assert!(eight > one, "max over 8 draws must exceed single draw on average");
        for _ in 0..100 {
            let t = m.step_time(4, &mut rng);
            assert!(t >= 0.08 - 1e-12 && t <= 0.12 + 1e-12);
        }
    }

    #[test]
    fn straggler_factors_scale_the_critical_path() {
        // Unit factors reproduce step_time bitwise from the same stream...
        let m = ComputeModel { base: 0.01, jitter: 0.3, straggler_prob: 0.2, straggler_slowdown: 4.0 };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..100 {
            let plain = m.step_time(8, &mut a);
            let unit = m.step_time_stragglers(8, &mut b, |_| 1.0);
            assert_eq!(plain.to_bits(), unit.to_bits());
        }
        // ...and a single slow worker dominates the synchronous max.
        let fixed = ComputeModel::fixed(0.01);
        let mut rng = Rng::new(3);
        let t = fixed.step_time_stragglers(8, &mut rng, |w| if w == 5 { 7.0 } else { 1.0 });
        assert!((t - 0.07).abs() < 1e-15, "critical path {t}");
    }

    #[test]
    fn stragglers_create_a_tail() {
        let mut rng = Rng::new(2);
        let m = ComputeModel {
            base: 0.01,
            jitter: 0.0,
            straggler_prob: 0.1,
            straggler_slowdown: 10.0,
        };
        let times: Vec<f64> = (0..300).map(|_| m.step_time(8, &mut rng)).collect();
        let slow = times.iter().filter(|&&t| t > 0.05).count();
        assert!(slow > 100, "with 8 workers at p=0.1, most steps hit a straggler: {slow}");
        assert!(slow < 300);
    }
}
