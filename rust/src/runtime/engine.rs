//! PJRT execution engine: loads AOT-lowered HLO *text* artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them on the CPU PJRT client. Python never runs here.
//!
//! Interchange is HLO text, not serialized protos: jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};

/// Wrapper around the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact.
    pub fn load(&self, path: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))?;
        Ok(Executable { exe, name: path.to_string() })
    }
}

/// One compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements
    /// (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple().context("decomposing result tuple")
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// 1-D f32 literal.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// 2-D i32 literal (row-major `rows x cols`).
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(v.len() == rows * cols, "shape mismatch");
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// 2-D f32 literal (row-major `rows x cols`).
pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(v.len() == rows * cols, "shape mismatch");
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a Vec<f32> from a literal.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_scalar_f32(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}
