//! Flat parameter/gradient tensors with a named-layer layout.
//!
//! The L2 artifacts expose the model as ONE flat f32 vector plus a layout
//! manifest (`artifacts/<model>_layout.txt`: `name offset size` per tensor).
//! The flat view is what fused AR-Topk compresses; the layout gives LWTopk
//! its layer boundaries and the coordinator its bucketing.

use anyhow::{bail, Context, Result};

pub mod kernels;

/// One named parameter tensor inside the flat vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerInfo {
    pub name: String,
    pub offset: usize,
    pub size: usize,
}

/// Ordered layer table covering `[0, total)` contiguously.
#[derive(Debug, Clone, Default)]
pub struct Layout {
    pub layers: Vec<LayerInfo>,
}

impl Layout {
    /// Parse the `name offset size` rows written by `python/compile/aot.py`.
    pub fn parse(text: &str) -> Result<Layout> {
        let mut layers = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let (name, off, size) = (it.next(), it.next(), it.next());
            match (name, off, size) {
                (Some(n), Some(o), Some(s)) => layers.push(LayerInfo {
                    name: n.to_string(),
                    offset: o.parse().with_context(|| format!("line {}", i + 1))?,
                    size: s.parse().with_context(|| format!("line {}", i + 1))?,
                }),
                _ => bail!("layout line {}: expected `name offset size`", i + 1),
            }
        }
        let l = Layout { layers };
        l.validate()?;
        Ok(l)
    }

    pub fn load(path: &str) -> Result<Layout> {
        Layout::parse(&std::fs::read_to_string(path).with_context(|| path.to_string())?)
    }

    /// Build a synthetic layout from (name, size) pairs.
    pub fn from_sizes(sizes: &[(&str, usize)]) -> Layout {
        let mut layers = Vec::new();
        let mut off = 0;
        for (name, size) in sizes {
            layers.push(LayerInfo { name: name.to_string(), offset: off, size: *size });
            off += size;
        }
        Layout { layers }
    }

    /// A single-layer layout (for cost-model experiments where only the
    /// total size matters).
    pub fn single(total: usize) -> Layout {
        Layout::from_sizes(&[("all", total)])
    }

    fn validate(&self) -> Result<()> {
        let mut off = 0;
        for l in &self.layers {
            if l.offset != off {
                bail!("layer `{}` offset {} != expected {}", l.name, l.offset, off);
            }
            if l.size == 0 {
                bail!("layer `{}` has zero size", l.name);
            }
            off += l.size;
        }
        Ok(())
    }

    pub fn total(&self) -> usize {
        self.layers.last().map(|l| l.offset + l.size).unwrap_or(0)
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Flat f32 parameter/gradient vector.
pub type ParamVec = Vec<f32>;

/// y += a * x — delegates to the chunked kernel (bitwise-equal to the
/// scalar loop; see `tensor::kernels`).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    kernels::axpy(y, a, x);
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    kernels::scale(x, a);
}

/// Sum of squares (f64 accumulation — gradient norms get large). Uses the
/// crate's lane-split reduction policy (`kernels::sq_norm_lanes`): the
/// result is a pure function of the input, not of chunking or threads.
pub fn sq_norm(x: &[f32]) -> f64 {
    kernels::sq_norm_lanes(x)
}

/// The crate-wide NaN ordering policy: a total order on `f64` treating NaN
/// as the SMALLEST value, so a NaN (exploding-loss) quantity can never win
/// a max-selection and sorts never panic. Used by VAR worker selection,
/// the Top-k comparators and eval argmax — one policy, one place.
pub fn nan_min_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        // flexlint::allow(nan-partial-cmp): this IS the total-order implementation — both sides proven non-NaN
        (false, false) => a.partial_cmp(&b).expect("non-NaN values compare"),
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
    }
}

/// [`nan_min_cmp`] for `f32` (f32→f64 is lossless and order/NaN
/// preserving, so this is the same policy, not a second copy).
pub fn nan_min_cmp_f32(a: f32, b: f32) -> std::cmp::Ordering {
    nan_min_cmp(a as f64, b as f64)
}

/// Dot product under the same lane-split policy as [`sq_norm`].
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    kernels::dot_lanes(a, b)
}

/// Elementwise add into a fresh vector.
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    kernels::add_into(a, b, &mut out);
    out
}

/// Load a little-endian f32 binary file (e.g. `artifacts/<m>_init.f32`).
pub fn load_f32_file(path: &str) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| path.to_string())?;
    if bytes.len() % 4 != 0 {
        bail!("{path}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_layout_roundtrip() {
        let text = "tok_embed 0 1000\nblock0.qkv 1000 300\nhead 1300 64\n";
        let l = Layout::parse(text).unwrap();
        assert_eq!(l.num_layers(), 3);
        assert_eq!(l.total(), 1364);
        assert_eq!(l.layers[1].name, "block0.qkv");
        assert_eq!(l.layers[1].offset, 1000);
    }

    #[test]
    fn parse_rejects_gaps_and_zero() {
        assert!(Layout::parse("a 0 10\nb 11 5\n").is_err()); // gap
        assert!(Layout::parse("a 0 0\n").is_err()); // zero size
        assert!(Layout::parse("a 0\n").is_err()); // short row
    }

    #[test]
    fn from_sizes_contiguous() {
        let l = Layout::from_sizes(&[("a", 3), ("b", 7)]);
        assert_eq!(l.total(), 10);
        assert_eq!(l.layers[1].offset, 3);
        assert_eq!(Layout::single(42).total(), 42);
    }

    #[test]
    fn vector_ops() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![10.5, 21.0]);
        assert!((sq_norm(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
        assert_eq!(add(&[1.0], &[2.0]), vec![3.0]);
    }

    #[test]
    fn nan_min_cmp_is_a_total_order_with_nan_smallest() {
        use std::cmp::Ordering::*;
        assert_eq!(nan_min_cmp(1.0, 2.0), Less);
        assert_eq!(nan_min_cmp(2.0, 1.0), Greater);
        assert_eq!(nan_min_cmp(1.0, 1.0), Equal);
        assert_eq!(nan_min_cmp(f64::NAN, -1e300), Less);
        assert_eq!(nan_min_cmp(-1e300, f64::NAN), Greater);
        assert_eq!(nan_min_cmp(f64::NAN, f64::NAN), Equal);
        assert_eq!(nan_min_cmp_f32(f32::NAN, f32::NEG_INFINITY), Less);
        assert_eq!(nan_min_cmp_f32(0.0, f32::NAN), Greater);
        // Sorting a NaN-poisoned slice must not panic and puts NaN first.
        let mut v = vec![2.0f64, f64::NAN, 1.0];
        v.sort_by(|a, b| nan_min_cmp(*a, *b));
        assert!(v[0].is_nan());
        assert_eq!(&v[1..], &[1.0, 2.0]);
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("flexcomm_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.f32");
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, &bytes).unwrap();
        let got = load_f32_file(path.to_str().unwrap()).unwrap();
        assert_eq!(got, vals);
    }
}
