//! Descriptive statistics + Gaussian kernel density estimation.
//!
//! The KDE backs the paper's Figs 4/7/8 (iteration-density plots of
//! broadcasting ranks / chosen CRs / chosen collectives).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| crate::tensor::nan_min_cmp(*a, *b));
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Exponentially-weighted moving average tracker.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Gaussian KDE evaluated on a uniform grid.
///
/// Bandwidth defaults to Scott's rule `n^(-1/5) * std`, floored to a small
/// epsilon so degenerate (constant) samples still render as a spike.
pub struct Kde {
    pub grid: Vec<f64>,
    pub density: Vec<f64>,
}

pub fn kde(samples: &[f64], lo: f64, hi: f64, points: usize) -> Kde {
    assert!(points >= 2 && hi > lo);
    let grid: Vec<f64> = (0..points)
        .map(|i| lo + (hi - lo) * i as f64 / (points - 1) as f64)
        .collect();
    if samples.is_empty() {
        return Kde { density: vec![0.0; points], grid };
    }
    let n = samples.len() as f64;
    let bw = (std_dev(samples) * n.powf(-0.2)).max((hi - lo) * 1e-3);
    let norm = 1.0 / (n * bw * (2.0 * std::f64::consts::PI).sqrt());
    let density = grid
        .iter()
        .map(|&x| {
            samples
                .iter()
                .map(|&s| {
                    let z = (x - s) / bw;
                    (-0.5 * z * z).exp()
                })
                .sum::<f64>()
                * norm
        })
        .collect();
    Kde { grid, density }
}

/// Histogram over equal-width bins; returns per-bin counts.
pub fn histogram(samples: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &s in samples {
        if s < lo || s > hi {
            continue;
        }
        let b = (((s - lo) / w) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
}

/// Render a one-line unicode sparkline of a density/series (for terminal
/// "figures": the experiment harnesses print these next to the CSV dumps).
pub fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (lo, hi) = (min(values), max(values));
    if values.is_empty() || !(hi > lo) {
        return values.iter().map(|_| BARS[0]).collect();
    }
    values
        .iter()
        .map(|&v| {
            let t = ((v - lo) / (hi - lo) * 7.0).round() as usize;
            BARS[t.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..50 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn kde_integrates_to_one() {
        let mut r = Rng::new(0);
        let samples: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let k = kde(&samples, -5.0, 5.0, 401);
        let dx = 10.0 / 400.0;
        let integral: f64 = k.density.iter().sum::<f64>() * dx;
        assert!((integral - 1.0).abs() < 0.02, "integral {integral}");
        // Peak near zero for standard normal samples.
        let peak = k
            .density
            .iter()
            .enumerate()
            .max_by(|a, b| crate::tensor::nan_min_cmp(*a.1, *b.1))
            .unwrap()
            .0;
        assert!((k.grid[peak]).abs() < 0.3);
    }

    #[test]
    fn percentile_survives_nan_poisoning() {
        // NaN sorts first under the crate total order (nan_min_cmp): no
        // panic, deterministic placement, finite percentiles unchanged at
        // the top end.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p100 = percentile(&xs, 100.0);
        assert_eq!(p100, 3.0);
        let p0 = percentile(&xs, 0.0);
        assert!(p0.is_nan(), "NaN is smallest under the total order");
        // Repeat runs are bitwise-stable (sort is deterministic).
        assert_eq!(percentile(&xs, 100.0).to_bits(), p100.to_bits());
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 0.95], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    #[test]
    fn sparkline_len() {
        assert_eq!(sparkline(&[0.0, 1.0, 0.5]).chars().count(), 3);
    }
}
