//! Quickstart: train one model with DenseSGD vs AR-Topk on a constrained
//! link and see the speed/accuracy trade the paper is about.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the pure-rust host model so it runs in seconds with no artifacts,
//! and the Session builder API (DESIGN.md §8) — misconfigurations are
//! typed errors at `build()`, not panics mid-run.

use anyhow::Result;
use flexcomm::coordinator::session::Session;
use flexcomm::coordinator::trainer::Strategy;
use flexcomm::coordinator::worker::ComputeModel;
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::runtime::HostMlp;
use flexcomm::util::table::Table;

fn run(strategy: Strategy, cr: f64, label: &str) -> Result<(String, f64, f64, f64)> {
    let report = Session::builder()
        .workers(8)
        .steps(300)
        .steps_per_epoch(30)
        .lr(0.2)
        .momentum(0.9)
        .strategy(strategy)
        .static_cr(cr)
        // A constrained inter-node link: 4 ms latency, 2 Gbps.
        .schedule(NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 2.0)))
        .compute(ComputeModel::with_jitter(0.020, 0.05))
        .eval_every(30)
        .seed(7)
        .source(Box::new(HostMlp::default_preset(7)))
        .build()?
        .run();
    let s = report.summary();
    Ok((
        label.to_string(),
        s.mean_step_s * 1e3,
        report.best_accuracy().unwrap_or(f64::NAN) * 100.0,
        report.virtual_time_s,
    ))
}

fn main() -> Result<()> {
    println!("flexcomm quickstart — DenseSGD vs AR-Topk on a 4ms/2Gbps link\n");
    let rows = vec![
        run(Strategy::parse("dense-ring")?, 1.0, "DenseSGD (Ring-AR)")?,
        run(Strategy::parse("artopk-star")?, 0.01, "STAR-Topk CR 0.01 (ART-Ring)")?,
        run(Strategy::parse("flexible")?, 0.01, "Flexible CR 0.01")?,
    ];
    let mut t = Table::new(["method", "t_step (ms)", "best acc (%)", "total time (s)"]);
    for (label, ms, acc, total) in &rows {
        t.row([
            label.clone(),
            format!("{ms:.2}"),
            format!("{acc:.2}"),
            format!("{total:.1}"),
        ]);
    }
    t.print();
    println!(
        "\nSame step budget: the flexible strategy (Eqn 5 collective choice) finishes \
         {:.1}x faster than DenseSGD and {:.1}x faster than fixed ART-Ring — at this \
         model size and link, AG is the right collective and the selector finds it.",
        rows[0].3 / rows[2].3,
        rows[1].3 / rows[2].3
    );
    Ok(())
}
