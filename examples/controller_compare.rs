//! Controller-comparison sweep (ISSUE 5): the same model × network
//! scenario under every registered adaptation policy — {static low,
//! static high, gravac, moo} — printing time-to-accuracy rows, the
//! GraVAC-style evaluation that motivates a pluggable control plane
//! (which policy wins is workload- and network-dependent).
//!
//!     cargo run --release --example controller_compare -- \
//!         [--steps 400] [--net c2] [--target 0.85] [--seed 7]
//!
//! `--net` accepts a comma-separated scenario list (ISSUE 7), so one
//! invocation ranks every controller under several environments:
//!
//!     cargo run --release --example controller_compare -- \
//!         --net straggler,hetero,churn --steps 24 --target 0.99
//!
//! The verify gate runs this at tiny step counts (`--steps 24`) across
//! ALL `CONTROLLER_TABLE` entries and the three fleet scenarios, so an
//! unregistered or panicking controller — or one that breaks under
//! stragglers, per-worker links or churn — fails loudly there.

use anyhow::{ensure, Result};
use flexcomm::coordinator::controller::CONTROLLER_TABLE;
use flexcomm::experiments::{controller_rows, print_controller_sweep};
use flexcomm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 400)?;
    let scenarios = args.str_or("net", "c2");
    let target = args.f64_or("target", 0.85)?;
    let seed = args.u64_or("seed", 7)?;

    let non_static = CONTROLLER_TABLE.iter().filter(|e| e.name != "static").count();
    let mut total = 0usize;
    for scenario in scenarios.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let rows = controller_rows(scenario, steps, seed, target)?;
        print_controller_sweep(scenario, &rows, target);

        // Gate assertions (smoke mode relies on these): the sweep covered
        // every registered controller and every run actually trained.
        ensure!(
            rows.len() == 2 + non_static,
            "{scenario}: sweep rows {} != 2 static + {non_static} registry entries",
            rows.len()
        );
        for r in &rows {
            // Above-chance floor that holds even at smoke step counts (the
            // host MLP has 16 classes, so chance is ~6%).
            ensure!(
                r.best_acc.is_finite() && r.best_acc > 0.15,
                "{scenario}/{}: degenerate accuracy {}",
                r.label,
                r.best_acc
            );
            ensure!(r.virtual_time_s > 0.0, "{scenario}/{}: no simulated time", r.label);
        }
        total += rows.len();
        println!();
    }
    ensure!(total > 0, "no scenarios given");
    println!("controller sweep: {total} rows OK");
    Ok(())
}
