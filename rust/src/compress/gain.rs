//! Compression gain (GraVAC [2], §2-C3): `E||g_c||² / E||g_e||²` — the
//! statistical-efficiency heuristic that drives the MOO controller.
//!
//! Gain ≈ 1 means compression lost little signal; small gain means heavy
//! information loss. Fig 3 plots these trajectories; the adaptive
//! controller re-explores CRs when the inter-iteration gain drifts beyond
//! `gain-threshold` (10% in the paper).

use crate::util::stats::Ewma;

/// Instantaneous gain of one compression event.
pub fn gain(sq_norm_compressed: f64, sq_norm_error_fed: f64) -> f64 {
    if sq_norm_error_fed <= 0.0 {
        return 1.0; // nothing to lose
    }
    (sq_norm_compressed / sq_norm_error_fed).clamp(0.0, 1.0)
}

/// Tracks smoothed gain and fires when it drifts beyond a threshold
/// relative to the last *accepted* level (the paper's 10% trigger).
#[derive(Debug, Clone)]
pub struct GainTracker {
    ewma: Ewma,
    /// Gain level at the last accepted (re-)configuration.
    anchor: Option<f64>,
    /// Relative-change trigger, e.g. 0.1 for 10%.
    pub threshold: f64,
    history: Vec<f64>,
}

impl GainTracker {
    pub fn new(threshold: f64) -> Self {
        assert!(threshold > 0.0);
        GainTracker {
            ewma: Ewma::new(0.2),
            anchor: None,
            threshold,
            history: Vec::new(),
        }
    }

    /// Record one step's gain; returns `true` if the smoothed gain drifted
    /// past the threshold since the last anchor (i.e. re-exploration due).
    pub fn record(&mut self, g: f64) -> bool {
        let smoothed = self.ewma.update(g);
        self.history.push(g);
        match self.anchor {
            None => {
                self.anchor = Some(smoothed);
                false
            }
            Some(a) => {
                let drift = if a > 0.0 { (smoothed - a).abs() / a } else { 0.0 };
                drift > self.threshold
            }
        }
    }

    /// Accept the current level as the new anchor (after re-configuring).
    pub fn rearm(&mut self) {
        self.anchor = self.ewma.get();
    }

    pub fn smoothed(&self) -> Option<f64> {
        self.ewma.get()
    }

    pub fn history(&self) -> &[f64] {
        &self.history
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gain_formula() {
        assert_eq!(gain(0.5, 1.0), 0.5);
        assert_eq!(gain(2.0, 1.0), 1.0); // clamped
        assert_eq!(gain(0.0, 0.0), 1.0); // degenerate
    }

    #[test]
    fn stable_gain_never_triggers() {
        let mut t = GainTracker::new(0.1);
        let mut fired = false;
        for _ in 0..100 {
            fired |= t.record(0.8);
        }
        assert!(!fired);
    }

    #[test]
    fn drift_triggers_and_rearm_resets() {
        let mut t = GainTracker::new(0.1);
        for _ in 0..20 {
            assert!(!t.record(0.8));
        }
        // Collapse the gain (e.g. step-size decay regime): must fire.
        let mut fired = false;
        for _ in 0..20 {
            fired |= t.record(0.4);
        }
        assert!(fired);
        t.rearm();
        // Stable at the new level: no more firing.
        let mut fired2 = false;
        for _ in 0..20 {
            fired2 |= t.record(t.smoothed().unwrap());
        }
        assert!(!fired2);
    }

    #[test]
    fn lower_cr_gives_lower_gain_on_gaussian() {
        // Shape check backing Fig 3: gain falls with CR.
        use crate::compress::{Compressor, TopK};
        use crate::tensor::Layout;
        let mut gen = crate::util::proptest::Gen { rng: crate::util::rng::Rng::new(2) };
        let g = gen.vec_normal(20_000, 1.0);
        // Denominator through the crate reduction policy (was a
        // sequential .map().sum(); the assertions are monotonic, far
        // above low-bit drift).
        let e = crate::tensor::sq_norm(&g);
        let mut prev = 1.1;
        for cr in [0.5, 0.1, 0.01, 0.001] {
            let s = TopK::new().compress(&g, cr, &Layout::single(g.len()));
            let gg = gain(s.sq_norm(), e);
            assert!(gg < prev, "gain not decreasing at cr={cr}");
            prev = gg;
        }
    }
}
