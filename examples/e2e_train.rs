//! End-to-end driver: train a transformer LM through the FULL stack —
//! L1 Pallas kernels inside the L2 jax graph, AOT-lowered to HLO, executed
//! via PJRT from the L3 rust coordinator, with AR-Topk compression, Eqn 5
//! collective switching and the MOO-adaptive CR controller, under the
//! paper's C2 unpredictable-network schedule.
//!
//!     make artifacts                  # exports mlp/tiny/small presets
//!     cargo run --release --example e2e_train -- --preset small --steps 300
//!
//! `--preset base` / `--preset large` (~27M / ~88M params) require
//! `make artifacts-large` first. Results stream to results/e2e_<preset>.csv
//! (CsvSink observer — a killed run still leaves a trace) and are recorded
//! in EXPERIMENTS.md. `--progress` prints live step/eval/switch lines.

use anyhow::{Context, Result};
use flexcomm::coordinator::controller::AdaptiveConfig;
use flexcomm::coordinator::observer::{CsvSink, ProgressPrinter};
use flexcomm::coordinator::session::Session;
use flexcomm::coordinator::trainer::{CrControl, Strategy};
use flexcomm::coordinator::worker::ComputeModel;
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::runtime::{find_artifacts_dir, Engine, ModelArtifacts, PjrtModel};
use flexcomm::util::cli::Args;
use flexcomm::util::table::Table;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let preset = args.str_or("preset", "small");
    let steps = args.u64_or("steps", 300)?;
    let workers = args.usize_or("workers", 8)?;
    let seed = args.u64_or("seed", 0)?;
    let adaptive = !args.flag("static-cr");

    println!("e2e_train: preset={preset} steps={steps} workers={workers} adaptive={adaptive}");
    let dir = find_artifacts_dir()?;
    let arts = ModelArtifacts::load(&dir, &preset)
        .context("preset artifacts missing — run `make artifacts` (or artifacts-large)")?;
    let params = arts.param_count()?;
    println!("model: kind={} params={}", arts.kind(), params);

    let engine = Engine::cpu()?;
    let t_load = std::time::Instant::now();
    let model = PjrtModel::load(&engine, arts, seed)?;
    println!("artifacts compiled in {:.1?}s", t_load.elapsed().as_secs_f64());

    let spe = (steps / 10).max(1);
    let csv_path = format!("results/e2e_{preset}.csv");
    let mut builder = Session::builder()
        .workers(workers)
        .steps(steps)
        .steps_per_epoch(spe)
        .lr(args.f64_or("lr", 0.05)? as f32)
        .momentum(0.9)
        .weight_decay(0.0001)
        .lr_decay(vec![(steps * 7 / 10, 0.2)])
        .strategy(Strategy::parse("flexible")?)
        .cr(if adaptive {
            CrControl::Adaptive(AdaptiveConfig { probe_iters: 5, seed, ..Default::default() })
        } else {
            CrControl::Static(args.f64_or("cr", 0.01)?)
        })
        .schedule(NetSchedule::c2(10.0)) // 10 virtual epochs across the run
        // t_compute proxied at ViT-scale per Fig 1a.
        .compute(ComputeModel::with_jitter(0.110, 0.05))
        .eval_every(spe)
        .seed(seed)
        .threads(args.usize_or("threads", 0)?)
        .source(Box::new(model));
    if args.flag("progress") {
        builder = builder.observer(Box::new(ProgressPrinter::every(spe)));
    }
    // Validate before CsvSink::create truncates any previous results file.
    let session = builder.build()?.observer(Box::new(CsvSink::create(&csv_path)?));

    let wall = std::time::Instant::now();
    let report = session.run();
    let wall_s = wall.elapsed().as_secs_f64();

    // Loss curve.
    println!("\nloss curve (per {spe} steps):");
    let mut curve = Table::new(["step", "epoch", "train loss", "eval loss", "eval acc"]);
    let mut eval_iter = report.metrics.evals.iter();
    for chunk_start in (0..report.metrics.steps.len()).step_by(spe as usize) {
        let end = (chunk_start + spe as usize).min(report.metrics.steps.len());
        let s = report.metrics.summary_range(chunk_start, end);
        let ev = eval_iter.next();
        curve.row([
            format!("{}", end),
            format!("{:.1}", report.metrics.steps[end - 1].epoch),
            format!("{:.4}", s.final_loss),
            ev.map(|e| format!("{:.4}", e.1)).unwrap_or_default(),
            ev.map(|e| format!("{:.2}%", e.2 * 100.0)).unwrap_or_default(),
        ]);
    }
    curve.print();

    let s = report.summary();
    let first_loss = report.metrics.steps.first().map(|m| m.loss).unwrap_or(f64::NAN);
    println!("\nsummary:");
    let mut t = Table::new(["metric", "value"]);
    t.row(["train loss", &format!("{first_loss:.4} -> {:.4}", s.final_loss)]);
    let final_acc = report.final_accuracy().unwrap_or(f64::NAN) * 100.0;
    t.row(["final eval acc", &format!("{final_acc:.2}%")]);
    t.row(["mean t_step (ms)", &format!("{:.2}", s.mean_step_s * 1e3)]);
    t.row(["  compute/comp/sync (ms)", &format!(
        "{:.2} / {:.2} / {:.2}",
        s.mean_compute_s * 1e3, s.mean_comp_s * 1e3, s.mean_sync_s * 1e3
    )]);
    t.row(["mean gain", &format!("{:.3}", s.mean_gain)]);
    t.row(["virtual cluster time (s)", &format!("{:.1}", report.virtual_time_s)]);
    t.row(["MOO explore overhead (s)", &format!("{:.1}", report.explore_overhead_s)]);
    t.row(["real wall time (s)", &format!("{wall_s:.1}")]);
    t.print();

    // Collective + CR usage (Figs 7/8 view of this run).
    let mut counts = std::collections::BTreeMap::new();
    for (kind, n) in report.metrics.collective_counts() {
        counts.insert(kind.name(), n);
    }
    println!("\ncollectives used: {counts:?}");
    let crs = report.metrics.crs_used();
    let distinct: std::collections::BTreeSet<String> =
        crs.iter().map(|c| format!("{c:.4}")).collect();
    println!("CRs used: {distinct:?}");

    println!("\nstreamed {csv_path}");
    Ok(())
}
