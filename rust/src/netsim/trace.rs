//! Trace-driven network environments: replay measured (epoch, α, β)
//! samples as a [`NetworkModel`].
//!
//! The paper's variability argument (§2-C2) is grounded in *measured*
//! cloud/cluster behaviour; [`TraceModel`] closes that loop by replaying a
//! measurement file — iperf/traceroute logs reduced to
//! `(epoch, alpha_ms, bw_gbps)` rows — as the simulation's ground truth,
//! so any real network recording becomes a reproducible scenario.
//!
//! Two file formats (picked by extension, `.json` vs anything else):
//!
//! **CSV** — optional header, `#` comments, one sample per line:
//! ```text
//! # my WAN, 2026-07-14
//! epoch,alpha_ms,bw_gbps
//! 0.0,1.0,25.0
//! 12.0,10.0,10.0
//! 24.0,50.0,1.0
//! ```
//!
//! **JSON** — an object with an optional `"name"` and a `"points"` array:
//! ```text
//! {"name": "wan", "points": [
//!   {"epoch": 0.0, "alpha_ms": 1.0, "bw_gbps": 25.0},
//!   {"epoch": 12.0, "alpha_ms": 10.0, "bw_gbps": 10.0}
//! ]}
//! ```
//!
//! Samples are replayed piecewise-constant (each row holds until the
//! next), matching `NetSchedule` phase semantics; epochs before the first
//! sample report the first sample.

use crate::netsim::cost_model::LinkParams;
use crate::netsim::model::{NetModelError, NetworkModel};

/// One measured sample; holds from `epoch` until the next sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TracePoint {
    pub epoch: f64,
    pub alpha_ms: f64,
    pub bw_gbps: f64,
}

impl TracePoint {
    pub fn link(&self) -> LinkParams {
        LinkParams::from_ms_gbps(self.alpha_ms, self.bw_gbps)
    }
}

/// A measured-trace network environment (see the module docs for the file
/// formats).
///
/// ```
/// use flexcomm::netsim::model::NetworkModel;
/// use flexcomm::netsim::trace::TraceModel;
///
/// let path = std::env::temp_dir().join("flexcomm_doctest_trace.csv");
/// std::fs::write(&path, "epoch,alpha_ms,bw_gbps\n0,1,25\n10,50,1\n").unwrap();
/// let t = TraceModel::load(path.to_str().unwrap()).unwrap();
/// assert_eq!(t.points().len(), 2);
/// assert_eq!(t.link_at(3.0).bw_gbps().round(), 25.0);  // holds first sample
/// assert_eq!(t.link_at(99.0).alpha_ms().round(), 50.0); // holds last sample
/// assert!(t.describe().starts_with("trace:"));
/// std::fs::remove_file(&path).unwrap();
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct TraceModel {
    name: String,
    points: Vec<TracePoint>,
}

impl TraceModel {
    /// Build from in-memory samples. `points` must be non-empty, strictly
    /// increasing in epoch, with finite `alpha_ms >= 0` and `bw_gbps > 0`.
    pub fn from_points(
        name: impl Into<String>,
        points: Vec<TracePoint>,
    ) -> Result<TraceModel, NetModelError> {
        let name = name.into();
        if points.is_empty() {
            return Err(NetModelError::EmptyTrace { path: name });
        }
        for (i, p) in points.iter().enumerate() {
            if !p.epoch.is_finite() || !p.alpha_ms.is_finite() || p.alpha_ms < 0.0 {
                return Err(NetModelError::TraceParse {
                    path: name,
                    line: i + 1,
                    reason: format!("bad sample (epoch {}, alpha_ms {})", p.epoch, p.alpha_ms),
                });
            }
            if !p.bw_gbps.is_finite() || p.bw_gbps <= 0.0 {
                return Err(NetModelError::TraceParse {
                    path: name,
                    line: i + 1,
                    reason: format!("bandwidth must be finite and > 0 (got {})", p.bw_gbps),
                });
            }
            if i > 0 && points[i - 1].epoch >= p.epoch {
                return Err(NetModelError::UnsortedTrace { path: name, line: i + 1 });
            }
        }
        Ok(TraceModel { name, points })
    }

    /// Load a trace file; `.json` parses the JSON form, everything else
    /// the CSV form. The model's name defaults to the file stem (JSON may
    /// override it with a `"name"` field).
    pub fn load(path: &str) -> Result<TraceModel, NetModelError> {
        let text = std::fs::read_to_string(path).map_err(|e| NetModelError::TraceIo {
            path: path.to_string(),
            reason: e.to_string(),
        })?;
        let stem = std::path::Path::new(path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("trace")
            .to_string();
        if path.to_ascii_lowercase().ends_with(".json") {
            Self::parse_json(&text, path, stem)
        } else {
            Self::parse_csv(&text, path, stem)
        }
    }

    fn parse_csv(text: &str, path: &str, name: String) -> Result<TraceModel, NetModelError> {
        let mut points = Vec::new();
        let mut line_nos = Vec::new(); // real file line per point (diagnostics)
        let mut header_allowed = true; // at most ONE leading header line
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(str::trim).collect();
            // The FIRST content line may be the all-text header; anything
            // with even one numeric field is a data row (a typo'd value in
            // row 1 of a headerless file must error, not vanish as a
            // pseudo-header), and later non-numeric lines always error.
            if header_allowed {
                header_allowed = false;
                if fields.iter().all(|f| f.parse::<f64>().is_err()) {
                    continue;
                }
            }
            if fields.len() != 3 {
                return Err(NetModelError::TraceParse {
                    path: path.to_string(),
                    line: line_no,
                    reason: format!("expected `epoch,alpha_ms,bw_gbps`, got {} fields", fields.len()),
                });
            }
            let num = |s: &str, what: &str| -> Result<f64, NetModelError> {
                s.parse().map_err(|_| NetModelError::TraceParse {
                    path: path.to_string(),
                    line: line_no,
                    reason: format!("bad {what} `{s}`"),
                })
            };
            points.push(TracePoint {
                epoch: num(fields[0], "epoch")?,
                alpha_ms: num(fields[1], "alpha_ms")?,
                bw_gbps: num(fields[2], "bw_gbps")?,
            });
            line_nos.push(line_no);
        }
        if points.is_empty() {
            return Err(NetModelError::EmptyTrace { path: path.to_string() });
        }
        Self::from_points(name, points).map_err(|e| e.with_location(path, &line_nos))
    }

    fn parse_json(text: &str, path: &str, stem: String) -> Result<TraceModel, NetModelError> {
        let mut p = JsonCursor { text, pos: 0, path };
        p.skip_ws();
        p.expect('{')?;
        let mut name = stem;
        let mut points: Option<Vec<TracePoint>> = None;
        loop {
            p.skip_ws();
            if p.eat('}') {
                break;
            }
            let key = p.parse_string()?;
            p.skip_ws();
            p.expect(':')?;
            p.skip_ws();
            match key.as_str() {
                "name" => name = p.parse_string()?,
                "points" => points = Some(p.parse_points()?),
                other => {
                    return Err(p.err(format!("unknown key `{other}` (expected name|points)")))
                }
            }
            p.skip_ws();
            if !p.eat(',') {
                p.skip_ws();
                p.expect('}')?;
                break;
            }
        }
        // Strict by design: anything after the root object (e.g. a botched
        // concatenation of two trace files) is an error, never silently
        // ignored data.
        p.skip_ws();
        if p.peek().is_some() {
            return Err(p.err("trailing content after the trace object".into()));
        }
        let points = points.ok_or_else(|| NetModelError::EmptyTrace { path: path.to_string() })?;
        if points.is_empty() {
            return Err(NetModelError::EmptyTrace { path: path.to_string() });
        }
        Self::from_points(name, points).map_err(|e| e.with_location(path, &[]))
    }

    /// The samples, in epoch order.
    pub fn points(&self) -> &[TracePoint] {
        &self.points
    }

    /// Serialize back to the CSV form ([`TraceModel::load`] round-trips
    /// it: every written value re-parses to the identical f64).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("epoch,alpha_ms,bw_gbps\n");
        for p in &self.points {
            out.push_str(&format!("{},{},{}\n", p.epoch, p.alpha_ms, p.bw_gbps));
        }
        out
    }

    /// Write the CSV form to `path` (creating parent directories).
    pub fn save_csv(&self, path: &str) -> Result<(), NetModelError> {
        let io = |e: std::io::Error| NetModelError::TraceIo {
            path: path.to_string(),
            reason: e.to_string(),
        };
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(io)?;
            }
        }
        std::fs::write(path, self.to_csv()).map_err(io)
    }
}

impl NetModelError {
    /// Re-point an in-memory validation error at the file it came from:
    /// `from_points` reports the POINT INDEX as `line`; `line_map` (one
    /// file line per point, from the CSV reader) translates it to the real
    /// file line, so comment/header lines don't skew diagnostics. An empty
    /// map keeps the index (JSON, where points have no own line).
    fn with_location(self, path: &str, line_map: &[usize]) -> NetModelError {
        let p = path.to_string();
        let fix = |line: usize| line_map.get(line - 1).copied().unwrap_or(line);
        match self {
            NetModelError::EmptyTrace { .. } => NetModelError::EmptyTrace { path: p },
            NetModelError::TraceParse { line, reason, .. } => {
                NetModelError::TraceParse { path: p, line: fix(line), reason }
            }
            NetModelError::UnsortedTrace { line, .. } => {
                NetModelError::UnsortedTrace { path: p, line: fix(line) }
            }
            other => other,
        }
    }
}

impl NetworkModel for TraceModel {
    fn link_at(&self, epoch: f64) -> LinkParams {
        let mut cur = &self.points[0];
        for p in &self.points {
            if epoch >= p.epoch {
                cur = p;
            } else {
                break;
            }
        }
        cur.link()
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn describe(&self) -> String {
        format!("trace:{}[{} pts]", self.name, self.points.len())
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

/// Minimal cursor over the constrained trace-JSON grammar (offline build:
/// no serde). Strict by design — unknown keys and malformed values are
/// typed errors, not silent defaults.
struct JsonCursor<'a> {
    text: &'a str,
    pos: usize,
    path: &'a str,
}

impl JsonCursor<'_> {
    fn err(&self, reason: String) -> NetModelError {
        let line = self.text[..self.pos.min(self.text.len())]
            .bytes()
            .filter(|&b| b == b'\n')
            .count()
            + 1;
        NetModelError::TraceParse { path: self.path.to_string(), line, reason }
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.text[self.pos..].chars().next()
    }

    fn eat(&mut self, c: char) -> bool {
        if self.peek() == Some(c) {
            self.pos += c.len_utf8();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, c: char) -> Result<(), NetModelError> {
        if self.eat(c) {
            Ok(())
        } else {
            Err(self.err(format!("expected `{c}`, found {:?}", self.peek())))
        }
    }

    fn parse_string(&mut self) -> Result<String, NetModelError> {
        self.skip_ws();
        self.expect('"')?;
        let start = self.pos;
        // Trace names/keys never contain escapes; reject them explicitly.
        while let Some(c) = self.peek() {
            match c {
                '"' => {
                    let s = self.text[start..self.pos].to_string();
                    self.pos += 1;
                    return Ok(s);
                }
                '\\' => return Err(self.err("escape sequences not supported".into())),
                _ => self.pos += c.len_utf8(),
            }
        }
        Err(self.err("unterminated string".into()))
    }

    fn parse_number(&mut self) -> Result<f64, NetModelError> {
        self.skip_ws();
        let start = self.pos;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E'))
        {
            self.pos += 1;
        }
        let tok = &self.text[start..self.pos];
        tok.parse().map_err(|_| self.err(format!("bad number `{tok}`")))
    }

    fn parse_points(&mut self) -> Result<Vec<TracePoint>, NetModelError> {
        self.expect('[')?;
        let mut out = Vec::new();
        loop {
            self.skip_ws();
            if self.eat(']') {
                break;
            }
            out.push(self.parse_point()?);
            self.skip_ws();
            if !self.eat(',') {
                self.skip_ws();
                self.expect(']')?;
                break;
            }
        }
        Ok(out)
    }

    fn parse_point(&mut self) -> Result<TracePoint, NetModelError> {
        self.expect('{')?;
        let (mut epoch, mut alpha_ms, mut bw_gbps) = (None, None, None);
        loop {
            self.skip_ws();
            if self.eat('}') {
                break;
            }
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(':')?;
            let v = self.parse_number()?;
            match key.as_str() {
                "epoch" => epoch = Some(v),
                "alpha_ms" => alpha_ms = Some(v),
                "bw_gbps" => bw_gbps = Some(v),
                other => {
                    return Err(
                        self.err(format!("unknown key `{other}` (epoch|alpha_ms|bw_gbps)"))
                    )
                }
            }
            self.skip_ws();
            if !self.eat(',') {
                self.skip_ws();
                self.expect('}')?;
                break;
            }
        }
        match (epoch, alpha_ms, bw_gbps) {
            (Some(epoch), Some(alpha_ms), Some(bw_gbps)) => {
                Ok(TracePoint { epoch, alpha_ms, bw_gbps })
            }
            _ => Err(self.err("point needs epoch, alpha_ms and bw_gbps".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<TracePoint> {
        vec![
            TracePoint { epoch: 0.0, alpha_ms: 1.0, bw_gbps: 25.0 },
            TracePoint { epoch: 12.0, alpha_ms: 10.0, bw_gbps: 10.0 },
            TracePoint { epoch: 24.0, alpha_ms: 50.0, bw_gbps: 1.0 },
        ]
    }

    fn tmp(name: &str, content: &str) -> String {
        let p = std::env::temp_dir().join(name);
        std::fs::write(&p, content).unwrap();
        p.to_str().unwrap().to_string()
    }

    #[test]
    fn replays_piecewise_constant_with_hold_semantics() {
        let t = TraceModel::from_points("m", pts()).unwrap();
        assert_eq!(t.link_at(0.0).bw_gbps().round(), 25.0);
        assert_eq!(t.link_at(11.9).bw_gbps().round(), 25.0);
        assert_eq!(t.link_at(12.0).bw_gbps().round(), 10.0);
        // Before the first sample and beyond the last: hold.
        assert_eq!(t.link_at(-1.0).alpha_ms().round(), 1.0);
        assert_eq!(t.link_at(1e6).alpha_ms().round(), 50.0);
    }

    #[test]
    fn csv_loads_with_header_comments_and_blank_lines() {
        let p = tmp(
            "flexcomm_trace_csv.csv",
            "# measured on the lab WAN\nepoch,alpha_ms,bw_gbps\n\n0,1,25\n12,10,10\n24,50,1\n",
        );
        let t = TraceModel::load(&p).unwrap();
        assert_eq!(t.points(), &pts()[..]);
        assert_eq!(t.name(), "flexcomm_trace_csv");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn csv_round_trips_exactly() {
        let orig = TraceModel::from_points("rt", pts()).unwrap();
        let p = tmp("flexcomm_trace_rt.csv", &orig.to_csv());
        let back = TraceModel::load(&p).unwrap();
        assert_eq!(back.points(), orig.points(), "to_csv -> load must be lossless");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn json_loads_with_embedded_name() {
        let p = tmp(
            "flexcomm_trace.json",
            r#"{ "name": "wan-week",
                 "points": [ {"epoch": 0, "alpha_ms": 1.0, "bw_gbps": 25},
                             {"epoch": 12, "alpha_ms": 10, "bw_gbps": 10} ] }"#,
        );
        let t = TraceModel::load(&p).unwrap();
        assert_eq!(t.name(), "wan-week");
        assert_eq!(t.points().len(), 2);
        assert_eq!(t.link_at(13.0).alpha_ms().round(), 10.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn malformed_inputs_are_typed_errors_with_line_numbers() {
        let p = tmp("flexcomm_trace_bad1.csv", "epoch,alpha_ms,bw_gbps\n0,1\n");
        assert!(matches!(
            TraceModel::load(&p).unwrap_err(),
            NetModelError::TraceParse { line: 2, .. }
        ));
        let p2 = tmp("flexcomm_trace_bad2.csv", "0,1,25\n0,2,10\n");
        assert!(matches!(
            TraceModel::load(&p2).unwrap_err(),
            NetModelError::UnsortedTrace { line: 2, .. }
        ));
        let p3 = tmp("flexcomm_trace_bad3.csv", "# only comments\n");
        assert!(matches!(TraceModel::load(&p3).unwrap_err(), NetModelError::EmptyTrace { .. }));
        let p4 = tmp("flexcomm_trace_bad4.json", r#"{"points": [{"epoch": 0}]}"#);
        assert!(matches!(TraceModel::load(&p4).unwrap_err(), NetModelError::TraceParse { .. }));
        let p5 = tmp("flexcomm_trace_bad5.csv", "0,1,0\n");
        assert!(matches!(TraceModel::load(&p5).unwrap_err(), NetModelError::TraceParse { .. }));
        for p in [p, p2, p3, p4, p5] {
            let _ = std::fs::remove_file(&p);
        }
        assert!(matches!(
            TraceModel::load("/definitely/not/here.csv").unwrap_err(),
            NetModelError::TraceIo { .. }
        ));
    }

    /// Only ONE leading header line may be non-numeric: a corrupted data
    /// row (typo'd epoch) must be a typed error, not silently dropped as
    /// "another header" — dropping it would replay a trace whose early
    /// conditions are wrong with no diagnostic.
    #[test]
    fn corrupted_data_rows_are_not_silently_dropped() {
        let p = tmp(
            "flexcomm_trace_bad6.csv",
            "epoch,alpha_ms,bw_gbps\nO.0,1.0,25.0\n12,10,10\n",
        );
        assert!(matches!(
            TraceModel::load(&p).unwrap_err(),
            NetModelError::TraceParse { line: 2, .. }
        ));
        // Headerless file with a typo in the FIRST row: partially-numeric
        // lines are data rows, never a pseudo-header.
        let p2 = tmp("flexcomm_trace_bad6b.csv", "O.0,1.0,25.0\n12,10,10\n");
        assert!(matches!(
            TraceModel::load(&p2).unwrap_err(),
            NetModelError::TraceParse { line: 1, .. }
        ));
        for p in [p, p2] {
            let _ = std::fs::remove_file(&p);
        }
    }

    /// Range/order diagnostics point at the REAL file line even when
    /// comment and header lines precede the data.
    #[test]
    fn validation_errors_report_real_file_lines_past_headers() {
        let p = tmp(
            "flexcomm_trace_bad7.csv",
            "# note\nepoch,alpha_ms,bw_gbps\n0,1,25\n0,2,10\n",
        );
        assert!(matches!(
            TraceModel::load(&p).unwrap_err(),
            NetModelError::UnsortedTrace { line: 4, .. }
        ));
        let _ = std::fs::remove_file(&p);
    }

    /// Strictness: trailing content after the root JSON object (e.g. two
    /// concatenated trace files) is an error, never silently-ignored data.
    #[test]
    fn json_rejects_trailing_content() {
        let p = tmp(
            "flexcomm_trace_bad8.json",
            r#"{"points": [{"epoch": 0, "alpha_ms": 1, "bw_gbps": 25}]}{"points": []}"#,
        );
        assert!(matches!(TraceModel::load(&p).unwrap_err(), NetModelError::TraceParse { .. }));
        let _ = std::fs::remove_file(&p);
    }
}
