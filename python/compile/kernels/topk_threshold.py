"""L1 Pallas kernels: MSTopk-style threshold estimation + masking.

The paper's MSTopk [21] approximates top-k over the fused gradient by
estimating a magnitude threshold with multiple sampling/bisection rounds
(they use 25).  A max-heap top-k (their AR-Topk choice) is thread-divergent
and hostile to TPU vector hardware, so the TPU-native restatement is:

  * ``count_above`` — a blockwise VPU reduction counting ``|g| > tau`` per
    8x128-lane-friendly block, summed on the host graph;
  * a ``lax.while_loop`` bisection on the scalar unit driving ``R`` rounds of
    that counting kernel to converge on the threshold for a target k;
  * ``mask`` — one vectorized select pass zeroing sub-threshold entries.

Everything here is reduction/select shaped: bandwidth-bound, one HBM pass
per round.  See ``ef_compress.py`` for the fused single-pass variant used on
the training path once tau is known.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat block length: a multiple of the 8x128 VPU tile (=1024 lanes) so every
# block maps to whole vector registers.
BLOCK = 4096


def _pad_flat(g, block):
    """Flatten and zero-pad to a block multiple; zeros never exceed tau>0."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    np_ = -(-n // block) * block
    return jnp.pad(flat, (0, np_ - n)), n


def _count_kernel(g_ref, tau_ref, o_ref):
    """Per-block count of |g| > tau (f32 so the sum stays a vector op)."""
    tau = tau_ref[0]
    o_ref[0] = jnp.sum((jnp.abs(g_ref[...]) > tau).astype(jnp.float32))


def count_above(g, tau, *, block=BLOCK):
    """Total number of |g| > tau as a scalar f32, via blockwise Pallas counts."""
    gp, _ = _pad_flat(g, block)
    nblocks = gp.shape[0] // block
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)
    partial = pl.pallas_call(
        _count_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        interpret=True,
    )(gp, tau_arr)
    # Padded zeros satisfy |0| > tau only if tau < 0; callers use tau >= 0.
    return jnp.sum(partial)


def _absmax_kernel(g_ref, o_ref):
    o_ref[0] = jnp.max(jnp.abs(g_ref[...]))


def abs_max(g, *, block=BLOCK):
    """max |g| via blockwise Pallas partial maxima."""
    gp, _ = _pad_flat(g, block)
    nblocks = gp.shape[0] // block
    partial = pl.pallas_call(
        _absmax_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        interpret=True,
    )(gp)
    return jnp.max(partial)


def estimate_threshold(g, k, *, rounds=25, block=BLOCK):
    """Bisect a magnitude threshold tau with count(|g| > tau) ~ k.

    Mirrors MSTopk's multi-round estimation (paper uses 25 rounds).  The
    returned tau satisfies count(|g| > tau) <= k <= count(|g| >= tau) up to
    bisection resolution; masking with ``|g| >= tau`` keeps ~k entries.

    ``k`` may be a traced scalar (f32 count) — the training path feeds the
    CR-dependent k at runtime through a single lowered artifact.
    """
    k = jnp.asarray(k, jnp.float32)
    hi = abs_max(g, block=block)
    lo = jnp.float32(0.0)

    def body(i, lohi):
        lo_, hi_ = lohi
        mid = 0.5 * (lo_ + hi_)
        cnt = count_above(g, mid, block=block)
        # too many kept -> raise the floor; else lower the ceiling.
        too_many = cnt > k
        return jnp.where(too_many, mid, lo_), jnp.where(too_many, hi_, mid)

    lo, hi = jax.lax.fori_loop(0, rounds, body, (lo, hi))
    # lo is the tightest threshold observed that still keeps > k entries:
    # masking at >= hi keeps <= k, at >= lo keeps >= k. Return lo so we err
    # on keeping slightly more (the paper's MSTopk does the same).
    return lo


def _mask_kernel(g_ref, tau_ref, o_ref):
    tau = tau_ref[0]
    g = g_ref[...]
    o_ref[...] = jnp.where(jnp.abs(g) >= tau, g, jnp.zeros_like(g))


def mask(g, tau, *, block=BLOCK):
    """Zero entries with |g| < tau; preserves shape/dtype of g (f32)."""
    shape = g.shape
    gp, n = _pad_flat(g, block)
    nblocks = gp.shape[0] // block
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _mask_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        interpret=True,
    )(gp, tau_arr)
    return out[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("rounds", "block"))
def mstopk(g, k, *, rounds=25, block=BLOCK):
    """Full MSTopk: estimate tau for top-k, then mask. Returns (masked, tau)."""
    tau = estimate_threshold(g, k, rounds=rounds, block=block)
    return mask(g, tau, block=block), tau
