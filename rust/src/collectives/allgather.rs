//! Allgather: recursive doubling (Table I row 5):
//! `α·log N + (N-1)Mβ` where M is the per-worker contribution.
//!
//! Two flavours: a dense concat used by VAR-Topk's variance exchange, and
//! the sparse (values + indices) gather that synchronizes Top-k compressed
//! gradients (the paper's AG baseline path).

use crate::collectives::{ceil_log2, CommReport};
use crate::compress::SparseGrad;
use crate::netsim::cost_model::LinkParams;

/// Dense allgather: every worker contributes `parts[w]`; returns the
/// concatenation (identical on every worker) and the comm report.
///
/// Recursive-doubling round structure: in round d each worker exchanges the
/// `2^d · M` bytes it has accumulated so far.
pub fn allgather_concat(parts: &[Vec<f32>], link: LinkParams) -> (Vec<f32>, CommReport) {
    let n = parts.len();
    assert!(n >= 1);
    let mut report = CommReport::default();
    let m_bytes = 4.0 * parts.iter().map(|p| p.len()).max().unwrap_or(0) as f64;
    let mut out = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
    for p in parts {
        out.extend_from_slice(p);
    }
    if n > 1 {
        // Recursive doubling: round d exchanges 2^d blocks; total (N-1)M.
        let rounds = ceil_log2(n);
        let mut sent_blocks = 0.0;
        for d in 0..rounds {
            let blocks = f64::min((1u64 << d) as f64, n as f64 - 1.0 - sent_blocks);
            report.add_round(link, blocks * m_bytes);
            sent_blocks += blocks;
        }
    }
    (out, report)
}

/// Sparse Top-k allgather (the AG compression path, §3-D): each worker
/// contributes `k` (index, value) pairs = `8k` bytes; every worker ends with
/// the elementwise SUM of all scattered contributions in a dense vector.
///
/// Cost: `α·log N + 2Mcβ(N-1)` with `Mc = 4k` value-bytes (indices double it).
pub fn allgather_sparse(
    parts: &[SparseGrad],
    dense_len: usize,
    link: LinkParams,
) -> (Vec<f32>, CommReport) {
    let n = parts.len();
    assert!(n >= 1);
    let mut report = CommReport::default();
    let per_worker_bytes =
        8.0 * parts.iter().map(|p| p.indices.len()).max().unwrap_or(0) as f64;
    let mut dense = vec![0.0f32; dense_len];
    for p in parts {
        debug_assert_eq!(p.dense_len, dense_len);
        for (&i, &v) in p.indices.iter().zip(&p.values) {
            dense[i as usize] += v;
        }
    }
    if n > 1 {
        let rounds = ceil_log2(n);
        let mut sent_blocks = 0.0;
        for d in 0..rounds {
            let blocks = f64::min((1u64 << d) as f64, n as f64 - 1.0 - sent_blocks);
            report.add_round(link, blocks * per_worker_bytes);
            sent_blocks += blocks;
        }
    }
    (dense, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;
    use crate::util::proptest::{check, ensure};

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(1.0, 10.0)
    }

    #[test]
    fn concat_order_and_content() {
        let parts = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let (out, _) = allgather_concat(&parts, link());
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dense_time_matches_closed_form_pow2() {
        for n in [2usize, 4, 8, 16] {
            let m = 256;
            let parts = vec![vec![1.0f32; m]; n];
            let (_, r) = allgather_concat(&parts, link());
            let want = cost_model::allgather(link(), 4.0 * m as f64, n);
            assert!(
                (r.seconds - want).abs() / want < 1e-9,
                "n={n}: sim {} vs model {}",
                r.seconds,
                want
            );
        }
    }

    #[test]
    fn sparse_sums_overlapping_indices() {
        let a = SparseGrad { indices: vec![0, 3], values: vec![1.0, 2.0], dense_len: 5 };
        let b = SparseGrad { indices: vec![3, 4], values: vec![10.0, 20.0], dense_len: 5 };
        let (dense, _) = allgather_sparse(&[a, b], 5, link());
        assert_eq!(dense, vec![1.0, 0.0, 0.0, 12.0, 20.0]);
    }

    #[test]
    fn sparse_time_matches_ag_topk_cost() {
        // k entries per worker -> Mc = 4k bytes; cost formula uses 2*Mc.
        let n = 8;
        let dense_len = 100_000;
        let k = 1000;
        let parts: Vec<SparseGrad> = (0..n)
            .map(|w| SparseGrad {
                indices: (0..k as u32).collect(),
                values: vec![w as f32; k],
                dense_len,
            })
            .collect();
        let (_, r) = allgather_sparse(&parts, dense_len, link());
        let m = 4.0 * dense_len as f64;
        let c = k as f64 / dense_len as f64;
        let want = cost_model::ag_topk(link(), m, n, c);
        assert!(
            (r.seconds - want).abs() / want < 1e-9,
            "sim {} vs model {}",
            r.seconds,
            want
        );
    }

    #[test]
    fn property_sparse_equals_dense_scatter_sum() {
        check("sparse AG == scatter-add", 50, |g| {
            let n = g.usize_in(1, 6);
            let len = g.usize_in(4, 200);
            let mut want = vec![0.0f32; len];
            let mut parts = Vec::new();
            for _ in 0..n {
                let k = g.usize_in(0, len.min(16));
                let idx = g.rng.sample_indices(len, k);
                let vals = g.vec_normal(k, 1.0);
                for (&i, &v) in idx.iter().zip(&vals) {
                    want[i] += v;
                }
                parts.push(SparseGrad {
                    indices: idx.iter().map(|&i| i as u32).collect(),
                    values: vals,
                    dense_len: len,
                });
            }
            let (dense, _) = allgather_sparse(&parts, len, link());
            crate::util::proptest::all_close(&dense, &want, 1e-5)
        });
    }

    #[test]
    fn single_worker_no_comm() {
        let parts = vec![vec![1.0, 2.0]];
        let (out, r) = allgather_concat(&parts, link());
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(r.seconds, 0.0);
        ensure(r.rounds == 0, "rounds").unwrap();
    }
}
