//! Perf-pass micro-benches for the L3 hot paths (EXPERIMENTS.md §Perf):
//! Top-k selection (heap vs quickselect), MSTopk threshold rounds, ring
//! allreduce arithmetic, sparse allgather scatter, EF bookkeeping, and the
//! threaded worker engine (grad+compress stage, threads=1 vs N — the
//! ISSUE 2 acceptance bench; also run in smoke mode by scripts/verify.sh,
//! which hard-fails if the parallel stage is not bitwise-identical to the
//! serial one).
//!
//!     cargo bench --bench hotpath
//!     FLEXCOMM_BENCH_FAST=1 cargo bench --bench hotpath   (CI smoke mode)

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::ring_allreduce;
use flexcomm::compress::topk::{topk_indices, topk_indices_select};
use flexcomm::compress::{Compressor, EfState, MsTopk};
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::tensor::Layout;
use flexcomm::util::bench::Bencher;
use flexcomm::util::pool::ThreadPool;
use flexcomm::util::rng::Rng;

fn main() {
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    let dim: usize = if fast { 200_000 } else { 4_000_000 };
    let mut rng = Rng::new(0);
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);
    let k = dim / 100;
    let mut b = Bencher::from_env();

    // Top-k selection: the paper's max-heap vs quickselect.
    b.bench(&format!("topk heap        G={dim} k={k}"), || {
        Bencher::black_box(topk_indices(&g, k));
    });
    b.bench(&format!("topk quickselect G={dim} k={k}"), || {
        Bencher::black_box(topk_indices_select(&g, k));
    });

    // MSTopk threshold rounds.
    for rounds in [10u32, 25] {
        let mut ms = MsTopk::new(rounds);
        b.bench(&format!("mstopk rounds={rounds} G={dim}"), || {
            Bencher::black_box(ms.compress(&g, 0.01, &Layout::single(dim)));
        });
    }

    // Ring allreduce arithmetic (data path, 8 workers).
    let n = 8;
    let bufs: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let link = LinkParams::from_ms_gbps(1.0, 10.0);
    b.bench(&format!("ring_allreduce data n={n} m={}", dim / 4), || {
        let mut bb = bufs.clone();
        Bencher::black_box(ring_allreduce(&mut bb, link));
    });

    // Full AR-Topk exchange (compress + residuals + reduce).
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(100 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
    b.bench(&format!("artopk exchange n={n} G={} cr=0.01", dim / 4), || {
        let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(dim / 4)).collect();
        Bencher::black_box(art.exchange(&grads, &mut ef, 0.01, 0, link));
    });

    // EF bookkeeping alone.
    let mut ef = EfState::new(dim);
    let sparse = flexcomm::compress::SparseGrad {
        indices: (0..k as u32).collect(),
        values: vec![1.0; k],
        dense_len: dim,
    };
    b.bench(&format!("error-feedback update G={dim}"), || {
        let ge = ef.error_fed(&g);
        ef.update(Bencher::black_box(ge), &sparse);
    });

    // ------------------------------------------------------------------
    // Threaded worker engine: the grad+compress stage of a 4-worker step
    // (per worker: O(G) gradient transform + error-feed + top-k select),
    // threads=1 vs all cores. ISSUE 2 acceptance: >=1.5x on a >=4-core
    // host. The outputs must be bitwise identical — that part is a hard
    // check, valid on any core count.
    // ------------------------------------------------------------------
    let nw = 4;
    let wdim = dim / 4;
    let wk = wdim / 100;
    let base: Vec<Vec<f32>> = (0..nw)
        .map(|i| {
            let mut v = vec![0.0; wdim];
            Rng::new(1000 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let residual = vec![0.01f32; wdim];
    let stage = |pool: &ThreadPool| -> Vec<Vec<u32>> {
        pool.map(nw, |w| {
            // "grad": a deterministic O(G) per-worker transform standing in
            // for backprop, then the AG-path compress (EF + selection).
            let g_w: Vec<f32> = base[w].iter().map(|&v| v * 1.000123 + 0.1).collect();
            let g_e: Vec<f32> = g_w.iter().zip(&residual).map(|(a, r)| a + r).collect();
            topk_indices_select(&g_e, wk)
        })
    };
    let serial = ThreadPool::serial();
    let threaded = ThreadPool::auto(0);
    assert_eq!(
        stage(&serial),
        stage(&threaded),
        "threaded grad+compress stage must be bitwise-identical to serial"
    );
    let m1 = b.bench(&format!("grad+compress stage n={nw} threads=1"), || {
        Bencher::black_box(stage(&serial));
    });
    let mn = b.bench(
        &format!("grad+compress stage n={nw} threads={}", threaded.threads()),
        || {
            Bencher::black_box(stage(&threaded));
        },
    );
    let speedup = m1.mean_secs() / mn.mean_secs();
    println!(
        "grad+compress stage speedup: {speedup:.2}x with {} threads on {} cores \
         (target >=1.5x on >=4 cores)",
        threaded.threads(),
        ThreadPool::available()
    );

    // Pooled AR-Topk (VAR computes every worker's top-k, so it parallelizes).
    let mut art_var =
        ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring).with_pool(threaded);
    b.bench(&format!("artopk VAR exchange n={nw} threads={}", threaded.threads()), || {
        let mut ef: Vec<EfState> = (0..nw).map(|_| EfState::new(wdim)).collect();
        Bencher::black_box(art_var.exchange(&base, &mut ef, 0.01, 0, link));
    });

    println!("\n{} measurements recorded (see EXPERIMENTS.md §Perf).", b.results.len());
}
