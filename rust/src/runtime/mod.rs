//! PJRT runtime (L3 ⇄ L2 boundary): load the AOT-lowered HLO artifacts and
//! execute them from the training hot path, plus host-side gradient sources
//! for simulator-only experiments.

pub mod artifact;
pub mod engine;
pub mod host_model;
pub mod pjrt_model;

pub use artifact::{find_artifacts_dir, ModelArtifacts};
pub use engine::Engine;
pub use host_model::{HostMlp, SyntheticGrad};
pub use pjrt_model::PjrtModel;
