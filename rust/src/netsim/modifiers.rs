//! Composable network-environment modifiers.
//!
//! Each wrapper takes any [`NetworkModel`] and perturbs what it reports,
//! replacing the overlay *fields* that used to be baked into
//! `NetSchedule` (`with_jitter`/`with_congestion`) with free-standing
//! compositions: `Congestion(Jitter(c2))`, `Diurnal(trace)`, ...
//!
//! Determinism contract (DESIGN.md §9): every wrapper's perturbation is a
//! pure function of `(its own parameters, epoch)` — stochastic wrappers
//! derive a fresh RNG per 0.1-epoch bucket from their seed, exactly like
//! the old in-schedule overlays, so the same composition replays
//! bit-identically. Composition applies inside-out (the outermost wrapper
//! perturbs last). Stochastic wrappers composed with the SAME seed draw
//! correlated streams — give each overlay its own seed.
//!
//! All wrappers perturb the **inter**-node link only: `topology_at` keeps
//! the inner model's intra link and node shape, mirroring the paper's
//! setup where `tc` shapes the TCP side while in-machine hardware stays
//! fixed.

use crate::netsim::cost_model::{LinkParams, Topology};
use crate::netsim::model::{NetModelError, NetworkModel};
use crate::util::rng::Rng;

/// Per-0.1-epoch-bucket RNG — the same derivation the old in-schedule
/// overlays used, so migrated call sites replay identically.
fn bucket_rng(seed: u64, epoch: f64) -> Rng {
    let bucket = (epoch * 10.0).floor() as u64;
    Rng::new(seed ^ bucket.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Per-worker RNG for draws that must be a pure function of worker id
/// (fast/slow fleet splits). The odd multiplier decorrelates adjacent ids.
fn worker_rng(seed: u64, worker: usize) -> Rng {
    Rng::new(seed ^ (worker as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F))
}

/// Per-(worker, step) RNG for draws that must be a pure function of both
/// (straggler tails) — NOT of thread schedule, preserving DESIGN.md §7.
fn worker_step_rng(seed: u64, worker: usize, step: u64) -> Rng {
    Rng::new(
        seed ^ (worker as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F)
            ^ step.wrapping_mul(0xE703_7ED1_A0B4_28DB),
    )
}

fn bad(modifier: &'static str, reason: String) -> NetModelError {
    NetModelError::BadModifier { modifier, reason }
}

macro_rules! impl_inter_modifier {
    ($ty:ident) => {
        impl NetworkModel for $ty {
            fn link_at(&self, epoch: f64) -> LinkParams {
                self.perturb(self.inner.link_at(epoch), epoch)
            }

            fn topology_at(&self, epoch: f64) -> Topology {
                let mut t = self.inner.topology_at(epoch);
                t.inter = self.perturb(t.inter, epoch);
                t
            }

            // Fleet hooks pass through the stack so e.g. Jitter can wrap a
            // HeterogeneousLinks fleet without flattening it. On a
            // homogeneous inner model `worker_link_at == link_at` bitwise,
            // because the same perturbation hits the same inner link.
            fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
                self.perturb(self.inner.worker_link_at(worker, epoch), epoch)
            }

            fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
                self.inner.straggler_factor(worker, step)
            }

            fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
                self.inner.active_workers_at(epoch, n)
            }

            fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
                self.inner.catchup_cost_at(epoch, model_bytes)
            }

            fn name(&self) -> &str {
                self.inner.name()
            }

            fn describe(&self) -> String {
                format!("{}+{}", self.inner.describe(), self.suffix())
            }

            fn clone_model(&self) -> Box<dyn NetworkModel> {
                Box::new(self.clone())
            }
        }
    };
}

/// Multiplicative observation-free jitter: α and bandwidth each move by a
/// uniform ±`frac` factor, re-drawn deterministically per 0.1-epoch
/// bucket (identical to the old `NetSchedule::with_jitter` overlay).
#[derive(Debug, Clone)]
pub struct Jitter {
    inner: Box<dyn NetworkModel>,
    frac: f64,
    seed: u64,
}

impl Jitter {
    /// `frac` must be in `[0, 1)` (a full-unit jitter could zero the link).
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        frac: f64,
        seed: u64,
    ) -> Result<Jitter, NetModelError> {
        if !(0.0..1.0).contains(&frac) {
            return Err(bad("jitter", format!("frac {frac} outside [0, 1)")));
        }
        Ok(Jitter { inner: Box::new(inner), frac, seed })
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        if self.frac == 0.0 {
            return link;
        }
        let mut rng = bucket_rng(self.seed, epoch);
        let ja = 1.0 + self.frac * (2.0 * rng.f64() - 1.0);
        let jb = 1.0 + self.frac * (2.0 * rng.f64() - 1.0);
        link.alpha *= ja;
        link.beta /= jb; // jitter bandwidth, not beta, symmetrically
        link
    }

    fn suffix(&self) -> String {
        format!("jitter({})", self.frac)
    }
}

impl_inter_modifier!(Jitter);

/// Congestion episodes: with probability `prob` per 0.1-epoch bucket the
/// effective bandwidth collapses by `factor` (identical to the old
/// `NetSchedule::with_congestion` overlay).
#[derive(Debug, Clone)]
pub struct CongestionEpisodes {
    inner: Box<dyn NetworkModel>,
    prob: f64,
    factor: f64,
    seed: u64,
}

impl CongestionEpisodes {
    /// `prob` in `[0, 1]`, `factor >= 1`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        prob: f64,
        factor: f64,
        seed: u64,
    ) -> Result<CongestionEpisodes, NetModelError> {
        if !(0.0..=1.0).contains(&prob) {
            return Err(bad("congestion", format!("prob {prob} outside [0, 1]")));
        }
        if factor.is_nan() || factor < 1.0 {
            return Err(bad("congestion", format!("factor {factor} must be >= 1")));
        }
        Ok(CongestionEpisodes { inner: Box::new(inner), prob, factor, seed })
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        if self.prob == 0.0 {
            return link;
        }
        let mut rng = bucket_rng(self.seed, epoch);
        if rng.f64() < self.prob {
            link.beta *= self.factor;
        }
        link
    }

    fn suffix(&self) -> String {
        format!("congestion({},{})", self.prob, self.factor)
    }
}

impl_inter_modifier!(CongestionEpisodes);

/// Diurnal load: effective bandwidth swings sinusoidally by ±`amplitude`
/// over a `period_epochs` cycle (a shared WAN's day/night utilization —
/// the §2-C2 "resource sharing" variability source). Deterministic, no
/// RNG; latency is untouched (queueing on a shared path shows up as
/// throughput first).
#[derive(Debug, Clone)]
pub struct Diurnal {
    inner: Box<dyn NetworkModel>,
    amplitude: f64,
    period_epochs: f64,
}

impl Diurnal {
    /// `amplitude` in `[0, 1)` (1 would zero the bandwidth at the trough),
    /// `period_epochs > 0`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        amplitude: f64,
        period_epochs: f64,
    ) -> Result<Diurnal, NetModelError> {
        if !(0.0..1.0).contains(&amplitude) {
            return Err(bad("diurnal", format!("amplitude {amplitude} outside [0, 1)")));
        }
        if period_epochs.is_nan() || period_epochs <= 0.0 {
            return Err(bad("diurnal", format!("period {period_epochs} must be > 0")));
        }
        Ok(Diurnal { inner: Box::new(inner), amplitude, period_epochs })
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        let phase = 2.0 * std::f64::consts::PI * epoch / self.period_epochs;
        let mult = 1.0 + self.amplitude * phase.sin();
        link.beta /= mult; // bandwidth × mult  ⇔  β ÷ mult
        link
    }

    fn suffix(&self) -> String {
        format!("diurnal({},{})", self.amplitude, self.period_epochs)
    }
}

impl_inter_modifier!(Diurnal);

/// Link flapping: every `period_epochs` cycle, the last `down_frac` of the
/// cycle reroutes over a `factor`-times-worse backup path (α and β both
/// degrade — a failover crosses extra hops AND loses capacity).
/// Deterministic square wave, no RNG.
#[derive(Debug, Clone)]
pub struct Flapping {
    inner: Box<dyn NetworkModel>,
    period_epochs: f64,
    down_frac: f64,
    factor: f64,
}

impl Flapping {
    /// `period_epochs > 0`, `down_frac` in `(0, 1)`, `factor >= 1`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        period_epochs: f64,
        down_frac: f64,
        factor: f64,
    ) -> Result<Flapping, NetModelError> {
        if period_epochs.is_nan() || period_epochs <= 0.0 {
            return Err(bad("flap", format!("period {period_epochs} must be > 0")));
        }
        if down_frac.is_nan() || down_frac <= 0.0 || down_frac >= 1.0 {
            return Err(bad("flap", format!("down_frac {down_frac} outside (0, 1)")));
        }
        if factor.is_nan() || factor < 1.0 {
            return Err(bad("flap", format!("factor {factor} must be >= 1")));
        }
        Ok(Flapping { inner: Box::new(inner), period_epochs, down_frac, factor })
    }

    /// True when `epoch` falls in the degraded tail of its cycle.
    pub fn is_down(&self, epoch: f64) -> bool {
        let pos = (epoch / self.period_epochs).rem_euclid(1.0);
        pos >= 1.0 - self.down_frac
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        if self.is_down(epoch) {
            link.alpha *= self.factor;
            link.beta *= self.factor;
        }
        link
    }

    fn suffix(&self) -> String {
        format!("flap({},{},{})", self.period_epochs, self.down_frac, self.factor)
    }
}

impl_inter_modifier!(Flapping);

/// Asymmetric degradation: a constant multiplier on α and a constant
/// divisor on bandwidth, independently. Models the paper's observation
/// that latency and bandwidth drift independently (Tables I/II/VI corners:
/// `asym(50, 1)` is the high-α/high-bw regime where Allgather wins).
#[derive(Debug, Clone)]
pub struct AsymmetricDegrade {
    inner: Box<dyn NetworkModel>,
    alpha_mult: f64,
    bw_div: f64,
}

impl AsymmetricDegrade {
    /// Both factors `>= 1` (this wrapper only degrades; at least one may
    /// be exactly 1 for a single-axis perturbation).
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        alpha_mult: f64,
        bw_div: f64,
    ) -> Result<AsymmetricDegrade, NetModelError> {
        if alpha_mult.is_nan() || bw_div.is_nan() || alpha_mult < 1.0 || bw_div < 1.0 {
            return Err(bad(
                "asym",
                format!("factors must be >= 1 (got alpha x{alpha_mult}, bw /{bw_div})"),
            ));
        }
        Ok(AsymmetricDegrade { inner: Box::new(inner), alpha_mult, bw_div })
    }

    fn perturb(&self, mut link: LinkParams, _epoch: f64) -> LinkParams {
        link.alpha *= self.alpha_mult;
        link.beta *= self.bw_div; // bandwidth ÷ d  ⇔  β × d
        link
    }

    fn suffix(&self) -> String {
        format!("asym({},{})", self.alpha_mult, self.bw_div)
    }
}

impl_inter_modifier!(AsymmetricDegrade);

/// Two-level topology overlay: `workers_per_node` ranks share a fixed
/// `intra` link; the wrapped model drives the inter-node side. The generic
/// counterpart of `NetSchedule::with_topology` — it composes over traces
/// and other modifiers too.
#[derive(Debug, Clone)]
pub struct TwoLevel {
    inner: Box<dyn NetworkModel>,
    intra: LinkParams,
    workers_per_node: usize,
}

impl TwoLevel {
    /// `workers_per_node >= 1` (1 degenerates to the flat inner model).
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        intra: LinkParams,
        workers_per_node: usize,
    ) -> Result<TwoLevel, NetModelError> {
        if workers_per_node == 0 {
            return Err(bad("2level", "workers_per_node must be >= 1".into()));
        }
        Ok(TwoLevel { inner: Box::new(inner), intra, workers_per_node })
    }
}

impl NetworkModel for TwoLevel {
    fn link_at(&self, epoch: f64) -> LinkParams {
        self.inner.link_at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        if self.workers_per_node > 1 {
            Topology::two_level(self.intra, self.inner.link_at(epoch), self.workers_per_node)
        } else {
            self.inner.topology_at(epoch)
        }
    }

    fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
        self.inner.worker_link_at(worker, epoch)
    }

    fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
        self.inner.straggler_factor(worker, step)
    }

    fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
        self.inner.active_workers_at(epoch, n)
    }

    fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
        self.inner.catchup_cost_at(epoch, model_bytes)
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn describe(&self) -> String {
        format!("{}+2level(x{})", self.inner.describe(), self.workers_per_node)
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

/// Heterogeneous fleet links: a deterministic `slow_frac` share of workers
/// (keyed by worker id + seed, stable across the whole run) rides a
/// `degrade`-times-worse path — α multiplied, bandwidth divided. The
/// fleet-shared `link_at` stays the inner model's backbone view (that is
/// what the probe measures and what homogeneous fast paths price), so
/// every consumer that never asks per-worker is untouched bitwise.
#[derive(Debug, Clone)]
pub struct HeterogeneousLinks {
    inner: Box<dyn NetworkModel>,
    slow_frac: f64,
    degrade: f64,
    seed: u64,
}

impl HeterogeneousLinks {
    /// `slow_frac` in `[0, 1]`, `degrade >= 1`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        slow_frac: f64,
        degrade: f64,
        seed: u64,
    ) -> Result<HeterogeneousLinks, NetModelError> {
        if !(0.0..=1.0).contains(&slow_frac) {
            return Err(bad("hetero", format!("slow_frac {slow_frac} outside [0, 1]")));
        }
        if degrade.is_nan() || degrade < 1.0 {
            return Err(bad("hetero", format!("degrade {degrade} must be >= 1")));
        }
        Ok(HeterogeneousLinks { inner: Box::new(inner), slow_frac, degrade, seed })
    }

    /// True when `worker` is on the degraded path — a pure function of
    /// (seed, worker), so the fast/slow split never moves mid-run.
    pub fn is_slow(&self, worker: usize) -> bool {
        worker_rng(self.seed, worker).f64() < self.slow_frac
    }

    fn suffix(&self) -> String {
        format!("hetero({},{})", self.slow_frac, self.degrade)
    }
}

impl NetworkModel for HeterogeneousLinks {
    fn link_at(&self, epoch: f64) -> LinkParams {
        self.inner.link_at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        self.inner.topology_at(epoch)
    }

    fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
        let mut l = self.inner.worker_link_at(worker, epoch);
        if self.is_slow(worker) {
            l.alpha *= self.degrade;
            l.beta *= self.degrade; // bandwidth ÷ d  ⇔  β × d
        }
        l
    }

    fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
        self.inner.straggler_factor(worker, step)
    }

    fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
        self.inner.active_workers_at(epoch, n)
    }

    fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
        self.inner.catchup_cost_at(epoch, model_bytes)
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn describe(&self) -> String {
        format!("{}+{}", self.inner.describe(), self.suffix())
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

/// Straggler tail on compute: with probability `prob` per (worker, step),
/// that worker's compute time stretches by a uniform draw in
/// `[1, slowdown]` — the tail-latency distribution Agarwal et al. show
/// inverts compression speedup claims. A pure function of
/// `(worker, step, seed)`, composing multiplicatively over any inner
/// straggler source; links are untouched.
#[derive(Debug, Clone)]
pub struct StragglerTail {
    inner: Box<dyn NetworkModel>,
    prob: f64,
    slowdown: f64,
    seed: u64,
}

impl StragglerTail {
    /// `prob` in `[0, 1]`, `slowdown >= 1`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        prob: f64,
        slowdown: f64,
        seed: u64,
    ) -> Result<StragglerTail, NetModelError> {
        if !(0.0..=1.0).contains(&prob) {
            return Err(bad("straggler", format!("prob {prob} outside [0, 1]")));
        }
        if slowdown.is_nan() || slowdown < 1.0 {
            return Err(bad("straggler", format!("slowdown {slowdown} must be >= 1")));
        }
        Ok(StragglerTail { inner: Box::new(inner), prob, slowdown, seed })
    }

    /// This wrapper's own factor (before composing with the inner model).
    pub fn factor(&self, worker: usize, step: u64) -> f64 {
        if self.prob == 0.0 {
            return 1.0;
        }
        let mut rng = worker_step_rng(self.seed, worker, step);
        if rng.f64() < self.prob {
            1.0 + rng.f64() * (self.slowdown - 1.0)
        } else {
            1.0
        }
    }

    fn suffix(&self) -> String {
        format!("straggler({},{})", self.prob, self.slowdown)
    }
}

impl NetworkModel for StragglerTail {
    fn link_at(&self, epoch: f64) -> LinkParams {
        self.inner.link_at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        self.inner.topology_at(epoch)
    }

    fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
        self.inner.worker_link_at(worker, epoch)
    }

    fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
        self.factor(worker, step) * self.inner.straggler_factor(worker, step)
    }

    fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
        self.inner.active_workers_at(epoch, n)
    }

    fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
        self.inner.catchup_cost_at(epoch, model_bytes)
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn describe(&self) -> String {
        format!("{}+{}", self.inner.describe(), self.suffix())
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

/// Elastic membership: a schedule of `(epoch, frac)` events, each shifting
/// the live-worker count by `frac` of the configured fleet (negative =
/// leave, positive = join). The count is clamped to `[1, n]` — the numeric
/// engine sizes per-worker state up front, so churn idles workers rather
/// than minting new ones. A join declares a catch-up cost: the joiner
/// streams the current model over the link at the event's epoch,
/// `catchup_factor × (α + M·β)` — charged once per observed growth by
/// whichever engine notices the membership edge.
#[derive(Debug, Clone)]
pub struct Churn {
    inner: Box<dyn NetworkModel>,
    events: Vec<(f64, f64)>,
    catchup_factor: f64,
}

impl Churn {
    /// `events` non-empty with finite, strictly increasing, non-negative
    /// epochs and finite non-zero fractions; `catchup_factor >= 0`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        events: Vec<(f64, f64)>,
        catchup_factor: f64,
    ) -> Result<Churn, NetModelError> {
        if events.is_empty() {
            return Err(bad("churn", "no membership events".into()));
        }
        let mut prev = f64::NEG_INFINITY;
        for &(e, d) in &events {
            if !e.is_finite() || e < 0.0 {
                return Err(bad("churn", format!("event epoch {e} must be finite >= 0")));
            }
            if e <= prev {
                return Err(bad("churn", format!("event epochs must strictly increase at {e}")));
            }
            if !d.is_finite() || d == 0.0 {
                return Err(bad("churn", format!("event frac {d} must be finite nonzero")));
            }
            prev = e;
        }
        if catchup_factor.is_nan() || catchup_factor < 0.0 {
            return Err(bad("churn", format!("catchup_factor {catchup_factor} must be >= 0")));
        }
        Ok(Churn { inner: Box::new(inner), events, catchup_factor })
    }

    fn suffix(&self) -> String {
        format!("churn({}ev,x{})", self.events.len(), self.catchup_factor)
    }
}

impl NetworkModel for Churn {
    fn link_at(&self, epoch: f64) -> LinkParams {
        self.inner.link_at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        self.inner.topology_at(epoch)
    }

    fn worker_link_at(&self, worker: usize, epoch: f64) -> LinkParams {
        self.inner.worker_link_at(worker, epoch)
    }

    fn straggler_factor(&self, worker: usize, step: u64) -> f64 {
        self.inner.straggler_factor(worker, step)
    }

    fn active_workers_at(&self, epoch: f64, n: usize) -> usize {
        let base = self.inner.active_workers_at(epoch, n);
        let cum: f64 =
            self.events.iter().filter(|(e, _)| *e <= epoch).map(|(_, d)| d).sum();
        let scaled = (base as f64 * (1.0 + cum).max(0.0)).round() as usize;
        scaled.clamp(1, base)
    }

    fn catchup_cost_at(&self, epoch: f64, model_bytes: f64) -> f64 {
        match self.events.iter().rev().find(|(e, _)| *e <= epoch) {
            Some(&(_, d)) if d > 0.0 => {
                let l = self.inner.link_at(epoch);
                self.catchup_factor * (l.alpha + model_bytes * l.beta)
                    + self.inner.catchup_cost_at(epoch, model_bytes)
            }
            _ => self.inner.catchup_cost_at(epoch, model_bytes),
        }
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn describe(&self) -> String {
        format!("{}+{}", self.inner.describe(), self.suffix())
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::NetSchedule;
    use crate::util::proptest::{check, ensure};

    fn base() -> NetSchedule {
        NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))
    }

    /// The DELETED `NetSchedule::at` overlay logic, verbatim — the
    /// "before" reference that pins the migration as a no-behavior-change
    /// refactor: a lone jitter (or congestion) wrapper must reproduce the
    /// old in-schedule overlay bit-for-bit.
    fn legacy_overlay(
        mut link: LinkParams,
        epoch: f64,
        jitter_frac: f64,
        congestion_prob: f64,
        congestion_factor: f64,
        seed: u64,
    ) -> LinkParams {
        if jitter_frac == 0.0 && congestion_prob == 0.0 {
            return link;
        }
        let bucket = (epoch * 10.0).floor() as u64;
        let mut rng = Rng::new(seed ^ bucket.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if jitter_frac > 0.0 {
            let ja = 1.0 + jitter_frac * (2.0 * rng.f64() - 1.0);
            let jb = 1.0 + jitter_frac * (2.0 * rng.f64() - 1.0);
            link.alpha *= ja;
            link.beta /= jb;
        }
        if congestion_prob > 0.0 && rng.f64() < congestion_prob {
            link.beta *= congestion_factor;
        }
        link
    }

    #[test]
    fn jitter_wrapper_is_bitwise_equal_to_the_old_overlay() {
        check("jitter == legacy with_jitter", 300, |g| {
            let frac = g.f64_in(0.0, 0.5);
            let seed = g.rng.next_u64();
            let epoch = g.f64_in(0.0, 60.0);
            let j = Jitter::wrap(base(), frac, seed).unwrap();
            let got = j.link_at(epoch);
            let want = legacy_overlay(base().at(epoch), epoch, frac, 0.0, 1.0, seed);
            ensure(
                got.alpha.to_bits() == want.alpha.to_bits()
                    && got.beta.to_bits() == want.beta.to_bits(),
                format!("epoch {epoch} frac {frac} seed {seed}: {got:?} vs {want:?}"),
            )
        });
    }

    #[test]
    fn congestion_wrapper_is_bitwise_equal_to_the_old_overlay() {
        check("congestion == legacy with_congestion", 300, |g| {
            let prob = g.f64_in(0.0, 1.0);
            let factor = g.f64_in(1.0, 20.0);
            let seed = g.rng.next_u64();
            let epoch = g.f64_in(0.0, 60.0);
            let c = CongestionEpisodes::wrap(base(), prob, factor, seed).unwrap();
            let got = c.link_at(epoch);
            let want = legacy_overlay(base().at(epoch), epoch, 0.0, prob, factor, seed);
            ensure(
                got.alpha.to_bits() == want.alpha.to_bits()
                    && got.beta.to_bits() == want.beta.to_bits(),
                format!("epoch {epoch} prob {prob} seed {seed}: {got:?} vs {want:?}"),
            )
        });
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let s = Jitter::wrap(NetSchedule::c1(50.0), 0.1, 7).unwrap();
        let a = s.link_at(3.14);
        let b = s.link_at(3.14);
        assert_eq!(a, b, "same epoch must give same link");
        let base = NetSchedule::c1(50.0).at(3.14);
        assert!((a.alpha / base.alpha - 1.0).abs() <= 0.1 + 1e-9);
        let ratio = base.beta / a.beta;
        assert!((ratio - 1.0).abs() <= 0.1 + 1e-9);
    }

    #[test]
    fn congestion_reduces_bandwidth_sometimes() {
        let s = CongestionEpisodes::wrap(
            NetSchedule::static_link(LinkParams::from_ms_gbps(1.0, 10.0)),
            0.5,
            10.0,
            3,
        )
        .unwrap();
        let (mut congested, mut free) = (0, 0);
        for i in 0..200 {
            let l = s.link_at(i as f64 * 0.1);
            if l.bw_gbps() < 2.0 {
                congested += 1;
            } else {
                free += 1;
            }
        }
        assert!(congested > 30, "{congested}");
        assert!(free > 30, "{free}");
    }

    #[test]
    fn diurnal_cycles_bandwidth_and_keeps_it_positive() {
        let d = Diurnal::wrap(base(), 0.5, 10.0).unwrap();
        let bw = |e: f64| d.link_at(e).bw_gbps();
        // Quarter-cycle peak, three-quarter trough, node at cycle ends.
        assert!((bw(2.5) - 30.0).abs() < 1e-6, "{}", bw(2.5));
        assert!((bw(7.5) - 10.0).abs() < 1e-6, "{}", bw(7.5));
        assert!((bw(0.0) - 20.0).abs() < 1e-6);
        assert!((bw(10.0) - 20.0).abs() < 1e-6);
        for i in 0..100 {
            let l = d.link_at(i as f64 * 0.37);
            assert!(l.beta > 0.0 && l.beta.is_finite());
            assert_eq!(l.alpha, 4e-3, "diurnal must not touch latency");
        }
    }

    #[test]
    fn flapping_degrades_exactly_the_down_window() {
        let f = Flapping::wrap(base(), 10.0, 0.3, 16.0).unwrap();
        let up = f.link_at(2.0);
        let down = f.link_at(8.0); // pos 0.8 >= 0.7
        assert!(!f.is_down(2.0) && f.is_down(8.0));
        assert!((down.alpha / up.alpha - 16.0).abs() < 1e-9);
        assert!((down.beta / up.beta - 16.0).abs() < 1e-9);
        // Periodic: the next cycle flaps the same way.
        assert_eq!(f.link_at(18.0), down);
        assert_eq!(f.link_at(12.0), up);
    }

    #[test]
    fn asymmetric_degrade_moves_one_axis_at_a_time() {
        let lat = AsymmetricDegrade::wrap(base(), 50.0, 1.0).unwrap();
        let l = lat.link_at(0.0);
        assert!((l.alpha_ms() - 200.0).abs() < 1e-9);
        assert!((l.bw_gbps() - 20.0).abs() < 1e-9);
        let bw = AsymmetricDegrade::wrap(base(), 1.0, 4.0).unwrap();
        let l = bw.link_at(0.0);
        assert!((l.alpha_ms() - 4.0).abs() < 1e-9);
        assert!((l.bw_gbps() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn two_level_overlay_drives_inter_only() {
        let intra = LinkParams::from_ms_gbps(0.01, 100.0);
        let m = TwoLevel::wrap(
            Jitter::wrap(NetSchedule::c1(50.0), 0.1, 9).unwrap(),
            intra,
            4,
        )
        .unwrap();
        for epoch in [0.0, 13.0, 26.0, 40.0] {
            let t = m.topology_at(epoch);
            assert_eq!(t.workers_per_node, 4);
            // The inter side follows the (jittered) schedule...
            assert_eq!(t.inter, m.link_at(epoch));
            // ...while the intra link stays the fixed in-machine hardware.
            assert_eq!(t.intra, intra);
        }
    }

    #[test]
    fn modifiers_perturb_only_the_inter_link_of_two_level_inner_models() {
        let intra = LinkParams::from_ms_gbps(0.01, 100.0);
        let sched = NetSchedule::c1(50.0).with_topology(intra, 2);
        let j = Jitter::wrap(sched, 0.2, 5).unwrap();
        let t = j.topology_at(3.0);
        assert_eq!(t.intra, intra);
        assert_eq!(t.workers_per_node, 2);
        assert_eq!(t.inter, j.link_at(3.0));
    }

    #[test]
    fn describe_records_the_composition_in_order() {
        let m = CongestionEpisodes::wrap(
            Jitter::wrap(NetSchedule::c2(50.0), 0.15, 13).unwrap(),
            0.2,
            8.0,
            14,
        )
        .unwrap();
        assert_eq!(m.describe(), "c2+jitter(0.15)+congestion(0.2,8)");
        assert_eq!(m.name(), "c2", "base name survives wrapping");
    }

    #[test]
    fn bad_compositions_are_typed_errors() {
        assert!(matches!(
            Jitter::wrap(base(), 1.5, 0),
            Err(NetModelError::BadModifier { modifier: "jitter", .. })
        ));
        assert!(matches!(
            Jitter::wrap(base(), f64::NAN, 0),
            Err(NetModelError::BadModifier { .. })
        ));
        assert!(CongestionEpisodes::wrap(base(), 1.1, 2.0, 0).is_err());
        assert!(CongestionEpisodes::wrap(base(), 0.5, 0.5, 0).is_err());
        assert!(Diurnal::wrap(base(), 1.0, 10.0).is_err());
        assert!(Diurnal::wrap(base(), 0.5, 0.0).is_err());
        assert!(Flapping::wrap(base(), 0.0, 0.3, 2.0).is_err());
        assert!(Flapping::wrap(base(), 1.0, 1.0, 2.0).is_err());
        assert!(Flapping::wrap(base(), 1.0, 0.3, 0.9).is_err());
        assert!(AsymmetricDegrade::wrap(base(), 0.5, 1.0).is_err());
        assert!(TwoLevel::wrap(base(), LinkParams::from_ms_gbps(0.01, 100.0), 0).is_err());
        assert!(matches!(
            HeterogeneousLinks::wrap(base(), 1.5, 2.0, 0),
            Err(NetModelError::BadModifier { modifier: "hetero", .. })
        ));
        assert!(HeterogeneousLinks::wrap(base(), 0.5, 0.9, 0).is_err());
        assert!(matches!(
            StragglerTail::wrap(base(), -0.1, 2.0, 0),
            Err(NetModelError::BadModifier { modifier: "straggler", .. })
        ));
        assert!(StragglerTail::wrap(base(), 0.1, 0.5, 0).is_err());
        assert!(matches!(
            Churn::wrap(base(), vec![], 1.0),
            Err(NetModelError::BadModifier { modifier: "churn", .. })
        ));
        assert!(Churn::wrap(base(), vec![(1.0, -0.2), (1.0, 0.2)], 1.0).is_err());
        assert!(Churn::wrap(base(), vec![(1.0, 0.0)], 1.0).is_err());
        assert!(Churn::wrap(base(), vec![(-1.0, 0.2)], 1.0).is_err());
        assert!(Churn::wrap(base(), vec![(1.0, 0.2)], -1.0).is_err());
    }

    #[test]
    fn hetero_splits_the_fleet_deterministically_and_leaves_link_at_alone() {
        let h = HeterogeneousLinks::wrap(base(), 0.25, 8.0, 22).unwrap();
        let shared = h.link_at(3.0);
        assert_eq!(shared, base().at(3.0), "backbone view untouched");
        let n = 1024;
        let mut slow = 0;
        for w in 0..n {
            let l = h.worker_link_at(w, 3.0);
            assert_eq!(h.is_slow(w), l != shared, "worker {w}");
            if h.is_slow(w) {
                slow += 1;
                assert!((l.alpha / shared.alpha - 8.0).abs() < 1e-12);
                assert!((l.beta / shared.beta - 8.0).abs() < 1e-12);
            } else {
                assert_eq!(l, shared);
            }
            // Stable across epochs: the split is keyed by id, not time.
            assert_eq!(h.is_slow(w), h.worker_link_at(w, 40.0) != h.link_at(40.0));
        }
        let frac = slow as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.05, "slow share {frac}");
    }

    #[test]
    fn straggler_tail_is_pure_bounded_and_hits_its_rate() {
        check("straggler factor pure + bounded", 200, |g| {
            let prob = g.f64_in(0.0, 1.0);
            let slow = g.f64_in(1.0, 16.0);
            let seed = g.rng.next_u64();
            let s = StragglerTail::wrap(base(), prob, slow, seed).unwrap();
            let w = g.usize_in(0, 4096);
            let step = g.usize_in(0, 10_000) as u64;
            let f = s.straggler_factor(w, step);
            ensure(
                f >= 1.0 && f <= slow + 1e-12 && f == s.straggler_factor(w, step),
                format!("factor {f} for prob {prob} slow {slow}"),
            )
        });
        let s = StragglerTail::wrap(base(), 0.1, 8.0, 21).unwrap();
        let mut hits = 0;
        let trials = 4000;
        for w in 0..200 {
            for step in 0..(trials / 200) {
                if s.straggler_factor(w, step) > 1.0 {
                    hits += 1;
                }
            }
        }
        let rate = hits as f64 / trials as f64;
        assert!((rate - 0.1).abs() < 0.03, "straggler rate {rate}");
        // Links and topology are untouched.
        assert_eq!(s.link_at(2.0), base().at(2.0));
        assert_eq!(s.worker_link_at(7, 2.0), base().at(2.0));
    }

    #[test]
    fn churn_walks_its_schedule_and_declares_catchup_on_joins_only() {
        let events = vec![(5.0, -0.25), (10.0, -0.125), (15.0, 0.375)];
        let c = Churn::wrap(base(), events, 1.0).unwrap();
        let n = 1024;
        assert_eq!(c.active_workers_at(0.0, n), 1024);
        assert_eq!(c.active_workers_at(5.0, n), 768);
        assert_eq!(c.active_workers_at(12.0, n), 640);
        assert_eq!(c.active_workers_at(20.0, n), 1024);
        // Clamped to >= 1 even if the schedule would empty the fleet.
        let drain = Churn::wrap(base(), vec![(1.0, -2.0)], 0.0).unwrap();
        assert_eq!(drain.active_workers_at(2.0, 8), 1);
        // Never exceeds the configured fleet.
        let grow = Churn::wrap(base(), vec![(1.0, 3.0)], 0.0).unwrap();
        assert_eq!(grow.active_workers_at(2.0, 8), 8);
        // Catch-up: zero before any event and after leaves; the declared
        // join cost is the model stream over the link at that epoch.
        let m = 1e8;
        assert_eq!(c.catchup_cost_at(0.0, m), 0.0);
        assert_eq!(c.catchup_cost_at(7.0, m), 0.0);
        let l = base().at(16.0);
        let want = l.alpha + m * l.beta;
        assert!((c.catchup_cost_at(16.0, m) - want).abs() < 1e-12);
    }

    #[test]
    fn outer_modifiers_preserve_per_worker_structure() {
        let h = HeterogeneousLinks::wrap(base(), 0.5, 4.0, 9).unwrap();
        let j = Jitter::wrap(h.clone(), 0.1, 5).unwrap();
        // Jitter perturbs every worker's link the same way per epoch, so
        // the slow/fast ratio survives wrapping.
        let (slow, fast) = (0..64)
            .map(|w| (w, h.is_slow(w)))
            .fold((None, None), |(s, f), (w, is)| if is { (Some(w), f) } else { (s, Some(w)) });
        let (ws, wf) = (slow.unwrap(), fast.unwrap());
        let (ls, lf) = (j.worker_link_at(ws, 2.0), j.worker_link_at(wf, 2.0));
        assert!((ls.alpha / lf.alpha - 4.0).abs() < 1e-9);
        assert!((ls.beta / lf.beta - 4.0).abs() < 1e-9);
        // And the straggler/churn hooks pass through macro'd wrappers.
        let st = Jitter::wrap(
            StragglerTail::wrap(base(), 1.0, 4.0, 3).unwrap(),
            0.1,
            6,
        )
        .unwrap();
        assert!(st.straggler_factor(0, 0) > 1.0);
        let ch = Jitter::wrap(
            Churn::wrap(base(), vec![(1.0, -0.5)], 1.0).unwrap(),
            0.1,
            6,
        )
        .unwrap();
        assert_eq!(ch.active_workers_at(2.0, 8), 4);
        assert_eq!(ch.describe(), "static+churn(1ev,x1)+jitter(0.1)");
    }
}
