//! Session-API acceptance tests (DESIGN.md §8), from OUTSIDE the crate:
//! a custom `CommStrategy` written in this test file trains end-to-end
//! with zero trainer changes, builder misconfigurations surface as typed
//! errors, and the observer stream carries the whole run.

use flexcomm::collectives::{CollectiveKind, CommReport};
use flexcomm::coordinator::controller::AdaptiveConfig;
use flexcomm::coordinator::observer::{CrChange, CsvSink, EvalRecord, NetChange, TrainObserver};
use flexcomm::coordinator::session::{ConfigError, Session};
use flexcomm::coordinator::strategy::{
    CommPlan, CommStrategy, ExchangeCtx, ExchangeOutcome, StepCtx,
};
use flexcomm::coordinator::trainer::Strategy;
use flexcomm::coordinator::worker::ComputeModel;
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::netsim::model::NetModelError;
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::netsim::trace::TraceModel;
use flexcomm::runtime::HostMlp;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A strategy the crate has never heard of: exact mean of the raw
/// gradients with NO communication at all (an oracle "infinitely fast
/// network" baseline). Registered purely through the builder — no
/// trainer.rs, strategy.rs or enum changes.
struct InstantMean;

impl CommStrategy for InstantMean {
    fn name(&self) -> &'static str {
        "instant-mean"
    }

    fn is_compressed(&self) -> bool {
        false
    }

    fn plan(&self, _ctx: &StepCtx) -> CommPlan {
        CommPlan::unpriced(CollectiveKind::Custom("instant-mean"))
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
        let n = ctx.n_workers();
        let mut update = vec![0.0f32; ctx.dim()];
        for g in ctx.grads {
            for (u, v) in update.iter_mut().zip(g) {
                *u += *v;
            }
        }
        for u in update.iter_mut() {
            *u /= n as f32;
        }
        ExchangeOutcome {
            update,
            comm: CommReport::default(),
            t_comp: 0.0,
            collective: CollectiveKind::Custom("instant-mean"),
            selected_rank: None,
            gain: 1.0,
        }
    }
}

/// Acceptance: a new strategy drives a full training run from a test
/// file. Its numerics equal DenseSGD's exact mean, so it must learn.
#[test]
fn custom_strategy_trains_end_to_end() {
    let report = Session::builder()
        .workers(4)
        .steps(120)
        .steps_per_epoch(20)
        .lr(0.3)
        .momentum(0.6)
        .comm_strategy(Box::new(InstantMean))
        .static_cr(1.0)
        .compute(ComputeModel::fixed(0.01))
        .eval_every(0)
        .seed(42)
        .source(Box::new(HostMlp::default_preset(7)))
        .build()
        .expect("custom strategy builds")
        .run();
    assert_eq!(report.strategy, "instant-mean");
    let acc = report.final_accuracy().unwrap();
    assert!(acc > 0.8, "instant-mean accuracy {acc}");
    // The custom kind is a first-class metrics identity...
    assert!(report
        .metrics
        .collectives_used()
        .iter()
        .all(|c| *c == CollectiveKind::Custom("instant-mean")));
    assert!(report.metrics.to_csv().contains("instant-mean"));
    // ...and no communication was ever charged.
    assert!(report.metrics.steps.iter().all(|m| m.t_sync == 0.0));
}

#[test]
fn builder_rejects_misconfigurations_with_typed_errors() {
    let base = || {
        Session::builder()
            .workers(4)
            .steps(1)
            .compute(ComputeModel::fixed(0.01))
            .source(Box::new(HostMlp::default_preset(1)))
    };
    assert_eq!(base().workers(0).build().err(), Some(ConfigError::ZeroWorkers));
    assert!(matches!(
        base().static_cr(0.0).build().err(),
        Some(ConfigError::CrOutOfRange(_))
    ));
    let ragged = NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))
        .with_topology(LinkParams::from_ms_gbps(0.01, 100.0), 4);
    assert_eq!(
        base().workers(6).schedule(ragged).build().err(),
        Some(ConfigError::RaggedTopology { n_workers: 6, workers_per_node: 4 })
    );
    assert!(matches!(
        base()
            .strategy(Strategy::parse("dense-ring").unwrap())
            .adaptive_cr(AdaptiveConfig::default())
            .build()
            .err(),
        Some(ConfigError::AdaptiveNeedsCompression { .. })
    ));
    // Network environments reject with typed errors too: unknown scenario
    // specs and unloadable traces (ISSUE 4 tentpole).
    assert!(matches!(
        base().network_spec("not-a-scenario").build().err(),
        Some(ConfigError::Network(NetModelError::UnknownScenario { .. }))
    ));
    assert!(matches!(
        base().network_spec("trace:/no/such/file.csv").build().err(),
        Some(ConfigError::Network(NetModelError::TraceIo { .. }))
    ));
    // Model registry rejections surface typed too (ISSUE 8): an unknown
    // `--model` spec names the registry in its message.
    let err = Session::builder()
        .workers(4)
        .steps(1)
        .compute(ComputeModel::fixed(0.01))
        .model_spec("not-a-model")
        .build()
        .err();
    assert!(matches!(err, Some(ConfigError::Model(_))), "{err:?}");
    assert!(err.unwrap().to_string().contains("matreg"), "message lists registry");
}

/// ISSUE 8 acceptance: both real learners resolve from the registry via
/// `.model_spec(..)`, demonstrably learn under exact DenseSGD, and stay
/// within tolerance of the dense accuracy under AG-Topk at CR = 0.1 —
/// compression costs bytes, not convergence.
#[test]
fn real_models_learn_dense_and_survive_compression() {
    // (spec, lr hint, chance-level accuracy floor for that dataset).
    for (model, lr, chance) in [("mlp", 0.3f32, 0.5), ("matreg", 0.05, 0.1)] {
        let run = |strategy: &str, cr: f64| {
            Session::builder()
                .workers(4)
                .steps(400)
                .steps_per_epoch(100)
                .lr(lr)
                .momentum(0.9)
                .strategy(Strategy::parse(strategy).unwrap())
                .static_cr(cr)
                .compute(ComputeModel::fixed(0.005))
                .eval_every(100)
                .seed(7)
                .model_spec(model)
                .build()
                .expect("registry model builds")
                .run()
        };
        let dense = run("dense-ring", 1.0);
        let dense_acc = dense.best_accuracy().unwrap();
        assert!(
            dense_acc > chance + 0.15,
            "{model}: dense best acc {dense_acc} not clearly above chance {chance}"
        );
        let comp = run("ag-topk", 0.1);
        let comp_acc = comp.best_accuracy().unwrap();
        assert!(
            comp_acc > chance,
            "{model}: compressed best acc {comp_acc} at or below chance {chance}"
        );
        assert!(
            comp_acc >= dense_acc - 0.25,
            "{model}: CR=0.1 destroyed learning: dense {dense_acc} vs compressed {comp_acc}"
        );
    }
}

#[derive(Default)]
struct StreamCounts {
    steps: AtomicU64,
    evals: AtomicU64,
    cr_changes: AtomicU64,
}

struct StreamCounter(Arc<StreamCounts>);

impl TrainObserver for StreamCounter {
    fn on_step(&mut self, _m: &flexcomm::coordinator::metrics::StepMetrics) {
        self.0.steps.fetch_add(1, Ordering::Relaxed);
    }
    fn on_eval(&mut self, _e: &EvalRecord) {
        self.0.evals.fetch_add(1, Ordering::Relaxed);
    }
    fn on_cr_change(&mut self, c: &CrChange) {
        assert!(c.to > 0.0 && c.to <= 1.0, "cr change out of range: {c:?}");
        self.0.cr_changes.fetch_add(1, Ordering::Relaxed);
    }
}

/// The observer stream covers the whole run: every recorded step, every
/// eval (periodic + final), and the adaptive controller's CR decisions.
#[test]
fn observer_stream_carries_the_whole_run() {
    // Parameters mirror the in-crate adaptive test that pins ">= 2
    // distinct CRs used" (C2 phase changes force re-solves), so at least
    // one CR change is guaranteed to land on the stream.
    let counts = Arc::new(StreamCounts::default());
    let report = Session::builder()
        .workers(4)
        .steps(100)
        .steps_per_epoch(25)
        .lr(0.3)
        .momentum(0.6)
        .strategy(Strategy::parse("flexible").unwrap())
        .adaptive_cr(AdaptiveConfig { probe_iters: 3, ..Default::default() })
        .schedule(NetSchedule::c2(4.0))
        .compute(ComputeModel::fixed(0.005))
        .eval_every(25)
        .seed(5)
        .observer(Box::new(StreamCounter(counts.clone())))
        .source(Box::new(HostMlp::default_preset(11)))
        .build()
        .expect("valid adaptive config")
        .run();
    assert_eq!(counts.steps.load(Ordering::Relaxed), 100);
    assert_eq!(
        counts.steps.load(Ordering::Relaxed) as usize,
        report.metrics.steps.len(),
        "observer stream and recorder must agree"
    );
    // 100 steps / eval_every 25 = 4 periodic evals; the final eval folds
    // into the last periodic one (steps divisible by eval_every), so no
    // duplicate eval of the same parameters.
    assert_eq!(counts.evals.load(Ordering::Relaxed), 4);
    assert_eq!(counts.evals.load(Ordering::Relaxed) as usize, report.metrics.evals.len());
    // Every distinct recorded CR beyond the first implies a fired event.
    let distinct: std::collections::BTreeSet<u64> =
        report.metrics.crs_used().iter().map(|c| (c * 1e9) as u64).collect();
    assert!(distinct.len() >= 2, "adaptive CR never moved: {distinct:?}");
    assert!(counts.cr_changes.load(Ordering::Relaxed) >= 1);
}

/// ISSUE 5 acceptance, from outside the crate: the `gravac` controller is
/// a drop-in via `.controller_spec(..)`, steers the CR ladder during a
/// real run, attributes its decisions on the observer stream, and the
/// report names it.
#[test]
fn gravac_controller_walks_the_ladder_end_to_end() {
    struct CrLog(Arc<std::sync::Mutex<Vec<CrChange>>>);
    impl TrainObserver for CrLog {
        fn on_cr_change(&mut self, c: &CrChange) {
            self.0.lock().unwrap().push(*c);
        }
    }
    let changes = Arc::new(std::sync::Mutex::new(Vec::new()));
    let report = Session::builder()
        .workers(4)
        .steps(120)
        .steps_per_epoch(25)
        .lr(0.3)
        .momentum(0.6)
        .strategy(Strategy::parse("flexible").unwrap())
        .static_cr(0.05)
        .controller_spec("gravac")
        .schedule(NetSchedule::c2(4.0))
        .compute(ComputeModel::fixed(0.005))
        .seed(5)
        .observer(Box::new(CrLog(changes.clone())))
        .source(Box::new(HostMlp::default_preset(11)))
        .build()
        .expect("gravac config valid")
        .run();
    assert_eq!(report.controller, "gravac");
    // No checkpointed exploration ever runs: the ladder walk is free.
    assert_eq!(report.explore_overhead_s, 0.0);
    let changes = changes.lock().unwrap();
    assert!(!changes.is_empty(), "gravac never moved the CR");
    for c in changes.iter() {
        assert_eq!(c.by, "gravac");
        assert!(
            c.reason == "ladder-descend" || c.reason == "gain-collapse",
            "unexpected reason {c:?}"
        );
        assert!(c.to > 0.0 && c.to <= 0.1 + 1e-12, "{c:?}");
    }
    // The first move is always a descent from the ladder top.
    assert_eq!(changes[0].reason, "ladder-descend");
    assert!((changes[0].from - 0.1).abs() < 1e-12, "{:?}", changes[0]);
    assert!(report.best_accuracy().unwrap() > 0.6);
}

struct NetChangeLog(Arc<std::sync::Mutex<Vec<NetChange>>>);

impl TrainObserver for NetChangeLog {
    fn on_net_change(&mut self, n: &NetChange) {
        self.0.lock().unwrap().push(*n);
    }
}

/// `on_net_change` fires exactly at the environment's ground-truth
/// boundaries: C1 over 3 virtual epochs has 3 phase changes after epoch 0,
/// each visible from the typed observer stream so CSV consumers can
/// correlate strategy switches with the network events that caused them.
#[test]
fn net_change_events_track_phase_boundaries() {
    let changes = Arc::new(std::sync::Mutex::new(Vec::new()));
    let report = Session::builder()
        .workers(4)
        .steps(60)
        .steps_per_epoch(20) // 3 virtual epochs: C1 breaks at 0.72/1.44/2.16
        .strategy(Strategy::parse("flexible").unwrap())
        .static_cr(0.05)
        .network(NetSchedule::c1(3.0))
        .compute(ComputeModel::fixed(0.005))
        .seed(3)
        .observer(Box::new(NetChangeLog(changes.clone())))
        .source(Box::new(HostMlp::default_preset(3)))
        .build()
        .expect("valid config")
        .run();
    assert_eq!(report.network, "c1");
    let changes = changes.lock().unwrap();
    assert_eq!(changes.len(), 3, "one event per crossed phase boundary: {changes:?}");
    for c in changes.iter() {
        assert_ne!(c.from, c.to, "events only on real changes: {c:?}");
        assert!(c.step > 0 && c.step < 60);
    }
    // Sanity: the first C1 break is 25 Gbps -> 1 Gbps at epoch 0.72.
    assert_eq!(changes[0].to.bw_gbps().round(), 1.0);
    assert!((changes[0].epoch - 0.75).abs() < 0.05, "{:?}", changes[0]);
}

/// ISSUE 4 acceptance: a trace-file-driven run works end-to-end via
/// `Session::builder().network(TraceModel::load(path)?)`, and the CSV
/// output names the scenario.
#[test]
fn trace_file_drives_a_run_end_to_end() {
    let dir = std::env::temp_dir();
    let trace_path = dir.join("flexcomm_session_api_trace.csv");
    let csv_path = dir.join("flexcomm_session_api_trace_out.csv");
    std::fs::write(&trace_path, "epoch,alpha_ms,bw_gbps\n0,1,25\n1,50,1\n2,4,20\n").unwrap();

    let run = || -> Result<(), ConfigError> {
        let session = Session::builder()
            .workers(4)
            .steps(50)
            .steps_per_epoch(20)
            .strategy(Strategy::parse("flexible").unwrap())
            .static_cr(0.05)
            .network(TraceModel::load(trace_path.to_str().unwrap())?)
            .compute(ComputeModel::fixed(0.005))
            .seed(9)
            .source(Box::new(HostMlp::default_preset(9)))
            .build()?;
        let scenario = session.network_describe();
        let session = session.observer(Box::new(
            CsvSink::create_with_scenario(csv_path.to_str().unwrap(), &scenario).unwrap(),
        ));
        let report = session.run();
        assert_eq!(report.network, "trace:flexcomm_session_api_trace[3 pts]");
        assert_eq!(report.metrics.steps.len(), 50);
        // The trace's slow middle phase (50 ms / 1 Gbps) must be visible
        // in the recorded conditions.
        assert!(report.metrics.steps.iter().any(|m| m.alpha_ms > 30.0));
        Ok(())
    };
    run().expect("trace-driven run");

    let text = std::fs::read_to_string(&csv_path).unwrap();
    assert!(
        text.starts_with("# net=trace:flexcomm_session_api_trace[3 pts]\n"),
        "CSV must name the scenario: {}",
        text.lines().next().unwrap_or("")
    );
    assert!(text.lines().any(|l| l.starts_with("# net_change")), "{text}");
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_file(&csv_path);
}
