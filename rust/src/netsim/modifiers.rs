//! Composable network-environment modifiers.
//!
//! Each wrapper takes any [`NetworkModel`] and perturbs what it reports,
//! replacing the overlay *fields* that used to be baked into
//! `NetSchedule` (`with_jitter`/`with_congestion`) with free-standing
//! compositions: `Congestion(Jitter(c2))`, `Diurnal(trace)`, ...
//!
//! Determinism contract (DESIGN.md §9): every wrapper's perturbation is a
//! pure function of `(its own parameters, epoch)` — stochastic wrappers
//! derive a fresh RNG per 0.1-epoch bucket from their seed, exactly like
//! the old in-schedule overlays, so the same composition replays
//! bit-identically. Composition applies inside-out (the outermost wrapper
//! perturbs last). Stochastic wrappers composed with the SAME seed draw
//! correlated streams — give each overlay its own seed.
//!
//! All wrappers perturb the **inter**-node link only: `topology_at` keeps
//! the inner model's intra link and node shape, mirroring the paper's
//! setup where `tc` shapes the TCP side while in-machine hardware stays
//! fixed.

use crate::netsim::cost_model::{LinkParams, Topology};
use crate::netsim::model::{NetModelError, NetworkModel};
use crate::util::rng::Rng;

/// Per-0.1-epoch-bucket RNG — the same derivation the old in-schedule
/// overlays used, so migrated call sites replay identically.
fn bucket_rng(seed: u64, epoch: f64) -> Rng {
    let bucket = (epoch * 10.0).floor() as u64;
    Rng::new(seed ^ bucket.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

fn bad(modifier: &'static str, reason: String) -> NetModelError {
    NetModelError::BadModifier { modifier, reason }
}

macro_rules! impl_inter_modifier {
    ($ty:ident) => {
        impl NetworkModel for $ty {
            fn link_at(&self, epoch: f64) -> LinkParams {
                self.perturb(self.inner.link_at(epoch), epoch)
            }

            fn topology_at(&self, epoch: f64) -> Topology {
                let mut t = self.inner.topology_at(epoch);
                t.inter = self.perturb(t.inter, epoch);
                t
            }

            fn name(&self) -> &str {
                self.inner.name()
            }

            fn describe(&self) -> String {
                format!("{}+{}", self.inner.describe(), self.suffix())
            }

            fn clone_model(&self) -> Box<dyn NetworkModel> {
                Box::new(self.clone())
            }
        }
    };
}

/// Multiplicative observation-free jitter: α and bandwidth each move by a
/// uniform ±`frac` factor, re-drawn deterministically per 0.1-epoch
/// bucket (identical to the old `NetSchedule::with_jitter` overlay).
#[derive(Debug, Clone)]
pub struct Jitter {
    inner: Box<dyn NetworkModel>,
    frac: f64,
    seed: u64,
}

impl Jitter {
    /// `frac` must be in `[0, 1)` (a full-unit jitter could zero the link).
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        frac: f64,
        seed: u64,
    ) -> Result<Jitter, NetModelError> {
        if !(0.0..1.0).contains(&frac) {
            return Err(bad("jitter", format!("frac {frac} outside [0, 1)")));
        }
        Ok(Jitter { inner: Box::new(inner), frac, seed })
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        if self.frac == 0.0 {
            return link;
        }
        let mut rng = bucket_rng(self.seed, epoch);
        let ja = 1.0 + self.frac * (2.0 * rng.f64() - 1.0);
        let jb = 1.0 + self.frac * (2.0 * rng.f64() - 1.0);
        link.alpha *= ja;
        link.beta /= jb; // jitter bandwidth, not beta, symmetrically
        link
    }

    fn suffix(&self) -> String {
        format!("jitter({})", self.frac)
    }
}

impl_inter_modifier!(Jitter);

/// Congestion episodes: with probability `prob` per 0.1-epoch bucket the
/// effective bandwidth collapses by `factor` (identical to the old
/// `NetSchedule::with_congestion` overlay).
#[derive(Debug, Clone)]
pub struct CongestionEpisodes {
    inner: Box<dyn NetworkModel>,
    prob: f64,
    factor: f64,
    seed: u64,
}

impl CongestionEpisodes {
    /// `prob` in `[0, 1]`, `factor >= 1`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        prob: f64,
        factor: f64,
        seed: u64,
    ) -> Result<CongestionEpisodes, NetModelError> {
        if !(0.0..=1.0).contains(&prob) {
            return Err(bad("congestion", format!("prob {prob} outside [0, 1]")));
        }
        if factor.is_nan() || factor < 1.0 {
            return Err(bad("congestion", format!("factor {factor} must be >= 1")));
        }
        Ok(CongestionEpisodes { inner: Box::new(inner), prob, factor, seed })
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        if self.prob == 0.0 {
            return link;
        }
        let mut rng = bucket_rng(self.seed, epoch);
        if rng.f64() < self.prob {
            link.beta *= self.factor;
        }
        link
    }

    fn suffix(&self) -> String {
        format!("congestion({},{})", self.prob, self.factor)
    }
}

impl_inter_modifier!(CongestionEpisodes);

/// Diurnal load: effective bandwidth swings sinusoidally by ±`amplitude`
/// over a `period_epochs` cycle (a shared WAN's day/night utilization —
/// the §2-C2 "resource sharing" variability source). Deterministic, no
/// RNG; latency is untouched (queueing on a shared path shows up as
/// throughput first).
#[derive(Debug, Clone)]
pub struct Diurnal {
    inner: Box<dyn NetworkModel>,
    amplitude: f64,
    period_epochs: f64,
}

impl Diurnal {
    /// `amplitude` in `[0, 1)` (1 would zero the bandwidth at the trough),
    /// `period_epochs > 0`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        amplitude: f64,
        period_epochs: f64,
    ) -> Result<Diurnal, NetModelError> {
        if !(0.0..1.0).contains(&amplitude) {
            return Err(bad("diurnal", format!("amplitude {amplitude} outside [0, 1)")));
        }
        if period_epochs.is_nan() || period_epochs <= 0.0 {
            return Err(bad("diurnal", format!("period {period_epochs} must be > 0")));
        }
        Ok(Diurnal { inner: Box::new(inner), amplitude, period_epochs })
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        let phase = 2.0 * std::f64::consts::PI * epoch / self.period_epochs;
        let mult = 1.0 + self.amplitude * phase.sin();
        link.beta /= mult; // bandwidth × mult  ⇔  β ÷ mult
        link
    }

    fn suffix(&self) -> String {
        format!("diurnal({},{})", self.amplitude, self.period_epochs)
    }
}

impl_inter_modifier!(Diurnal);

/// Link flapping: every `period_epochs` cycle, the last `down_frac` of the
/// cycle reroutes over a `factor`-times-worse backup path (α and β both
/// degrade — a failover crosses extra hops AND loses capacity).
/// Deterministic square wave, no RNG.
#[derive(Debug, Clone)]
pub struct Flapping {
    inner: Box<dyn NetworkModel>,
    period_epochs: f64,
    down_frac: f64,
    factor: f64,
}

impl Flapping {
    /// `period_epochs > 0`, `down_frac` in `(0, 1)`, `factor >= 1`.
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        period_epochs: f64,
        down_frac: f64,
        factor: f64,
    ) -> Result<Flapping, NetModelError> {
        if period_epochs.is_nan() || period_epochs <= 0.0 {
            return Err(bad("flap", format!("period {period_epochs} must be > 0")));
        }
        if down_frac.is_nan() || down_frac <= 0.0 || down_frac >= 1.0 {
            return Err(bad("flap", format!("down_frac {down_frac} outside (0, 1)")));
        }
        if factor.is_nan() || factor < 1.0 {
            return Err(bad("flap", format!("factor {factor} must be >= 1")));
        }
        Ok(Flapping { inner: Box::new(inner), period_epochs, down_frac, factor })
    }

    /// True when `epoch` falls in the degraded tail of its cycle.
    pub fn is_down(&self, epoch: f64) -> bool {
        let pos = (epoch / self.period_epochs).rem_euclid(1.0);
        pos >= 1.0 - self.down_frac
    }

    fn perturb(&self, mut link: LinkParams, epoch: f64) -> LinkParams {
        if self.is_down(epoch) {
            link.alpha *= self.factor;
            link.beta *= self.factor;
        }
        link
    }

    fn suffix(&self) -> String {
        format!("flap({},{},{})", self.period_epochs, self.down_frac, self.factor)
    }
}

impl_inter_modifier!(Flapping);

/// Asymmetric degradation: a constant multiplier on α and a constant
/// divisor on bandwidth, independently. Models the paper's observation
/// that latency and bandwidth drift independently (Tables I/II/VI corners:
/// `asym(50, 1)` is the high-α/high-bw regime where Allgather wins).
#[derive(Debug, Clone)]
pub struct AsymmetricDegrade {
    inner: Box<dyn NetworkModel>,
    alpha_mult: f64,
    bw_div: f64,
}

impl AsymmetricDegrade {
    /// Both factors `>= 1` (this wrapper only degrades; at least one may
    /// be exactly 1 for a single-axis perturbation).
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        alpha_mult: f64,
        bw_div: f64,
    ) -> Result<AsymmetricDegrade, NetModelError> {
        if alpha_mult.is_nan() || bw_div.is_nan() || alpha_mult < 1.0 || bw_div < 1.0 {
            return Err(bad(
                "asym",
                format!("factors must be >= 1 (got alpha x{alpha_mult}, bw /{bw_div})"),
            ));
        }
        Ok(AsymmetricDegrade { inner: Box::new(inner), alpha_mult, bw_div })
    }

    fn perturb(&self, mut link: LinkParams, _epoch: f64) -> LinkParams {
        link.alpha *= self.alpha_mult;
        link.beta *= self.bw_div; // bandwidth ÷ d  ⇔  β × d
        link
    }

    fn suffix(&self) -> String {
        format!("asym({},{})", self.alpha_mult, self.bw_div)
    }
}

impl_inter_modifier!(AsymmetricDegrade);

/// Two-level topology overlay: `workers_per_node` ranks share a fixed
/// `intra` link; the wrapped model drives the inter-node side. The generic
/// counterpart of `NetSchedule::with_topology` — it composes over traces
/// and other modifiers too.
#[derive(Debug, Clone)]
pub struct TwoLevel {
    inner: Box<dyn NetworkModel>,
    intra: LinkParams,
    workers_per_node: usize,
}

impl TwoLevel {
    /// `workers_per_node >= 1` (1 degenerates to the flat inner model).
    pub fn wrap(
        inner: impl NetworkModel + 'static,
        intra: LinkParams,
        workers_per_node: usize,
    ) -> Result<TwoLevel, NetModelError> {
        if workers_per_node == 0 {
            return Err(bad("2level", "workers_per_node must be >= 1".into()));
        }
        Ok(TwoLevel { inner: Box::new(inner), intra, workers_per_node })
    }
}

impl NetworkModel for TwoLevel {
    fn link_at(&self, epoch: f64) -> LinkParams {
        self.inner.link_at(epoch)
    }

    fn topology_at(&self, epoch: f64) -> Topology {
        if self.workers_per_node > 1 {
            Topology::two_level(self.intra, self.inner.link_at(epoch), self.workers_per_node)
        } else {
            self.inner.topology_at(epoch)
        }
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn describe(&self) -> String {
        format!("{}+2level(x{})", self.inner.describe(), self.workers_per_node)
    }

    fn clone_model(&self) -> Box<dyn NetworkModel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::NetSchedule;
    use crate::util::proptest::{check, ensure};

    fn base() -> NetSchedule {
        NetSchedule::static_link(LinkParams::from_ms_gbps(4.0, 20.0))
    }

    /// The DELETED `NetSchedule::at` overlay logic, verbatim — the
    /// "before" reference that pins the migration as a no-behavior-change
    /// refactor: a lone jitter (or congestion) wrapper must reproduce the
    /// old in-schedule overlay bit-for-bit.
    fn legacy_overlay(
        mut link: LinkParams,
        epoch: f64,
        jitter_frac: f64,
        congestion_prob: f64,
        congestion_factor: f64,
        seed: u64,
    ) -> LinkParams {
        if jitter_frac == 0.0 && congestion_prob == 0.0 {
            return link;
        }
        let bucket = (epoch * 10.0).floor() as u64;
        let mut rng = Rng::new(seed ^ bucket.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if jitter_frac > 0.0 {
            let ja = 1.0 + jitter_frac * (2.0 * rng.f64() - 1.0);
            let jb = 1.0 + jitter_frac * (2.0 * rng.f64() - 1.0);
            link.alpha *= ja;
            link.beta /= jb;
        }
        if congestion_prob > 0.0 && rng.f64() < congestion_prob {
            link.beta *= congestion_factor;
        }
        link
    }

    #[test]
    fn jitter_wrapper_is_bitwise_equal_to_the_old_overlay() {
        check("jitter == legacy with_jitter", 300, |g| {
            let frac = g.f64_in(0.0, 0.5);
            let seed = g.rng.next_u64();
            let epoch = g.f64_in(0.0, 60.0);
            let j = Jitter::wrap(base(), frac, seed).unwrap();
            let got = j.link_at(epoch);
            let want = legacy_overlay(base().at(epoch), epoch, frac, 0.0, 1.0, seed);
            ensure(
                got.alpha.to_bits() == want.alpha.to_bits()
                    && got.beta.to_bits() == want.beta.to_bits(),
                format!("epoch {epoch} frac {frac} seed {seed}: {got:?} vs {want:?}"),
            )
        });
    }

    #[test]
    fn congestion_wrapper_is_bitwise_equal_to_the_old_overlay() {
        check("congestion == legacy with_congestion", 300, |g| {
            let prob = g.f64_in(0.0, 1.0);
            let factor = g.f64_in(1.0, 20.0);
            let seed = g.rng.next_u64();
            let epoch = g.f64_in(0.0, 60.0);
            let c = CongestionEpisodes::wrap(base(), prob, factor, seed).unwrap();
            let got = c.link_at(epoch);
            let want = legacy_overlay(base().at(epoch), epoch, 0.0, prob, factor, seed);
            ensure(
                got.alpha.to_bits() == want.alpha.to_bits()
                    && got.beta.to_bits() == want.beta.to_bits(),
                format!("epoch {epoch} prob {prob} seed {seed}: {got:?} vs {want:?}"),
            )
        });
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let s = Jitter::wrap(NetSchedule::c1(50.0), 0.1, 7).unwrap();
        let a = s.link_at(3.14);
        let b = s.link_at(3.14);
        assert_eq!(a, b, "same epoch must give same link");
        let base = NetSchedule::c1(50.0).at(3.14);
        assert!((a.alpha / base.alpha - 1.0).abs() <= 0.1 + 1e-9);
        let ratio = base.beta / a.beta;
        assert!((ratio - 1.0).abs() <= 0.1 + 1e-9);
    }

    #[test]
    fn congestion_reduces_bandwidth_sometimes() {
        let s = CongestionEpisodes::wrap(
            NetSchedule::static_link(LinkParams::from_ms_gbps(1.0, 10.0)),
            0.5,
            10.0,
            3,
        )
        .unwrap();
        let (mut congested, mut free) = (0, 0);
        for i in 0..200 {
            let l = s.link_at(i as f64 * 0.1);
            if l.bw_gbps() < 2.0 {
                congested += 1;
            } else {
                free += 1;
            }
        }
        assert!(congested > 30, "{congested}");
        assert!(free > 30, "{free}");
    }

    #[test]
    fn diurnal_cycles_bandwidth_and_keeps_it_positive() {
        let d = Diurnal::wrap(base(), 0.5, 10.0).unwrap();
        let bw = |e: f64| d.link_at(e).bw_gbps();
        // Quarter-cycle peak, three-quarter trough, node at cycle ends.
        assert!((bw(2.5) - 30.0).abs() < 1e-6, "{}", bw(2.5));
        assert!((bw(7.5) - 10.0).abs() < 1e-6, "{}", bw(7.5));
        assert!((bw(0.0) - 20.0).abs() < 1e-6);
        assert!((bw(10.0) - 20.0).abs() < 1e-6);
        for i in 0..100 {
            let l = d.link_at(i as f64 * 0.37);
            assert!(l.beta > 0.0 && l.beta.is_finite());
            assert_eq!(l.alpha, 4e-3, "diurnal must not touch latency");
        }
    }

    #[test]
    fn flapping_degrades_exactly_the_down_window() {
        let f = Flapping::wrap(base(), 10.0, 0.3, 16.0).unwrap();
        let up = f.link_at(2.0);
        let down = f.link_at(8.0); // pos 0.8 >= 0.7
        assert!(!f.is_down(2.0) && f.is_down(8.0));
        assert!((down.alpha / up.alpha - 16.0).abs() < 1e-9);
        assert!((down.beta / up.beta - 16.0).abs() < 1e-9);
        // Periodic: the next cycle flaps the same way.
        assert_eq!(f.link_at(18.0), down);
        assert_eq!(f.link_at(12.0), up);
    }

    #[test]
    fn asymmetric_degrade_moves_one_axis_at_a_time() {
        let lat = AsymmetricDegrade::wrap(base(), 50.0, 1.0).unwrap();
        let l = lat.link_at(0.0);
        assert!((l.alpha_ms() - 200.0).abs() < 1e-9);
        assert!((l.bw_gbps() - 20.0).abs() < 1e-9);
        let bw = AsymmetricDegrade::wrap(base(), 1.0, 4.0).unwrap();
        let l = bw.link_at(0.0);
        assert!((l.alpha_ms() - 4.0).abs() < 1e-9);
        assert!((l.bw_gbps() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn two_level_overlay_drives_inter_only() {
        let intra = LinkParams::from_ms_gbps(0.01, 100.0);
        let m = TwoLevel::wrap(
            Jitter::wrap(NetSchedule::c1(50.0), 0.1, 9).unwrap(),
            intra,
            4,
        )
        .unwrap();
        for epoch in [0.0, 13.0, 26.0, 40.0] {
            let t = m.topology_at(epoch);
            assert_eq!(t.workers_per_node, 4);
            // The inter side follows the (jittered) schedule...
            assert_eq!(t.inter, m.link_at(epoch));
            // ...while the intra link stays the fixed in-machine hardware.
            assert_eq!(t.intra, intra);
        }
    }

    #[test]
    fn modifiers_perturb_only_the_inter_link_of_two_level_inner_models() {
        let intra = LinkParams::from_ms_gbps(0.01, 100.0);
        let sched = NetSchedule::c1(50.0).with_topology(intra, 2);
        let j = Jitter::wrap(sched, 0.2, 5).unwrap();
        let t = j.topology_at(3.0);
        assert_eq!(t.intra, intra);
        assert_eq!(t.workers_per_node, 2);
        assert_eq!(t.inter, j.link_at(3.0));
    }

    #[test]
    fn describe_records_the_composition_in_order() {
        let m = CongestionEpisodes::wrap(
            Jitter::wrap(NetSchedule::c2(50.0), 0.15, 13).unwrap(),
            0.2,
            8.0,
            14,
        )
        .unwrap();
        assert_eq!(m.describe(), "c2+jitter(0.15)+congestion(0.2,8)");
        assert_eq!(m.name(), "c2", "base name survives wrapping");
    }

    #[test]
    fn bad_compositions_are_typed_errors() {
        assert!(matches!(
            Jitter::wrap(base(), 1.5, 0),
            Err(NetModelError::BadModifier { modifier: "jitter", .. })
        ));
        assert!(matches!(
            Jitter::wrap(base(), f64::NAN, 0),
            Err(NetModelError::BadModifier { .. })
        ));
        assert!(CongestionEpisodes::wrap(base(), 1.1, 2.0, 0).is_err());
        assert!(CongestionEpisodes::wrap(base(), 0.5, 0.5, 0).is_err());
        assert!(Diurnal::wrap(base(), 1.0, 10.0).is_err());
        assert!(Diurnal::wrap(base(), 0.5, 0.0).is_err());
        assert!(Flapping::wrap(base(), 0.0, 0.3, 2.0).is_err());
        assert!(Flapping::wrap(base(), 1.0, 1.0, 2.0).is_err());
        assert!(Flapping::wrap(base(), 1.0, 0.3, 0.9).is_err());
        assert!(AsymmetricDegrade::wrap(base(), 0.5, 1.0).is_err());
        assert!(TwoLevel::wrap(base(), LinkParams::from_ms_gbps(0.01, 100.0), 0).is_err());
    }
}
