//! [`PjrtModel`]: the production gradient source — executes the L2 jax
//! model (with its L1 Pallas kernels lowered inside) via PJRT.
//!
//! Supports both artifact kinds exported by `aot.py`:
//! * `transformer` — grad/eval consume `(params[P], tokens[B, T+1] i32)`;
//!   batches come from [`MarkovCorpus`].
//! * `mlp` — grad/eval consume `(params[P], x[B, F] f32, y[B] i32)`;
//!   batches come from [`ClusterDataset`].
//!
//! Also wraps the fused `ef_topk_<P>` artifact (threshold estimation +
//! EF-compress, L1 Pallas kernels) so the coordinator can offload
//! compression to XLA — the integration tests pin it against the rust
//! [`MsTopk`](crate::compress::MsTopk) implementation.

use crate::coordinator::worker::GradSource;
use crate::data::synth::{ClusterDataset, MarkovCorpus};
use crate::runtime::artifact::ModelArtifacts;
use crate::runtime::engine::{
    lit_f32, lit_f32_2d, lit_i32_2d, lit_scalar, to_scalar_f32, to_vec_f32, Engine, Executable,
};
use crate::tensor::Layout;
use anyhow::{bail, Context, Result};

enum Task {
    Transformer { corpus: MarkovCorpus, batch: usize, seq: usize },
    Mlp { data: ClusterDataset, batch: usize, features: usize },
}

/// PJRT-backed model.
pub struct PjrtModel {
    arts: ModelArtifacts,
    grad_exe: Executable,
    eval_exe: Executable,
    step_exe: Option<Executable>,
    ef_exe: Option<Executable>,
    task: Task,
    dim: usize,
    /// Class-skew for the MLP task (federated knob); ignored by the LM.
    pub skew: f64,
}

impl PjrtModel {
    /// Load a preset's artifacts on `engine`.
    pub fn load(engine: &Engine, arts: ModelArtifacts, seed: u64) -> Result<PjrtModel> {
        let dim = arts.param_count()?;
        let grad_exe = engine.load(arts.grad_path().to_str().context("utf8")?)?;
        let eval_exe = engine.load(arts.eval_path().to_str().context("utf8")?)?;
        let step_exe = if arts.step_path().exists() {
            Some(engine.load(arts.step_path().to_str().context("utf8")?)?)
        } else {
            None
        };
        let ef_path = arts.ef_topk_path()?;
        let ef_exe = if ef_path.exists() {
            Some(engine.load(ef_path.to_str().context("utf8")?)?)
        } else {
            None
        };
        let task = match arts.kind() {
            "transformer" => Task::Transformer {
                corpus: MarkovCorpus::new(arts.meta_usize("vocab")?, 4, 0.8, seed),
                batch: arts.meta_usize("batch")?,
                seq: arts.meta_usize("seq")?,
            },
            "mlp" => Task::Mlp {
                data: ClusterDataset::new(
                    arts.meta_usize("features")?,
                    arts.meta_usize("classes")?,
                    2.0,
                    0.35,
                    seed,
                ),
                batch: arts.meta_usize("batch")?,
                features: arts.meta_usize("features")?,
            },
            k => bail!("unknown artifact kind `{k}`"),
        };
        Ok(PjrtModel { arts, grad_exe, eval_exe, step_exe, ef_exe, task, dim, skew: 0.0 })
    }

    fn batch_literals(
        &self,
        worker: usize,
        n_workers: usize,
        step: u64,
    ) -> Result<Vec<xla::Literal>> {
        match &self.task {
            Task::Transformer { corpus, batch, seq } => {
                let toks = corpus.batch(worker, step, *batch, *seq);
                Ok(vec![lit_i32_2d(&toks, *batch, seq + 1)?])
            }
            Task::Mlp { data, batch, features } => {
                let (x, y) = data.batch(worker, n_workers, step, *batch, self.skew);
                Ok(vec![
                    lit_f32_2d(&x, *batch, *features)?,
                    xla::Literal::vec1(&y),
                ])
            }
        }
    }

    fn run_loss_grad(&self, params: &[f32], batch: Vec<xla::Literal>) -> Result<(f64, Vec<f32>)> {
        let mut inputs = vec![lit_f32(params)];
        inputs.extend(batch);
        let out = self.grad_exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 2, "grad artifact must return (loss, grads)");
        Ok((to_scalar_f32(&out[0])? as f64, to_vec_f32(&out[1])?))
    }

    /// SGD+momentum step executed by the L2 `step` artifact.
    pub fn sgd_step(
        &self,
        params: &[f32],
        momentum: &[f32],
        grads: &[f32],
        lr: f32,
        mom: f32,
        wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.step_exe.as_ref().context("no step artifact")?;
        let out = exe.run(&[
            lit_f32(params),
            lit_f32(momentum),
            lit_f32(grads),
            lit_scalar(lr),
            lit_scalar(mom),
            lit_scalar(wd),
        ])?;
        anyhow::ensure!(out.len() == 2, "step artifact must return (params, mom)");
        Ok((to_vec_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    /// Fused L1 EF-compress: `(g, residual, k)` ->
    /// `(g_c, residual', ||g_c||², ||g_e||², tau)`.
    pub fn ef_topk(
        &self,
        g: &[f32],
        residual: &[f32],
        k: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f64, f64, f32)> {
        let exe = self.ef_exe.as_ref().context("no ef_topk artifact")?;
        let out = exe.run(&[lit_f32(g), lit_f32(residual), lit_scalar(k)])?;
        anyhow::ensure!(out.len() == 5, "ef_topk must return 5 values");
        Ok((
            to_vec_f32(&out[0])?,
            to_vec_f32(&out[1])?,
            to_scalar_f32(&out[2])? as f64,
            to_scalar_f32(&out[3])? as f64,
            to_scalar_f32(&out[4])?,
        ))
    }

    pub fn has_ef_topk(&self) -> bool {
        self.ef_exe.is_some()
    }

    pub fn artifacts(&self) -> &ModelArtifacts {
        &self.arts
    }
}

impl GradSource for PjrtModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn layout(&self) -> &Layout {
        &self.arts.layout
    }

    fn init_params(&mut self) -> Vec<f32> {
        // Use the exact init snapshot python wrote so L2 and L3 agree.
        crate::tensor::load_f32_file(
            self.arts.init_path().to_str().expect("utf8"),
        )
        .expect("reading init snapshot (run `make artifacts`)")
    }

    // NB: `GradSource` now requires `Send + Sync` and a `&self` grad so the
    // trainer can fan workers out across threads. The PJRT CPU client is
    // documented thread-safe, but if the vendored `xla` wrapper types lack
    // the auto-traits this impl will surface it at compile time — wrap the
    // executables accordingly when re-enabling the `pjrt` feature.
    fn grad(&self, params: &[f32], worker: usize, n_workers: usize, step: u64) -> (f64, Vec<f32>) {
        let batch = self
            .batch_literals(worker, n_workers, step)
            .expect("building batch literals");
        self.run_loss_grad(params, batch).expect("PJRT grad execution")
    }

    fn eval(&mut self, params: &[f32]) -> (f64, f64) {
        // Held-out shard: a worker id outside the training range.
        let batch = self
            .batch_literals(usize::MAX / 2, 1, u64::MAX / 2)
            .expect("eval batch");
        let mut inputs = vec![lit_f32(params)];
        inputs.extend(batch);
        let out = self.eval_exe.run(&inputs).expect("PJRT eval execution");
        let loss = to_scalar_f32(&out[0]).expect("loss") as f64;
        let correct = to_scalar_f32(&out[1]).expect("correct") as f64;
        let total = match &self.task {
            Task::Transformer { batch, seq, .. } => (*batch * *seq) as f64,
            Task::Mlp { batch, .. } => *batch as f64,
        };
        (loss, correct / total)
    }

    fn name(&self) -> String {
        format!("pjrt-{}", self.arts.name)
    }
}
