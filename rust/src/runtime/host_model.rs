//! Host-side gradient sources (no PJRT required).
//!
//! * [`HostMlp`] — a pure-rust MLP with manual backprop on the synthetic
//!   cluster task. Numerically the same architecture as the python `mlp`
//!   preset; used by the accuracy-bearing table harnesses (III/IV/V) where
//!   thousands of steps must run fast, and cross-checked against the PJRT
//!   path in `rust/tests/`.
//! * [`SyntheticGrad`] — paper-scale gradient *tensors* (1e8..1e9 params)
//!   with realistic heavy-tailed statistics for cost-only experiments
//!   (Tables II/VI, Figs 2/5); no model behind them.

use crate::coordinator::worker::GradSource;
use crate::data::synth::ClusterDataset;
use crate::tensor::Layout;
use crate::util::rng::Rng;

/// Pure-rust MLP classifier: dims `[features, hidden.., classes]`,
/// ReLU activations, softmax cross-entropy.
pub struct HostMlp {
    dims: Vec<usize>,
    layout: Layout,
    data: ClusterDataset,
    batch: usize,
    /// Class-skew across workers (0 = iid; the federated knob).
    pub skew: f64,
    eval_cache: Option<(Vec<f32>, Vec<i32>)>,
    seed: u64,
}

impl HostMlp {
    pub fn new(features: usize, hidden: &[usize], classes: usize, batch: usize, seed: u64) -> Self {
        HostMlp::with_noise(features, hidden, classes, batch, 0.35, seed)
    }

    /// Like [`HostMlp::new`] with an explicit cluster-noise level —
    /// `noise/sep` controls task hardness (the Bayes accuracy ceiling).
    pub fn with_noise(
        features: usize,
        hidden: &[usize],
        classes: usize,
        batch: usize,
        noise: f32,
        seed: u64,
    ) -> Self {
        HostMlp::with_data_params(features, hidden, classes, batch, 2.0, noise, seed)
    }

    /// Full control over the cluster task (separation AND noise).
    pub fn with_data_params(
        features: usize,
        hidden: &[usize],
        classes: usize,
        batch: usize,
        sep: f32,
        noise: f32,
        seed: u64,
    ) -> Self {
        let mut dims = vec![features];
        dims.extend_from_slice(hidden);
        dims.push(classes);
        let mut sizes: Vec<(String, usize)> = Vec::new();
        for i in 0..dims.len() - 1 {
            sizes.push((format!("fc{i}.w"), dims[i] * dims[i + 1]));
            sizes.push((format!("fc{i}.b"), dims[i + 1]));
        }
        let layout = Layout::from_sizes(
            &sizes.iter().map(|(n, s)| (n.as_str(), *s)).collect::<Vec<_>>(),
        );
        let data = ClusterDataset::new(features, classes, sep, noise, seed);
        HostMlp { dims, layout, data, batch, skew: 0.0, eval_cache: None, seed }
    }

    /// The default config mirroring the python `mlp` preset.
    pub fn default_preset(seed: u64) -> Self {
        HostMlp::new(64, &[256, 128], 16, 32, seed)
    }

    /// A harder task (overlapping clusters): the Bayes ceiling is ~89%, so
    /// accuracy stays off 100% and statistical-efficiency differences
    /// between CRs are visible — used by the Table III/IV/V harnesses.
    pub fn hard_preset(seed: u64) -> Self {
        // 53,664 params so CR 0.001 still keeps k = 54 (a resolution the
        // paper's 11M+ models always have); Bayes ceiling ~89%.
        HostMlp::with_data_params(64, &[256, 128], 32, 32, 0.8, 1.8, seed)
    }

    fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    fn slice<'a>(&self, params: &'a [f32], layer: usize) -> (&'a [f32], &'a [f32]) {
        let w = &self.layout.layers[2 * layer];
        let b = &self.layout.layers[2 * layer + 1];
        (
            &params[w.offset..w.offset + w.size],
            &params[b.offset..b.offset + b.size],
        )
    }

    /// Forward returning all activations (a[0] = input .. a[L] = logits).
    fn forward(&self, params: &[f32], x: &[f32], batch: usize) -> Vec<Vec<f32>> {
        let mut acts = vec![x.to_vec()];
        for l in 0..self.n_layers() {
            let (w, b) = self.slice(params, l);
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let a = acts.last().unwrap();
            let mut z = vec![0.0f32; batch * dout];
            for r in 0..batch {
                let row = &a[r * din..(r + 1) * din];
                let out = &mut z[r * dout..(r + 1) * dout];
                out.copy_from_slice(b);
                for (i, &xi) in row.iter().enumerate() {
                    if xi != 0.0 {
                        let wrow = &w[i * dout..(i + 1) * dout];
                        for (o, &wv) in out.iter_mut().zip(wrow) {
                            *o += xi * wv;
                        }
                    }
                }
            }
            if l < self.n_layers() - 1 {
                for v in z.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            acts.push(z);
        }
        acts
    }

    /// (loss, grads) on one (x, y) batch via manual backprop.
    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[i32], batch: usize) -> (f64, Vec<f32>) {
        let acts = self.forward(params, x, batch);
        let classes = *self.dims.last().unwrap();
        let logits = acts.last().unwrap();

        // Softmax CE + dlogits.
        let mut loss = 0.0f64;
        let mut dz = vec![0.0f32; batch * classes];
        for r in 0..batch {
            let row = &logits[r * classes..(r + 1) * classes];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let label = y[r] as usize;
            loss += -((exps[label] / z).ln() as f64);
            let drow = &mut dz[r * classes..(r + 1) * classes];
            for c in 0..classes {
                drow[c] = (exps[c] / z - (c == label) as u8 as f32) / batch as f32;
            }
        }
        loss /= batch as f64;

        // Backprop.
        let mut grads = vec![0.0f32; self.layout.total()];
        let mut dz_cur = dz;
        for l in (0..self.n_layers()).rev() {
            let (w, _) = self.slice(params, l);
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let a = &acts[l];
            let wl = &self.layout.layers[2 * l];
            let bl = &self.layout.layers[2 * l + 1];
            {
                let gw = &mut grads[wl.offset..wl.offset + wl.size];
                for r in 0..batch {
                    let arow = &a[r * din..(r + 1) * din];
                    let drow = &dz_cur[r * dout..(r + 1) * dout];
                    for (i, &ai) in arow.iter().enumerate() {
                        if ai != 0.0 {
                            let grow = &mut gw[i * dout..(i + 1) * dout];
                            for (g, &d) in grow.iter_mut().zip(drow) {
                                *g += ai * d;
                            }
                        }
                    }
                }
            }
            {
                let gb = &mut grads[bl.offset..bl.offset + bl.size];
                for r in 0..batch {
                    let drow = &dz_cur[r * dout..(r + 1) * dout];
                    for (g, &d) in gb.iter_mut().zip(drow) {
                        *g += d;
                    }
                }
            }
            if l > 0 {
                // da = dz W^T, then mask by relu'(a) (a itself is post-relu).
                let mut da = vec![0.0f32; batch * din];
                for r in 0..batch {
                    let drow = &dz_cur[r * dout..(r + 1) * dout];
                    let darow = &mut da[r * din..(r + 1) * din];
                    for i in 0..din {
                        let wrow = &w[i * dout..(i + 1) * dout];
                        let mut acc = 0.0f32;
                        for (d, &wv) in drow.iter().zip(wrow) {
                            acc += d * wv;
                        }
                        darow[i] = acc;
                    }
                    let arow = &a[r * din..(r + 1) * din];
                    for (dv, &av) in darow.iter_mut().zip(arow) {
                        if av <= 0.0 {
                            *dv = 0.0;
                        }
                    }
                }
                dz_cur = da;
            }
        }
        (loss, grads)
    }
}

impl GradSource for HostMlp {
    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    fn init_params(&mut self) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ 0x1217);
        let mut p = vec![0.0f32; self.layout.total()];
        for l in 0..self.n_layers() {
            let wl = &self.layout.layers[2 * l];
            let std = (2.0 / self.dims[l] as f64).sqrt() as f32;
            rng.fill_normal(&mut p[wl.offset..wl.offset + wl.size], std.min(0.08));
            // biases stay zero
        }
        p
    }

    fn grad(&self, params: &[f32], worker: usize, n_workers: usize, step: u64) -> (f64, Vec<f32>) {
        let (x, y) = self.data.batch(worker, n_workers, step, self.batch, self.skew);
        self.loss_grad(params, &x, &y, self.batch)
    }

    fn eval(&mut self, params: &[f32]) -> (f64, f64) {
        let n = 1024;
        if self.eval_cache.is_none() {
            self.eval_cache = Some(self.data.eval_batch(n));
        }
        let (x, y) = self.eval_cache.clone().unwrap();
        let acts = self.forward(params, &x, n);
        let classes = *self.dims.last().unwrap();
        let logits = acts.last().unwrap();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for r in 0..n {
            let row = &logits[r * classes..(r + 1) * classes];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let label = y[r] as usize;
            loss += -(((row[label] - mx).exp() / z).ln() as f64);
            // NaN-tolerant argmax (crate NaN policy: NaN never wins): an
            // eval after a NaN-poisoned step reports garbage accuracy
            // instead of panicking the run.
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| crate::tensor::nan_min_cmp_f32(*a.1, *b.1))
                .map(|(c, _)| c)
                .unwrap_or(0);
            correct += (pred == label) as usize;
        }
        (loss / n as f64, correct as f64 / n as f64)
    }

    fn name(&self) -> String {
        format!("host-mlp{:?}", self.dims)
    }
}

/// Paper-scale synthetic gradients for cost-only experiments.
///
/// Statistics: heavy-tailed mixture (95% N(0,σ²) + 5% N(0,(8σ)²)) so Top-k
/// selection is meaningful, with σ decaying over steps like real training
/// (§2-B: gradients start volatile and saturate).
pub struct SyntheticGrad {
    layout: Layout,
    seed: u64,
    decay_steps: f64,
}

impl SyntheticGrad {
    pub fn new(dim: usize, seed: u64) -> Self {
        SyntheticGrad { layout: synthetic_model_layout(dim), seed, decay_steps: 500.0 }
    }

    pub fn with_layout(layout: Layout, seed: u64) -> Self {
        SyntheticGrad { layout, seed, decay_steps: 500.0 }
    }

    fn sigma(&self, step: u64) -> f32 {
        (1.0 / (1.0 + step as f64 / self.decay_steps)).sqrt() as f32
    }
}

/// A DNN-shaped layout: sizes skewed like real models (embedding/head huge,
/// norms tiny) so LWTopk-vs-fused experiments see realistic imbalance.
pub fn synthetic_model_layout(total: usize) -> Layout {
    // ~60% in 2 big tensors, rest split across 14 medium/small ones.
    let big = total * 3 / 10;
    let mut sizes: Vec<(String, usize)> = vec![
        ("embed".into(), big.max(1)),
        ("head".into(), big.max(1)),
    ];
    let mut rest = total - sizes.iter().map(|s| s.1).sum::<usize>();
    let n_mid = 14;
    for i in 0..n_mid {
        let s = if i + 1 == n_mid { rest } else { (rest / (n_mid - i)).max(1) };
        if s == 0 {
            break;
        }
        sizes.push((format!("block{i}"), s));
        rest -= s;
    }
    Layout::from_sizes(&sizes.iter().map(|(n, s)| (n.as_str(), *s)).collect::<Vec<_>>())
}

impl GradSource for SyntheticGrad {
    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    fn init_params(&mut self) -> Vec<f32> {
        vec![0.0; self.layout.total()]
    }

    fn grad(&self, _params: &[f32], worker: usize, _n: usize, step: u64) -> (f64, Vec<f32>) {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xA076_1D64_78BD_642F),
        );
        let sigma = self.sigma(step);
        let dim = self.dim();
        let mut g = vec![0.0f32; dim];
        for v in g.iter_mut() {
            let heavy = rng.f64() < 0.05;
            *v = rng.normal_f32(0.0, if heavy { 8.0 * sigma } else { sigma });
        }
        // Synthetic "loss": decays deterministically; accuracy is N/A.
        let loss = 2.0 * self.sigma(step) as f64;
        (loss, g)
    }

    fn eval(&mut self, _params: &[f32]) -> (f64, f64) {
        (f64::NAN, f64::NAN)
    }

    fn name(&self) -> String {
        format!("synthetic-{}", self.dim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_gradcheck_small() {
        // Finite-difference check on a tiny network.
        let mut mlp = HostMlp::new(3, &[4], 2, 4, 0);
        let params = mlp.init_params();
        let (x, y) = mlp.data.batch(0, 1, 0, 4, 0.0);
        let (_, g) = mlp.loss_grad(&params, &x, &y, 4);
        let eps = 1e-3f32;
        let mut checked = 0;
        for idx in [0usize, 3, 7, 12, params.len() - 1, params.len() / 2] {
            let mut p1 = params.clone();
            p1[idx] += eps;
            let (l1, _) = mlp.loss_grad(&p1, &x, &y, 4);
            let mut p2 = params.clone();
            p2[idx] -= eps;
            let (l2, _) = mlp.loss_grad(&p2, &x, &y, 4);
            let fd = ((l1 - l2) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs analytic {}",
                g[idx]
            );
            checked += 1;
        }
        assert_eq!(checked, 6);
    }

    #[test]
    fn mlp_learns_with_plain_sgd() {
        let mut mlp = HostMlp::default_preset(1);
        let mut params = mlp.init_params();
        let (l0, a0) = mlp.eval(&params);
        for step in 0..150 {
            let (_, g) = mlp.grad(&params, 0, 1, step);
            for (p, gv) in params.iter_mut().zip(&g) {
                *p -= 0.4 * gv;
            }
        }
        let (l1, a1) = mlp.eval(&params);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
        assert!(a1 > a0 + 0.3, "acc {a0} -> {a1}");
        assert!(a1 > 0.8, "final acc {a1}");
    }

    #[test]
    fn mlp_deterministic() {
        let mut a = HostMlp::default_preset(3);
        let mut b = HostMlp::default_preset(3);
        let pa = a.init_params();
        let pb = b.init_params();
        assert_eq!(pa, pb);
        let (la, ga) = a.grad(&pa, 2, 4, 5);
        let (lb, gb) = b.grad(&pb, 2, 4, 5);
        assert_eq!(la, lb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn synthetic_layout_covers_total() {
        for total in [1000usize, 12345, 11_700_000] {
            let l = synthetic_model_layout(total);
            assert_eq!(l.total(), total);
            assert!(l.num_layers() >= 3);
        }
    }

    #[test]
    fn synthetic_grads_decay_and_are_heavy_tailed() {
        let mut s = SyntheticGrad::new(50_000, 0);
        let p = s.init_params();
        let (_, g0) = s.grad(&p, 0, 8, 0);
        let (_, g9) = s.grad(&p, 0, 8, 5000);
        let e0: f64 = g0.iter().map(|&v| (v as f64).powi(2)).sum();
        let e9: f64 = g9.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(e9 < e0 * 0.5, "energy must decay: {e0} -> {e9}");
        // Heavy tail: top 1% carries far more than 1% of the energy.
        let mut mags: Vec<f32> = g0.iter().map(|v| v * v).collect();
        mags.sort_by(|a, b| crate::tensor::nan_min_cmp_f32(*b, *a));
        let top1: f64 = mags[..500].iter().map(|&v| v as f64).sum();
        assert!(top1 / e0 > 0.05, "top-1% energy share {}", top1 / e0);
    }

    /// The magnitude sort above runs through the crate f32 NaN total
    /// order: a poisoned gradient must sort deterministically (NaN last
    /// in descending order), never panic.
    #[test]
    fn magnitude_sort_survives_nan_poisoning() {
        let mut mags = vec![3.0f32, f32::NAN, 1.0, 2.0];
        mags.sort_by(|a, b| crate::tensor::nan_min_cmp_f32(*b, *a));
        assert_eq!(mags[0], 3.0);
        assert_eq!(mags[1], 2.0);
        assert_eq!(mags[2], 1.0);
        assert!(mags[3].is_nan(), "NaN is smallest, so last when descending");
    }

    #[test]
    fn synthetic_workers_differ_but_replay() {
        let s = SyntheticGrad::new(1000, 7);
        let p = vec![0.0; 1000];
        let (_, a) = s.grad(&p, 0, 4, 3);
        let (_, b) = s.grad(&p, 1, 4, 3);
        let (_, a2) = s.grad(&p, 0, 4, 3);
        assert_ne!(a, b);
        assert_eq!(a, a2);
    }
}
