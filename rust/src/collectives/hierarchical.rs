//! Two-level hierarchical allreduce over a [`Topology`]: binomial reduce to
//! each node's leader on the fast intra-node link, ring allreduce among the
//! leaders on the slow inter-node link, binomial broadcast back intra-node.
//!
//! Round structure for `w` ranks/node and `L = N/w` nodes: `⌈log2 w⌉`
//! full-vector rounds on the intra link each way, plus the leaders'
//! `2(L-1)`-round ring on the inter link — total
//! `2·⌈log2 w⌉(α_i + Mβ_i) + 2(L-1)α_e + 2((L-1)/L)Mβ_e`, matching
//! [`cost_model::hierarchical_allreduce`](crate::netsim::cost_model::hierarchical_allreduce)
//! exactly for any `w` (the ring term is exact when `L` divides `M`).
//!
//! The slow link is paid only `L`-wide — the reason this op flips the
//! dense-collective crossover on fast-intra/slow-inter clusters (Agarwal et
//! al.), where flat ring/tree/HD all price the full N on the bottleneck.

use crate::collectives::{ceil_log2, ring_allreduce, CommReport};
use crate::netsim::cost_model::Topology;

/// In-place SUM hierarchical allreduce. Workers are grouped by consecutive
/// rank: node `g` owns ranks `[g·w, (g+1)·w)` with `g·w` as its leader.
/// `bufs.len()` must be a multiple of `topo.workers_per_node`. After the
/// call every buffer holds the elementwise sum.
pub fn hierarchical_allreduce(bufs: &mut [Vec<f32>], topo: Topology) -> CommReport {
    let n = bufs.len();
    assert!(n >= 1);
    let w = topo.workers_per_node.max(1);
    assert!(n % w == 0, "cluster size {n} not divisible by workers_per_node {w}");
    let m = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == m), "buffer length mismatch");
    let mut report = CommReport::default();
    if n == 1 || m == 0 {
        return report;
    }
    if w == 1 {
        // Flat degenerate case: plain ring over the inter link.
        return ring_allreduce(bufs, topo.inter);
    }
    let nodes = n / w;
    let bytes = 4.0 * m as f64;
    let rounds = ceil_log2(w);

    // Phase 1: intra-node binomial reduce to each node's leader. All nodes
    // run the same round in parallel, so each round is charged once.
    for d in 0..rounds {
        let step = 1usize << d;
        let mut any = false;
        for g in 0..nodes {
            let base = g * w;
            for local in (0..w).rev() {
                if local & step != 0 && local & (step - 1) == 0 {
                    let src = base + local;
                    let dst = src - step;
                    let (lo, hi) = bufs.split_at_mut(src);
                    for (dv, sv) in lo[dst].iter_mut().zip(&hi[0]) {
                        *dv += *sv;
                    }
                    any = true;
                }
            }
        }
        if any {
            report.add_round(topo.intra, bytes);
        }
    }

    // Phase 2: ring allreduce among the node leaders on the inter link.
    let mut leaders: Vec<Vec<f32>> = (0..nodes).map(|g| std::mem::take(&mut bufs[g * w])).collect();
    report.merge(ring_allreduce(&mut leaders, topo.inter));
    for (g, buf) in leaders.into_iter().enumerate() {
        bufs[g * w] = buf;
    }

    // Phase 3: intra-node binomial broadcast from the leaders (mirror).
    for d in (0..rounds).rev() {
        let step = 1usize << d;
        let mut any = false;
        for g in 0..nodes {
            let base = g * w;
            for local in 0..w {
                if local & step != 0 && local & (step - 1) == 0 {
                    let dst = base + local;
                    let src = dst - step;
                    let (lo, hi) = bufs.split_at_mut(dst);
                    hi[0].copy_from_slice(&lo[src]);
                    any = true;
                }
            }
        }
        if any {
            report.add_round(topo.intra, bytes);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::{self, LinkParams};
    use crate::util::proptest::{all_close, check, ensure};

    fn asym() -> Topology {
        Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(10.0, 1.0),
            4,
        )
    }

    #[test]
    fn sums_exactly_2x4() {
        let mut bufs: Vec<Vec<f32>> = (0..8).map(|r| vec![r as f32; 4]).collect();
        hierarchical_allreduce(&mut bufs, asym());
        for b in &bufs {
            assert_eq!(b, &vec![28.0; 4]);
        }
    }

    #[test]
    fn time_matches_closed_form() {
        // Exact for any w (⌈log⌉ intra rounds) when nodes | m (ring
        // chunking); (3, 6) pins the non-power-of-two-w case.
        for (w, n) in [(2usize, 8usize), (4, 8), (2, 4), (8, 8), (3, 6)] {
            let topo = Topology::two_level(
                LinkParams::from_ms_gbps(0.05, 50.0),
                LinkParams::from_ms_gbps(5.0, 2.0),
                w,
            );
            let m = 8 * 300;
            let mut bufs = vec![vec![1.0f32; m]; n];
            let r = hierarchical_allreduce(&mut bufs, topo);
            let want = cost_model::hierarchical_allreduce(topo, 4.0 * m as f64, n);
            assert!(
                (r.seconds - want).abs() / want < 1e-9,
                "w={w} n={n}: sim {} vs model {}",
                r.seconds,
                want
            );
            let nodes = (n / w) as u32;
            assert_eq!(r.rounds, 2 * ceil_log2(w) + 2 * (nodes - 1));
        }
    }

    #[test]
    fn beats_flat_ring_on_asymmetric_topology() {
        let topo = asym();
        let m = 100_000;
        let mut a = vec![vec![1.0f32; m]; 8];
        let mut b = vec![vec![1.0f32; m]; 8];
        let hier = hierarchical_allreduce(&mut a, topo);
        let flat = crate::collectives::ring_allreduce(&mut b, topo.inter);
        assert!(
            hier.seconds < flat.seconds,
            "hier {} vs flat ring {}",
            hier.seconds,
            flat.seconds
        );
        assert_eq!(a, b, "both must produce the same sums");
    }

    #[test]
    fn w1_degenerates_to_flat_ring() {
        let topo = Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(5.0, 2.0),
            1,
        );
        let m = 4 * 100;
        let mut a = vec![vec![1.0f32; m]; 4];
        let mut b = vec![vec![1.0f32; m]; 4];
        let hier = hierarchical_allreduce(&mut a, topo);
        let ring = crate::collectives::ring_allreduce(&mut b, topo.inter);
        assert_eq!(hier, ring);
        assert_eq!(a, b);
    }

    #[test]
    fn property_sum_any_grouping() {
        check("hierarchical sums for any (w, nodes, m)", 50, |g| {
            let w = g.usize_in(1, 5);
            let nodes = g.usize_in(1, 4);
            let n = w * nodes;
            let m = g.usize_in(1, 120);
            let topo = Topology::two_level(
                LinkParams::from_ms_gbps(0.01, 100.0),
                LinkParams::from_ms_gbps(2.0, 5.0),
                w,
            );
            let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(m, 1.0)).collect();
            let mut want = vec![0.0f32; m];
            for b in &bufs {
                for (wv, v) in want.iter_mut().zip(b) {
                    *wv += v;
                }
            }
            let mut got = bufs;
            hierarchical_allreduce(&mut got, topo);
            for (i, b) in got.iter().enumerate() {
                all_close(b, &want, 1e-4).map_err(|e| format!("worker {i}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn ragged_cluster_rejected() {
        let mut bufs = vec![vec![1.0f32; 4]; 6];
        hierarchical_allreduce(&mut bufs, asym());
    }

    #[test]
    fn single_worker_is_noop() {
        let topo = Topology::two_level(
            LinkParams::from_ms_gbps(0.01, 100.0),
            LinkParams::from_ms_gbps(2.0, 5.0),
            1,
        );
        let mut bufs = vec![vec![3.0f32, 4.0]];
        let r = hierarchical_allreduce(&mut bufs, topo);
        assert_eq!(r, CommReport::default());
        assert_eq!(bufs[0], vec![3.0, 4.0]);
    }

    #[test]
    fn deterministic() {
        check("hierarchical deterministic", 20, |g| {
            let w = *g.choose(&[2usize, 4]);
            let n = w * 2;
            let m = g.usize_in(1, 64);
            let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(m, 1.0)).collect();
            let topo = asym();
            let topo = Topology { workers_per_node: w, ..topo };
            let mut a = bufs.clone();
            let mut b = bufs;
            let ra = hierarchical_allreduce(&mut a, topo);
            let rb = hierarchical_allreduce(&mut b, topo);
            ensure(a == b && ra == rb, "nondeterministic")
        });
    }
}
