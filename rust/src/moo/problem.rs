//! The CR-selection problem (Eqn 6): minimize
//! `(t_comp(c), t_sync(c), 1/gain(c))` over `c ∈ [c_low, c_high]`.
//!
//! The controller measures a handful of candidate CRs (the paper probes
//! `[0.1, 0.033, 0.011, 0.004, 0.001]` for 10 iterations each under
//! checkpoint/restore) and the problem interpolates the three objectives
//! piecewise-linearly in log-CR between those measurements — NSGA-II then
//! searches the continuous range and the knee point becomes `c_optimal`.

use crate::moo::nsga2::Problem;
use crate::moo::pareto::{knee_point, pareto_front};

/// Measured profile of one candidate CR.
#[derive(Debug, Clone, Copy)]
pub struct CandidateProfile {
    pub cr: f64,
    /// Mean measured compression+decompression time (s).
    pub t_comp: f64,
    /// Mean (simulated) communication time at the current link (s).
    pub t_sync: f64,
    /// Mean compression gain in (0, 1].
    pub gain: f64,
}

/// Candidate CR ladder used by the paper: `c_low` scaled by ~3x steps up to
/// `c_high` => [0.001, 0.004 (? ~0.003·...), 0.011, 0.033, 0.1] for the
/// default bounds. Returned descending (0.1 first) to match §3-E1.
pub fn candidate_crs(c_low: f64, c_high: f64, factor: f64) -> Vec<f64> {
    assert!(c_low > 0.0 && c_high > c_low && factor > 1.0);
    // Descend from c_high by `factor` steps; once the next step would land
    // within half a (geometric) step of c_low, snap to c_low. Reproduces
    // the paper's ladder [0.1, 0.033, 0.011, 0.004, 0.001].
    let mut out = vec![c_high];
    let mut c = c_high;
    loop {
        c /= factor;
        if c <= c_low * factor.sqrt() {
            break;
        }
        out.push(c);
    }
    out.push(c_low);
    out
}

/// Continuous CR problem over measured candidates.
#[derive(Debug, Clone)]
pub struct CrProblem {
    /// Sorted ascending by cr.
    profiles: Vec<CandidateProfile>,
}

impl CrProblem {
    pub fn new(mut profiles: Vec<CandidateProfile>) -> Self {
        assert!(profiles.len() >= 2, "need at least two candidate profiles");
        profiles.sort_by(|a, b| crate::tensor::nan_min_cmp(a.cr, b.cr));
        for p in &profiles {
            assert!(p.cr > 0.0 && p.gain > 0.0 && p.gain <= 1.0 + 1e-9);
        }
        CrProblem { profiles }
    }

    pub fn c_low(&self) -> f64 {
        self.profiles[0].cr
    }

    pub fn c_high(&self) -> f64 {
        self.profiles[self.profiles.len() - 1].cr
    }

    /// Map a gene in [0,1] to a CR (log-uniform across the bounds).
    pub fn gene_to_cr(&self, gene: f64) -> f64 {
        let lo = self.c_low().ln();
        let hi = self.c_high().ln();
        (lo + gene.clamp(0.0, 1.0) * (hi - lo)).exp()
    }

    /// Piecewise-linear interpolation (in log-cr) of the three objectives.
    pub fn objectives_at(&self, cr: f64) -> (f64, f64, f64) {
        let cr = cr.clamp(self.c_low(), self.c_high());
        let x = cr.ln();
        let ps = &self.profiles;
        let mut i = 0;
        while i + 2 < ps.len() && x > ps[i + 1].cr.ln() {
            i += 1;
        }
        let (a, b) = (&ps[i], &ps[i + 1]);
        let (xa, xb) = (a.cr.ln(), b.cr.ln());
        let t = if xb > xa { ((x - xa) / (xb - xa)).clamp(0.0, 1.0) } else { 0.0 };
        let lerp = |u: f64, v: f64| u + t * (v - u);
        (
            lerp(a.t_comp, b.t_comp),
            lerp(a.t_sync, b.t_sync),
            1.0 / lerp(a.gain, b.gain).max(1e-9),
        )
    }

    /// Solve with NSGA-II and return the knee-point `c_optimal`.
    pub fn solve(&self, seed: u64) -> f64 {
        let cfg = crate::moo::nsga2::Nsga2Config { seed, ..Default::default() };
        let res = crate::moo::nsga2::optimize(self, &cfg);
        let front: Vec<&crate::moo::nsga2::Individual> = res.front();
        let objs: Vec<Vec<f64>> = front.iter().map(|i| i.objectives.clone()).collect();
        let idx: Vec<usize> = (0..objs.len()).collect();
        let pf = pareto_front(&objs);
        let chosen = if pf.is_empty() { idx[0] } else { knee_point(&objs, &pf) };
        self.gene_to_cr(front[chosen].genes[0])
    }
}

impl Problem for CrProblem {
    fn n_var(&self) -> usize {
        1
    }
    fn n_obj(&self) -> usize {
        3
    }
    fn evaluate(&self, x: &[f64]) -> Vec<f64> {
        let cr = self.gene_to_cr(x[0]);
        let (t_comp, t_sync, inv_gain) = self.objectives_at(cr);
        vec![t_comp, t_sync, inv_gain]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> Vec<CandidateProfile> {
        // Realistic shape: lower CR -> cheaper comp+sync, lower gain.
        [0.001, 0.004, 0.011, 0.033, 0.1]
            .iter()
            .map(|&cr| CandidateProfile {
                cr,
                t_comp: 0.002 + 0.01 * cr,
                t_sync: 0.005 + 0.4 * cr,
                gain: (0.35 + 0.12 * (cr as f64).ln().abs().recip() * 10.0).min(0.99),
            })
            .collect()
    }

    #[test]
    fn candidate_ladder_matches_paper() {
        let crs = candidate_crs(0.001, 0.1, 3.0);
        assert_eq!(crs.len(), 5);
        assert!((crs[0] - 0.1).abs() < 1e-12);
        assert!((crs[4] - 0.001).abs() < 1e-12);
        // ~[0.1, 0.027, 0.009, 0.003, 0.001] with exact 3x from below.
        assert!(crs.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn gene_mapping_is_log_uniform() {
        let p = CrProblem::new(ladder());
        assert!((p.gene_to_cr(0.0) - 0.001).abs() < 1e-9);
        assert!((p.gene_to_cr(1.0) - 0.1).abs() < 1e-9);
        let mid = p.gene_to_cr(0.5);
        assert!((mid - 0.01).abs() / 0.01 < 0.01, "log-midpoint, got {mid}");
    }

    #[test]
    fn interpolation_hits_measured_points() {
        let p = CrProblem::new(ladder());
        for prof in ladder() {
            let (tc, ts, ig) = p.objectives_at(prof.cr);
            assert!((tc - prof.t_comp).abs() < 1e-9);
            assert!((ts - prof.t_sync).abs() < 1e-9);
            assert!((ig - 1.0 / prof.gain).abs() < 1e-6);
        }
    }

    #[test]
    fn solve_returns_in_bounds_and_interior() {
        let p = CrProblem::new(ladder());
        let c = p.solve(11);
        assert!(c >= 0.001 - 1e-12 && c <= 0.1 + 1e-12);
    }

    /// A NaN `cr` must no longer panic inside the sort comparator: the
    /// total order places it first and the TYPED validation (`cr > 0.0`)
    /// rejects it with a meaningful assert instead.
    #[test]
    #[should_panic(expected = "p.cr > 0.0")]
    fn nan_cr_is_rejected_by_validation_not_comparator() {
        let mut profs = ladder();
        profs[2].cr = f64::NAN;
        let _ = CrProblem::new(profs);
    }

    #[test]
    fn gain_dominant_profile_pushes_cr_up() {
        // If sync is free (fast net), higher CR (higher gain) should win.
        let fast_net: Vec<CandidateProfile> = [0.001, 0.01, 0.1]
            .iter()
            .map(|&cr| CandidateProfile {
                cr,
                t_comp: 0.001,
                t_sync: 1e-5, // negligible
                gain: 0.3 + 0.6 * (cr / 0.1),
            })
            .collect();
        let slow_net: Vec<CandidateProfile> = [0.001, 0.01, 0.1]
            .iter()
            .map(|&cr| CandidateProfile {
                cr,
                t_comp: 0.001,
                t_sync: 10.0 * cr, // dominant
                gain: 0.3 + 0.6 * (cr / 0.1),
            })
            .collect();
        let c_fast = CrProblem::new(fast_net).solve(5);
        let c_slow = CrProblem::new(slow_net).solve(5);
        assert!(
            c_fast > c_slow,
            "fast net should tolerate higher CR: fast {c_fast} slow {c_slow}"
        );
    }
}
