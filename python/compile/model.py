"""L2: the jax compute graphs AOT-lowered for the rust coordinator.

Two model families act as proxies for the paper's four DNNs (DESIGN.md §3):

  * ``transformer`` — a decoder-only LM (configurable depth/width).  Its
    dense projections route through the L1 Pallas matmul kernel so the
    kernel lowers into the same HLO artifact the rust runtime executes.
  * ``mlp`` — a small classifier over synthetic feature clusters; the fast
    model for tests and the quickstart example.

Exported graphs per preset (see ``aot.py``):

  grad : (params[P], tokens/x..)              -> (loss, grads[P])
  eval : (params[P], tokens/x..)              -> (loss, ncorrect)
  step : (params[P], mom[P], grads[P], hyper) -> (params', mom')

All parameters live in ONE flat f32 vector with a published layout
(name/offset/size per layer) — the rust side needs layer boundaries for
LWTopk and the flat view for fused AR-Topk, and a flat vector makes the
PJRT ABI trivial.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul as pallas_matmul


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    dim: int
    layers: int
    heads: int
    seq: int
    batch: int  # per-worker batch size baked into the artifact
    use_pallas: bool = True  # route MLP-block matmuls through the L1 kernel

    @property
    def mlp_hidden(self) -> int:
        return 4 * self.dim


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    name: str
    features: int
    hidden: Tuple[int, ...]
    classes: int
    batch: int


# ---------------------------------------------------------------------------
# Presets. Transformer presets are sized to ladder up toward the paper's
# model scales; cost-model experiments additionally use the paper's exact
# parameter counts (defined rust-side) since only M matters there.
# ---------------------------------------------------------------------------
TRANSFORMER_PRESETS: Dict[str, TransformerConfig] = {
    c.name: c
    for c in [
        TransformerConfig("tiny", vocab=256, dim=64, layers=2, heads=2, seq=32, batch=8),
        TransformerConfig("small", vocab=512, dim=192, layers=4, heads=4, seq=64, batch=8),
        TransformerConfig("base", vocab=2048, dim=512, layers=8, heads=8, seq=128, batch=8),
        TransformerConfig("large", vocab=4096, dim=768, layers=12, heads=12, seq=128, batch=4),
    ]
}

MLP_PRESETS: Dict[str, MlpConfig] = {
    c.name: c
    for c in [
        MlpConfig("mlp", features=64, hidden=(256, 128), classes=16, batch=32),
        MlpConfig("mlp-wide", features=128, hidden=(1024, 512, 256), classes=32, batch=32),
    ]
}


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------
def transformer_layout(cfg: TransformerConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) for every parameter tensor ("layer" for LWTopk)."""
    ly: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_embed", (cfg.vocab, cfg.dim)),
        ("pos_embed", (cfg.seq, cfg.dim)),
    ]
    for i in range(cfg.layers):
        p = f"block{i}."
        ly += [
            (p + "ln1.g", (cfg.dim,)),
            (p + "ln1.b", (cfg.dim,)),
            (p + "attn.qkv", (cfg.dim, 3 * cfg.dim)),
            (p + "attn.out", (cfg.dim, cfg.dim)),
            (p + "ln2.g", (cfg.dim,)),
            (p + "ln2.b", (cfg.dim,)),
            (p + "mlp.fc", (cfg.dim, cfg.mlp_hidden)),
            (p + "mlp.proj", (cfg.mlp_hidden, cfg.dim)),
        ]
    ly += [
        ("lnf.g", (cfg.dim,)),
        ("lnf.b", (cfg.dim,)),
        ("head", (cfg.dim, cfg.vocab)),
    ]
    return ly


def mlp_layout(cfg: MlpConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    dims = (cfg.features,) + cfg.hidden + (cfg.classes,)
    ly: List[Tuple[str, Tuple[int, ...]]] = []
    for i in range(len(dims) - 1):
        ly.append((f"fc{i}.w", (dims[i], dims[i + 1])))
        ly.append((f"fc{i}.b", (dims[i + 1],)))
    return ly


def layout_sizes(layout) -> List[Tuple[str, int, int]]:
    """(name, offset, size) rows; also what ``aot.py`` writes to *_layout.txt."""
    rows, off = [], 0
    for name, shape in layout:
        size = 1
        for s in shape:
            size *= s
        rows.append((name, off, size))
        off += size
    return rows


def param_count(layout) -> int:
    rows = layout_sizes(layout)
    return rows[-1][1] + rows[-1][2] if rows else 0


def unflatten(flat: jnp.ndarray, layout) -> Dict[str, jnp.ndarray]:
    out, off = {}, 0
    for name, shape in layout:
        size = 1
        for s in shape:
            size *= s
        out[name] = jax.lax.dynamic_slice_in_dim(flat, off, size).reshape(shape)
        off += size
    return out


def init_params(layout, seed: int = 0) -> jnp.ndarray:
    """Scaled-normal init, returned as the flat vector the artifacts consume."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in layout:
        key, sub = jax.random.split(key)
        fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
        if name.endswith((".b", "ln1.g", "ln2.g", "lnf.g")):
            base = jnp.ones(shape) if name.endswith(".g") else jnp.zeros(shape)
            chunks.append(base.reshape(-1).astype(jnp.float32))
        else:
            std = (2.0 / fan_in) ** 0.5 * (0.02 ** 0.0)
            std = min(std, 0.08) if len(shape) > 1 else 0.02
            chunks.append(
                (jax.random.normal(sub, shape) * std).reshape(-1).astype(jnp.float32)
            )
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Transformer forward
# ---------------------------------------------------------------------------
def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _dense(x2d, w, use_pallas: bool):
    """(rows, k) @ (k, n); the Pallas kernel is the MLP-block hot path."""
    if use_pallas:
        return pallas_matmul(x2d, w)
    return jnp.matmul(x2d, w, preferred_element_type=jnp.float32)


def transformer_logits(cfg: TransformerConfig, params: Dict[str, jnp.ndarray], tokens):
    """tokens [B, T] int32 -> logits [B, T, V]."""
    b, t = tokens.shape
    d = cfg.dim
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :t, :]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.layers):
        p = f"block{i}."
        h = _layer_norm(x, params[p + "ln1.g"], params[p + "ln1.b"])
        qkv = jnp.matmul(h.reshape(b * t, d), params[p + "attn.qkv"]).reshape(
            b, t, 3, cfg.heads, d // cfg.heads
        )
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(d / cfg.heads)
        att = jnp.where(causal[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", att, v).reshape(b, t, d)
        x = x + jnp.matmul(o.reshape(b * t, d), params[p + "attn.out"]).reshape(b, t, d)
        h = _layer_norm(x, params[p + "ln2.g"], params[p + "ln2.b"])
        # MLP block: the FLOP hot spot — routed through the L1 Pallas kernel.
        hh = _dense(h.reshape(b * t, d), params[p + "mlp.fc"], cfg.use_pallas)
        hh = jax.nn.gelu(hh)
        hh = _dense(hh, params[p + "mlp.proj"], cfg.use_pallas)
        x = x + hh.reshape(b, t, d)
    x = _layer_norm(x, params["lnf.g"], params["lnf.b"])
    logits = jnp.matmul(x.reshape(b * t, d), params["head"]).reshape(b, t, cfg.vocab)
    return logits


def transformer_loss(cfg: TransformerConfig, flat_params, tokens):
    """tokens [B, T+1]: positions 0..T-1 are inputs, 1..T targets."""
    layout = transformer_layout(cfg)
    params = unflatten(flat_params, layout)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = transformer_logits(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def transformer_eval(cfg: TransformerConfig, flat_params, tokens):
    layout = transformer_layout(cfg)
    params = unflatten(flat_params, layout)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = transformer_logits(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == tgt).astype(jnp.float32))
    return jnp.mean(nll), correct


# ---------------------------------------------------------------------------
# MLP classifier forward
# ---------------------------------------------------------------------------
def mlp_logits(cfg: MlpConfig, params, x):
    h = x
    n = len(cfg.hidden) + 1
    for i in range(n):
        h = jnp.matmul(h, params[f"fc{i}.w"]) + params[f"fc{i}.b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def mlp_loss(cfg: MlpConfig, flat_params, x, y):
    params = unflatten(flat_params, mlp_layout(cfg))
    logits = mlp_logits(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def mlp_eval(cfg: MlpConfig, flat_params, x, y):
    params = unflatten(flat_params, mlp_layout(cfg))
    logits = mlp_logits(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct


# ---------------------------------------------------------------------------
# Graphs exported by aot.py
# ---------------------------------------------------------------------------
def grad_fn(kind: str, cfg):
    """(flat_params, batch...) -> (loss, flat_grads)."""
    if kind == "transformer":

        def f(flat_params, tokens):
            loss, grads = jax.value_and_grad(
                lambda p: transformer_loss(cfg, p, tokens)
            )(flat_params)
            return loss, grads

        return f
    if kind == "mlp":

        def f(flat_params, x, y):
            loss, grads = jax.value_and_grad(lambda p: mlp_loss(cfg, p, x, y))(
                flat_params
            )
            return loss, grads

        return f
    raise ValueError(kind)


def eval_fn(kind: str, cfg):
    if kind == "transformer":
        return lambda p, tokens: transformer_eval(cfg, p, tokens)
    if kind == "mlp":
        return lambda p, x, y: mlp_eval(cfg, p, x, y)
    raise ValueError(kind)


def sgd_step_fn():
    """Momentum-SGD update: (params, mom, grads, lr, momentum, wd) -> (params', mom')."""

    def f(params, mom, grads, lr, momentum, weight_decay):
        g = grads + weight_decay * params
        mom_new = momentum * mom + g
        return params - lr * mom_new, mom_new

    return f
