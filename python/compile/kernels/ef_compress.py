"""L1 Pallas kernel: fused error-feedback compression step (paper Eqn 2).

Once a threshold tau is known (from ``topk_threshold.estimate_threshold``),
the per-step compression work is four elementwise/reduction passes:

    g_e  = g + residual
    g_c  = g_e * [|g_e| >= tau]
    res' = g_e - g_c
    gain terms ||g_c||^2, ||g_e||^2        (GraVAC compression gain)

Done naively that is 4+ HBM round-trips over a tensor the size of the model.
This kernel fuses all of it into ONE pass: each block is read once from HBM
into VMEM, produces both output blocks and two partial-sum lanes.  That is
the roofline move for a bandwidth-bound op — see EXPERIMENTS.md §Perf for
the measured pass-count ablation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _ef_kernel(g_ref, r_ref, tau_ref, gc_ref, rn_ref, nc_ref, ne_ref):
    tau = tau_ref[0]
    g_e = g_ref[...] + r_ref[...]
    keep = jnp.abs(g_e) >= tau
    g_c = jnp.where(keep, g_e, jnp.zeros_like(g_e))
    gc_ref[...] = g_c
    rn_ref[...] = g_e - g_c
    nc_ref[0] = jnp.sum(g_c * g_c)
    ne_ref[0] = jnp.sum(g_e * g_e)


def _pad_flat(x, block):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    np_ = -(-n // block) * block
    return jnp.pad(flat, (0, np_ - n)), n


@functools.partial(jax.jit, static_argnames=("block",))
def ef_compress(g, residual, tau, *, block=BLOCK):
    """Fused EF-compress. Returns (g_c, residual', ||g_c||^2, ||g_e||^2).

    Shapes of ``g`` and ``residual`` must match; output tensors keep that
    shape. ``tau`` is a scalar (may be traced).
    """
    shape = g.shape
    gp, n = _pad_flat(g, block)
    rp, _ = _pad_flat(residual, block)
    nblocks = gp.shape[0] // block
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)
    g_c, res, nc, ne = pl.pallas_call(
        _ef_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(gp.shape, jnp.float32),
            jax.ShapeDtypeStruct(gp.shape, jnp.float32),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=True,
    )(gp, rp, tau_arr)
    return (
        g_c[:n].reshape(shape),
        res[:n].reshape(shape),
        jnp.sum(nc),
        jnp.sum(ne),
    )


def vmem_bytes(block=BLOCK, dtype_bytes=4):
    """VMEM working set per grid step: 2 in blocks + 2 out blocks + scalars."""
    return 4 * block * dtype_bytes + 3 * dtype_bytes
