//! Pluggable communication strategies — the strategy seam of the Session
//! API (DESIGN.md §8).
//!
//! The paper's core claim is that the *best* communication method changes
//! with network conditions, which means the trainer must treat strategies
//! as interchangeable plug-ins. [`CommStrategy`] is that plug-in surface:
//! `plan` decides (from the probed network view) which collective the step
//! will use, `exchange` executes the compress-and-communicate phase over
//! the true topology, and `observe` lets adaptive strategies react to the
//! recorded step. The trainer drives exactly those three calls — it has no
//! per-strategy `match` arms — so a new strategy (an AR-compatible
//! compressor, a GraVAC-style controller, local SGD, ...) is a new impl
//! handed to
//! [`SessionBuilder::comm_strategy`](crate::coordinator::session::SessionBuilder::comm_strategy),
//! not trainer surgery.
//!
//! The classic [`Strategy`] enum remains as the pure config/CLI surface:
//! [`STRATEGY_TABLE`] maps names to enum values (the one table CLI help
//! and parsing share) and [`instantiate`] maps enum values to the trait
//! objects implemented here.

use crate::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use crate::collectives::{
    allgather_sparse, collective, dense_op, CollectiveKind, CommReport,
};
use crate::compress::{gain::gain, Compressor, CompressorKind, EfState, SparseGrad};
use crate::coordinator::metrics::StepMetrics;
use crate::coordinator::observer::StrategySwitch;
use crate::coordinator::selector;
use crate::coordinator::trainer::{DenseFlavor, Strategy};
use crate::netsim::cost_model::Topology;
use crate::tensor::Layout;
use crate::util::pool::ThreadPool;
use anyhow::{bail, Result};
use std::time::Instant;

/// What a strategy sees when planning a step: the probed (noisy) network
/// view plus the scalars the Eqn 5 deciders need.
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    pub step: u64,
    pub n_workers: usize,
    /// Effective message bytes (`4 · dim · msg_scale`).
    pub model_bytes: f64,
    /// Current compression ratio (1.0 nominal for dense strategies).
    pub cr: f64,
    /// The selector's view of the cluster: probed inter link, known intra.
    pub probed_topo: Topology,
}

/// A planned step: which collective will run, and (when a cost model
/// priced the decision) the predicted communication seconds at the probed
/// link — logged so Fig 8-style decisions can be audited.
#[derive(Debug, Clone, Copy)]
pub struct CommPlan {
    pub kind: CollectiveKind,
    pub predicted_s: Option<f64>,
}

impl CommPlan {
    /// Plan `kind` priced by the registry's closed-form cost at the probed
    /// topology (custom kinds have no registry entry and stay unpriced).
    pub fn priced(kind: CollectiveKind, ctx: &StepCtx) -> CommPlan {
        let predicted_s = match kind {
            CollectiveKind::Custom(_) => None,
            k => {
                let op = collective(k);
                Some(op.predict(ctx.probed_topo, ctx.model_bytes, ctx.n_workers, ctx.cr))
            }
        };
        CommPlan { kind, predicted_s }
    }

    /// Plan with no cost prediction attached.
    pub fn unpriced(kind: CollectiveKind) -> CommPlan {
        CommPlan { kind, predicted_s: None }
    }
}

/// What a strategy gets to execute an exchange: this step's plan, every
/// worker's raw gradient, the per-worker error-feedback state (owned by
/// the engine so checkpoint/restore covers it), and the true
/// (msg_scale-adjusted) topology the data actually moves over.
pub struct ExchangeCtx<'a> {
    pub plan: CommPlan,
    pub grads: &'a [Vec<f32>],
    pub ef: &'a mut [EfState],
    /// Layer layout of the model (LWTopk and bucketing compressors).
    pub layout: &'a Layout,
    pub true_topo: Topology,
    pub cr: f64,
    pub step: u64,
    /// The engine's worker pool; strategies run per-worker phases on it so
    /// `--threads` applies uniformly (DESIGN.md §7).
    pub pool: ThreadPool,
}

impl ExchangeCtx<'_> {
    pub fn n_workers(&self) -> usize {
        self.grads.len()
    }

    pub fn dim(&self) -> usize {
        self.grads.first().map_or(0, Vec::len)
    }
}

/// One executed exchange. `update` is the AVERAGED model update (identical
/// on every worker of the simulated cluster); `t_comp` is the measured
/// critical-path compression seconds (before `comp_scale`), and
/// `collective` is the metrics identity of what ran (custom strategies use
/// [`CollectiveKind::Custom`]).
pub struct ExchangeOutcome {
    pub update: Vec<f32>,
    pub comm: CommReport,
    pub t_comp: f64,
    pub collective: CollectiveKind,
    /// Rank that broadcast its indices (AR-Topk family only).
    pub selected_rank: Option<usize>,
    /// Compression gain (1.0 for exact dense exchanges).
    pub gain: f64,
}

/// A compression-communication strategy as a trainer plug-in.
///
/// Contract: `plan` is called once per step with the probed network view;
/// `exchange` executes that plan (the same `CommPlan` arrives in the
/// [`ExchangeCtx`]); `observe` sees every completed step's metrics and may
/// report an internal mode change for the observer stream. Determinism:
/// with a static CR, `plan`/`exchange` must be pure functions of their
/// inputs and the strategy's own state so runs replay bit-identically for
/// any thread count (DESIGN.md §7).
pub trait CommStrategy: Send {
    /// Display name (reports, logs).
    fn name(&self) -> &'static str;

    /// Whether exchanges compress (CR semantics apply; adaptive-CR control
    /// requires this).
    fn is_compressed(&self) -> bool;

    /// Decide the collective for this step from the probed network view.
    fn plan(&self, ctx: &StepCtx) -> CommPlan;

    /// Execute the planned exchange over the true topology.
    fn exchange(&mut self, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome;

    /// Post-step feedback: the metrics of the step that just ran. Called
    /// for RECORDED steps only — the exploration harness's checkpointed
    /// steps are rolled back, so strategy state never learns from a
    /// timeline that did not happen (DESIGN.md §10). Return a
    /// [`StrategySwitch`] to surface an internal mode change on the
    /// observer stream (delivered immediately, stamped with this step).
    fn observe(&mut self, _m: &StepMetrics) -> Option<StrategySwitch> {
        None
    }

    /// Controller-directed selection-policy switch
    /// ([`ControlAction::SwitchSelectionPolicy`](crate::coordinator::controller::ControlAction)).
    /// Return the PREVIOUS policy when applied (the engine fires the
    /// observer event from it), `None` when this strategy has no notion
    /// of a selection policy.
    fn set_selection_policy(&mut self, _p: SelectionPolicy) -> Option<SelectionPolicy> {
        None
    }

    /// Controller-directed collective pinning
    /// ([`ControlAction::SwitchCollective`](crate::coordinator::controller::ControlAction)).
    /// Return `true` when applied; strategies that re-decide per step
    /// (flexible/auto flavors) may decline. The observable collective
    /// change surfaces through the per-step switch detection.
    fn set_collective(&mut self, _k: CollectiveKind) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// The built-in strategies (the paper's five families).
// ---------------------------------------------------------------------------

/// DenseSGD baseline: exact dense allreduce via the collective registry;
/// auto flavors re-decide per step from the probed link/topology.
pub struct DenseStrategy {
    pub flavor: DenseFlavor,
}

impl CommStrategy for DenseStrategy {
    fn name(&self) -> &'static str {
        "DenseSGD"
    }

    fn is_compressed(&self) -> bool {
        false
    }

    fn plan(&self, ctx: &StepCtx) -> CommPlan {
        let kind = match self.flavor {
            DenseFlavor::Ring => CollectiveKind::RingAllreduce,
            DenseFlavor::Tree => CollectiveKind::TreeAllreduce,
            DenseFlavor::HalvingDoubling => CollectiveKind::HalvingDoublingAllreduce,
            DenseFlavor::Hierarchical => CollectiveKind::HierarchicalAllreduce,
            DenseFlavor::Ps => CollectiveKind::PsStar,
            DenseFlavor::Auto => {
                selector::choose_dense(ctx.probed_topo.inter, ctx.model_bytes, ctx.n_workers)
            }
            DenseFlavor::TopoAuto => {
                // The argmin already priced its pick — keep it instead of
                // re-running predict through the registry.
                let c =
                    selector::choose_dense_topo(ctx.probed_topo, ctx.model_bytes, ctx.n_workers);
                return CommPlan { kind: c.kind, predicted_s: Some(c.predicted_s) };
            }
        };
        CommPlan::priced(kind, ctx)
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
        let kind = ctx.plan.kind;
        let op = dense_op(kind).expect("dense kind registered");
        let mut bufs = ctx.grads.to_vec();
        let comm = op.run(&mut bufs, ctx.true_topo);
        let mut update = bufs.into_iter().next().unwrap();
        crate::tensor::scale(&mut update, 1.0 / ctx.n_workers() as f32);
        ExchangeOutcome {
            update,
            comm,
            t_comp: 0.0,
            collective: kind,
            selected_rank: None,
            gain: 1.0,
        }
    }

    /// A controller can pin any fixed dense flavour; the auto flavors are
    /// re-decided per step and cannot be pinned from outside.
    fn set_collective(&mut self, k: CollectiveKind) -> bool {
        let flavor = match k {
            CollectiveKind::RingAllreduce => DenseFlavor::Ring,
            CollectiveKind::TreeAllreduce => DenseFlavor::Tree,
            CollectiveKind::HalvingDoublingAllreduce => DenseFlavor::HalvingDoubling,
            CollectiveKind::HierarchicalAllreduce => DenseFlavor::Hierarchical,
            CollectiveKind::PsStar => DenseFlavor::Ps,
            _ => return false,
        };
        self.flavor = flavor;
        true
    }
}

/// One simulated worker's lane on the AG path: its compressor instance
/// plus the step arenas (error-fed staging buffer, compressed part)
/// reused across steps. A lane is touched by exactly one pool slot per
/// region, so no synchronization (DESIGN.md §7).
struct AgWorker {
    comp: Box<dyn Compressor>,
    g_e: Vec<f32>,
    part: SparseGrad,
}

impl AgWorker {
    fn new(comp: Box<dyn Compressor>) -> Self {
        AgWorker { comp, g_e: Vec::new(), part: SparseGrad::default() }
    }
}

/// Compress-then-Allgather (LW/MS-Topk path): per-worker error-feed +
/// compress concurrently on the pool, then a sparse allgather.
pub struct AgCompressStrategy {
    workers: Vec<AgWorker>,
}

impl AgCompressStrategy {
    /// One compressor instance per worker, all from the same seed —
    /// Random-k then draws the SAME shared index sequence on every worker
    /// (the AR-compatible behaviour its module docs describe).
    pub fn new(kind: CompressorKind, n_workers: usize, seed: u64) -> Self {
        AgCompressStrategy {
            workers: (0..n_workers).map(|_| AgWorker::new(kind.build(seed))).collect(),
        }
    }
}

impl CommStrategy for AgCompressStrategy {
    fn name(&self) -> &'static str {
        "AG-compress"
    }

    fn is_compressed(&self) -> bool {
        true
    }

    fn plan(&self, ctx: &StepCtx) -> CommPlan {
        CommPlan::priced(CollectiveKind::AllgatherTopk, ctx)
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
        ag_exchange(&mut self.workers, ctx)
    }
}

/// AR-Topk with a fixed selection policy and AR flavour (§3-A/B). The
/// policy and flavour are controller-switchable
/// ([`CommStrategy::set_selection_policy`] / [`CommStrategy::set_collective`]) —
/// `artopk-auto` is exactly this strategy composed with the
/// [`PolicySwitchController`](crate::coordinator::controller::PolicySwitchController).
pub struct ArTopkStrategy {
    op: ArTopk,
    name: &'static str,
}

impl ArTopkStrategy {
    pub fn new(policy: SelectionPolicy, flavor: ArFlavor, pool: ThreadPool) -> Self {
        ArTopkStrategy { op: ArTopk::new(policy, flavor).with_pool(pool), name: "AR-Topk" }
    }

    /// Same operator under a distinct display name (the `artopk-auto`
    /// registry row, so reports distinguish auto-switched runs).
    pub fn named(
        name: &'static str,
        policy: SelectionPolicy,
        flavor: ArFlavor,
        pool: ThreadPool,
    ) -> Self {
        ArTopkStrategy { op: ArTopk::new(policy, flavor).with_pool(pool), name }
    }

    /// AR-Topk over the sampled-threshold selection backend (the
    /// `artopk-sampled` registry row). Bitwise-identical trajectories to
    /// the quickselect operator — the exact-k repair contract in
    /// [`crate::compress::sampledk`] — so this row only moves `t_comp`.
    pub fn sampled(policy: SelectionPolicy, flavor: ArFlavor, pool: ThreadPool) -> Self {
        ArTopkStrategy {
            op: ArTopk::new(policy, flavor).with_sampled_topk().with_pool(pool),
            name: "AR-Topk-sampled",
        }
    }
}

impl CommStrategy for ArTopkStrategy {
    fn name(&self) -> &'static str {
        self.name
    }

    fn is_compressed(&self) -> bool {
        true
    }

    fn plan(&self, ctx: &StepCtx) -> CommPlan {
        CommPlan::priced(ar_kind(self.op.flavor), ctx)
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
        art_exchange(&mut self.op, ctx)
    }

    fn set_selection_policy(&mut self, p: SelectionPolicy) -> Option<SelectionPolicy> {
        let prev = self.op.policy;
        self.op.policy = p;
        Some(prev)
    }

    fn set_collective(&mut self, k: CollectiveKind) -> bool {
        match selector::ar_flavor(k) {
            Some(f) => {
                self.op.flavor = f;
                true
            }
            None => false,
        }
    }
}

/// The full flexible strategy (§3-D): Eqn 5 picks AG vs ART-Ring vs
/// ART-Tree per step on the probed link; both data paths are owned here.
pub struct FlexibleStrategy {
    op: ArTopk,
    ag_workers: Vec<AgWorker>,
}

impl FlexibleStrategy {
    pub fn new(policy: SelectionPolicy, n_workers: usize, seed: u64, pool: ThreadPool) -> Self {
        FlexibleStrategy {
            op: ArTopk::new(policy, ArFlavor::Ring).with_pool(pool),
            ag_workers: (0..n_workers)
                .map(|_| AgWorker::new(CompressorKind::TopK.build(seed)))
                .collect(),
        }
    }
}

impl CommStrategy for FlexibleStrategy {
    fn name(&self) -> &'static str {
        "Flexible"
    }

    fn is_compressed(&self) -> bool {
        true
    }

    fn plan(&self, ctx: &StepCtx) -> CommPlan {
        let c = selector::choose(ctx.probed_topo.inter, ctx.model_bytes, ctx.n_workers, ctx.cr);
        CommPlan { kind: c.kind, predicted_s: Some(c.predicted_s) }
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
        match selector::ar_flavor(ctx.plan.kind) {
            Some(f) => {
                self.op.flavor = f;
                art_exchange(&mut self.op, ctx)
            }
            None => ag_exchange(&mut self.ag_workers, ctx),
        }
    }

    fn set_selection_policy(&mut self, p: SelectionPolicy) -> Option<SelectionPolicy> {
        let prev = self.op.policy;
        self.op.policy = p;
        Some(prev)
    }
}

fn ar_kind(flavor: ArFlavor) -> CollectiveKind {
    match flavor {
        ArFlavor::Ring => CollectiveKind::ArTopkRing,
        ArFlavor::Tree => CollectiveKind::ArTopkTree,
    }
}

/// AG path shared by [`AgCompressStrategy`] and [`FlexibleStrategy`]:
/// error-feed + compress every worker's gradient concurrently across the
/// pool (each worker lane owns its `EfState`, compressor and arenas — no
/// shared mutable state), then allgather. The whole Eqn-2 cycle runs in
/// the lane arenas (`error_fed_into` -> `compress_into` -> `update_swap`),
/// so steady-state steps allocate nothing on the billed path. `t_comp` is
/// the max of per-worker durations MEASURED INSIDE the
/// concurrently-running tasks — the critical-path worker a synchronous
/// cluster step waits for, independent of this host's core count while
/// the pool is not oversubscribed (DESIGN.md §7).
fn ag_exchange(workers: &mut [AgWorker], ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
    let n = ctx.n_workers();
    let dim = ctx.dim();
    let cr = ctx.cr;
    let grads = ctx.grads;
    let layout = ctx.layout;
    let pool = ctx.pool.clone();
    let mut lanes: Vec<(&mut EfState, &mut AgWorker)> =
        ctx.ef.iter_mut().zip(workers.iter_mut()).collect();
    let results = pool.map_mut(&mut lanes, |w, lane| {
        let (ef, worker) = lane;
        // flexlint::allow(unsanctioned-clock): billed t_comp — measured INSIDE the pool task, on the critical path (DESIGN.md §7)
        let t0 = Instant::now();
        ef.error_fed_into(&grads[w], &mut worker.g_e);
        worker.comp.compress_into(&worker.g_e, cr, layout, &mut worker.part);
        let mut dt = t0.elapsed().as_secs_f64();
        // Gain bookkeeping is metrics-only — keep its O(G) pass OFF the
        // billed compression path (a cluster wouldn't run it).
        let e_sq = crate::tensor::sq_norm(&worker.g_e);
        let g = gain(worker.part.sq_norm(), e_sq);
        // flexlint::allow(unsanctioned-clock): second billed segment, resumes after the unbilled gain bookkeeping
        let t1 = Instant::now();
        ef.update_swap(&mut worker.g_e, &worker.part);
        dt += t1.elapsed().as_secs_f64();
        (g, dt)
    });
    drop(lanes);
    let mut gain_acc = 0.0f64;
    let mut t_comp = 0.0f64;
    for (g, dt) in results {
        gain_acc += g;
        t_comp = t_comp.max(dt);
    }
    // The collective wants a contiguous `&[SparseGrad]`: take the parts
    // out of the lanes (cheap pointer moves), gather, hand them back so
    // the arenas survive into the next step.
    let mut parts: Vec<SparseGrad> =
        workers.iter_mut().map(|w| std::mem::take(&mut w.part)).collect();
    let (mut update, comm) = allgather_sparse(&parts, dim, ctx.true_topo.inter);
    for (w, p) in workers.iter_mut().zip(parts.drain(..)) {
        w.part = p;
    }
    crate::tensor::scale(&mut update, 1.0 / n as f32);
    ExchangeOutcome {
        update,
        comm,
        t_comp,
        collective: CollectiveKind::AllgatherTopk,
        selected_rank: None,
        gain: gain_acc / n as f64,
    }
}

/// AR-Topk path (Alg 1) shared by the fixed, flexible and auto strategies.
fn art_exchange(op: &mut ArTopk, ctx: &mut ExchangeCtx<'_>) -> ExchangeOutcome {
    let n = ctx.n_workers();
    let kind = ar_kind(op.flavor);
    let (grads, cr, step, link) = (ctx.grads, ctx.cr, ctx.step, ctx.true_topo.inter);
    let res = op.exchange(grads, ctx.ef, cr, step, link);
    // Critical-path compression time (parallel workers): see DESIGN.md §7.
    let t_comp = res.comp_wall_s;
    let mut update = res.update.to_dense();
    crate::tensor::scale(&mut update, 1.0 / n as f32);
    let g = res.gain_terms.iter().map(|&(c, e)| gain(c, e)).sum::<f64>() / n as f64;
    ExchangeOutcome {
        update,
        comm: res.comm,
        t_comp,
        collective: kind,
        selected_rank: Some(res.selected),
        gain: g,
    }
}

// ---------------------------------------------------------------------------
// The name table + registry mapping (the config/CLI surface).
// ---------------------------------------------------------------------------

/// The one strategy-name table: CLI parsing, config files and `--help`
/// text all read from here, so a new built-in strategy is one new row.
pub const STRATEGY_TABLE: &[(&str, Strategy)] = &[
    ("dense-ring", Strategy::DenseSgd { flavor: DenseFlavor::Ring }),
    ("dense-tree", Strategy::DenseSgd { flavor: DenseFlavor::Tree }),
    ("dense-hd", Strategy::DenseSgd { flavor: DenseFlavor::HalvingDoubling }),
    ("dense-hier", Strategy::DenseSgd { flavor: DenseFlavor::Hierarchical }),
    ("dense-ps", Strategy::DenseSgd { flavor: DenseFlavor::Ps }),
    ("dense", Strategy::DenseSgd { flavor: DenseFlavor::Auto }),
    ("dense-auto", Strategy::DenseSgd { flavor: DenseFlavor::Auto }),
    ("dense-topo", Strategy::DenseSgd { flavor: DenseFlavor::TopoAuto }),
    ("ag-topk", Strategy::AgCompress { kind: CompressorKind::TopK }),
    ("ag-lwtopk", Strategy::AgCompress { kind: CompressorKind::LwTopk }),
    ("ag-mstopk", Strategy::AgCompress { kind: CompressorKind::MsTopk }),
    ("ag-randomk", Strategy::AgCompress { kind: CompressorKind::RandomK }),
    ("ag-sampledk", Strategy::AgCompress { kind: CompressorKind::SampledK }),
    (
        "artopk-star",
        Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
    ),
    (
        "artopk-star-tree",
        Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Tree },
    ),
    (
        "artopk-var",
        Strategy::ArTopkFixed { policy: SelectionPolicy::Var, flavor: ArFlavor::Ring },
    ),
    ("artopk-auto", Strategy::ArTopkAuto { flavor: ArFlavor::Ring }),
    (
        "artopk-sampled",
        Strategy::ArTopkSampled { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
    ),
    ("flexible", Strategy::Flexible { policy: SelectionPolicy::Star }),
    ("flexible-var", Strategy::Flexible { policy: SelectionPolicy::Var }),
];

impl Strategy {
    /// Parse a strategy name from [`STRATEGY_TABLE`]; the error lists
    /// every valid name.
    pub fn parse(s: &str) -> Result<Strategy> {
        match STRATEGY_TABLE.iter().find(|(name, _)| *name == s) {
            Some(&(_, strategy)) => Ok(strategy),
            None => bail!(
                "unknown strategy `{s}` (valid: {})",
                Strategy::names().collect::<Vec<_>>().join(", ")
            ),
        }
    }

    /// Every valid strategy name, in table order (CLI help text).
    pub fn names() -> impl Iterator<Item = &'static str> {
        STRATEGY_TABLE.iter().map(|(name, _)| *name)
    }
}

/// Map a config-surface [`Strategy`] to its executable [`CommStrategy`]
/// object (the strategy registry's constructor column). Custom strategies
/// skip this entirely via
/// [`SessionBuilder::comm_strategy`](crate::coordinator::session::SessionBuilder::comm_strategy).
pub fn instantiate(
    strategy: Strategy,
    n_workers: usize,
    seed: u64,
    pool: ThreadPool,
) -> Box<dyn CommStrategy> {
    match strategy {
        Strategy::DenseSgd { flavor } => Box::new(DenseStrategy { flavor }),
        Strategy::AgCompress { kind } => Box::new(AgCompressStrategy::new(kind, n_workers, seed)),
        Strategy::ArTopkFixed { policy, flavor } => {
            Box::new(ArTopkStrategy::new(policy, flavor, pool))
        }
        Strategy::ArTopkSampled { policy, flavor } => {
            Box::new(ArTopkStrategy::sampled(policy, flavor, pool))
        }
        Strategy::Flexible { policy } => {
            Box::new(FlexibleStrategy::new(policy, n_workers, seed, pool))
        }
        // The auto-switching behavior lives in the control plane: the
        // builder composes a PolicySwitchController alongside the CR
        // controller for this strategy (DESIGN.md §10). The operator
        // itself is a plain AR-Topk starting at STAR.
        Strategy::ArTopkAuto { flavor } => Box::new(ArTopkStrategy::named(
            "AR-Topk-auto",
            SelectionPolicy::Star,
            flavor,
            pool,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model::LinkParams;

    fn ctx(cr: f64) -> StepCtx {
        StepCtx {
            step: 0,
            n_workers: 8,
            model_bytes: 4e8,
            cr,
            probed_topo: Topology::flat(LinkParams::from_ms_gbps(4.0, 20.0)),
        }
    }

    #[test]
    fn table_parses_every_name_and_rejects_unknown() {
        for (name, strategy) in STRATEGY_TABLE {
            assert_eq!(Strategy::parse(name).unwrap(), *strategy, "{name}");
        }
        let err = Strategy::parse("nope").unwrap_err().to_string();
        assert!(err.contains("dense-ring") && err.contains("flexible-var"), "{err}");
        // The aliases stay equivalent.
        assert_eq!(Strategy::parse("dense").unwrap(), Strategy::parse("dense-auto").unwrap());
    }

    #[test]
    fn instantiate_covers_the_table() {
        let pool = ThreadPool::serial();
        for (name, strategy) in STRATEGY_TABLE {
            let obj = instantiate(*strategy, 4, 0, pool.clone());
            assert_eq!(
                obj.is_compressed(),
                strategy.is_compressed(),
                "{name}: trait/enum compression flag must agree"
            );
        }
    }

    #[test]
    fn dense_plans_resolve_flavors_and_price() {
        let s = DenseStrategy { flavor: DenseFlavor::Ring };
        let p = s.plan(&ctx(1.0));
        assert_eq!(p.kind, CollectiveKind::RingAllreduce);
        assert!(p.predicted_s.unwrap() > 0.0);
        // Auto on a flat latency-bearing link still picks a dense kind.
        let s = DenseStrategy { flavor: DenseFlavor::TopoAuto };
        let p = s.plan(&ctx(1.0));
        assert!(dense_op(p.kind).is_some(), "{:?}", p.kind);
    }

    #[test]
    fn flexible_plan_matches_selector() {
        let s = FlexibleStrategy::new(SelectionPolicy::Star, 8, 0, ThreadPool::serial());
        for cr in [0.1, 0.001] {
            let c = ctx(cr);
            let p = s.plan(&c);
            let want = selector::choose(c.probed_topo.inter, c.model_bytes, c.n_workers, cr);
            assert_eq!(p.kind, want.kind);
            assert_eq!(p.predicted_s, Some(want.predicted_s));
        }
    }

    /// The control-plane hooks: AR-Topk strategies accept policy and
    /// flavour switches (returning the previous policy for the event
    /// stream), dense strategies accept fixed-flavour pins, and
    /// strategies without the concept decline.
    #[test]
    fn control_hooks_apply_where_meaningful() {
        let pool = ThreadPool::serial();
        let mut art = ArTopkStrategy::new(SelectionPolicy::Star, ArFlavor::Ring, pool);
        assert_eq!(art.set_selection_policy(SelectionPolicy::Var), Some(SelectionPolicy::Star));
        assert_eq!(art.set_selection_policy(SelectionPolicy::Star), Some(SelectionPolicy::Var));
        assert!(art.set_collective(CollectiveKind::ArTopkTree));
        assert_eq!(art.plan(&ctx(0.05)).kind, CollectiveKind::ArTopkTree);
        assert!(!art.set_collective(CollectiveKind::RingAllreduce), "not an AR kind");

        let mut dense = DenseStrategy { flavor: DenseFlavor::Ring };
        assert!(dense.set_collective(CollectiveKind::TreeAllreduce));
        assert_eq!(dense.plan(&ctx(1.0)).kind, CollectiveKind::TreeAllreduce);
        assert!(!dense.set_collective(CollectiveKind::ArTopkRing), "not a dense kind");
        assert!(dense.set_selection_policy(SelectionPolicy::Var).is_none());

        let mut ag = AgCompressStrategy::new(CompressorKind::TopK, 4, 0);
        assert!(ag.set_selection_policy(SelectionPolicy::Var).is_none());
        assert!(!ag.set_collective(CollectiveKind::TreeAllreduce));
    }

    /// The `artopk-auto` registry row instantiates the plain AR-Topk
    /// operator under its own display name — the trial/commit behavior is
    /// composed as a controller, not embedded here.
    #[test]
    fn artopk_auto_is_a_named_artopk() {
        let s = instantiate(
            Strategy::ArTopkAuto { flavor: ArFlavor::Ring },
            4,
            0,
            ThreadPool::serial(),
        );
        assert_eq!(s.name(), "AR-Topk-auto");
        assert!(s.is_compressed());
        assert_eq!(s.plan(&ctx(0.05)).kind, CollectiveKind::ArTopkRing);
    }

    #[test]
    fn custom_plan_is_unpriced() {
        let p = CommPlan::priced(CollectiveKind::Custom("my-op"), &ctx(0.5));
        assert!(p.predicted_s.is_none());
        assert_eq!(p.kind.name(), "my-op");
    }
}
