//! Figs 6/7/8 + §3-E2: MOO-adaptive training under the paper's C1/C2
//! network configurations.
//!
//! * prints the emulated schedule (Fig 6),
//! * trains with the full flexible stack + MOO controller,
//! * prints the KDE of CRs used over training (Fig 7),
//! * prints the density of collectives used (Fig 8),
//! * compares final accuracy against the best static-CR AR-Topk run and
//!   DenseSGD (§3-E2's claim: adaptive >= static, ~DenseSGD level).
//!
//!     cargo run --release --example fig7_8_moo_density -- [--steps 800]
//!         [--model ViT]

use anyhow::Result;
use flexcomm::artopk::{ArFlavor, SelectionPolicy};
use flexcomm::collectives::CollectiveKind;
use flexcomm::coordinator::controller::AdaptiveConfig;
use flexcomm::coordinator::session::TrainReport;
use flexcomm::coordinator::trainer::{CrControl, DenseFlavor, Strategy};
use flexcomm::experiments::{
    print_kde, proxy_cfg, run_proxy, write_csv, GPU_COMPRESS_SPEEDUP, PAPER_COMPUTE_MS,
    PAPER_MODELS,
};
use flexcomm::netsim::schedule::NetSchedule;
use flexcomm::util::cli::Args;
use flexcomm::util::table::Table;

const PROXY_PARAMS: f64 = 53_664.0;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 800)?;
    let model = args.str_or("model", "ViT");
    let (_, params) = PAPER_MODELS
        .iter()
        .find(|(m, _)| *m == model)
        .copied()
        .unwrap_or(("ViT", 86.6e6));
    let compute_ms = PAPER_COMPUTE_MS
        .iter()
        .find(|(m, _)| *m == model)
        .map(|(_, c)| *c)
        .unwrap_or(110.0);
    let msg_scale = params / PROXY_PARAMS;
    let spe = steps / 50; // 50 virtual epochs like the paper

    let mk = |strategy, cr: CrControl, schedule: NetSchedule, seed| {
        let mut cfg = proxy_cfg(strategy, cr, steps, seed);
        cfg.net = Box::new(schedule);
        cfg.steps_per_epoch = spe.max(1);
        cfg.msg_scale = msg_scale;
        cfg.comp_scale = msg_scale / GPU_COMPRESS_SPEEDUP;
        cfg.compute =
            flexcomm::coordinator::worker::ComputeModel::with_jitter(compute_ms * 1e-3, 0.05);
        run_proxy(cfg, seed)
    };

    let mut summary = Table::new(["config", "method", "best acc (%)", "mean t_step (ms)"]);
    let mut csv = String::from("config,step,cr,collective,alpha_ms,bw_gbps\n");

    for cname in ["c1", "c2"] {
        let schedule = NetSchedule::preset(cname, 50.0)?;
        println!("\n=== Configuration {} (Fig 6) ===", cname.to_uppercase());
        let mut t = Table::new(["from epoch", "alpha (ms)", "bw (Gbps)"]);
        for p in schedule.phases() {
            t.row([
                format!("{:.0}", p.from_epoch),
                format!("{:.0}", p.link.alpha_ms()),
                format!("{:.0}", p.link.bw_gbps()),
            ]);
        }
        t.print();

        // MOO-adaptive flexible run.
        let adaptive = mk(
            Strategy::Flexible { policy: SelectionPolicy::Star },
            CrControl::Adaptive(AdaptiveConfig { probe_iters: 5, ..Default::default() }),
            schedule.clone(),
            3,
        );
        // Static baselines.
        let static_01 = mk(
            Strategy::ArTopkFixed { policy: SelectionPolicy::Star, flavor: ArFlavor::Ring },
            CrControl::Static(0.01),
            schedule.clone(),
            3,
        );
        let dense = mk(
            Strategy::DenseSgd { flavor: DenseFlavor::Auto },
            CrControl::Static(1.0),
            schedule.clone(),
            3,
        );

        // Fig 7: KDE of log10(CR) used.
        let crs: Vec<f64> = adaptive.metrics.crs_used().iter().map(|c| c.log10()).collect();
        println!("\nFig 7 — density of log10(CR) used ({}):", cname.to_uppercase());
        print_kde(&format!("{} adaptive CRs", cname), &crs, -3.2, -0.8);

        // Fig 8: collective densities.
        println!("\nFig 8 — collective usage ({}):", cname.to_uppercase());
        let used = adaptive.metrics.collectives_used();
        let mut tab = Table::new(["collective", "steps", "share"]);
        for kind in [
            CollectiveKind::AllgatherTopk,
            CollectiveKind::ArTopkRing,
            CollectiveKind::ArTopkTree,
        ] {
            let c = used.iter().filter(|&&k| k == kind).count();
            tab.row([
                kind.name().to_string(),
                c.to_string(),
                format!("{:.1}%", 100.0 * c as f64 / used.len() as f64),
            ]);
        }
        tab.print();

        for m in &adaptive.metrics.steps {
            csv.push_str(&format!(
                "{cname},{},{:.5},{},{:.2},{:.2}\n",
                m.step,
                m.cr,
                m.collective.name(),
                m.alpha_ms,
                m.bw_gbps
            ));
        }

        let acc = |r: &TrainReport| r.best_accuracy().unwrap_or(f64::NAN) * 100.0;
        let ms = |r: &TrainReport| r.summary().mean_step_s * 1e3;
        summary.row([
            cname.to_uppercase(),
            "MOO-adaptive".into(),
            format!("{:.2}", acc(&adaptive)),
            format!("{:.2}", ms(&adaptive)),
        ]);
        summary.row([
            cname.to_uppercase(),
            "STAR-Topk 0.01".into(),
            format!("{:.2}", acc(&static_01)),
            format!("{:.2}", ms(&static_01)),
        ]);
        summary.row([
            cname.to_uppercase(),
            "DenseSGD".into(),
            format!("{:.2}", acc(&dense)),
            format!("{:.2}", ms(&dense)),
        ]);
    }

    println!("\n== §3-E2 — MOO-adaptive vs static ({model} proxy) ==");
    summary.print();
    let p = write_csv("results/fig7_8_moo.csv", &csv)?;
    println!("\nper-step CR/collective trace -> {p}");
    Ok(())
}
