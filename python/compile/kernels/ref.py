"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest sweeps (see
``python/tests/test_kernels.py``) assert the Pallas implementations match
these references with ``assert_allclose`` across shapes and dtypes drawn by
hypothesis.  Keep them boring and obviously-correct.
"""

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul: (m, k) @ (k, n) -> (m, n) in f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def count_above_ref(g: jnp.ndarray, tau) -> jnp.ndarray:
    """Number of elements with |g| > tau (scalar f32 count)."""
    return jnp.sum((jnp.abs(g) > tau).astype(jnp.float32))


def threshold_topk_ref(g: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact magnitude threshold that keeps the top-k entries of |g|.

    Returns the k-th largest magnitude; masking with ``|g| >= tau`` keeps at
    least k entries (more under ties).
    """
    mags = jnp.sort(jnp.abs(g.reshape(-1)))[::-1]
    return mags[k - 1]


def mask_ref(g: jnp.ndarray, tau) -> jnp.ndarray:
    """Zero every entry with |g| < tau (keep >= tau)."""
    return jnp.where(jnp.abs(g) >= tau, g, jnp.zeros_like(g))


def ef_compress_ref(g, residual, tau):
    """Fused error-feedback compression step (Eqn 2 of the paper).

    g_e  = g + residual            (error-fed gradient)
    g_c  = g_e  masked at |.| >= tau
    res' = g_e - g_c
    Also returns the compression-gain terms ||g_c||^2 and ||g_e||^2
    (GraVAC gain = E||g_c||^2 / E||g_e||^2).
    """
    g_e = g + residual
    g_c = jnp.where(jnp.abs(g_e) >= tau, g_e, jnp.zeros_like(g_e))
    res = g_e - g_c
    norm_c = jnp.sum(g_c * g_c)
    norm_e = jnp.sum(g_e * g_e)
    return g_c, res, norm_c, norm_e


def sq_norm_ref(g: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(g * g)
