"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes/block sizes; every property asserts
``assert_allclose`` against the reference.  This is the CORE correctness
signal for the compute layer — the same kernels lower into the HLO the rust
runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ef_compress as efc
from compile.kernels import matmul as mm
from compile.kernels import ref
from compile.kernels import topk_threshold as tkt

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape, dtype=np.float32, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, (m, k)), _arr(rng, (k, n))
    got = mm.matmul_fwd_only(jnp.array(x), jnp.array(w), bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    m=st.integers(2, 48),
    k=st.integers(2, 48),
    n=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_vjp_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = jnp.array(_arr(rng, (m, k))), jnp.array(_arr(rng, (k, n)))
    gx, gw = jax.grad(lambda a, b: jnp.sum(mm.matmul(a, b) ** 2), (0, 1))(x, w)
    rx, rw = jax.grad(lambda a, b: jnp.sum(ref.matmul_ref(a, b) ** 2), (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-3, atol=1e-3)


def test_matmul_bf16_inputs_accumulate_f32():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((33, 47)).astype(jnp.bfloat16)
    w = rng.standard_normal((47, 29)).astype(jnp.bfloat16)
    got = mm.matmul_fwd_only(jnp.array(x), jnp.array(w), bm=16, bn=16, bk=16)
    assert got.dtype == jnp.float32
    want = ref.matmul_ref(
        jnp.array(x, jnp.float32), jnp.array(w, jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-2, atol=3e-2)


def test_mxu_utilization_estimate_bounds():
    assert mm.mxu_utilization_estimate(128, 128, 128) == 1.0
    u = mm.mxu_utilization_estimate(129, 128, 128)
    assert 0.0 < u < 1.0
    assert mm.vmem_bytes() == (128 * 128 * 3) * 4


# ---------------------------------------------------------------------------
# count / absmax / threshold / mask
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(
    n=st.integers(1, 20000),
    tau=st.floats(0.0, 3.0),
    block=st.sampled_from([256, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_count_above_matches_ref(n, tau, block, seed):
    rng = np.random.default_rng(seed)
    g = _arr(rng, (n,))
    got = tkt.count_above(jnp.array(g), tau, block=block)
    np.testing.assert_allclose(float(got), float(ref.count_above_ref(g, tau)))


@settings(**SETTINGS)
@given(n=st.integers(1, 20000), seed=st.integers(0, 2**31 - 1))
def test_abs_max_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    g = _arr(rng, (n,))
    got = tkt.abs_max(jnp.array(g), block=1024)
    np.testing.assert_allclose(float(got), float(np.max(np.abs(g))), rtol=1e-6)


@settings(**SETTINGS)
@given(
    n=st.integers(64, 20000),
    frac=st.floats(0.005, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_mstopk_keeps_about_k(n, frac, seed):
    rng = np.random.default_rng(seed)
    g = _arr(rng, (n,))
    k = max(1, int(n * frac))
    masked, tau = tkt.mstopk(jnp.array(g), float(k), rounds=25, block=1024)
    kept = int(np.sum(np.asarray(masked) != 0.0))
    # Continuous values: 25 bisection rounds pin the count to within ~2%+1.
    assert abs(kept - k) <= max(2, int(0.02 * k) + 1), (kept, k)
    # Every kept entry must dominate every dropped entry in magnitude.
    mags = np.abs(g)
    kept_mask = np.asarray(masked) != 0.0
    if kept and kept < n:
        assert mags[kept_mask].min() >= mags[~kept_mask].max() - 1e-6


@settings(**SETTINGS)
@given(
    n=st.integers(1, 8192),
    tau=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_mask_matches_ref(n, tau, seed):
    rng = np.random.default_rng(seed)
    g = _arr(rng, (n,))
    got = tkt.mask(jnp.array(g), tau, block=1024)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.mask_ref(g, tau)))


def test_mask_preserves_2d_shape():
    rng = np.random.default_rng(1)
    g = _arr(rng, (37, 53))
    got = tkt.mask(jnp.array(g), 0.7, block=256)
    assert got.shape == g.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.mask_ref(g, 0.7)))


# ---------------------------------------------------------------------------
# fused EF-compress
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(
    n=st.integers(1, 20000),
    tau=st.floats(0.0, 2.0),
    rscale=st.floats(0.0, 1.0),
    block=st.sampled_from([256, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ef_compress_matches_ref(n, tau, rscale, block, seed):
    rng = np.random.default_rng(seed)
    g, r = _arr(rng, (n,)), _arr(rng, (n,), scale=rscale)
    gc, res, nc, ne = efc.ef_compress(jnp.array(g), jnp.array(r), tau, block=block)
    rgc, rres, rnc, rne = ref.ef_compress_ref(g, r, tau)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(rgc), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(res), np.asarray(rres), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(nc), float(rnc), rtol=1e-4)
    np.testing.assert_allclose(float(ne), float(rne), rtol=1e-4)


@settings(**SETTINGS)
@given(n=st.integers(2, 8192), seed=st.integers(0, 2**31 - 1))
def test_ef_compress_invariants(n, seed):
    """Structural invariants: g_c + res == g_e, supports disjoint, gain <= 1."""
    rng = np.random.default_rng(seed)
    g, r = _arr(rng, (n,)), _arr(rng, (n,), scale=0.3)
    tau = float(np.median(np.abs(g + r)))
    gc, res, nc, ne = efc.ef_compress(jnp.array(g), jnp.array(r), tau, block=1024)
    gc, res = np.asarray(gc), np.asarray(res)
    np.testing.assert_allclose(gc + res, g + r, rtol=1e-6, atol=1e-7)
    assert np.all((gc == 0.0) | (res == 0.0))
    assert float(nc) <= float(ne) * (1 + 1e-5)


def test_ef_compress_tau_zero_is_identity():
    rng = np.random.default_rng(3)
    g, r = _arr(rng, (1000,)), _arr(rng, (1000,))
    gc, res, nc, ne = efc.ef_compress(jnp.array(g), jnp.array(r), 0.0, block=256)
    np.testing.assert_allclose(np.asarray(gc), g + r, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res), np.zeros_like(g), atol=1e-7)
    np.testing.assert_allclose(float(nc), float(ne), rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: estimate tau then fused compress == exact top-k semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cr", [0.1, 0.01, 0.004])
def test_threshold_plus_ef_matches_exact_topk(cr):
    rng = np.random.default_rng(7)
    n = 50000
    g = rng.standard_normal(n).astype(np.float32)
    r = np.zeros(n, np.float32)
    k = int(n * cr)
    tau = tkt.estimate_threshold(jnp.array(g), float(k), rounds=25, block=4096)
    gc, _, nc, ne = efc.ef_compress(jnp.array(g), jnp.array(r), tau, block=4096)
    kept = int(np.sum(np.asarray(gc) != 0))
    assert abs(kept - k) <= max(2, int(0.02 * k) + 1)
    gain = float(nc) / float(ne)
    exact_tau = float(ref.threshold_topk_ref(jnp.array(g), k))
    exact_gain = float(np.sum(g[np.abs(g) >= exact_tau] ** 2) / np.sum(g**2))
    np.testing.assert_allclose(gain, exact_gain, rtol=0.05)
