//! Fig 5: scale-out communication cost of AG vs AR-Topk at CR 0.1 as N
//! grows 2..8(..32), on a 5ms / 1Gbps link (ResNet50-sized tensor).
//! Both the closed form and the real collective implementations.
//!
//! Second stage (ISSUE 7): the FLEET sweep — pure cost-model scale-out
//! to 16384 workers under the `c1`, `c2` and `hetero` registry
//! scenarios, locating the AG-vs-ART-Ring crossover N per scenario and
//! emitting `BENCH_scaleout.json` for the verify gate. Heterogeneous
//! fleets are priced through the ISSUE 7 conservative path (the
//! componentwise-slowest worker link), exactly what
//! `Collective::predict_hetero` does for the compressed ops.
//!
//!     cargo bench --bench fig5_scaleout
//!     FLEXCOMM_BENCH_FAST=1 cargo bench --bench fig5_scaleout   (CI smoke)

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::{allgather_sparse, cheapest_hetero};
use flexcomm::compress::{Compressor, EfState, TopK};
use flexcomm::netsim::cost_model::{self, LinkParams};
use flexcomm::netsim::model::build_scenario;
use flexcomm::tensor::Layout;
use flexcomm::util::rng::Rng;
use flexcomm::util::stats::sparkline;
use flexcomm::util::table::Table;

/// Minimal JSON string escape (mirrors util::bench's writer; keys here are
/// ASCII scenario/op names so only quotes/backslashes matter).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct SweepPoint {
    n: usize,
    ag_s: f64,
    art_ring_s: f64,
    dense_op: &'static str,
    dense_s: f64,
}

struct ScenarioSweep {
    name: &'static str,
    crossover_n: Option<usize>,
    points: Vec<SweepPoint>,
}

/// Cost-only fleet scale-out for one registry scenario: per-worker links at
/// epoch 0, AG vs ART-Ring priced on the slowest participant (the
/// conservative hetero path), plus the cheapest DENSE collective for
/// reference. O(n) per point, no per-worker dense state.
fn fleet_sweep(name: &'static str, m: f64, cr: f64, max_n: usize) -> ScenarioSweep {
    let net = build_scenario(name, 2.0).expect("registry scenario");
    let topo = net.topology_at(0.0);
    let mut points = Vec::new();
    let mut crossover_n = None;
    let mut n = 2usize;
    while n <= max_n {
        let links: Vec<LinkParams> = (0..n).map(|w| net.worker_link_at(w, 0.0)).collect();
        let slow = cost_model::slowest_link(&links);
        let ag_s = cost_model::ag_topk(slow, m, n, cr);
        let art_ring_s = cost_model::art_ring(slow, m, n, cr);
        let (op, dense_s) = cheapest_hetero(topo, &links, m, cr);
        if crossover_n.is_none() && art_ring_s < ag_s {
            crossover_n = Some(n);
        }
        points.push(SweepPoint { n, ag_s, art_ring_s, dense_op: op.name(), dense_s });
        n *= 2;
    }
    ScenarioSweep { name, crossover_n, points }
}

fn write_scaleout_json(
    path: &std::path::Path,
    m: f64,
    cr: f64,
    sweeps: &[ScenarioSweep],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"bench\": \"scaleout\",\n \"model_bytes\": {m:.1}, \"cr\": {cr},\n \"scenarios\": ["
    ));
    for (i, s) in sweeps.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let cross = match s.crossover_n {
            Some(n) => n.to_string(),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "\n  {{\"name\": {}, \"crossover_n\": {cross}, \"sweep\": [",
            json_str(s.name)
        ));
        for (j, p) in s.points.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n   {{\"n\": {}, \"ag_s\": {:.6}, \"art_ring_s\": {:.6}, \
                 \"dense_op\": {}, \"dense_s\": {:.6}}}",
                p.n,
                p.ag_s,
                p.art_ring_s,
                json_str(p.dense_op),
                p.dense_s
            ));
        }
        out.push_str("]}");
    }
    out.push_str("\n]}\n");
    std::fs::write(path, out)
}

fn main() {
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    let params = 25.6e6; // ResNet50
    let cr = 0.1;
    let l = LinkParams::from_ms_gbps(5.0, 1.0);
    let m = 4.0 * params;
    let sim_dim = if fast { 20_000 } else { 100_000 };
    let scale = params / sim_dim as f64;
    let ls = LinkParams { alpha: l.alpha, beta: l.beta * scale };

    println!("Fig 5 — scale-out at CR 0.1, 5ms/1Gbps, ResNet50 tensor\n");
    let mut t = Table::new(["N", "AG model (ms)", "AG sim (ms)", "ART-Ring model (ms)", "ART-Ring sim (ms)"]);
    let mut ag_series = Vec::new();
    let mut art_series = Vec::new();
    let ns: &[usize] =
        if fast { &[2, 4, 8, 16] } else { &[2, 3, 4, 5, 6, 7, 8, 16, 32] };
    for &n in ns {
        let mut rng = Rng::new(n as u64);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0; sim_dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        // Real ops.
        let layout = Layout::single(sim_dim);
        let mut tk = TopK::with_quickselect();
        let parts: Vec<_> = grads.iter().map(|g| tk.compress(g, cr, &layout)).collect();
        let (_, rep_ag) = allgather_sparse(&parts, sim_dim, ls);
        let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(sim_dim)).collect();
        let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
        let rep_art = art.exchange(&grads, &mut ef, cr, 0, ls).comm;

        let ag_model = cost_model::ag_topk(l, m, n, cr) * 1e3;
        let art_model = cost_model::art_ring(l, m, n, cr) * 1e3;
        ag_series.push(ag_model);
        art_series.push(art_model);
        t.row([
            n.to_string(),
            format!("{ag_model:.0}"),
            format!("{:.0}", rep_ag.seconds * 1e3),
            format!("{art_model:.0}"),
            format!("{:.0}", rep_art.seconds * 1e3),
        ]);
    }
    t.print();
    println!("\nAG       {}", sparkline(&ag_series));
    println!("ART-Ring {}", sparkline(&art_series));
    println!(
        "\nShape check (paper Fig 5): AG cost climbs steeply with N \
         (bandwidth O(MN)); ART-Ring inclines gently (ring β-term ~ \
         independent of N, broadcast grows as log N)."
    );

    // ---- Fleet scale-out (ISSUE 7): cost-only sweep to 16384 workers ----
    let max_n = 16_384;
    let sweeps: Vec<ScenarioSweep> = ["c1", "c2", "hetero"]
        .into_iter()
        .map(|s| fleet_sweep(s, m, cr, max_n))
        .collect();

    println!("\nFleet scale-out — AG vs ART-Ring crossover per scenario (n ≤ {max_n})\n");
    let mut ft = Table::new(["scenario", "crossover N", "AG @16384 (s)", "ART-Ring @16384 (s)", "best dense @16384"]);
    for s in &sweeps {
        let last = s.points.last().expect("non-empty sweep");
        ft.row([
            s.name.to_string(),
            s.crossover_n.map_or_else(|| format!("> {max_n}"), |n| n.to_string()),
            format!("{:.2}", last.ag_s),
            format!("{:.2}", last.art_ring_s),
            format!("{} ({:.2}s)", last.dense_op, last.dense_s),
        ]);
    }
    ft.print();

    let json_path = std::path::Path::new("BENCH_scaleout.json");
    write_scaleout_json(json_path, m, cr, &sweeps).expect("write BENCH_scaleout.json");
    println!("\nwrote {} ({} scenarios)", json_path.display(), sweeps.len());
}
