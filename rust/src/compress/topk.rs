//! Exact fused Top-k — the compressor inside AR-Topk (§3-A).
//!
//! The paper sorts with a max-heap over the fused (all-layer) gradient:
//! heapify is O(G), extracting k maxima O(k·log G).  That heap path is
//! implemented here verbatim; [`topk_indices_select`] is the
//! quickselect alternative (O(G) expected) used by the perf pass — both
//! return identical sets (property-tested) so the trainer can switch via
//! [`TopK::with_quickselect`].

use crate::compress::{k_for, Compressor, SparseGrad};
use crate::tensor::{kernels, Layout};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Total order on (|value|, index) pairs: DESCENDING magnitude with NaN as
/// the smallest magnitude (the crate-wide policy,
/// [`crate::tensor::nan_min_cmp_f32`], flipped for descending order), ties
/// broken by ASCENDING index.
///
/// Treating NaN as unordered-`Equal` (the old `unwrap_or(Equal)`) is NOT a
/// total order: `select_nth_unstable_by` may panic ("comparison function
/// does not correctly implement a total order") and `BinaryHeap` silently
/// misorders once a single gradient entry goes NaN (exploding loss). With
/// NaN-smallest, a NaN entry never displaces a finite one from the top-k
/// and selection stays deterministic, so a NaN step trains through and
/// surfaces as a NaN loss instead of a panic.
pub(crate) fn mag_desc_idx_asc(a: &(f32, u32), b: &(f32, u32)) -> Ordering {
    crate::tensor::nan_min_cmp_f32(b.0, a.0).then_with(|| a.1.cmp(&b.1))
}

/// (|value|, index) heap entry; Ord follows [`mag_desc_idx_asc`] so the
/// max-heap pops largest magnitude first, ties by lower index, NaN last.
struct Entry(f32, u32);

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // "Greater" = pops first: reverse the descending sort order.
        mag_desc_idx_asc(&(self.0, self.1), &(other.0, other.1)).reverse()
    }
}

/// Max-heap top-k (paper's method): O(G) heapify + O(k log G) pops.
pub fn topk_indices(g: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(g.len());
    let heap: BinaryHeap<Entry> = g
        .iter()
        .enumerate()
        .map(|(i, &v)| Entry(v.abs(), i as u32))
        .collect();
    let mut heap = heap;
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        out.push(heap.pop().expect("k <= len").1);
    }
    out.sort_unstable();
    out
}

/// [`topk_indices`] over a PRECOMPUTED magnitude buffer (the fused
/// error-feed, `kernels::error_feed_abs_into`, already paid the `abs`
/// pass). Selection is identical: `mags[i]` must equal `|g[i]|`.
pub fn topk_indices_mags(mags: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(mags.len());
    let mut heap: BinaryHeap<Entry> =
        mags.iter().enumerate().map(|(i, &m)| Entry(m, i as u32)).collect();
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        out.push(heap.pop().expect("k <= len").1);
    }
    out.sort_unstable();
    out
}

/// Quickselect top-k: O(G) expected. Same selection as [`topk_indices`]
/// (ties broken by lower index).
pub fn topk_indices_select(g: &[f32], k: usize) -> Vec<u32> {
    let mut scratch = SelectScratch::default();
    let mut out = Vec::new();
    quickselect_into(g, k, &mut scratch, &mut out);
    out
}

/// Which exact top-k algorithm a selection call site runs. All three
/// produce the IDENTICAL index set (and therefore identical values) under
/// `mag_desc_idx_asc` — property-tested here and in
/// [`crate::compress::sampledk`] — so backend choice only moves `t_comp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectBackend {
    /// Paper-verbatim max-heap: O(G) heapify + O(k log G) pops.
    Heap,
    /// `select_nth_unstable`-based quickselect: expected O(G).
    Quickselect,
    /// Sampled-threshold filter + exact-k repair
    /// ([`crate::compress::sampledk::sampled_topk_into`]): expected O(G)
    /// with a much smaller constant (one filtering pass over G, selection
    /// only over a sample plus ~k survivors).
    Sampled,
}

/// Reusable selection workspace (per worker lane, never shared across
/// threads): quickselect's (|value|, index) pair buffer and the sampled
/// backend's sample buffer. Holding one of these across steps removes the
/// two O(G) allocations per selection.
#[derive(Debug, Clone, Default)]
pub struct SelectScratch {
    pub(crate) pairs: Vec<(f32, u32)>,
    pub(crate) sample: Vec<(f32, u32)>,
}

/// Run `backend`'s selection of the top `k` of `g` into the caller-owned
/// `out` (cleared first; ascending index order — the wire format). All
/// backends are bitwise-equivalent; `scratch` is only an arena.
pub fn select_into(
    backend: SelectBackend,
    g: &[f32],
    k: usize,
    scratch: &mut SelectScratch,
    out: &mut Vec<u32>,
) {
    match backend {
        SelectBackend::Heap => {
            out.clear();
            out.extend(topk_indices(g, k));
        }
        SelectBackend::Quickselect => {
            quickselect_into(g, k, scratch, out);
        }
        SelectBackend::Sampled => {
            crate::compress::sampledk::sampled_topk_into(g, k, scratch, out);
        }
    }
}

/// [`select_into`] over a PRECOMPUTED magnitude buffer (`mags[i]` must
/// equal `|g[i]|`): same backends, same selection, no `abs` pass.
pub fn select_mags_into(
    backend: SelectBackend,
    mags: &[f32],
    k: usize,
    scratch: &mut SelectScratch,
    out: &mut Vec<u32>,
) {
    match backend {
        SelectBackend::Heap => {
            out.clear();
            out.extend(topk_indices_mags(mags, k));
        }
        SelectBackend::Quickselect => {
            quickselect_mags_into(mags, k, scratch, out);
        }
        SelectBackend::Sampled => {
            crate::compress::sampledk::sampled_topk_mags_into(mags, k, scratch, out);
        }
    }
}

/// Shared quickselect core over prepared (magnitude, index) pairs.
/// Order DESC by magnitude (NaN smallest), ties ASC by index; take the
/// first k. The comparator is a total order, which
/// `select_nth_unstable_by` requires even on NaN-poisoned gradients.
/// Callers guarantee `0 < k < pairs.len()`.
fn quickselect_pairs(pairs: &mut [(f32, u32)], k: usize, out: &mut Vec<u32>) {
    pairs.select_nth_unstable_by(k - 1, mag_desc_idx_asc);
    out.extend(pairs[..k].iter().map(|&(_, i)| i));
    out.sort_unstable();
}

/// Arena-reusing [`topk_indices_select`]: identical output, allocations
/// amortised into `scratch`/`out`.
fn quickselect_into(g: &[f32], k: usize, scratch: &mut SelectScratch, out: &mut Vec<u32>) {
    let k = k.min(g.len());
    out.clear();
    if k == 0 {
        return;
    }
    if k == g.len() {
        out.extend(0..g.len() as u32);
        return;
    }
    kernels::abs_pairs_into(g, &mut scratch.pairs);
    quickselect_pairs(&mut scratch.pairs, k, out);
}

/// [`quickselect_into`] over precomputed magnitudes.
fn quickselect_mags_into(mags: &[f32], k: usize, scratch: &mut SelectScratch, out: &mut Vec<u32>) {
    let k = k.min(mags.len());
    out.clear();
    if k == 0 {
        return;
    }
    if k == mags.len() {
        out.extend(0..mags.len() as u32);
        return;
    }
    kernels::pairs_into(mags, &mut scratch.pairs);
    quickselect_pairs(&mut scratch.pairs, k, out);
}

/// Fused-tensor exact Top-k compressor over a pluggable [`SelectBackend`].
#[derive(Debug, Clone)]
pub struct TopK {
    backend: SelectBackend,
    scratch: SelectScratch,
}

impl TopK {
    pub fn new() -> Self {
        TopK::with_backend(SelectBackend::Heap)
    }

    /// Perf-pass variant: expected-O(G) selection instead of the heap.
    pub fn with_quickselect() -> Self {
        TopK::with_backend(SelectBackend::Quickselect)
    }

    pub fn with_backend(backend: SelectBackend) -> Self {
        TopK { backend, scratch: SelectScratch::default() }
    }

    pub fn backend(&self) -> SelectBackend {
        self.backend
    }

    /// Top-`k` indices of `g`, ascending. `&mut` because the selection
    /// scratch arena is reused across calls (output is call-independent).
    pub fn select(&mut self, g: &[f32], k: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.select_into(g, k, &mut out);
        out
    }

    /// [`TopK::select`] into a caller-owned index buffer.
    pub fn select_into(&mut self, g: &[f32], k: usize, out: &mut Vec<u32>) {
        select_into(self.backend, g, k, &mut self.scratch, out);
    }
}

impl Default for TopK {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn compress(&mut self, g: &[f32], cr: f64, layout: &Layout) -> SparseGrad {
        let mut out = SparseGrad::default();
        self.compress_into(g, cr, layout, &mut out);
        out
    }

    fn compress_into(&mut self, g: &[f32], cr: f64, _layout: &Layout, out: &mut SparseGrad) {
        let k = k_for(cr, g.len());
        // Take the index buffer out of `out` so `self` and `out` don't
        // overlap borrows; hand it back below.
        let mut indices = std::mem::take(&mut out.indices);
        self.select_into(g, k, &mut indices);
        out.values.clear();
        out.values.extend(indices.iter().map(|&i| g[i as usize]));
        out.indices = indices;
        out.dense_len = g.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn picks_largest_magnitudes() {
        let g = [0.1, -5.0, 2.0, 0.0, 3.0, -0.2];
        assert_eq!(topk_indices(&g, 3), vec![1, 2, 4]);
        assert_eq!(topk_indices_select(&g, 3), vec![1, 2, 4]);
    }

    #[test]
    fn ties_break_by_lower_index() {
        let g = [1.0, -1.0, 1.0, 1.0];
        assert_eq!(topk_indices(&g, 2), vec![0, 1]);
        assert_eq!(topk_indices_select(&g, 2), vec![0, 1]);
    }

    #[test]
    fn k_edge_cases() {
        let g = [1.0, 2.0];
        assert_eq!(topk_indices(&g, 0), Vec::<u32>::new());
        assert_eq!(topk_indices(&g, 2), vec![0, 1]);
        assert_eq!(topk_indices(&g, 99), vec![0, 1]);
        assert_eq!(topk_indices_select(&g, 99), vec![0, 1]);
    }

    #[test]
    fn heap_and_quickselect_agree() {
        check("heap == quickselect", 150, |g| {
            let n = g.usize_in(1, 500);
            let v = g.vec_normal(n, 1.0);
            let k = g.usize_in(0, n);
            ensure(
                topk_indices(&v, k) == topk_indices_select(&v, k),
                format!("mismatch n={n} k={k}"),
            )
        });
    }

    #[test]
    fn selected_dominate_dropped() {
        check("topk dominance", 100, |g| {
            let n = g.usize_in(2, 300);
            let v = g.vec_normal(n, 1.0);
            let k = g.usize_in(1, n - 1);
            let idx = topk_indices(&v, k);
            let min_kept = idx.iter().map(|&i| v[i as usize].abs()).fold(f32::MAX, f32::min);
            let chosen: std::collections::HashSet<u32> = idx.into_iter().collect();
            for (i, &x) in v.iter().enumerate() {
                if !chosen.contains(&(i as u32)) {
                    ensure(x.abs() <= min_kept + 1e-7, format!("dropped {i} bigger"))?;
                }
            }
            Ok(())
        });
    }

    /// NaN-poisoned gradients (exploding loss) must not panic either
    /// selector, must never beat finite entries into the top-k, and both
    /// selectors must stay in agreement.
    #[test]
    fn nan_entries_sort_last_and_never_panic() {
        let g = [1.0f32, f32::NAN, 3.0, 2.0, f32::NAN, 0.5];
        assert_eq!(topk_indices(&g, 3), vec![0, 2, 3]);
        assert_eq!(topk_indices_select(&g, 3), vec![0, 2, 3]);
        // k spanning into the NaN tail: NaNs fill by ascending index.
        assert_eq!(topk_indices(&g, 5), vec![0, 1, 2, 3, 5]);
        assert_eq!(topk_indices_select(&g, 5), vec![0, 1, 2, 3, 5]);
        // Fully-NaN gradient: deterministic, index-ordered, no panic.
        let all_nan = [f32::NAN; 4];
        assert_eq!(topk_indices(&all_nan, 2), vec![0, 1]);
        assert_eq!(topk_indices_select(&all_nan, 2), vec![0, 1]);
    }

    #[test]
    fn heap_and_quickselect_agree_with_nans() {
        check("heap == quickselect with NaNs", 80, |g| {
            let n = g.usize_in(1, 200);
            let mut v = g.vec_normal(n, 1.0);
            for _ in 0..g.usize_in(0, n / 4 + 1) {
                let at = g.usize_in(0, n - 1);
                v[at] = f32::NAN;
            }
            let k = g.usize_in(0, n);
            ensure(
                topk_indices(&v, k) == topk_indices_select(&v, k),
                format!("mismatch n={n} k={k}"),
            )
        });
    }

    /// The precomputed-magnitude path must make the SAME selection as the
    /// g-path for every backend — NaN/ties included — since AR-Topk's
    /// fused error-feed hands `select_mags_into` the `|g_e|` buffer.
    #[test]
    fn mags_path_selects_identically_for_all_backends() {
        check("select_mags == select", 100, |g| {
            let n = g.usize_in(1, 400);
            let mut v = g.vec_normal(n, 1.0);
            for _ in 0..g.usize_in(0, n / 5 + 1) {
                v[g.usize_in(0, n - 1)] = f32::NAN;
            }
            let mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
            let k = g.usize_in(0, n);
            for backend in
                [SelectBackend::Heap, SelectBackend::Quickselect, SelectBackend::Sampled]
            {
                let mut scratch = SelectScratch::default();
                let (mut a, mut b) = (Vec::new(), Vec::new());
                select_into(backend, &v, k, &mut scratch, &mut a);
                select_mags_into(backend, &mags, k, &mut scratch, &mut b);
                ensure(a == b, format!("{backend:?} n={n} k={k}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn compressor_interface() {
        let mut c = TopK::new();
        let layout = Layout::single(10);
        let g: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let s = c.compress(&g, 0.3, &layout);
        assert_eq!(s.k(), 3);
        assert_eq!(s.indices, vec![7, 8, 9]);
        assert_eq!(s.values, vec![7.0, 8.0, 9.0]);
        assert_eq!(c.name(), "topk");
    }
}
