//! First-party persistent parked-worker pool (offline build: no `rayon`)
//! — the execution engine behind the trainer's per-worker parallelism
//! (DESIGN.md §7).
//!
//! Workers are spawned ONCE when the pool is created (one pool per
//! `Session`, its handle cloned into the trainer and every operator) and
//! parked on a condvar between parallel regions. A region publishes one
//! type-erased job, wakes the workers, and blocks until every chunk
//! reports done — so borrowed data (parameters, gradients, error-feedback
//! state) still crosses into the workers without `Arc`/cloning, exactly as
//! with the old `std::thread::scope` pool, but without paying a thread
//! spawn/join per region. At small-tensor scale that spawn cost dominated
//! the work itself (the §7 trade-off this design removes); the
//! `hotpath` bench's spawn-vs-park stage measures the difference.
//!
//! Determinism contract (unchanged from the scoped pool): results are
//! returned **by item index**, work is split into the same contiguous
//! index chunks (`chunk = ceil(n / min(threads, n))`), and items never
//! share mutable state (no atomics on floats, no reduction across
//! threads), so the output of [`ThreadPool::map`]/[`ThreadPool::map_mut`]
//! is bitwise identical for every thread count — parked-worker reuse only
//! changes wall-clock time. The trainer's parallel-vs-sequential property
//! tests (`rust/tests/determinism.rs`) pin this end to end, including the
//! pool-lifecycle test (two sequential `Session::run()`s replay
//! identically — worker reuse is invisible).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

thread_local! {
    /// Set while a thread is one of OUR parked workers: a nested
    /// `map`/`map_mut` from inside a region runs inline instead of
    /// re-entering the (non-reentrant) region protocol. Results are
    /// identical by the determinism contract; only scheduling changes.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// The published job of one parallel region: a borrowed closure with its
/// lifetime erased. Sound because [`Inner::run_region`] blocks until every
/// participating worker has finished with it and clears it before
/// returning, so the borrow outlives all uses.
type RawJob = &'static (dyn Fn(usize) + Sync);

/// Region/coordination state shared between the caller and the parked
/// workers. All transitions happen under the one mutex; `work_cv` wakes
/// parked workers on a new epoch, `done_cv` wakes the caller when the last
/// chunk finishes.
struct RegionState {
    /// Bumped once per region; workers park while `epoch == last_seen`.
    epoch: u64,
    job: Option<RawJob>,
    /// Worker slots participating in the current region (slot i runs chunk
    /// i); workers with index >= slots skip the epoch and re-park.
    slots: usize,
    /// Participating slots that have not yet finished.
    remaining: usize,
    /// First panic payload out of the region's closures (re-raised on the
    /// caller after the region completes, matching `std::thread::scope`).
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<RegionState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Serializes whole regions: `map`/`map_mut` take `&self`, so two
    /// threads sharing one handle must not interleave region setup.
    region_lock: Mutex<()>,
}

/// The spawned-worker half of a pool; dropped (= shut down and joined)
/// when the last [`ThreadPool`] handle goes away.
struct Inner {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Inner {
    fn spawn(threads: usize) -> Inner {
        let shared = Arc::new(Shared {
            state: Mutex::new(RegionState {
                epoch: 0,
                job: None,
                slots: 0,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            region_lock: Mutex::new(()),
        });
        let handles = (0..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("flexcomm-pool-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn pool worker")
            })
            .collect();
        Inner { shared, handles }
    }

    /// Publish `f` as the region job, wake the workers, block until all
    /// `slots` chunks are done, then clear the job and re-raise any worker
    /// panic. The blocking wait is what makes the lifetime erasure in
    /// [`RawJob`] sound.
    fn run_region(&self, slots: usize, f: &(dyn Fn(usize) + Sync)) {
        // SAFETY: the job reference is only reachable through `state.job`,
        // which is cleared below before this frame (and therefore the
        // borrow) ends; workers touch it only between epoch publish and
        // their `remaining` decrement, both inside this call's lifetime.
        let job: RawJob = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), RawJob>(f)
        };
        let region = self.shared.region_lock.lock().unwrap();
        let mut st = self.shared.state.lock().unwrap();
        debug_assert!(st.job.is_none() && st.remaining == 0);
        st.job = Some(job);
        st.slots = slots;
        st.remaining = slots;
        st.epoch = st.epoch.wrapping_add(1);
        self.shared.work_cv.notify_all();
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let panic = st.panic.take();
        // Release BOTH guards before re-raising: unwinding through a held
        // guard would poison the mutex and wedge every later region — the
        // pool must stay usable after a caught panicking region.
        drop(st);
        drop(region);
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, index: usize) {
    IN_POOL_WORKER.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
            seen = st.epoch;
            if index < st.slots {
                st.job
            } else {
                None
            }
        };
        if let Some(f) = job {
            // Catch panics so the worker survives (the pool stays usable)
            // and the payload reaches the caller, like scope() re-raising.
            let result = catch_unwind(AssertUnwindSafe(|| f(index)));
            let mut st = shared.state.lock().unwrap();
            if let Err(p) = result {
                if st.panic.is_none() {
                    st.panic = Some(p);
                }
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                shared.done_cv.notify_all();
            }
        }
    }
}

/// Raw-pointer courier for handing a region's output (and `map_mut`'s
/// items) to the workers. Each worker slot touches a disjoint contiguous
/// index range, so the aliasing is sound by construction.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A persistent fork-join pool: `threads` worker threads are spawned at
/// construction, parked between regions, and woken with contiguous-chunk
/// tasks (1 = no threads are spawned and every region runs inline on the
/// caller's thread).
///
/// The handle is a cheap `Arc` clone — the builder creates ONE pool per
/// `Session` and clones the handle into the trainer and every operator
/// ([`crate::artopk::ArTopk`], the strategies), so all of a session's
/// parallel regions share the same parked workers. Dropping the last
/// handle shuts the workers down and joins them.
///
/// ```
/// use flexcomm::util::pool::ThreadPool;
/// let pool = ThreadPool::new(4);
/// let squares = pool.map(8, |i| i * i);
/// assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
/// ```
#[derive(Clone)]
pub struct ThreadPool {
    threads: usize,
    /// `None` for serial pools: no worker threads exist at all.
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads).finish()
    }
}

/// Handles compare by capacity only — two pools of the same width are
/// interchangeable under the determinism contract.
impl PartialEq for ThreadPool {
    fn eq(&self, other: &Self) -> bool {
        self.threads == other.threads
    }
}

impl Eq for ThreadPool {}

impl ThreadPool {
    /// Pool with an explicit thread cap (clamped to >= 1). `threads > 1`
    /// spawns the parked workers immediately.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let inner = (threads > 1).then(|| Arc::new(Inner::spawn(threads)));
        ThreadPool { threads, inner }
    }

    /// `threads == 0` means "use the available hardware parallelism"
    /// (the `TrainConfig::threads` / `--threads` convention).
    pub fn auto(threads: usize) -> Self {
        if threads == 0 {
            ThreadPool::new(Self::available())
        } else {
            ThreadPool::new(threads)
        }
    }

    /// Single-threaded pool: every region runs inline, no workers spawned.
    pub fn serial() -> Self {
        ThreadPool::new(1)
    }

    /// Hardware parallelism of this host (>= 1).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Compute `f(0), f(1), .., f(n-1)` across the parked workers; returns
    /// the results in index order.
    ///
    /// `f` runs at most once per index. Panics in `f` propagate to the
    /// caller after the region completes (the pool stays usable).
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = self.threads.min(n);
        let inner = match &self.inner {
            Some(inner) if workers > 1 && !IN_POOL_WORKER.with(|w| w.get()) => inner,
            _ => return (0..n).map(f).collect(),
        };
        // Same chunking as the original scoped pool — part of the bitwise
        // contract (results are by index either way, but keeping the
        // shapes identical keeps per-chunk FP work identical too).
        let chunk = (n + workers - 1) / workers;
        let slots = (n + chunk - 1) / chunk;
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let job = |slot: usize| {
            let start = slot * chunk;
            let end = n.min(start + chunk);
            for i in start..end {
                let v = f(i);
                // SAFETY: slot ranges are disjoint and each index is
                // written exactly once; the old value is `None` (no-op
                // drop on overwrite).
                unsafe { *out_ptr.0.add(i) = Some(v) };
            }
        };
        inner.run_region(slots, &job);
        out.into_iter().map(|r| r.expect("every slot filled")).collect()
    }

    /// Like [`ThreadPool::map`] over disjoint mutable items: each worker
    /// slot owns a contiguous sub-range of `items`, so per-item state
    /// (error-feedback residuals, per-worker compressors, scratch arenas)
    /// mutates without locks. Results come back in item order.
    ///
    /// ```
    /// use flexcomm::util::pool::ThreadPool;
    /// let pool = ThreadPool::new(2);
    /// let mut xs = vec![1, 2, 3];
    /// let idx = pool.map_mut(&mut xs, |i, x| {
    ///     *x *= 2;
    ///     i
    /// });
    /// assert_eq!(xs, vec![2, 4, 6]);
    /// assert_eq!(idx, vec![0, 1, 2]);
    /// ```
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n);
        let inner = match &self.inner {
            Some(inner) if workers > 1 && !IN_POOL_WORKER.with(|w| w.get()) => inner,
            _ => return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect(),
        };
        let chunk = (n + workers - 1) / workers;
        let slots = (n + chunk - 1) / chunk;
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let items_ptr = SendPtr(items.as_mut_ptr());
        let job = |slot: usize| {
            let start = slot * chunk;
            let end = n.min(start + chunk);
            for i in start..end {
                // SAFETY: slot index ranges are disjoint, so each item is
                // exclusively borrowed by exactly one worker.
                let item: &mut T = unsafe { &mut *items_ptr.0.add(i) };
                let v = f(i, item);
                // SAFETY: as in `map` — one writer per index, `None` old
                // value.
                unsafe { *out_ptr.0.add(i) = Some(v) };
            }
        };
        inner.run_region(slots, &job);
        out.into_iter().map(|r| r.expect("every slot filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn map_returns_results_in_index_order() {
        for threads in [1, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let got = pool.map(10, |i| i * 3);
            assert_eq!(got, (0..10).map(|i| i * 3).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn map_handles_edge_sizes() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
        // More threads than items.
        assert_eq!(pool.map(2, |i| i), vec![0, 1]);
    }

    #[test]
    fn map_mut_mutates_every_item_once() {
        for threads in [1, 2, 5] {
            let pool = ThreadPool::new(threads);
            let mut xs = vec![0u64; 13];
            let idx = pool.map_mut(&mut xs, |i, x| {
                *x += 1 + i as u64;
                i
            });
            assert_eq!(idx, (0..13).collect::<Vec<_>>(), "threads={threads}");
            for (i, x) in xs.iter().enumerate() {
                assert_eq!(*x, 1 + i as u64, "threads={threads} item {i}");
            }
        }
    }

    #[test]
    fn borrows_shared_state_without_cloning() {
        let data: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let pool = ThreadPool::new(4);
        let sums = pool.map(4, |w| {
            data[w * 250..(w + 1) * 250].iter().map(|&v| v as f64).sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert!((total - 999.0 * 1000.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn results_bitwise_identical_across_thread_counts() {
        check("pool map deterministic across thread counts", 30, |g| {
            let n = g.usize_in(1, 17);
            let len = g.usize_in(1, 64);
            let base: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(len, 1.0)).collect();
            let work = |pool: &ThreadPool| -> Vec<f64> {
                pool.map(n, |w| base[w].iter().map(|&v| (v as f64).powi(2)).sum())
            };
            let serial = work(&ThreadPool::serial());
            for t in [2usize, 3, 8] {
                let par = work(&ThreadPool::new(t));
                ensure(
                    serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                    format!("threads={t} diverged"),
                )?;
            }
            Ok(())
        });
    }

    /// The persistence property: the SAME workers serve many regions — the
    /// set of OS threads that executed work never grows past the pool
    /// width across hundreds of parked/woken regions.
    #[test]
    fn workers_are_reused_across_regions() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let pool = ThreadPool::new(3);
        let seen: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        for _ in 0..200 {
            pool.map(3, |i| {
                seen.lock().unwrap().insert(std::thread::current().id());
                i
            });
        }
        let seen = seen.into_inner().unwrap();
        assert!(
            !seen.is_empty() && seen.len() <= 3,
            "expected <= 3 persistent workers, saw {} distinct threads",
            seen.len()
        );
        // And none of them is the caller: regions run on parked workers.
        assert!(!seen.contains(&std::thread::current().id()));
    }

    /// Handle clones share one set of parked workers (the per-Session
    /// ownership model: trainer + operators all hold clones).
    #[test]
    fn cloned_handles_share_workers() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let pool = ThreadPool::new(2);
        let clone = pool.clone();
        let seen: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        for p in [&pool, &clone] {
            for _ in 0..50 {
                p.map(2, |i| {
                    seen.lock().unwrap().insert(std::thread::current().id());
                    i
                });
            }
        }
        assert!(seen.into_inner().unwrap().len() <= 2, "clones must not spawn new workers");
        assert_eq!(pool, clone);
    }

    /// A nested map from inside a worker runs inline instead of
    /// deadlocking on the region protocol; results are unchanged.
    #[test]
    fn nested_map_runs_inline() {
        let pool = ThreadPool::new(2);
        let outer = pool.clone();
        let got = pool.map(4, move |i| outer.map(3, |j| i * 10 + j));
        let want: Vec<Vec<usize>> =
            (0..4).map(|i| (0..3).map(|j| i * 10 + j).collect()).collect();
        assert_eq!(got, want);
    }

    /// Oversubscription (more workers than cores — and than items) parks
    /// the excess workers; results are identical by contract.
    #[test]
    fn oversubscribed_pool_works() {
        let pool = ThreadPool::new(16);
        let got = pool.map(5, |i| i * i);
        assert_eq!(got, vec![0, 1, 4, 9, 16]);
        let mut xs = vec![1u32; 7];
        pool.map_mut(&mut xs, |i, x| *x += i as u32);
        assert_eq!(xs, vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn auto_and_available() {
        assert!(ThreadPool::available() >= 1);
        assert_eq!(ThreadPool::auto(0).threads(), ThreadPool::available());
        assert_eq!(ThreadPool::auto(3).threads(), 3);
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert_eq!(ThreadPool::serial().threads(), 1);
    }

    #[test]
    #[should_panic] // region re-raises after completion (payload rewrapped)
    fn worker_panics_propagate() {
        let pool = ThreadPool::new(2);
        pool.map(4, |i| {
            if i == 3 {
                panic!("boom");
            }
            i
        });
    }

    /// Workers survive a panicking region (the payload is re-raised on the
    /// caller, the parked threads live on) — the pool remains usable.
    #[test]
    fn pool_survives_a_panicking_region() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(4, |i| {
                if i == 1 {
                    panic!("poisoned region");
                }
                i
            })
        }));
        assert!(result.is_err(), "panic must propagate");
        // Same pool, next region: fully functional.
        assert_eq!(pool.map(6, |i| i + 1), vec![1, 2, 3, 4, 5, 6]);
    }
}
