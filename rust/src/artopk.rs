//! AR-Topk compression + communication (paper §3, Algorithm 1) — the core
//! contribution: an Allreduce-compatible Top-k.
//!
//! Per step, on each worker `r` with error-fed gradient `G_(i,r)`:
//! 1. local Top-k -> `(g_(i,r), ix_(i,r))`
//! 2. select ONE broadcasting worker `r̃`:
//!    * STAR-Topk: round-robin `r̃ = i % N` (staleness-based)
//!    * VAR-Topk : allgather each worker's `‖g_c‖²`, pick the max
//!      (variance-based; costs one extra 4N-byte AG — Alg 1 lines 10-13)
//! 3. Broadcast `ix_(i,r̃)` from `r̃` (cost: Mc index bytes)
//! 4. every worker gathers ITS OWN values at those indices, updates its
//!    residual against them (lines 15-16)
//! 5. AllReduce (ring or tree) the k values (cost: Mc value bytes)
//!
//! Total cost = Eqn 4a (ring) / 4b (tree); the flexible strategy picks
//! ring/tree/AG per Eqn 5 ([`crate::coordinator::selector`]).

// flexlint::allow-file(unsanctioned-clock): the whole module is the billed compression hot path — t_comp is measured here inside pool tasks by design (DESIGN.md §7)
use crate::collectives::{broadcast, ring_allreduce, tree_allreduce, CommReport};
use crate::compress::topk::{select_mags_into, SelectBackend, SelectScratch};
use crate::compress::{k_for, EfState, SparseGrad};
use crate::netsim::cost_model::LinkParams;
use crate::tensor::{kernels, nan_min_cmp};
use crate::util::pool::ThreadPool;

/// Worker-selection policy (§3-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionPolicy {
    /// Staleness-based round-robin (STAR-Topk).
    Star,
    /// Gradient-variance based (VAR-Topk).
    Var,
}

impl SelectionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SelectionPolicy::Star => "STAR-Topk",
            SelectionPolicy::Var => "VAR-Topk",
        }
    }
}

/// Which allreduce flavour reduces the values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArFlavor {
    Ring,
    Tree,
}

/// Outcome of one AR-Topk exchange.
#[derive(Debug)]
pub struct ArTopkResult {
    /// The aggregated (SUMMED, not yet averaged) sparse update, identical
    /// on every worker.
    pub update: SparseGrad,
    /// Rank that broadcast its indices this step (Fig 4 density data).
    pub selected: usize,
    /// Simulated communication time (selection AG + broadcast + AR).
    pub comm: CommReport,
    /// Gain statistics per worker: (‖g_c‖² at broadcast indices, ‖g_e‖²).
    pub gain_terms: Vec<(f64, f64)>,
    /// Wall-clock compression cost on the CRITICAL PATH: per phase
    /// (error-feed, selection, gather, residual update) the MAX of
    /// per-worker durations
    /// measured inside the concurrently-running [`ThreadPool`] tasks —
    /// the worker a synchronous cluster step waits for. Charging measured
    /// per-worker maxima (rather than the region's wall time) keeps the
    /// simulated cost independent of how many host cores the pool got,
    /// provided the pool is not oversubscribed (DESIGN.md §7).
    pub comp_wall_s: f64,
}

/// Per-worker step arena (DESIGN.md §7): every step-local buffer worker
/// `r` needs, owned by the operator and reused across steps. A lane is
/// only ever touched by the one pool slot that owns index `r` inside a
/// region, so lanes need no synchronization.
#[derive(Debug, Clone, Default)]
struct WorkerLane {
    /// Staged error-fed gradient; swapped with the residual at the update
    /// phase, so the outgoing residual Vec becomes next step's staging.
    g_e: Vec<f32>,
    /// `|g_e|` magnitudes, filled in the SAME fused error-feed pass
    /// (`kernels::error_feed_abs_into`) so selection never re-scans for
    /// `abs`. For STAR only the selected lane's buffer is read — the
    /// non-selected lanes' magnitudes are the (cheap, fused) price of
    /// keeping the error-feed phase uniform across lanes.
    mag: Vec<f32>,
    /// This worker's own values at the broadcast indices (allreduce input).
    vals: Vec<f32>,
    /// Local top-k indices (fresh for VAR on all lanes; for STAR only on
    /// the selected lane — stale elsewhere and never read).
    idx: Vec<u32>,
    /// Selection scratch for [`select_mags_into`].
    scratch: SelectScratch,
}

/// AR-Topk operator. Holds the selection backend and per-worker arenas;
/// residuals stay in the caller's [`EfState`]s (one per worker) so
/// compressors are swappable.
#[derive(Debug, Clone)]
pub struct ArTopk {
    pub policy: SelectionPolicy,
    pub flavor: ArFlavor,
    backend: SelectBackend,
    /// Runs the per-worker phases (error-feed, VAR top-k, gather, residual
    /// update); defaults to serial so standalone uses stay single-threaded.
    pool: ThreadPool,
    lanes: Vec<WorkerLane>,
    /// Value buffers cycled with `lanes[r].vals` for the allreduce.
    gather: Vec<Vec<f32>>,
}

impl ArTopk {
    pub fn new(policy: SelectionPolicy, flavor: ArFlavor) -> Self {
        ArTopk {
            policy,
            flavor,
            backend: SelectBackend::Quickselect,
            pool: ThreadPool::serial(),
            lanes: Vec::new(),
            gather: Vec::new(),
        }
    }

    /// Use the paper's max-heap Top-k instead of quickselect.
    pub fn with_heap_topk(mut self) -> Self {
        self.backend = SelectBackend::Heap;
        self
    }

    /// Use sampled-threshold selection with exact-k repair
    /// ([`crate::compress::sampledk`]): bitwise-identical indices and
    /// values, cheaper selection pass.
    pub fn with_sampled_topk(mut self) -> Self {
        self.backend = SelectBackend::Sampled;
        self
    }

    /// Run the per-worker phases on `pool` (the trainer passes its
    /// `TrainConfig::threads` pool). Results are bitwise identical for any
    /// thread count; only `comp_wall_s` (measured time) changes.
    pub fn with_pool(mut self, pool: ThreadPool) -> Self {
        self.pool = pool;
        self
    }

    fn ensure_lanes(&mut self, n: usize) {
        if self.lanes.len() < n {
            self.lanes.resize_with(n, WorkerLane::default);
        }
        if self.gather.len() < n {
            self.gather.resize_with(n, Vec::new);
        }
    }

    /// Execute one AR-Topk round (Alg 1 lines 5-17).
    ///
    /// `grads[r]` is worker r's RAW gradient for this step; `ef[r]` its
    /// error-feedback state (updated in place). `step` drives round-robin
    /// selection. Returns the summed sparse update (caller averages by N).
    pub fn exchange(
        &mut self,
        grads: &[Vec<f32>],
        ef: &mut [EfState],
        cr: f64,
        step: u64,
        link: LinkParams,
    ) -> ArTopkResult {
        let n = grads.len();
        assert!(n >= 1);
        assert_eq!(ef.len(), n);
        let dim = grads[0].len();
        let k = k_for(cr, dim);
        let mut comm = CommReport::default();
        self.ensure_lanes(n);
        let backend = self.backend;
        let pool = self.pool.clone();

        // Line 5: error-fed gradients — per worker, genuinely concurrent
        // across the pool's threads, staged into each lane's reused g_e
        // arena. Each worker's duration is measured INSIDE its task and
        // the charge is the max (critical path): the simulated cluster
        // cost stays independent of how many host cores the pool actually
        // got, as long as it isn't oversubscribed (DESIGN.md §7).
        let ef_ro: &[EfState] = ef;
        let ef_dts = pool.map_mut(&mut self.lanes[..n], |r, lane| {
            let t0 = std::time::Instant::now();
            // Fused Eqn-2a: g_e AND |g_e| in one pass, so the selection
            // phase below runs over precomputed magnitudes.
            ef_ro[r].error_fed_abs_into(&grads[r], &mut lane.g_e, &mut lane.mag);
            t0.elapsed().as_secs_f64()
        });
        let mut comp_wall_s = ef_dts.iter().copied().fold(0.0f64, f64::max);

        // Lines 6-13: local top-k + worker selection.
        //
        // Perf note (EXPERIMENTS.md §Perf): STAR selection is known up
        // front (i % N), and only the selected worker's indices are ever
        // used — so ONLY that worker runs Top-k. VAR needs every worker's
        // ||g_c||² and therefore every worker's local top-k; those run
        // concurrently on the pool.
        let selected = match self.policy {
            SelectionPolicy::Star => {
                let selected = (step % n as u64) as usize;
                let WorkerLane { mag, idx, scratch, .. } = &mut self.lanes[selected];
                let t0 = std::time::Instant::now();
                select_mags_into(backend, mag, k, scratch, idx);
                comp_wall_s += t0.elapsed().as_secs_f64();
                selected
            }
            SelectionPolicy::Var => {
                let per_worker: Vec<(f64, f64)> = pool.map_mut(&mut self.lanes[..n], |_r, lane| {
                    let WorkerLane { g_e, mag, idx, scratch, .. } = lane;
                    let t0 = std::time::Instant::now();
                    select_mags_into(backend, mag, k, scratch, idx);
                    // ||g_c||² under the crate lane-split reduction policy
                    // (kernels, DESIGN.md §7).
                    let var = kernels::sq_norm_gather_lanes(g_e, idx);
                    (var, t0.elapsed().as_secs_f64())
                });
                comp_wall_s += per_worker.iter().map(|p| p.1).fold(0.0f64, f64::max);
                let vars: Vec<f64> = per_worker.into_iter().map(|(var, _)| var).collect();
                // Sync variances via AG of one f32 per worker (4N bytes,
                // negligible but still charged).
                let parts: Vec<Vec<f32>> = vars.iter().map(|&v| vec![v as f32]).collect();
                let (_, rep) = crate::collectives::allgather_concat(&parts, link);
                comm.merge(rep);
                // NaN-smallest total order ([`nan_min_cmp`]): a worker
                // whose gradient exploded to NaN can never win VAR
                // selection, so one bad worker degrades selection instead
                // of panicking mid-run (the old `partial_cmp().unwrap()`).
                // All-NaN steps stay deterministic: last rank wins the
                // all-Equal tie, matching `max_by`.
                vars.iter()
                    .enumerate()
                    .max_by(|a, b| nan_min_cmp(*a.1, *b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            }
        };

        // Line 14: broadcast the selected worker's indices.
        let (bcast_idx, rep) = broadcast(&self.lanes[selected].idx, selected, n, link);
        comm.merge(rep);

        // Lines 15-16: every worker gathers its own values at those indices
        // into its lane's vals arena (concurrent across the pool -> max
        // per-worker measured charge)...
        let bcast_ref = &bcast_idx;
        let gain_dts: Vec<(f64, f64, f64)> = pool.map_mut(&mut self.lanes[..n], |_r, lane| {
            let WorkerLane { g_e, vals, .. } = lane;
            let t0 = std::time::Instant::now();
            vals.clear();
            vals.extend(bcast_ref.iter().map(|&i| g_e[i as usize]));
            let dt = t0.elapsed().as_secs_f64();
            // Gain bookkeeping is metrics-only — its O(G) norm pass stays
            // OFF the billed path (same policy as the AG path; the real
            // gather is O(k)).
            let sent_sq = crate::tensor::sq_norm(vals);
            let total_sq = crate::tensor::sq_norm(g_e);
            (sent_sq, total_sq, dt)
        });
        comp_wall_s += gain_dts.iter().map(|g| g.2).fold(0.0f64, f64::max);
        let gain_terms: Vec<(f64, f64)> =
            gain_dts.into_iter().map(|(c, e, _)| (c, e)).collect();
        // ...and updates its residual against exactly what it sent: zero
        // the sent coordinates in the staged g_e and SWAP it with the
        // residual (per-worker state, disjoint mutation; the outgoing
        // residual Vec becomes next step's staging arena). Billed like the
        // AG path's residual update: max per-worker measured duration.
        let mut pairs: Vec<(&mut EfState, &mut WorkerLane)> =
            ef.iter_mut().zip(self.lanes.iter_mut()).collect();
        let residual_dts = pool.map_mut(&mut pairs, |_r, (e, lane)| {
            let t0 = std::time::Instant::now();
            e.update_at_indices_swap(&mut lane.g_e, bcast_ref);
            t0.elapsed().as_secs_f64()
        });
        comp_wall_s += residual_dts.iter().copied().fold(0.0f64, f64::max);
        drop(pairs);

        // Line 17: allreduce the values at the broadcast indices. The
        // owned buffers cycle between `gather` and the lanes' vals arenas
        // step over step — no steady-state allocation.
        for (g, lane) in self.gather[..n].iter_mut().zip(&mut self.lanes[..n]) {
            std::mem::swap(g, &mut lane.vals);
        }
        let rep = match self.flavor {
            ArFlavor::Ring => ring_allreduce(&mut self.gather[..n], link),
            ArFlavor::Tree => tree_allreduce(&mut self.gather[..n], link),
        };
        comm.merge(rep);

        ArTopkResult {
            update: SparseGrad {
                indices: bcast_idx,
                values: self.gather.first().cloned().unwrap_or_default(),
                dense_len: dim,
            },
            selected,
            comm,
            gain_terms,
            comp_wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;
    use crate::util::proptest::{check, close, ensure};

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(1.0, 10.0)
    }

    fn setup(n: usize, dim: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<EfState>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let grads = (0..n)
            .map(|_| {
                let mut v = vec![0.0; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let ef = (0..n).map(|_| EfState::new(dim)).collect();
        (grads, ef)
    }

    #[test]
    fn star_round_robin_selection() {
        let (grads, mut ef) = setup(4, 64, 0);
        let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
        for step in 0..8u64 {
            let r = art.exchange(&grads, &mut ef, 0.1, step, link());
            assert_eq!(r.selected, (step % 4) as usize);
        }
    }

    #[test]
    fn var_selects_max_variance_worker() {
        let dim = 100;
        let mut grads = vec![vec![0.01f32; dim]; 4];
        grads[2] = vec![5.0; dim]; // dominant gradient mass on rank 2
        let mut ef: Vec<EfState> = (0..4).map(|_| EfState::new(dim)).collect();
        let mut art = ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring);
        let r = art.exchange(&grads, &mut ef, 0.1, 0, link());
        assert_eq!(r.selected, 2);
    }

    #[test]
    fn update_sums_values_at_broadcast_indices() {
        let (grads, mut ef) = setup(3, 50, 1);
        let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
        let r = art.exchange(&grads, &mut ef, 0.2, 0, link());
        let k = k_for(0.2, 50);
        assert_eq!(r.update.k(), k);
        for (&i, &v) in r.update.indices.iter().zip(&r.update.values) {
            // flexlint::allow(hot-loop-outside-kernels): test-only n-worker reference sum (strided across workers, not a hot-path reduction)
            let want: f32 = grads.iter().map(|g| g[i as usize]).sum();
            assert!((v - want).abs() < 1e-4, "idx {i}: {v} vs {want}");
        }
    }

    #[test]
    fn residuals_follow_alg1_lines_15_16() {
        let (grads, mut ef) = setup(2, 30, 2);
        let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Tree);
        let r = art.exchange(&grads, &mut ef, 0.1, 0, link());
        let chosen: std::collections::HashSet<u32> = r.update.indices.iter().copied().collect();
        for (w, e) in ef.iter().enumerate() {
            for (i, &res) in e.residual.iter().enumerate() {
                if chosen.contains(&(i as u32)) {
                    assert_eq!(res, 0.0, "worker {w} idx {i} sent but residual kept");
                } else {
                    assert_eq!(res, grads[w][i], "worker {w} idx {i} dropped mass lost");
                }
            }
        }
    }

    #[test]
    fn error_feedback_conserves_mass_across_steps() {
        check("artopk EF conservation", 25, |gen| {
            let n = gen.usize_in(2, 5);
            let dim = gen.usize_in(20, 120);
            let (grads, mut ef) = setup(n, dim, gen.rng.next_u64());
            let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
            // After one exchange: residual + sent == g_e (per worker).
            let g_e0: Vec<Vec<f32>> = (0..n).map(|r| ef[r].error_fed(&grads[r])).collect();
            let r = art.exchange(&grads, &mut ef, 0.15, 0, link());
            for w in 0..n {
                let mut reconstructed = ef[w].residual.clone();
                for &i in &r.update.indices {
                    reconstructed[i as usize] = g_e0[w][i as usize];
                }
                crate::util::proptest::all_close(&reconstructed, &g_e0[w], 1e-5)
                    .map_err(|e| format!("worker {w}: {e}"))?;
            }
            Ok(())
        });
    }

    /// One NaN-poisoned worker (exploding loss) must not panic VAR
    /// selection; the NaN worker can never win, so training continues and
    /// the damage is diagnosable, not fatal.
    #[test]
    fn var_selection_survives_nan_gradients() {
        let dim = 60;
        let (mut grads, mut ef) = setup(4, dim, 11);
        grads[1] = vec![f32::NAN; dim]; // worker 1 exploded
        grads[2] = vec![5.0; dim]; // worker 2 has the real mass
        let mut art = ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring);
        let r = art.exchange(&grads, &mut ef, 0.1, 0, link());
        assert_eq!(r.selected, 2, "NaN variance must lose to finite mass");
        // Every worker went through residual update, including the NaN one.
        assert!(ef[1].residual.iter().any(|v| v.is_nan()));
        // All-NaN step: still no panic, deterministic last-rank tie-break.
        let all_nan = vec![vec![f32::NAN; dim]; 4];
        let (_, mut ef2) = setup(4, dim, 12);
        let r = art.exchange(&all_nan, &mut ef2, 0.1, 0, link());
        assert_eq!(r.selected, 3);
    }

    /// The pooled operator is the sequential operator: bitwise-identical
    /// update, selection, gain terms and CommReport for any thread count.
    #[test]
    fn pooled_exchange_matches_serial_bitwise() {
        for policy in [SelectionPolicy::Star, SelectionPolicy::Var] {
            for n in [3usize, 4] {
                let (grads, ef0) = setup(n, 400, 21);
                let run = |pool: crate::util::pool::ThreadPool| {
                    let mut ef = ef0.clone();
                    let mut art = ArTopk::new(policy, ArFlavor::Ring).with_pool(pool);
                    let r = art.exchange(&grads, &mut ef, 0.05, 1, link());
                    (r, ef)
                };
                let (a, ef_a) = run(crate::util::pool::ThreadPool::serial());
                let (b, ef_b) = run(crate::util::pool::ThreadPool::new(4));
                assert_eq!(a.update.indices, b.update.indices, "{policy:?} n={n}");
                assert_eq!(a.update.values, b.update.values, "{policy:?} n={n}");
                assert_eq!(a.selected, b.selected);
                assert_eq!(a.comm, b.comm);
                assert_eq!(a.gain_terms, b.gain_terms);
                for (x, y) in ef_a.iter().zip(&ef_b) {
                    assert_eq!(x.residual, y.residual);
                }
            }
        }
    }

    /// Selection backends are interchangeable bitwise: heap, quickselect
    /// and sampled-threshold drive identical exchanges (update, selection,
    /// residuals) across multiple steps.
    #[test]
    fn selection_backends_exchange_identically() {
        for policy in [SelectionPolicy::Star, SelectionPolicy::Var] {
            let (grads, ef0) = setup(4, 600, 31);
            let run = |art: &mut ArTopk| {
                let mut ef = ef0.clone();
                let mut trace = Vec::new();
                for step in 0..4u64 {
                    let r = art.exchange(&grads, &mut ef, 0.04, step, link());
                    trace.push((r.update.indices, r.update.values, r.selected));
                }
                (trace, ef)
            };
            let (quick, ef_q) = run(&mut ArTopk::new(policy, ArFlavor::Ring));
            let (heap, ef_h) = run(&mut ArTopk::new(policy, ArFlavor::Ring).with_heap_topk());
            let (samp, ef_s) = run(&mut ArTopk::new(policy, ArFlavor::Ring).with_sampled_topk());
            assert_eq!(quick, heap, "{policy:?}: heap diverged");
            assert_eq!(quick, samp, "{policy:?}: sampled diverged");
            for ((a, b), c) in ef_q.iter().zip(&ef_h).zip(&ef_s) {
                assert_eq!(a.residual, b.residual);
                assert_eq!(a.residual, c.residual);
            }
        }
    }

    /// Lane arenas must be invisible: one operator reused over many steps
    /// produces the same trajectory as a fresh operator per step (the EF
    /// state carries all the algorithmic state; lanes are pure scratch).
    #[test]
    fn lane_arena_reuse_matches_fresh_operator() {
        for policy in [SelectionPolicy::Star, SelectionPolicy::Var] {
            let (grads, ef0) = setup(3, 300, 41);
            let mut ef_reused = ef0.clone();
            let mut ef_fresh = ef0.clone();
            let mut reused = ArTopk::new(policy, ArFlavor::Tree);
            for step in 0..5u64 {
                let a = reused.exchange(&grads, &mut ef_reused, 0.07, step, link());
                let mut fresh = ArTopk::new(policy, ArFlavor::Tree);
                let b = fresh.exchange(&grads, &mut ef_fresh, 0.07, step, link());
                assert_eq!(a.update.indices, b.update.indices, "{policy:?} step {step}");
                assert_eq!(a.update.values, b.update.values, "{policy:?} step {step}");
                assert_eq!(a.selected, b.selected);
                assert_eq!(a.gain_terms, b.gain_terms);
                for (x, y) in ef_reused.iter().zip(&ef_fresh) {
                    assert_eq!(x.residual, y.residual, "{policy:?} step {step}");
                }
            }
        }
    }

    #[test]
    fn comm_cost_matches_eqn4() {
        // Ring: α[2(N-1)+logN] + Mcβ[2(N-1)/N + logN] with Mc = 4k bytes.
        let n = 8;
        let dim = 80_000;
        let cr = 0.1;
        let (grads, mut ef) = setup(n, dim, 3);
        let mut ring = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
        let r = ring.exchange(&grads, &mut ef, cr, 0, link());
        let m = 4.0 * dim as f64;
        let want = cost_model::art_ring(link(), m, n, cr);
        close(r.comm.seconds, want, 1e-6).unwrap();

        let (grads, mut ef) = setup(n, dim, 4);
        let mut tree = ArTopk::new(SelectionPolicy::Star, ArFlavor::Tree);
        let r = tree.exchange(&grads, &mut ef, cr, 0, link());
        let want = cost_model::art_tree(link(), m, n, cr);
        close(r.comm.seconds, want, 1e-6).unwrap();
    }

    #[test]
    fn var_costs_more_than_star() {
        let n = 8;
        let (grads, mut ef1) = setup(n, 10_000, 5);
        let mut ef2 = ef1.clone();
        let mut star = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
        let mut var = ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring);
        let rs = star.exchange(&grads, &mut ef1, 0.01, 0, link());
        let rv = var.exchange(&grads, &mut ef2, 0.01, 0, link());
        assert!(rv.comm.seconds > rs.comm.seconds, "VAR must pay the extra AG");
    }

    #[test]
    fn gain_terms_bounded() {
        check("artopk gain in [0,1]", 20, |gen| {
            let n = gen.usize_in(2, 4);
            let dim = gen.usize_in(50, 200);
            let (grads, mut ef) = setup(n, dim, gen.rng.next_u64());
            let mut art = ArTopk::new(SelectionPolicy::Var, ArFlavor::Ring);
            let r = art.exchange(&grads, &mut ef, 0.1, 0, link());
            for &(c, e) in &r.gain_terms {
                ensure(c >= 0.0 && c <= e * (1.0 + 1e-9), format!("gain terms {c} {e}"))?;
            }
            Ok(())
        });
    }
}
