"""L1 Pallas kernels (interpret=True on CPU) + pure-jnp reference oracles."""

from . import ef_compress, matmul, ref, topk_threshold  # noqa: F401
