//! Chunked, branch-free kernels for the compression hot path — the single
//! audited home for every inner loop the AG and AR Eqn-2 cycles run
//! (DESIGN.md §7 "Kernel layer").
//!
//! Every kernel walks its input in fixed chunks of [`LANES`] = 8 elements
//! (`chunks_exact` + a scalar tail), with straight-line bodies the
//! autovectorizer can turn into SIMD and FMA without a gather or a
//! data-dependent branch. The `hot-loop-outside-kernels` flexlint rule
//! keeps new hot-path code from bypassing this module.
//!
//! ## The bitwise contract
//!
//! Kernels fall into exactly two classes, and each is pinned by property
//! tests against a **verbatim scalar reference** (tails `0..=17`, ties,
//! NaN and ±inf poisoning, empty input):
//!
//! * **Elementwise kernels** (`add_into`, `error_feed_abs_into`, `axpy`,
//!   `scale`, `abs_pairs_into`, `pairs_into`, `scatter_zero`,
//!   `scatter_add`, `abs_max`, `threshold_count`,
//!   `threshold_filter_into`) are **bitwise identical** to the scalar
//!   loops they replaced: each output element depends on exactly one
//!   input element (or, for `abs_max`, on an order-insensitive max), so
//!   chunking cannot move a single bit.
//! * **Lane-split reductions** (`sq_norm_lanes`, `dot_lanes`,
//!   `sq_norm_gather_lanes`) are THE crate reduction policy: element `i`
//!   accumulates into f64 lane `i % LANES`, and the 8 lane sums combine
//!   in one fixed pairwise order ([`combine_lanes`]). The result is a
//!   pure function of the input — invariant to thread count, chunking
//!   and call site by construction — but it is NOT the old sequential
//!   left-fold sum: rewiring `tensor::{sq_norm, dot}` through these
//!   kernels changed the low bits of gain terms and VAR variances
//!   crate-wide (every consumer moved together; run-vs-run determinism
//!   is untouched).
//!
//! ## Adding a kernel
//!
//! Write the chunked body here, keep the scalar reference **verbatim** in
//! this file's tests (that reference is the contract, not dead code), pin
//! it bitwise across tail lengths `0..=17` and NaN/±inf inputs, add a
//! scalar-vs-chunked pair to the `kernels` stage of
//! `rust/benches/hotpath.rs`, and rewire the call sites — the lint rule
//! will flag any that remain scalar.

/// Fixed chunk width (elements per vectorized step) shared by every
/// kernel. 8 f32 lanes = one AVX2 register; on narrower ISAs the compiler
/// splits the chunk, on wider ones it fuses two — the *numeric* result
/// never depends on what the hardware does because the lane policy is
/// defined in terms of this constant, not the target.
pub const LANES: usize = 8;

// ---------------------------------------------------------------------------
// Elementwise kernels (bitwise-equal to their scalar loops).
// ---------------------------------------------------------------------------

/// `out = a + b` elementwise — the fused error-feed `g + residual`
/// (Eqn 2a). `out` is cleared and fully overwritten; capacity is reserved
/// up front so the convenience path never pays realloc churn.
pub fn add_into(a: &[f32], b: &[f32], out: &mut Vec<f32>) {
    assert_eq!(a.len(), b.len(), "add_into: length mismatch");
    out.clear();
    out.reserve(a.len());
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        let mut buf = [0.0f32; LANES];
        for j in 0..LANES {
            buf[j] = xa[j] + xb[j];
        }
        out.extend_from_slice(&buf);
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        out.push(x + y);
    }
}

/// One pass producing BOTH `g_e = g + residual` and its magnitude buffer
/// `mag[i] = |g_e[i]|` — fusing the error-feed pass and the `|v|`
/// pair-building pass every top-k variant used to run separately. `mag`
/// feeds [`crate::compress::topk::select_mags_into`]; bitwise, `g_e`
/// matches [`add_into`] and `mag[i]` matches `g_e[i].abs()` exactly.
pub fn error_feed_abs_into(g: &[f32], residual: &[f32], g_e: &mut Vec<f32>, mag: &mut Vec<f32>) {
    assert_eq!(g.len(), residual.len(), "error_feed_abs_into: length mismatch");
    g_e.clear();
    g_e.reserve(g.len());
    mag.clear();
    mag.reserve(g.len());
    let mut cg = g.chunks_exact(LANES);
    let mut cr = residual.chunks_exact(LANES);
    for (xg, xr) in (&mut cg).zip(&mut cr) {
        let mut sum = [0.0f32; LANES];
        let mut abs = [0.0f32; LANES];
        for j in 0..LANES {
            let s = xg[j] + xr[j];
            sum[j] = s;
            abs[j] = s.abs();
        }
        g_e.extend_from_slice(&sum);
        mag.extend_from_slice(&abs);
    }
    for (x, y) in cg.remainder().iter().zip(cr.remainder()) {
        let s = x + y;
        g_e.push(s);
        mag.push(s.abs());
    }
}

/// `y += a * x` (FMA-friendly: one mul-add per lane, no cross-lane dep).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "axpy: length mismatch");
    let mut cy = y.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (ya, xa) in (&mut cy).zip(&mut cx) {
        for j in 0..LANES {
            ya[j] += a * xa[j];
        }
    }
    for (yi, xi) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yi += a * xi;
    }
}

/// `x *= a`.
pub fn scale(x: &mut [f32], a: f32) {
    let mut cx = x.chunks_exact_mut(LANES);
    for ch in &mut cx {
        for j in 0..LANES {
            ch[j] *= a;
        }
    }
    for xi in cx.into_remainder() {
        *xi *= a;
    }
}

/// Build the `(|g[i]|, i)` selection pairs — the magnitude pass of
/// quickselect/sampled top-k. `out` is cleared and fully overwritten.
pub fn abs_pairs_into(g: &[f32], out: &mut Vec<(f32, u32)>) {
    out.clear();
    out.reserve(g.len());
    let mut c = g.chunks_exact(LANES);
    let mut base = 0u32;
    for ch in &mut c {
        let mut buf = [(0.0f32, 0u32); LANES];
        for j in 0..LANES {
            buf[j] = (ch[j].abs(), base + j as u32);
        }
        out.extend_from_slice(&buf);
        base += LANES as u32;
    }
    for (j, &v) in c.remainder().iter().enumerate() {
        out.push((v.abs(), base + j as u32));
    }
}

/// [`abs_pairs_into`] over a PRECOMPUTED magnitude buffer (no `abs` —
/// the fused [`error_feed_abs_into`] already paid it).
pub fn pairs_into(mags: &[f32], out: &mut Vec<(f32, u32)>) {
    out.clear();
    out.reserve(mags.len());
    let mut c = mags.chunks_exact(LANES);
    let mut base = 0u32;
    for ch in &mut c {
        let mut buf = [(0.0f32, 0u32); LANES];
        for j in 0..LANES {
            buf[j] = (ch[j], base + j as u32);
        }
        out.extend_from_slice(&buf);
        base += LANES as u32;
    }
    for (j, &m) in c.remainder().iter().enumerate() {
        out.push((m, base + j as u32));
    }
}

/// Zero `x` at the given SORTED indices — the residual-update store
/// stream of `update_swap`/`update_at_indices_swap` (Eqn 2b). Sorted
/// ascending is the wire format every compressor and broadcast emits;
/// the kernel's store loop is branch-free either way, but sortedness
/// keeps the stores a forward stream the prefetcher can follow.
pub fn scatter_zero(x: &mut [f32], indices: &[u32]) {
    // flexlint::allow(release-silent-assert): sortedness is a prefetch hint, not a correctness invariant — zero-stores are order-insensitive and an out-of-range index still panics via slice indexing
    debug_assert!(
        indices.windows(2).all(|w| w[0] <= w[1]),
        "scatter_zero expects sorted indices (the wire format)"
    );
    for &i in indices {
        x[i as usize] = 0.0;
    }
}

/// `out[indices[j]] += values[j]` — the `SparseGrad::to_dense` scatter.
/// Duplicate indices accumulate (matching the scalar loop exactly).
pub fn scatter_add(out: &mut [f32], indices: &[u32], values: &[f32]) {
    assert_eq!(indices.len(), values.len(), "scatter_add: length mismatch");
    for (&i, &v) in indices.iter().zip(values) {
        out[i as usize] += v;
    }
}

/// `max_i |x[i]|`, NaN-ignoring (a NaN entry never becomes the max, and
/// an all-NaN or empty input returns 0.0) — the bisection upper bound of
/// MSTopk. Bitwise-equal to `x.iter().fold(0.0, |m, &v| m.max(v.abs()))`:
/// max over non-negative magnitudes is order-insensitive and
/// `f32::max(acc, NaN) == acc`.
pub fn abs_max(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut c = x.chunks_exact(LANES);
    for ch in &mut c {
        for j in 0..LANES {
            acc[j] = acc[j].max(ch[j].abs());
        }
    }
    for (j, &v) in c.remainder().iter().enumerate() {
        acc[j] = acc[j].max(v.abs());
    }
    let mut m = acc[0];
    for &a in &acc[1..] {
        m = m.max(a);
    }
    m
}

/// Count of `|x[i]| > tau`, predicate-as-integer (no branch in the loop
/// body) — MSTopk's per-round bisection count. NaN entries never pass
/// (`NaN > tau` is false), matching the scalar `filter(..).count()`.
pub fn threshold_count(x: &[f32], tau: f32) -> usize {
    let mut acc = [0usize; LANES];
    let mut c = x.chunks_exact(LANES);
    for ch in &mut c {
        for j in 0..LANES {
            acc[j] += (ch[j].abs() > tau) as usize;
        }
    }
    for (j, &v) in c.remainder().iter().enumerate() {
        acc[j] += (v.abs() > tau) as usize;
    }
    let mut total = 0;
    for &a in &acc {
        total += a;
    }
    total
}

/// The `mag_desc_idx_asc` total order (descending magnitude, NaN
/// smallest, ties by ascending index — see
/// [`crate::compress::topk`]) collapsed into ONE u64 so that
/// `a` ranks at-or-before `b` ⟺ `rank_key(a) >= rank_key(b)`:
/// an integer compare is the whole predicate, which is what makes
/// [`threshold_filter_into`] branch-free.
///
/// `mag` must be a magnitude: non-negative or NaN (i.e. produced by
/// `abs()`). The IEEE-754 bit pattern of a non-negative f32 is monotone
/// in its value, so `bits + 1` orders finite/inf magnitudes; NaN maps to
/// 0 (below everything, any payload), and the bitwise-NOT of the index
/// makes lower indices rank earlier within a magnitude tie.
#[inline]
pub fn rank_key(mag: f32, idx: u32) -> u64 {
    debug_assert!(
        mag.is_nan() || mag.is_sign_positive(),
        "rank_key expects a magnitude (non-negative or NaN), got {mag}"
    );
    let m = if mag.is_nan() { 0u64 } else { mag.to_bits() as u64 + 1 };
    (m << 32) | (!idx) as u64
}

/// The sampled-top-k filtering pass: keep every `(|g[i]|, i)` pair that
/// ranks at-or-before `threshold` under the total order (the exact prefix
/// the repair contract needs — see [`crate::compress::sampledk`]).
/// Branch-free compaction: every pair is written to the write cursor,
/// which advances by the integer predicate — no data-dependent branch for
/// the predictor to miss on. Output order and contents are bitwise-equal
/// to the scalar `push`-if loop.
pub fn threshold_filter_into(g: &[f32], threshold: (f32, u32), out: &mut Vec<(f32, u32)>) {
    let tk = rank_key(threshold.0, threshold.1);
    let len = g.len();
    // Grow-only: stale slots past the write cursor are never read (we
    // truncate to exactly the slots written this call).
    if out.len() < len {
        out.resize(len, (0.0, 0));
    }
    let mut w = 0usize;
    for (i, &v) in g.iter().enumerate() {
        let p = (v.abs(), i as u32);
        out[w] = p;
        w += (rank_key(p.0, p.1) >= tk) as usize;
    }
    out.truncate(w);
}

/// [`threshold_filter_into`] over a PRECOMPUTED magnitude buffer.
pub fn threshold_filter_mags_into(
    mags: &[f32],
    threshold: (f32, u32),
    out: &mut Vec<(f32, u32)>,
) {
    let tk = rank_key(threshold.0, threshold.1);
    let len = mags.len();
    if out.len() < len {
        out.resize(len, (0.0, 0));
    }
    let mut w = 0usize;
    for (i, &m) in mags.iter().enumerate() {
        out[w] = (m, i as u32);
        w += (rank_key(m, i as u32) >= tk) as usize;
    }
    out.truncate(w);
}

// ---------------------------------------------------------------------------
// Lane-split f64 reductions — THE crate reduction policy.
// ---------------------------------------------------------------------------

/// Combine the 8 lane accumulators in ONE fixed pairwise order. This
/// order is part of the reduction policy: changing it changes results
/// crate-wide and invalidates every recorded metric baseline.
#[inline]
fn combine_lanes(acc: [f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// `Σ x[i]²` in f64, lane-split: element `i` accumulates into lane
/// `i % LANES`, lanes combine via [`combine_lanes`]. A pure function of
/// the input — thread- and chunk-invariant by construction — and ~LANES×
/// more instruction-level parallelism than the sequential fold (each
/// scalar add had to wait for the previous one; the 8 lane chains run
/// concurrently in the FPU).
pub fn sq_norm_lanes(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut c = x.chunks_exact(LANES);
    for ch in &mut c {
        for j in 0..LANES {
            let v = ch[j] as f64;
            acc[j] += v * v;
        }
    }
    for (j, &v) in c.remainder().iter().enumerate() {
        let v = v as f64;
        acc[j] += v * v;
    }
    combine_lanes(acc)
}

/// `Σ a[i]·b[i]` in f64 under the same lane-split policy.
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot_lanes: length mismatch");
    let mut acc = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..LANES {
            acc[j] += xa[j] as f64 * xb[j] as f64;
        }
    }
    for (j, (&x, &y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        acc[j] += x as f64 * y as f64;
    }
    combine_lanes(acc)
}

/// `Σ x[idx[j]]²` — the gathered sq-norm of AR-Topk's VAR variance pass,
/// lane-split over the GATHER position `j` (not the gathered index), so
/// the result is a pure function of `(x, idx)`.
pub fn sq_norm_gather_lanes(x: &[f32], idx: &[u32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut c = idx.chunks_exact(LANES);
    for ch in &mut c {
        for j in 0..LANES {
            let v = x[ch[j] as usize] as f64;
            acc[j] += v * v;
        }
    }
    for (j, &i) in c.remainder().iter().enumerate() {
        let v = x[i as usize] as f64;
        acc[j] += v * v;
    }
    combine_lanes(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure, Gen};

    // -----------------------------------------------------------------
    // Verbatim scalar references. These are the contract: the elementwise
    // references are the exact pre-kernel loops, and the lane references
    // are the reduction policy written as a plain strided scalar loop.
    // The lint rule is allowed here by design — a reference that itself
    // routed through the kernels would pin nothing.
    // -----------------------------------------------------------------

    fn ref_add(a: &[f32], b: &[f32]) -> Vec<f32> {
        a.iter().zip(b).map(|(x, y)| x + y).collect()
    }

    fn ref_axpy(y: &mut [f32], a: f32, x: &[f32]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    fn ref_scale(x: &mut [f32], a: f32) {
        for xi in x.iter_mut() {
            *xi *= a;
        }
    }

    fn ref_abs_pairs(g: &[f32]) -> Vec<(f32, u32)> {
        g.iter().enumerate().map(|(i, &v)| (v.abs(), i as u32)).collect()
    }

    fn ref_abs_max(x: &[f32]) -> f32 {
        x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    fn ref_threshold_count(x: &[f32], tau: f32) -> usize {
        x.iter().filter(|&&v| v.abs() > tau).count()
    }

    /// The filtering pass exactly as `sampled_topk_into` wrote it before
    /// the kernel: comparator-based, one push per survivor.
    fn ref_threshold_filter(g: &[f32], t: (f32, u32)) -> Vec<(f32, u32)> {
        use crate::compress::topk::mag_desc_idx_asc;
        let mut out = Vec::new();
        for (i, &v) in g.iter().enumerate() {
            let p = (v.abs(), i as u32);
            if mag_desc_idx_asc(&p, &t) != std::cmp::Ordering::Greater {
                out.push(p);
            }
        }
        out
    }

    /// The lane-split policy as a plain strided scalar loop — the
    /// sequential-reference DEFINITION the chunked reductions are pinned
    /// against (NOT the old left-fold sum, which is a different policy).
    // flexlint::allow(hot-loop-outside-kernels): this IS the policy's scalar reference definition
    fn ref_sq_norm_lanes(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; LANES];
        for (i, &v) in x.iter().enumerate() {
            let v = v as f64;
            acc[i % LANES] += v * v;
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
    }

    // flexlint::allow(hot-loop-outside-kernels): scalar reference definition (see above)
    fn ref_dot_lanes(a: &[f32], b: &[f32]) -> f64 {
        let mut acc = [0.0f64; LANES];
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            acc[i % LANES] += x as f64 * y as f64;
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
    }

    /// The OLD sequential left-fold (pre-kernel `tensor::sq_norm`) — kept
    /// only to bound how far the policy change moved results.
    // flexlint::allow(hot-loop-outside-kernels): verbatim pre-kernel loop kept as a drift bound
    fn ref_sq_norm_seq(x: &[f32]) -> f64 {
        x.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn pair_bits(v: &[(f32, u32)]) -> Vec<(u32, u32)> {
        v.iter().map(|&(m, i)| (m.to_bits(), i)).collect()
    }

    /// A gradient with NaN/±inf/±0 poison sprinkled in — every kernel
    /// property runs over these, per the bitwise contract.
    fn poisoned(g: &mut Gen, n: usize) -> Vec<f32> {
        let mut v = g.vec_normal(n, 1.0);
        if n > 0 {
            for _ in 0..g.usize_in(0, n / 3 + 1) {
                let at = g.usize_in(0, n - 1);
                v[at] = *g.choose(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0]);
            }
        }
        v
    }

    /// Every tail length beyond two chunk widths, plus empty — the sizes
    /// the chunk/remainder split must cover, then a random size on top.
    fn case_lens(g: &mut Gen) -> Vec<usize> {
        let mut lens: Vec<usize> = (0..=2 * LANES + 1).collect();
        lens.push(g.usize_in(1, 3000));
        lens
    }

    #[test]
    fn add_into_bitwise_equals_scalar() {
        check("add_into == scalar", 60, |g| {
            for n in case_lens(g) {
                let a = poisoned(g, n);
                let b = poisoned(g, n);
                let mut out = Vec::new();
                add_into(&a, &b, &mut out);
                ensure(bits(&out) == bits(&ref_add(&a, &b)), format!("n={n}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn error_feed_abs_fuses_both_passes_bitwise() {
        check("error_feed_abs == add + abs", 60, |g| {
            for n in case_lens(g) {
                let a = poisoned(g, n);
                let r = poisoned(g, n);
                let (mut g_e, mut mag) = (Vec::new(), Vec::new());
                error_feed_abs_into(&a, &r, &mut g_e, &mut mag);
                let want = ref_add(&a, &r);
                ensure(bits(&g_e) == bits(&want), format!("g_e n={n}"))?;
                let want_mag: Vec<f32> = want.iter().map(|v| v.abs()).collect();
                ensure(bits(&mag) == bits(&want_mag), format!("mag n={n}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn axpy_and_scale_bitwise_equal_scalar() {
        check("axpy/scale == scalar", 60, |g| {
            for n in case_lens(g) {
                let x = poisoned(g, n);
                let a = g.f32_in(-3.0, 3.0);
                let mut y1 = poisoned(g, n);
                let mut y2 = y1.clone();
                axpy(&mut y1, a, &x);
                ref_axpy(&mut y2, a, &x);
                ensure(bits(&y1) == bits(&y2), format!("axpy n={n}"))?;
                scale(&mut y1, a);
                ref_scale(&mut y2, a);
                ensure(bits(&y1) == bits(&y2), format!("scale n={n}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn pair_builders_bitwise_equal_scalar() {
        check("abs_pairs/pairs == scalar", 60, |g| {
            for n in case_lens(g) {
                let v = poisoned(g, n);
                let mut out = Vec::new();
                abs_pairs_into(&v, &mut out);
                ensure(pair_bits(&out) == pair_bits(&ref_abs_pairs(&v)), format!("abs n={n}"))?;
                let mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
                pairs_into(&mags, &mut out);
                ensure(
                    pair_bits(&out) == pair_bits(&ref_abs_pairs(&v)),
                    format!("mags n={n}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn scatter_kernels_bitwise_equal_scalar() {
        check("scatter_zero/add == scalar", 60, |g| {
            let n = g.usize_in(1, 500);
            let k = g.usize_in(0, n);
            let mut rng = crate::util::rng::Rng::new(g.rng.next_u64());
            let idx_usize = rng.sample_indices(n, k);
            let idx: Vec<u32> = idx_usize.iter().map(|&i| i as u32).collect();
            let base = poisoned(g, n);

            let mut a = base.clone();
            let mut b = base.clone();
            scatter_zero(&mut a, &idx);
            for &i in &idx {
                b[i as usize] = 0.0;
            }
            ensure(bits(&a) == bits(&b), format!("zero n={n} k={k}"))?;

            let vals = poisoned(g, k);
            let mut a = base.clone();
            let mut b = base;
            scatter_add(&mut a, &idx, &vals);
            for (&i, &v) in idx.iter().zip(&vals) {
                b[i as usize] += v;
            }
            ensure(bits(&a) == bits(&b), format!("add n={n} k={k}"))
        });
    }

    #[test]
    fn abs_max_and_threshold_count_equal_scalar() {
        check("abs_max/threshold_count == scalar", 60, |g| {
            for n in case_lens(g) {
                let v = poisoned(g, n);
                ensure(
                    abs_max(&v).to_bits() == ref_abs_max(&v).to_bits(),
                    format!("abs_max n={n}"),
                )?;
                let tau = if n > 0 && g.bool() {
                    v[g.usize_in(0, n - 1)].abs()
                } else {
                    g.f32_in(0.0, 2.0)
                };
                ensure(
                    threshold_count(&v, tau) == ref_threshold_count(&v, tau),
                    format!("count n={n} tau={tau}"),
                )?;
            }
            Ok(())
        });
    }

    /// `rank_key` IS the total order: for all pairs (NaN, inf, ties, ±0
    /// included), integer comparison of keys agrees with
    /// `mag_desc_idx_asc` — "ranks at-or-before" ⟺ `key >= key`.
    #[test]
    fn rank_key_encodes_the_total_order() {
        use crate::compress::topk::mag_desc_idx_asc;
        check("rank_key == mag_desc_idx_asc", 150, |g| {
            let mag = |g: &mut Gen| -> f32 {
                if g.bool() {
                    g.f32_in(0.0, 3.0)
                } else {
                    (*g.choose(&[f32::NAN, f32::INFINITY, 0.0, 1.0, f32::MIN_POSITIVE])).abs()
                }
            };
            let a = (mag(g), g.usize_in(0, 40) as u32);
            let b = (mag(g), g.usize_in(0, 40) as u32);
            let want = mag_desc_idx_asc(&a, &b);
            let got = rank_key(b.0, b.1).cmp(&rank_key(a.0, a.1));
            ensure(got == want, format!("{a:?} vs {b:?}: key {got:?} order {want:?}"))
        });
    }

    #[test]
    fn threshold_filter_bitwise_equals_comparator_loop() {
        check("threshold_filter == scalar", 80, |g| {
            for n in case_lens(g) {
                let v = poisoned(g, n);
                let t = if n > 0 && g.bool() {
                    let i = g.usize_in(0, n - 1);
                    (v[i].abs(), i as u32)
                } else {
                    (g.f32_in(0.0, 2.0), g.usize_in(0, 50) as u32)
                };
                let want = ref_threshold_filter(&v, t);
                let mut out = Vec::new();
                threshold_filter_into(&v, t, &mut out);
                ensure(pair_bits(&out) == pair_bits(&want), format!("g-path n={n} t={t:?}"))?;
                // Arena reuse: a dirty, oversized buffer must not leak.
                threshold_filter_into(&v, t, &mut out);
                ensure(pair_bits(&out) == pair_bits(&want), format!("reuse n={n}"))?;
                let mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
                threshold_filter_mags_into(&mags, t, &mut out);
                ensure(pair_bits(&out) == pair_bits(&want), format!("mags n={n}"))?;
            }
            Ok(())
        });
    }

    /// The chunked reductions match their strided scalar DEFINITION
    /// bitwise, and sit within float-rounding distance of the old
    /// sequential fold (the policy change moved low bits, not values).
    #[test]
    fn lane_reductions_match_their_scalar_definition() {
        check("lane reductions == strided reference", 60, |g| {
            for n in case_lens(g) {
                let a = g.vec_normal(n, 1.0);
                let b = g.vec_normal(n, 1.0);
                ensure(
                    sq_norm_lanes(&a).to_bits() == ref_sq_norm_lanes(&a).to_bits(),
                    format!("sq_norm n={n}"),
                )?;
                ensure(
                    dot_lanes(&a, &b).to_bits() == ref_dot_lanes(&a, &b).to_bits(),
                    format!("dot n={n}"),
                )?;
                let seq = ref_sq_norm_seq(&a);
                let lanes = sq_norm_lanes(&a);
                ensure(
                    (lanes - seq).abs() <= 1e-9 * seq.abs().max(1.0),
                    format!("policy drift n={n}: {lanes} vs {seq}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn lane_reductions_poisoned_inputs_match_definition() {
        check("lane reductions poisoned", 60, |g| {
            for n in case_lens(g) {
                let a = poisoned(g, n);
                let b = poisoned(g, n);
                ensure(
                    sq_norm_lanes(&a).to_bits() == ref_sq_norm_lanes(&a).to_bits(),
                    format!("sq_norm n={n}"),
                )?;
                ensure(
                    dot_lanes(&a, &b).to_bits() == ref_dot_lanes(&a, &b).to_bits(),
                    format!("dot n={n}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn gather_reduction_matches_strided_definition() {
        check("sq_norm_gather == strided reference", 60, |g| {
            let n = g.usize_in(1, 800);
            let k = g.usize_in(0, n);
            let v = poisoned(g, n);
            let mut rng = crate::util::rng::Rng::new(g.rng.next_u64());
            let idx: Vec<u32> =
                rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
            let got = sq_norm_gather_lanes(&v, &idx);
            let mut acc = [0.0f64; LANES];
            for (j, &i) in idx.iter().enumerate() {
                let x = v[i as usize] as f64;
                acc[j % LANES] += x * x;
            }
            let want = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
                + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
            ensure(got.to_bits() == want.to_bits(), format!("n={n} k={k}"))
        });
    }

    /// Empty input is a hard edge for every kernel (chunks_exact(0) and
    /// the k_for(len=0) fix both land here).
    #[test]
    fn empty_inputs_are_well_defined() {
        let mut out = Vec::new();
        add_into(&[], &[], &mut out);
        assert!(out.is_empty());
        let (mut g_e, mut mag) = (vec![1.0f32], vec![1.0f32]);
        error_feed_abs_into(&[], &[], &mut g_e, &mut mag);
        assert!(g_e.is_empty() && mag.is_empty());
        axpy(&mut [], 2.0, &[]);
        scale(&mut [], 2.0);
        let mut pairs = vec![(1.0f32, 7u32)];
        abs_pairs_into(&[], &mut pairs);
        assert!(pairs.is_empty());
        scatter_zero(&mut [], &[]);
        scatter_add(&mut [], &[], &[]);
        assert_eq!(abs_max(&[]), 0.0);
        assert_eq!(threshold_count(&[], 0.0), 0);
        let mut filt = vec![(1.0f32, 7u32)];
        threshold_filter_into(&[], (0.5, 3), &mut filt);
        assert!(filt.is_empty());
        assert_eq!(sq_norm_lanes(&[]), 0.0);
        assert_eq!(dot_lanes(&[], &[]), 0.0);
        assert_eq!(sq_norm_gather_lanes(&[], &[]), 0.0);
    }

    /// Ties: equal magnitudes must survive/fall together with the index
    /// tiebreak, exactly as the comparator loop decided.
    #[test]
    fn threshold_filter_ties_resolved_by_index() {
        let g = [1.0f32, -1.0, 1.0, 0.5, 1.0];
        // Threshold at (1.0, idx 2): survivors are magnitude > 1.0 (none)
        // plus magnitude == 1.0 with index <= 2.
        let mut out = Vec::new();
        threshold_filter_into(&g, (1.0, 2), &mut out);
        assert_eq!(out, vec![(1.0, 0), (1.0, 1), (1.0, 2)]);
        assert_eq!(pair_bits(&out), pair_bits(&ref_threshold_filter(&g, (1.0, 2))));
    }

    #[test]
    fn lane_assignment_is_position_mod_lanes() {
        // Direct witness of the documented policy: moving one element to
        // a different position (different lane) changes nothing about the
        // total when values are equal, and the tail joins lanes 0..tail.
        let x = [2.0f32; 11]; // 8 + 3 tail: lanes 0..3 get two elements
        let want: f64 = 11.0 * 4.0;
        assert_eq!(sq_norm_lanes(&x), want);
        assert_eq!(ref_sq_norm_lanes(&x), want);
    }
}
