//! Network probing: the iperf/traceroute analogue.
//!
//! The paper runs a background process that measures bandwidth with iperf
//! and latency with traceroute, and triggers re-optimization when either
//! drifts past a threshold. The controller here likewise never reads the
//! environment's ground truth — it sees only noisy [`Probe`] observations
//! of whatever [`NetworkModel`] the run is configured with.

use crate::netsim::cost_model::LinkParams;
use crate::netsim::model::NetworkModel;
use crate::util::rng::Rng;

/// One observation of the link.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub epoch: f64,
    pub alpha_ms: f64,
    pub bw_gbps: f64,
}

impl Observation {
    pub fn link(&self) -> LinkParams {
        LinkParams::from_ms_gbps(self.alpha_ms, self.bw_gbps)
    }
}

/// Periodic prober with multiplicative observation noise and
/// relative-change detection. Reads conditions only through the
/// [`NetworkModel`] trait object, so it probes schedules, traces and
/// modifier compositions identically.
#[derive(Debug)]
pub struct Probe {
    net: Box<dyn NetworkModel>,
    noise_frac: f64,
    rng: Rng,
    last: Option<Observation>,
    /// Relative change in α or bandwidth that counts as "network changed".
    pub change_threshold: f64,
}

impl Probe {
    pub fn new(net: Box<dyn NetworkModel>, noise_frac: f64, seed: u64) -> Self {
        assert!((0.0..0.5).contains(&noise_frac));
        Probe {
            net,
            noise_frac,
            rng: Rng::new(seed),
            last: None,
            change_threshold: 0.2,
        }
    }

    /// Measure the link at `epoch` (noisy).
    pub fn measure(&mut self, epoch: f64) -> Observation {
        let truth = self.net.link_at(epoch);
        let na = 1.0 + self.noise_frac * (2.0 * self.rng.f64() - 1.0);
        let nb = 1.0 + self.noise_frac * (2.0 * self.rng.f64() - 1.0);
        Observation {
            epoch,
            alpha_ms: truth.alpha_ms() * na,
            bw_gbps: truth.bw_gbps() * nb,
        }
    }

    /// Measure and report whether the network changed materially since the
    /// last *accepted* observation (the paper's re-optimization trigger).
    pub fn measure_and_detect(&mut self, epoch: f64) -> (Observation, bool) {
        let obs = self.measure(epoch);
        let changed = match self.last {
            None => true,
            Some(prev) => {
                let da = rel_change(prev.alpha_ms, obs.alpha_ms);
                let db = rel_change(prev.bw_gbps, obs.bw_gbps);
                da > self.change_threshold || db > self.change_threshold
            }
        };
        if changed {
            self.last = Some(obs);
        }
        (obs, changed)
    }

    pub fn last(&self) -> Option<Observation> {
        self.last
    }
}

fn rel_change(old: f64, new: f64) -> f64 {
    if old == 0.0 {
        return if new == 0.0 { 0.0 } else { f64::INFINITY };
    }
    ((new - old) / old).abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::modifiers::Jitter;
    use crate::netsim::schedule::NetSchedule;

    #[test]
    fn noise_is_bounded() {
        let sched = NetSchedule::static_link(LinkParams::from_ms_gbps(10.0, 10.0));
        let mut p = Probe::new(Box::new(sched), 0.05, 1);
        for i in 0..100 {
            let o = p.measure(i as f64 * 0.1);
            assert!((o.alpha_ms - 10.0).abs() <= 0.5 + 1e-9);
            assert!((o.bw_gbps - 10.0).abs() <= 0.5 + 1e-9);
        }
    }

    #[test]
    fn detects_c1_phase_changes_and_not_noise() {
        let mut p = Probe::new(Box::new(NetSchedule::c1(50.0)), 0.02, 2);
        // First measurement always counts as a change (establishes baseline).
        let (_, first) = p.measure_and_detect(1.0);
        assert!(first);
        // Within a phase with small noise: no change events.
        let mut changes = 0;
        for i in 0..50 {
            let (_, ch) = p.measure_and_detect(2.0 + i as f64 * 0.1);
            changes += ch as u32;
        }
        assert_eq!(changes, 0);
        // Crossing epoch 12 (25 Gbps -> 1 Gbps) must trigger.
        let (_, ch) = p.measure_and_detect(13.0);
        assert!(ch);
    }

    #[test]
    fn deterministic_given_seed() {
        let s = Jitter::wrap(NetSchedule::c2(50.0), 0.05, 9).unwrap();
        let mut a = Probe::new(Box::new(s.clone()), 0.05, 42);
        let mut b = Probe::new(Box::new(s), 0.05, 42);
        for i in 0..20 {
            let (oa, ca) = a.measure_and_detect(i as f64);
            let (ob, cb) = b.measure_and_detect(i as f64);
            assert_eq!(oa.alpha_ms, ob.alpha_ms);
            assert_eq!(oa.bw_gbps, ob.bw_gbps);
            assert_eq!(ca, cb);
        }
    }
}
