//! flexlint — the repo's first-party invariant linter (DESIGN.md §13).
//!
//! Scans `rust/src/**` with the hand-rolled analyzer in
//! `flexcomm::analysis`, prints a human table, writes `LINT_REPORT.json`
//! and exits nonzero on any unsuppressed finding (the verify.sh gate).
//!
//! Exit codes: 0 clean, 1 findings, 2 configuration/self-test error.

use flexcomm::analysis::{self, report, Workspace, RULE_TABLE};
use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: flexlint [--root <dir>] [--rule <name>] [--report <path>] \
                     [--list] [--self-test]\n\
                     \n\
                     --root <dir>     scan root (default: rust/src)\n\
                     --rule <name>    run a single rule (see --list)\n\
                     --report <path>  JSON report path (default: LINT_REPORT.json)\n\
                     --list           print the rule registry and exit\n\
                     --self-test      run every rule's embedded fixtures and exit";

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut report_path = PathBuf::from("LINT_REPORT.json");
    let mut filter: Option<&'static str> = None;
    let mut list = false;
    let mut self_test = false;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage_error("--root needs a directory"),
            },
            "--report" => match args.next() {
                Some(v) => report_path = PathBuf::from(v),
                None => return usage_error("--report needs a path"),
            },
            "--rule" => match args.next() {
                Some(v) => match analysis::parse_rule_filter(&v) {
                    Ok(name) => filter = Some(name),
                    Err(e) => return usage_error(&e),
                },
                None => return usage_error("--rule needs a name"),
            },
            "--list" => list = true,
            "--self-test" => self_test = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => return usage_error(&format!("unknown argument `{other}`")),
        }
    }

    if list {
        print!("{}", report::rule_list());
        return ExitCode::SUCCESS;
    }
    if self_test {
        return run_self_test();
    }

    let ws = match Workspace::load(&root) {
        Ok(ws) => ws,
        Err(e) => {
            eprintln!("flexlint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let result = analysis::run(&ws, filter);
    if let Err(e) = report::write_report(&report_path, &ws, &result) {
        eprintln!("flexlint: cannot write {}: {e}", report_path.display());
        return ExitCode::from(2);
    }
    print!("{}", report::human_table(&ws, &result));
    if result.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage_error(msg: &str) -> ExitCode {
    eprintln!("flexlint: {msg}\n{USAGE}");
    ExitCode::from(2)
}

/// Exercise every RULE_TABLE fixture (the same contract the unit suite
/// pins): positive fires, negative is silent, suppression holds.
fn run_self_test() -> ExitCode {
    let mut failed = 0usize;
    for rule in RULE_TABLE {
        let fires = !analysis::run(&Workspace::fixture(rule.fires_on), Some(rule.name))
            .findings
            .is_empty();
        let clean = analysis::run(&Workspace::fixture(rule.clean_on), Some(rule.name))
            .findings
            .is_empty();
        let suppressed = rule.suppressed_on.map_or(true, |src| {
            let r = analysis::run(&Workspace::fixture(src), Some(rule.name));
            r.findings.is_empty() && r.suppressed >= 1
        });
        let ok = fires && clean && suppressed;
        println!(
            "{} {} (fires: {fires}, clean: {clean}, suppression: {suppressed})",
            if ok { "ok  " } else { "FAIL" },
            rule.name
        );
        if !ok {
            failed += 1;
        }
    }
    if failed == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}
