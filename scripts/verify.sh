#!/usr/bin/env bash
# flexcomm verify gate (DESIGN.md §6):
#   1. tier-1: release build, flexlint static-analysis gate (DESIGN.md §13),
#      then the full test suite (unit, integration, doctests)
#   2. smoke-mode hotpath bench: runs the threaded worker engine with
#      threads=1 and threads=N and hard-fails (assert inside the bench) if
#      the parallel grad+compress stage is not bitwise-identical to serial;
#      also prints the measured speedup (ISSUE 2 acceptance: >=1.5x on a
#      >=4-core host — informational here, CI hosts may have fewer cores)
#   3. rustfmt drift check
#   4. rustdoc with warnings denied — broken intra-doc links (the old
#      "DESIGN.md referenced but missing" class of rot) fail fast here
#
# Usage: scripts/verify.sh            (from the repo root)
#        FLEXCOMM_BENCH_FAST=1 is respected by the benches, not needed here.
set -uo pipefail
cd "$(dirname "$0")/.."

# Fail LOUDLY and EARLY when there is no toolchain: PR 1 shipped from a
# container without cargo and was therefore never compiled or tested
# ("desk-checked only"). Nothing below can stand in for a real run.
if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: FATAL: \`cargo\` not found on PATH." >&2
    echo "  The tier-1 gate is 'cargo build --release && cargo test -q';" >&2
    echo "  without a Rust toolchain NOTHING in this repo has been compiled" >&2
    echo "  or tested — do not treat a desk-check as verification." >&2
    echo "  Install a toolchain (https://rustup.rs) and re-run." >&2
    exit 2
fi

status=0
step() {
    echo
    echo "==> $*"
    if ! "$@"; then
        echo "FAILED: $*"
        status=1
    fi
}

step cargo build --release
# First-party static analysis (ISSUE 9, DESIGN.md §13): flexlint scans
# rust/src/** for determinism/billing/registry contract violations and
# exits nonzero on any unsuppressed finding. Runs BEFORE the test stages
# so a contract break is the first thing a red run shows. Same
# stale-record policy as the bench gates: a report left over from an
# earlier run must not mask a binary that silently stopped writing one.
rm -f LINT_REPORT.json
step cargo run --release --bin flexlint
if [ ! -f LINT_REPORT.json ]; then
    echo "verify: FATAL: LINT_REPORT.json not written by flexlint" >&2
    status=1
fi
step cargo run --release --bin flexlint -- --self-test
step cargo test -q
# Thread-matrix determinism (DESIGN.md §7): the persistent parked-worker
# pool must be bitwise invisible at every pool width. Run the determinism
# suite at the default test harness settings AND with the harness forced
# to 2 test threads (a cheap stand-in for a starved 2-core host, where
# parked workers share cores with the test harness itself) — the pool's
# outputs must not depend on how the OS schedules its workers.
step cargo test -q --test determinism
step cargo test -q --test determinism -- --test-threads=2
# Trace round-trip smoke (DESIGN.md §9): the example writes a 3-phase
# trace, loads it back and asserts `link_at` replays the written samples
# exactly, then replays the shipped measured trace
# (examples/traces/c2_measured.csv) and prints the scenario-registry
# sweep. Asserts inside the binary make failures exit nonzero.
step cargo run --release --example trace_replay
# Controller-sweep smoke (DESIGN.md §10): the comparison experiment at
# tiny step counts across ALL CONTROLLER_TABLE entries (static low/high,
# gravac, moo, any future registration). The example asserts row coverage
# and non-degenerate accuracy, so an unregistered or panicking controller
# fails this gate loudly.
step cargo run --release --example controller_compare -- --steps 24 --target 0.99
# Fleet-scenario controller sweep (ISSUE 7): every registered controller
# must also rank under the heterogeneous-fleet scenarios — per-worker
# compute tails (straggler), per-worker links (hetero) and membership
# churn with catch-up charges (churn). Same in-binary gate assertions.
step cargo run --release --example controller_compare -- \
    --net straggler,hetero,churn --steps 24 --target 0.99
# FleetSim smoke (ISSUE 7): price a 4096-worker heterogeneous fleet
# cost-only. The binary hard-asserts peak transient state stays O(n)
# (<= 2n + const f64 slots, independent of model size); additionally
# grep the printed bound here so a silently-removed assert fails loudly.
fleet_out=$(cargo run --release --quiet -- train --fleet-n 4096 --net hetero --steps 100) \
    || { echo "FAILED: fleet smoke run" >&2; status=1; }
echo "$fleet_out" | tail -n 5
if ! echo "$fleet_out" | grep -q "fleet state: peak .* f64 slots for n=4096 (O(n) bound 8256)"; then
    echo "verify: FATAL: fleet smoke did not report its O(n) state bound" >&2
    status=1
fi
# Benches are test = false (cargo test must not RUN them), so compile them
# explicitly — otherwise table2/table6/fig2/fig5 could bit-rot silently.
step cargo bench --no-run
rm -f BENCH_hotpath.json # a stale record must not mask a silent skip
step env FLEXCOMM_BENCH_FAST=1 cargo bench --bench hotpath
# The hotpath bench doubles as the perf-regression harness: it must leave
# a machine-readable record behind (spawn-vs-park, fresh-vs-arena, and the
# kernels stage — scalar reference vs chunked tensor::kernels primitive,
# hard bitwise assert inside the bench — all included). A missing file
# means the bench silently skipped its reporting — fail loudly, same
# policy as the missing-toolchain check.
if [ ! -f BENCH_hotpath.json ]; then
    echo "verify: FATAL: BENCH_hotpath.json not written by the hotpath bench" >&2
    status=1
fi
# Fleet scale-out record (ISSUE 7): the fig5 bench's second stage sweeps
# the cost model to 16384 workers under c1/c2/hetero and records the
# AG-vs-ART-Ring crossover N per scenario. Same missing-file policy.
rm -f BENCH_scaleout.json
step env FLEXCOMM_BENCH_FAST=1 cargo bench --bench fig5_scaleout
if [ ! -f BENCH_scaleout.json ]; then
    echo "verify: FATAL: BENCH_scaleout.json not written by the fig5 bench" >&2
    status=1
fi
# Sweep-server smoke (ISSUE 8, DESIGN.md §12): real learners x strategies
# x networks run as CONCURRENT sessions over one shared pool. --smoke
# enables the in-binary full-coverage gate (every grid cell produced a
# row, no error rows, every cell above its model's chance-accuracy
# floor), and the run must leave its machine-readable ranking behind.
rm -f BENCH_sweep.json # same stale-record policy as the bench gates
step cargo run --release --quiet -- sweep --smoke
if [ ! -f BENCH_sweep.json ]; then
    echo "verify: FATAL: BENCH_sweep.json not written by the sweep smoke" >&2
    status=1
fi
step cargo fmt --check
# Lint gate over every target (lib, bin, tests, benches, examples). Some
# minimal toolchains ship without the clippy component — that is a loud
# failure, not a skip, for the same reason as the missing-cargo check
# above: a gate that silently vanishes is worse than none.
if cargo clippy --version >/dev/null 2>&1; then
    step cargo clippy --all-targets -- -D warnings
else
    echo "verify: FATAL: cargo-clippy not installed (rustup component add clippy)" >&2
    status=1
fi
step env RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [ "$status" -ne 0 ]; then
    echo
    echo "verify: FAILED (see steps above)"
else
    echo
    echo "verify: OK"
fi
exit "$status"
