//! Synthetic workloads (DESIGN.md §3 substitutions).
//!
//! The paper trains vision models on CIFAR100/Food101/Caltech101/256; this
//! repo substitutes learnable synthetic tasks with the same *statistical*
//! roles: sharded per worker, optional non-i.i.d. skew (the federated
//! scenario of §4), deterministic per seed.

pub mod synth;

pub use synth::{ClusterDataset, MarkovCorpus};
