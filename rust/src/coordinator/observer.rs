//! Typed training-event stream — the observer seam of the Session API
//! (DESIGN.md §8).
//!
//! Consumers used to reach into the trainer's public fields
//! (`trainer.metrics`, `trainer.cur_cr`, `trainer.policy_switcher`) to see
//! what a run did; every new kind of instrumentation meant another public
//! field. [`TrainObserver`] replaces those reaches with a push stream of
//! typed events: per-step metrics, held-out evaluations, strategy switches
//! (collective OR selection-policy), adaptive-CR changes and ground-truth
//! network changes ([`NetChange`]). Observers are
//! registered on the [`SessionBuilder`](crate::coordinator::session::SessionBuilder)
//! and owned by the trainer for the life of the run; the canonical
//! [`MetricsLog`] recording always happens and comes back in the
//! [`TrainReport`](crate::coordinator::session::TrainReport).
//!
//! Shipped observers: [`MetricsLog`] (recorder — any observer-shaped
//! plumbing can embed one), [`CsvSink`] (streams rows to disk as they
//! happen, so a killed run still leaves a trace) and [`ProgressPrinter`]
//! (human-readable terminal lines).

use crate::coordinator::metrics::{MetricsLog, StepMetrics};
use crate::netsim::cost_model::LinkParams;
use anyhow::{Context, Result};
use std::io::Write;

/// One held-out evaluation record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalRecord {
    pub epoch: f64,
    pub loss: f64,
    /// Top-1 accuracy in [0, 1].
    pub accuracy: f64,
}

/// Which axis of the strategy switched (see [`StrategySwitch`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwitchDimension {
    /// The collective used for the exchange changed between recorded steps
    /// (the paper's Eqn 5 flexible switching, or a dense auto-selector
    /// crossing a crossover boundary).
    Collective,
    /// An AR-Topk auto strategy committed a STAR/VAR selection policy at
    /// the end of a trial cycle (§5 future work).
    SelectionPolicy,
}

impl SwitchDimension {
    pub fn name(&self) -> &'static str {
        match self {
            SwitchDimension::Collective => "collective",
            SwitchDimension::SelectionPolicy => "selection-policy",
        }
    }
}

/// A strategy-level decision change. `from == to` is possible for
/// [`SwitchDimension::SelectionPolicy`]: a trial cycle that re-commits the
/// incumbent policy is still an observable decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StrategySwitch {
    /// Recorded step at which the decision takes observable effect on the
    /// stream. Control decisions are made in the post-step control phase
    /// (DESIGN.md §10) and stamped with the committed step counter — a
    /// decision surrounding a checkpointed exploration is reported on the
    /// real timeline, never a rolled-back one.
    pub step: u64,
    pub dimension: SwitchDimension,
    pub from: &'static str,
    pub to: &'static str,
    /// Who decided: the [`Controller`](crate::coordinator::controller::Controller)
    /// name for control-plane decisions, the strategy name for per-step
    /// plan changes.
    pub by: &'static str,
    /// Short trigger tag (`"plan"`, `"trial"`, `"trial-commit"`, ...).
    pub reason: &'static str,
}

/// A controller decision that moved the compression ratio (e.g. the §3-E
/// MOO re-solve, or a GraVAC ladder step).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrChange {
    /// Step count AFTER the step that triggered the decision.
    pub step: u64,
    pub from: f64,
    pub to: f64,
    /// The deciding controller's name
    /// ([`Controller::name`](crate::coordinator::controller::Controller::name)).
    pub by: &'static str,
    /// Short trigger tag (`"warmup"`, `"gain-drift"`, `"net-change"`,
    /// `"ladder-descend"`, `"gain-collapse"`, ...).
    pub reason: &'static str,
}

/// The simulated network's TRUE inter-node link changed between recorded
/// steps: a schedule/trace phase boundary was crossed, or a stochastic
/// modifier (congestion episode, flap window, jitter bucket) fired. This
/// is ground truth — what the environment did, not what the noisy probe
/// saw — so CSV consumers can correlate strategy switches and CR changes
/// with the network events that caused them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetChange {
    /// Recorded step at which the new conditions first applied.
    pub step: u64,
    pub epoch: f64,
    pub from: LinkParams,
    pub to: LinkParams,
}

/// The simulated fleet's ACTIVE membership changed between recorded steps
/// (a [`Churn`](crate::netsim::modifiers::Churn) join/leave event fired).
/// Like [`NetChange`] this is ground truth about the environment; joins
/// additionally charge the scenario's declared catch-up cost
/// ([`NetworkModel::catchup_cost_at`](crate::netsim::model::NetworkModel::catchup_cost_at))
/// to the step that observes them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MembershipChange {
    /// Recorded step at which the new membership first applied.
    pub step: u64,
    pub epoch: f64,
    /// Active workers before the event.
    pub from: usize,
    /// Active workers after the event.
    pub to: usize,
}

/// Typed event stream over a training run.
///
/// All methods default to no-ops so observers implement only what they
/// need. Events fire for RECORDED steps only — the exploration harness's
/// checkpointed steps (DESIGN.md §10) are internal and rolled back, and
/// control decisions made around them are stamped with the committed step
/// counter. `on_strategy_switch` and `on_cr_change` events carry the
/// deciding controller's name and a trigger-reason tag, so sinks can
/// attribute every adaptation. `on_eval` fires for every held-out
/// evaluation including the final one.
pub trait TrainObserver: Send {
    /// A training step completed and was recorded.
    fn on_step(&mut self, _m: &StepMetrics) {}

    /// A held-out evaluation ran.
    fn on_eval(&mut self, _e: &EvalRecord) {}

    /// The strategy switched collective, or a controller switched the
    /// selection policy (the `by`/`reason` fields name the decider).
    fn on_strategy_switch(&mut self, _s: &StrategySwitch) {}

    /// A controller moved the compression ratio.
    fn on_cr_change(&mut self, _c: &CrChange) {}

    /// The TRUE network conditions changed since the previous recorded
    /// step (fires before that step's `on_step`).
    fn on_net_change(&mut self, _n: &NetChange) {}

    /// The fleet's active membership changed since the previous recorded
    /// step (fires before that step's `on_step`).
    fn on_membership_change(&mut self, _m: &MembershipChange) {}
}

/// The recorder: a [`MetricsLog`] is itself an observer, so custom
/// instrumentation can embed one and get the full summary/CSV machinery.
/// (The trainer always keeps its own canonical log — returned in the
/// [`TrainReport`](crate::coordinator::session::TrainReport) — so
/// registering a second recorder is only needed for bespoke plumbing.)
impl TrainObserver for MetricsLog {
    fn on_step(&mut self, m: &StepMetrics) {
        self.record(m.clone());
    }

    fn on_eval(&mut self, e: &EvalRecord) {
        self.record_eval(e.epoch, e.loss, e.accuracy);
    }
}

/// Streams step rows to a CSV file as they are recorded (same schema as
/// [`MetricsLog::to_csv`]), so an interrupted run still leaves data on
/// disk. Creation fails fast (missing directory is created, an unwritable
/// path errors at build time); later write failures disable the sink with
/// one stderr warning instead of poisoning the run.
pub struct CsvSink {
    path: String,
    out: std::io::BufWriter<std::fs::File>,
    failed: bool,
}

impl CsvSink {
    /// Open `path` (creating parent directories) and write the header.
    pub fn create(path: &str) -> Result<CsvSink> {
        Self::open(path, None)
    }

    /// Like [`CsvSink::create`], but first writes a `# net=<scenario>`
    /// comment line naming the network scenario
    /// ([`NetworkModel::describe`](crate::netsim::model::NetworkModel::describe)),
    /// so the file self-identifies which environment produced it.
    pub fn create_with_scenario(path: &str, scenario: &str) -> Result<CsvSink> {
        Self::open(path, Some(scenario))
    }

    fn open(path: &str, scenario: Option<&str>) -> Result<CsvSink> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating directory for {path}"))?;
            }
        }
        let file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
        let mut out = std::io::BufWriter::new(file);
        if let Some(s) = scenario {
            writeln!(out, "# net={s}").with_context(|| format!("writing header to {path}"))?;
        }
        writeln!(out, "{}", StepMetrics::CSV_HEADER)
            .with_context(|| format!("writing header to {path}"))?;
        Ok(CsvSink { path: path.to_string(), out, failed: false })
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    fn write_line(&mut self, line: &str) {
        if self.failed {
            return;
        }
        // Flush per row: the sink's whole point is that a killed run
        // (SIGKILL, Ctrl-C — no unwinding, Drop never runs) still leaves
        // its rows on disk. Steps are ms-scale; a row flush is noise.
        let res = writeln!(self.out, "{line}").and_then(|()| self.out.flush());
        if let Err(e) = res {
            eprintln!("CsvSink: writing {} failed ({e}); sink disabled", self.path);
            self.failed = true;
        }
    }
}

impl TrainObserver for CsvSink {
    fn on_step(&mut self, m: &StepMetrics) {
        self.write_line(&m.csv_row());
    }

    fn on_net_change(&mut self, n: &NetChange) {
        // Comment row between data rows: correlates the surrounding steps
        // with the ground-truth network event without breaking the schema.
        self.write_line(&format!(
            "# net_change step={} epoch={:.4} alpha_ms={:.3}->{:.3} bw_gbps={:.3}->{:.3}",
            n.step,
            n.epoch,
            n.from.alpha_ms(),
            n.to.alpha_ms(),
            n.from.bw_gbps(),
            n.to.bw_gbps()
        ));
    }

    fn on_membership_change(&mut self, m: &MembershipChange) {
        self.write_line(&format!(
            "# membership_change step={} epoch={:.4} active={}->{}",
            m.step, m.epoch, m.from, m.to
        ));
    }
}

impl Drop for CsvSink {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

/// Terminal progress lines: a step summary every `every` steps, plus every
/// eval, strategy switch and CR change as they happen.
pub struct ProgressPrinter {
    every: u64,
}

impl ProgressPrinter {
    /// Print a step line every `every` steps (clamped to >= 1).
    pub fn every(every: u64) -> Self {
        ProgressPrinter { every: every.max(1) }
    }
}

impl TrainObserver for ProgressPrinter {
    fn on_step(&mut self, m: &StepMetrics) {
        if m.step % self.every == 0 {
            println!(
                "step {:>6}  epoch {:>6.2}  loss {:>9.4}  t_step {:>8.2} ms  [{} cr {}]",
                m.step,
                m.epoch,
                m.loss,
                m.t_step() * 1e3,
                m.collective.name(),
                m.cr,
            );
        }
    }

    fn on_eval(&mut self, e: &EvalRecord) {
        println!(
            "eval   epoch {:>6.2}  loss {:>9.4}  acc {:.2}%",
            e.epoch,
            e.loss,
            e.accuracy * 100.0
        );
    }

    fn on_strategy_switch(&mut self, s: &StrategySwitch) {
        println!(
            "switch step {:>6}  {}: {} -> {}  [{} {}]",
            s.step,
            s.dimension.name(),
            s.from,
            s.to,
            s.by,
            s.reason
        );
    }

    fn on_cr_change(&mut self, c: &CrChange) {
        println!(
            "cr     step {:>6}  {:.5} -> {:.5}  [{} {}]",
            c.step, c.from, c.to, c.by, c.reason
        );
    }

    fn on_net_change(&mut self, n: &NetChange) {
        println!(
            "net    step {:>6}  alpha {:.2} -> {:.2} ms, bw {:.2} -> {:.2} Gbps",
            n.step,
            n.from.alpha_ms(),
            n.to.alpha_ms(),
            n.from.bw_gbps(),
            n.to.bw_gbps()
        );
    }

    fn on_membership_change(&mut self, m: &MembershipChange) {
        println!(
            "fleet  step {:>6}  active {} -> {}{}",
            m.step,
            m.from,
            m.to,
            if m.to > m.from { "  (join: catch-up charged)" } else { "" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveKind;

    fn m(step: u64) -> StepMetrics {
        StepMetrics {
            step,
            epoch: step as f64 / 10.0,
            loss: 0.5,
            t_compute: 0.01,
            t_comp: 0.001,
            t_sync: 0.02,
            collective: CollectiveKind::ArTopkRing,
            cr: 0.01,
            selected_rank: Some(1),
            gain: 0.9,
            alpha_ms: 4.0,
            bw_gbps: 20.0,
        }
    }

    #[test]
    fn metrics_log_records_as_observer() {
        let mut log = MetricsLog::default();
        let obs: &mut dyn TrainObserver = &mut log;
        obs.on_step(&m(0));
        obs.on_step(&m(1));
        obs.on_eval(&EvalRecord { epoch: 0.2, loss: 0.4, accuracy: 0.8 });
        assert_eq!(log.steps.len(), 2);
        assert_eq!(log.final_accuracy(), Some(0.8));
    }

    #[test]
    fn csv_sink_streams_rows() {
        let path = std::env::temp_dir().join("flexcomm_csv_sink_test.csv");
        let path = path.to_str().unwrap().to_string();
        {
            let mut sink = CsvSink::create(&path).unwrap();
            sink.on_step(&m(0));
            sink.on_step(&m(1));
        } // drop flushes
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(StepMetrics::CSV_HEADER));
        assert_eq!(text.lines().count(), 3, "{text}");
        assert!(text.contains("ART-Ring"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_sink_tags_scenario_and_net_changes() {
        let path = std::env::temp_dir().join("flexcomm_csv_sink_scenario.csv");
        let path = path.to_str().unwrap().to_string();
        {
            let mut sink = CsvSink::create_with_scenario(&path, "c2+jitter(0.15)").unwrap();
            sink.on_step(&m(0));
            sink.on_net_change(&NetChange {
                step: 1,
                epoch: 0.1,
                from: LinkParams::from_ms_gbps(1.0, 25.0),
                to: LinkParams::from_ms_gbps(50.0, 1.0),
            });
            sink.on_step(&m(1));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("# net=c2+jitter(0.15)\n"), "{text}");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[1], StepMetrics::CSV_HEADER);
        assert!(lines[3].starts_with("# net_change step=1"), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_sink_errors_on_unwritable_path() {
        // Parent "directory" is a regular file -> creation must error.
        let blocker = std::env::temp_dir().join("flexcomm_csv_sink_blocker");
        std::fs::write(&blocker, b"not a directory").unwrap();
        let bad = blocker.join("x.csv");
        assert!(CsvSink::create(bad.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(&blocker);
    }

    #[test]
    fn csv_sink_tags_membership_changes() {
        let path = std::env::temp_dir().join("flexcomm_csv_sink_membership.csv");
        let path = path.to_str().unwrap().to_string();
        {
            let mut sink = CsvSink::create(&path).unwrap();
            sink.on_step(&m(0));
            sink.on_membership_change(&MembershipChange {
                step: 1,
                epoch: 0.1,
                from: 1024,
                to: 768,
            });
            sink.on_step(&m(1));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(
            lines[2].starts_with("# membership_change step=1") && lines[2].contains("1024->768"),
            "{text}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn switch_dimension_names() {
        assert_eq!(SwitchDimension::Collective.name(), "collective");
        assert_eq!(SwitchDimension::SelectionPolicy.name(), "selection-policy");
    }
}
