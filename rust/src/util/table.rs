//! Aligned plain-text table printer for the experiment harnesses.
//!
//! Every table/figure harness prints rows in the same layout as the paper's
//! tables so paper-vs-measured comparison is a visual diff.

/// Column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let sep = {
            let mut s = String::from("|");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV form (for EXPERIMENTS.md-recorded artifacts).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds as adaptive ms/s string (paper tables are in ms).
pub fn fmt_ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

/// Format a fraction as a percentage.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.2}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["model", "t_step (ms)", "acc"]);
        t.row(["ResNet18", "98.7", "90.8%"]);
        t.row(["ViT", "475", "80.4%"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w), "{s}");
        assert!(s.contains("ResNet18"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        Table::new(["a", "b"]).row(["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(["k", "v"]);
        t.row(["a,b", "q\"q"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(0.0987), "98.70");
        assert_eq!(fmt_pct(0.908), "90.80%");
    }
}
