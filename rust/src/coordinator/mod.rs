//! L3 coordinator: the synchronous data-parallel training loop, the
//! Session API (builder-validated configs, pluggable communication
//! strategies, typed observer stream — DESIGN.md §8), collective selection
//! (Eqn 5), and the MOO-adaptive compression controller (§3-E).

pub mod adaptive;
pub mod checkpoint;
pub mod metrics;
pub mod observer;
pub mod policy_switch;
pub mod selector;
pub mod session;
pub mod strategy;
pub mod trainer;
pub mod worker;

pub use adaptive::AdaptiveConfig;
pub use metrics::{MetricsLog, StepMetrics};
pub use observer::{
    CrChange, CsvSink, EvalRecord, NetChange, ProgressPrinter, StrategySwitch,
    SwitchDimension, TrainObserver,
};
pub use session::{ConfigError, Session, SessionBuilder, TrainReport};
pub use strategy::{CommPlan, CommStrategy, ExchangeCtx, ExchangeOutcome, StepCtx};
pub use trainer::{Strategy, TrainConfig, Trainer};
pub use worker::{ComputeModel, GradSource};
