//! # flexlint — first-party invariant lints (DESIGN.md §13)
//!
//! A hand-rolled static-analysis pass over `rust/src/**` that turns this
//! repo's determinism, billing and registry conventions into a machine
//! gate (`cargo run --release --bin flexlint`, a verify.sh stage). No
//! `syn`, no dylint: the scanner is a length-preserving comment/string
//! stripper plus brace-matched `fn` spans ([`scan`]), and every rule is a
//! pure text check over that model ([`rules`]).
//!
//! The registry mirrors `STRATEGY_TABLE`/`NET_TABLE` style: [`RULE_TABLE`]
//! is the single source of truth — the CLI `--rule` filter, `--list`
//! output, the fixture suite and the suppression validator all read from
//! it, so adding a rule is one new row (name, docs line, three embedded
//! fixtures, check fn).
//!
//! ## Suppression
//!
//! An allow annotation — a line comment of `allow(<rule>): <reason>`
//! prefixed with the `flexlint::` marker — on the finding's line or the
//! line above suppresses that rule there; the `allow-file(<rule>):
//! <reason>` form at any line suppresses the rule for the whole file. The
//! reason is mandatory and the rule name must exist — a bare or
//! misspelled allow is itself a finding (`malformed-allow`), and that
//! rule cannot be suppressed, so the audit trail cannot rot silently.
//! Unused allows are tolerated (a fixed site may keep its annotation one
//! PR longer); block comments cannot carry allows (scanner limitation,
//! see [`scan`]).

pub mod report;
pub mod rules;
pub mod scan;

use scan::SourceFile;
use std::fs;
use std::io;
use std::path::Path;

/// One raw lint hit. `line` is 1-indexed; `excerpt` is the trimmed source
/// line for the human table.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub message: String,
}

/// One row of [`RULE_TABLE`].
pub struct RuleEntry {
    pub name: &'static str,
    /// One-line docs (shown by `--list` and in LINT_REPORT.json).
    pub summary: &'static str,
    /// Embedded fixture that MUST fire the rule (exercised by the fixture
    /// suite and by `flexlint --self-test`).
    pub fires_on: &'static str,
    /// Embedded fixture that must stay silent.
    pub clean_on: &'static str,
    /// Positive fixture plus an allow annotation that must suppress it;
    /// `None` only for rules that are unsuppressable by design.
    pub suppressed_on: Option<&'static str>,
    pub check: fn(&Workspace) -> Vec<Finding>,
}

/// The rule registry. Order is the report order.
pub const RULE_TABLE: &[RuleEntry] = &[
    RuleEntry {
        name: "nan-partial-cmp",
        summary: "float comparator via partial_cmp().unwrap()/expect()/unwrap_or(Equal) — \
                  use tensor::nan_min_cmp / nan_min_cmp_f32 (PR 2 NaN-panic class)",
        fires_on: r#"
fn rank(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#,
        clean_on: r#"
fn rank(v: &mut Vec<f64>) {
    v.sort_by(|a, b| crate::tensor::nan_min_cmp(*a, *b));
    let handled = 1.0_f64.partial_cmp(&2.0);
    let _ = handled.unwrap_or(std::cmp::Ordering::Less);
}
"#,
        suppressed_on: Some(
            r#"
fn rank(v: &mut Vec<f64>) {
    // flexlint::allow(nan-partial-cmp): inputs pre-validated finite by the caller
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#,
        ),
        check: rules::nan_partial_cmp,
    },
    RuleEntry {
        name: "unsanctioned-clock",
        summary: "Instant::now() outside the billing-sanctioned hot paths — breaks the \
                  DESIGN §7 t_comp contract (time is measured inside pool tasks)",
        fires_on: r#"
fn time_it() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
"#,
        clean_on: r#"
fn advance(clock: &mut f64, dt: f64) {
    *clock += dt.max(0.0);
}
"#,
        suppressed_on: Some(
            r#"
// flexlint::allow-file(unsanctioned-clock): fixture models a billed hot path
fn time_it() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
"#,
        ),
        check: rules::unsanctioned_clock,
    },
    RuleEntry {
        name: "shared-rng",
        summary: "shared/stateful or non-worker-keyed rng draw in a per-worker fn — \
                  order-dependent randomness broke §7 thread-invariance (PR 7 jitter bug)",
        fires_on: r#"
impl Trainer {
    fn grad(&mut self, worker: usize) -> f64 {
        let r = Rng::new(42);
        self.rng = self.rng.wrapping_add(1);
        r.next_f64() + worker as f64
    }
}
"#,
        clean_on: r#"
fn grad(seed: u64, worker: usize) -> f64 {
    let mut r = Rng::new(seed ^ (worker as u64 + 1).wrapping_mul(0x9E37));
    r.next_f64()
}
"#,
        suppressed_on: Some(
            r#"
impl Trainer {
    fn grad(&mut self, worker: usize) -> f64 {
        // flexlint::allow(shared-rng): single-worker probe path, draw order audited
        self.rng = self.rng.wrapping_add(worker as u64);
        0.0
    }
}
"#,
        ),
        check: rules::shared_rng,
    },
    RuleEntry {
        name: "registry-coverage",
        summary: "config-surface enum variant missing from its registry table, or a \
                  duplicate registry name (PR 5 review drift class)",
        fires_on: r#"
enum FixtureKind { Alpha, Beta, Gamma }
const FIXTURE_TABLE: &[(&str, FixtureKind)] = &[
    ("alpha", FixtureKind::Alpha),
    ("alpha", FixtureKind::Beta),
];
"#,
        clean_on: r#"
enum FixtureKind { Alpha, Beta }
const FIXTURE_TABLE: &[(&str, FixtureKind)] = &[
    ("alpha", FixtureKind::Alpha),
    ("beta", FixtureKind::Beta),
];
"#,
        suppressed_on: Some(
            r#"
enum FixtureKind {
    Alpha,
    // flexlint::allow(registry-coverage): staged variant, table row lands next PR
    Gamma,
}
const FIXTURE_TABLE: &[(&str, FixtureKind)] = &[("alpha", FixtureKind::Alpha)];
"#,
        ),
        check: rules::registry_coverage,
    },
    RuleEntry {
        name: "release-silent-assert",
        summary: "debug_assert! guarding an ordering invariant with no release-path \
                  fallback — release runs the arithmetic on garbage (VirtualClock class)",
        fires_on: r#"
fn ceil_log2(n: usize) -> u32 {
    debug_assert!(n >= 1);
    usize::BITS - (n - 1).leading_zeros()
}
"#,
        clean_on: r#"
fn advance(now: f64, t: f64) -> f64 {
    debug_assert!(t >= now);
    now + (t - now).max(0.0)
}
"#,
        suppressed_on: Some(
            r#"
fn below(n: u64) -> u64 {
    // flexlint::allow(release-silent-assert): release still panics loudly (mod by zero)
    debug_assert!(n > 0);
    n.wrapping_neg() % n
}
"#,
        ),
        check: rules::release_silent_assert,
    },
    RuleEntry {
        name: "take-without-putback",
        summary: "mem::take (or swap-with-empty) on an arena lane with no restore in the \
                  same fn — the lane is left empty and reallocates (PR 6 AG-lane hazard)",
        fires_on: r#"
fn drain(bufs: &mut Vec<Vec<f32>>) -> usize {
    let lane = std::mem::take(&mut bufs[0]);
    lane.len()
}
"#,
        clean_on: r#"
fn reuse(bufs: &mut Vec<Vec<f32>>) {
    let mut lane = std::mem::take(&mut bufs[0]);
    lane.push(1.0);
    bufs[0] = lane;
}
"#,
        suppressed_on: Some(
            r#"
fn hand_off(bufs: &mut Vec<Vec<f32>>) -> Vec<f32> {
    // flexlint::allow(take-without-putback): ownership moves to the caller by design
    std::mem::take(&mut bufs[0])
}
"#,
        ),
        check: rules::take_without_putback,
    },
    RuleEntry {
        name: "hot-loop-outside-kernels",
        summary: "scalar .map(..).sum() reduction or manual index-zeroing store in an \
                  audited hot file (compress/, tensor/, artopk.rs) bypassing \
                  tensor::kernels — the chunked kernel layer is the hot-path contract",
        fires_on: r#"
fn gain_denominator(g: &[f32]) -> f64 {
    g.iter().map(|&v| (v as f64) * (v as f64)).sum()
}
fn zero_sent(g_e: &mut [f32], idx: &[u32]) {
    for &i in idx {
        g_e[i as usize] = 0.0;
    }
}
"#,
        clean_on: r#"
fn gain_denominator(g: &[f32]) -> f64 {
    crate::tensor::kernels::sq_norm_lanes(g)
}
fn zero_sent(g_e: &mut [f32], idx: &[u32]) {
    crate::tensor::kernels::scatter_zero(g_e, idx);
}
fn labels(names: &[&str]) -> Vec<String> {
    names.iter().map(|n| n.to_uppercase()).collect()
}
"#,
        suppressed_on: Some(
            r#"
fn reference_sq_norm(g: &[f32]) -> f64 {
    // flexlint::allow(hot-loop-outside-kernels): verbatim scalar reference for the bitwise pin test
    g.iter().map(|&v| (v as f64) * (v as f64)).sum()
}
"#,
        ),
        check: rules::hot_loop_outside_kernels,
    },
    RuleEntry {
        name: "malformed-allow",
        summary: "flexlint::allow with no (rule), an unknown rule name, or no `: reason` — \
                  suppressions are audited and cannot rot (this rule is unsuppressable)",
        fires_on: r#"
fn noop() {
    // flexlint::allow(nan-partial-cmp)
    let _x = 1;
}
"#,
        clean_on: r#"
fn noop() {
    // flexlint::allow(take-without-putback): audited, the caller restores the lane
    let _x = 1;
}
"#,
        suppressed_on: None,
        check: rules::malformed_allow,
    },
];

/// Iterator over registered rule names (report order).
pub fn rule_names() -> impl Iterator<Item = &'static str> {
    RULE_TABLE.iter().map(|r| r.name)
}

/// Resolve a `--rule` CLI argument against [`RULE_TABLE`].
pub fn parse_rule_filter(name: &str) -> Result<&'static str, String> {
    rule_names().find(|n| *n == name).ok_or_else(|| {
        format!(
            "unknown rule `{name}` (valid: {})",
            rule_names().collect::<Vec<_>>().join(", ")
        )
    })
}

// ---------------------------------------------------------------------------
// Registry bindings: which enums must be covered by which tables.
// ---------------------------------------------------------------------------

/// How an enum's variants are proven reachable.
pub enum Coverage {
    /// `Enum::Variant` must appear inside the `[...]` initializer of the
    /// named `const`/`static` in the named file.
    TableSpan { table: &'static str, file: &'static str },
    /// `Enum::Variant` must appear in the body of SOME fn with one of
    /// these names, anywhere in the workspace (e.g. the `kind()` impls).
    FnBodies { fns: &'static [&'static str] },
}

pub struct EnumBinding {
    pub enum_name: &'static str,
    /// File (relative to the scan root) declaring the enum.
    pub enum_file: &'static str,
    pub coverage: Coverage,
    /// Variants exempt from coverage (e.g. `Custom` escape hatches).
    pub exempt: &'static [&'static str],
}

/// A string-keyed registry table whose names must be unique.
pub struct NameTable {
    pub table: &'static str,
    pub file: &'static str,
}

pub struct Bindings {
    pub enums: &'static [EnumBinding],
    pub tables: &'static [NameTable],
}

/// The real tree's bindings (used by `Workspace::load`).
pub const REGISTRY_BINDINGS: Bindings = Bindings {
    enums: &[
        EnumBinding {
            enum_name: "Strategy",
            enum_file: "coordinator/trainer.rs",
            coverage: Coverage::TableSpan {
                table: "STRATEGY_TABLE",
                file: "coordinator/strategy.rs",
            },
            exempt: &[],
        },
        EnumBinding {
            enum_name: "DenseFlavor",
            enum_file: "coordinator/trainer.rs",
            coverage: Coverage::TableSpan {
                table: "STRATEGY_TABLE",
                file: "coordinator/strategy.rs",
            },
            exempt: &[],
        },
        EnumBinding {
            enum_name: "CompressorKind",
            enum_file: "compress/mod.rs",
            coverage: Coverage::TableSpan {
                table: "STRATEGY_TABLE",
                file: "coordinator/strategy.rs",
            },
            exempt: &[],
        },
        EnumBinding {
            enum_name: "SelectionPolicy",
            enum_file: "artopk.rs",
            coverage: Coverage::TableSpan {
                table: "STRATEGY_TABLE",
                file: "coordinator/strategy.rs",
            },
            exempt: &[],
        },
        EnumBinding {
            enum_name: "ArFlavor",
            enum_file: "artopk.rs",
            coverage: Coverage::TableSpan {
                table: "STRATEGY_TABLE",
                file: "coordinator/strategy.rs",
            },
            exempt: &[],
        },
        EnumBinding {
            enum_name: "CollectiveKind",
            enum_file: "collectives/mod.rs",
            coverage: Coverage::FnBodies { fns: &["kind"] },
            exempt: &["Custom"],
        },
    ],
    tables: &[
        NameTable { table: "STRATEGY_TABLE", file: "coordinator/strategy.rs" },
        NameTable { table: "NET_TABLE", file: "netsim/model.rs" },
        NameTable { table: "CONTROLLER_TABLE", file: "coordinator/controller/mod.rs" },
        NameTable { table: "MODEL_TABLE", file: "models/mod.rs" },
    ],
};

/// Bindings for single-file fixture workspaces (`Workspace::fixture`):
/// the registry rule reads `enum FixtureKind` / `FIXTURE_TABLE` from the
/// synthetic `fixture.rs`.
pub const FIXTURE_BINDINGS: Bindings = Bindings {
    enums: &[EnumBinding {
        enum_name: "FixtureKind",
        enum_file: "fixture.rs",
        coverage: Coverage::TableSpan { table: "FIXTURE_TABLE", file: "fixture.rs" },
        exempt: &[],
    }],
    tables: &[NameTable { table: "FIXTURE_TABLE", file: "fixture.rs" }],
};

// ---------------------------------------------------------------------------
// Workspace + driver.
// ---------------------------------------------------------------------------

/// The parsed scan set: every `.rs` file under the root (sorted by path
/// for deterministic output) plus the registry bindings in force.
pub struct Workspace {
    pub files: Vec<SourceFile>,
    pub bindings: Bindings,
}

impl Workspace {
    /// Parse every `.rs` file under `root` with the real-tree bindings.
    pub fn load(root: &Path) -> io::Result<Workspace> {
        let mut rels = Vec::new();
        walk(root, root, &mut rels)?;
        rels.sort();
        let mut files = Vec::with_capacity(rels.len());
        for rel in &rels {
            let raw = fs::read_to_string(root.join(rel))?;
            files.push(SourceFile::parse(rel, &raw));
        }
        Ok(Workspace { files, bindings: REGISTRY_BINDINGS })
    }

    /// One synthetic `fixture.rs` with [`FIXTURE_BINDINGS`] (tests).
    pub fn fixture(src: &str) -> Workspace {
        Workspace {
            files: vec![SourceFile::parse("fixture.rs", src)],
            bindings: FIXTURE_BINDINGS,
        }
    }

    /// Look up a file by its root-relative path.
    pub fn file(&self, rel: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.rel == rel)
    }
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            let rel = path
                .strip_prefix(root)
                .expect("walk stays under root")
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// One lint run's outcome.
pub struct RunResult {
    /// Unsuppressed findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings silenced by a well-formed allow.
    pub suppressed: usize,
    /// Rules actually executed (respects the `--rule` filter).
    pub rules_run: Vec<&'static str>,
}

/// Run every rule (or just `filter`) over the workspace and apply the
/// suppression policy: a finding is silenced by a well-formed allow for
/// ITS rule on its line, the line above, or anywhere file-level.
/// `malformed-allow` findings are never suppressable.
pub fn run(ws: &Workspace, filter: Option<&str>) -> RunResult {
    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    let mut rules_run = Vec::new();
    for rule in RULE_TABLE {
        if let Some(f) = filter {
            if rule.name != f {
                continue;
            }
        }
        rules_run.push(rule.name);
        for finding in (rule.check)(ws) {
            if is_suppressed(ws, &finding) {
                suppressed += 1;
            } else {
                findings.push(finding);
            }
        }
    }
    findings.sort_by(|a, b| {
        a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
    RunResult { findings, suppressed, rules_run }
}

fn is_suppressed(ws: &Workspace, f: &Finding) -> bool {
    if f.rule == "malformed-allow" {
        return false;
    }
    let Some(file) = ws.file(&f.file) else { return false };
    file.allows.iter().any(|a| {
        a.rule == f.rule
            && a.reason.is_some()
            && (a.file_level || a.line == f.line || a.line + 1 == f.line)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_rule_fires_stays_silent_and_honors_suppression() {
        for rule in RULE_TABLE {
            let ws = Workspace::fixture(rule.fires_on);
            let r = run(&ws, Some(rule.name));
            assert!(!r.findings.is_empty(), "{}: positive fixture must fire", rule.name);
            assert!(
                r.findings.iter().all(|f| f.rule == rule.name),
                "{}: filtered run leaked findings from other rules",
                rule.name
            );

            let ws = Workspace::fixture(rule.clean_on);
            let r = run(&ws, Some(rule.name));
            assert!(
                r.findings.is_empty(),
                "{}: negative fixture fired: {:?}",
                rule.name,
                r.findings
                    .iter()
                    .map(|f| format!("{}:{} {}", f.file, f.line, f.message))
                    .collect::<Vec<_>>()
            );

            if let Some(src) = rule.suppressed_on {
                let ws = Workspace::fixture(src);
                let r = run(&ws, Some(rule.name));
                assert!(
                    r.findings.is_empty(),
                    "{}: suppression fixture still fired",
                    rule.name
                );
                assert!(r.suppressed >= 1, "{}: nothing was suppressed", rule.name);
            }
        }
    }

    #[test]
    fn rule_registry_is_complete_unique_and_cli_reachable() {
        assert!(RULE_TABLE.len() >= 6, "the issue mandates >= 6 rules");
        for rule in RULE_TABLE {
            assert!(!rule.summary.trim().is_empty(), "{}: docs line missing", rule.name);
            assert!(
                rule.name.chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "{}: rule names are kebab-case",
                rule.name
            );
            assert_eq!(parse_rule_filter(rule.name), Ok(rule.name));
        }
        let mut names: Vec<_> = rule_names().collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), RULE_TABLE.len(), "duplicate rule name");
        assert!(parse_rule_filter("no-such-rule").is_err());
    }

    #[test]
    fn malformed_allow_cannot_be_suppressed() {
        let src = "// flexlint::allow(malformed-allow): trying to silence the auditor\n\
                   // flexlint::allow(nan-partial-cmp)\n\
                   fn f() {}\n";
        let ws = Workspace::fixture(src);
        let r = run(&ws, Some("malformed-allow"));
        assert_eq!(r.findings.len(), 1, "the bare allow on line 2 must survive");
        assert_eq!(r.findings[0].line, 2);
        assert_eq!(r.suppressed, 0);
    }

    #[test]
    fn unknown_rule_allow_is_flagged_and_never_suppresses() {
        let src = "fn f(v: &mut Vec<f64>) {\n    \
                   // flexlint::allow(nan-partialcmp): typo in the rule name\n    \
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let ws = Workspace::fixture(src);
        let r = run(&ws, None);
        assert!(r.findings.iter().any(|f| f.rule == "nan-partial-cmp"));
        assert!(r.findings.iter().any(|f| f.rule == "malformed-allow"));
    }

    #[test]
    fn disguised_swap_take_flagged_but_live_swap_clean() {
        let bad = "fn f(bufs: &mut Vec<Vec<f32>>) {\n    \
                   std::mem::swap(&mut bufs[0], &mut Vec::new());\n}\n";
        let r = run(&Workspace::fixture(bad), Some("take-without-putback"));
        assert_eq!(r.findings.len(), 1);

        let ok = "fn g(a: &mut Vec<f32>, b: &mut Vec<f32>) {\n    \
                  std::mem::swap(a, b);\n}\n";
        let r = run(&Workspace::fixture(ok), Some("take-without-putback"));
        assert!(r.findings.is_empty(), "swap of two live places is self-restoring");
    }

    #[test]
    fn file_level_allow_covers_every_site_in_the_file() {
        let src = "// flexlint::allow-file(unsanctioned-clock): whole module is billed\n\
                   fn a() { let _ = std::time::Instant::now(); }\n\
                   fn b() { let _ = std::time::Instant::now(); }\n";
        let ws = Workspace::fixture(src);
        let r = run(&ws, Some("unsanctioned-clock"));
        assert!(r.findings.is_empty());
        assert_eq!(r.suppressed, 2);
    }

    #[test]
    fn registry_rule_reports_missing_variant_and_duplicate_name_lines() {
        let fires = RULE_TABLE
            .iter()
            .find(|r| r.name == "registry-coverage")
            .unwrap()
            .fires_on;
        let r = run(&Workspace::fixture(fires), Some("registry-coverage"));
        assert!(
            r.findings.iter().any(|f| f.message.contains("FixtureKind::Gamma")),
            "missing variant not reported: {:?}",
            r.findings.iter().map(|f| &f.message).collect::<Vec<_>>()
        );
        assert!(r.findings.iter().any(|f| f.message.contains("duplicate registry name")));
    }
}
