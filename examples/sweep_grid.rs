//! The sweep server's acceptance grid (ISSUE 8): 2 real learners x 3
//! strategies x 3 network scenarios x 2 controllers = 36 cells, all run
//! CONCURRENTLY over one shared persistent worker pool with a bounded
//! in-flight window, then ranked by simulated time-to-target-accuracy.
//!
//!     cargo run --release --example sweep_grid -- \
//!         [--steps 200] [--in-flight 6] [--threads 0] [--target 0.6]
//!
//! Every cell must produce a row (build rejections would surface as error
//! rows and fail the assertions below), and recorded metrics are bitwise
//! identical for ANY `--threads` / `--in-flight` — concurrency moves
//! wall-clock time, never results.

use anyhow::{ensure, Result};
use flexcomm::coordinator::sweep::SweepSpec;
use flexcomm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let spec = SweepSpec {
        models: vec!["mlp".into(), "matreg".into()],
        strategies: vec!["ag-topk".into(), "artopk-star".into(), "flexible".into()],
        nets: vec!["c1".into(), "c2".into(), "flaky".into()],
        controllers: vec!["static".into(), "gravac".into()],
        steps: args.u64_or("steps", 200)?,
        steps_per_epoch: args.u64_or("steps-per-epoch", 50)?,
        eval_every: args.u64_or("eval-every", 50)?,
        seed: args.u64_or("seed", 7)?,
        threads: args.usize_or("threads", 0)?,
        in_flight: args.usize_or("in-flight", 6)?,
        target_acc: args.f64_or("target", 0.6)?,
        ..SweepSpec::default()
    };
    let cells = spec.expand().len();
    println!(
        "sweep grid: {} models x {} strategies x {} nets x {} controllers = {cells} cells",
        spec.models.len(),
        spec.strategies.len(),
        spec.nets.len(),
        spec.controllers.len()
    );
    let report = spec.run()?;
    report.print_ranked();

    // Gate assertions: the ranked table is COMPLETE — every grid cell has
    // a row, no cell failed to build or run, every cell trained.
    ensure!(report.rows.len() == cells, "rows {} != cells {cells}", report.rows.len());
    ensure!(report.failed_cells() == 0, "{} cells failed", report.failed_cells());
    for r in &report.rows {
        ensure!(
            r.best_acc.is_finite() && r.best_acc > 0.0,
            "{}: degenerate accuracy {}",
            r.cell.id(),
            r.best_acc
        );
        ensure!(r.virtual_time_s > 0.0, "{}: no simulated time", r.cell.id());
    }
    let reached = report.rows.iter().filter(|r| r.time_to_target_s.is_some()).count();
    println!("sweep grid: {cells} cells OK, {reached} reached target {}", report.target_acc);
    Ok(())
}
