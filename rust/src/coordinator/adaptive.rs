//! MOO-adaptive compression controller (§3-E).
//!
//! Triggers, exactly as the paper specifies:
//! * **gain drift** ≥ `gain_threshold` (10%) — re-profile the candidate CR
//!   ladder: checkpoint, run each candidate for `probe_iters` steps
//!   recording (t_comp, t_sync, gain), restore, rebuild the MOO problem,
//!   solve (NSGA-II) for the knee-point `c_optimal`;
//! * **network change** (probe detects α or bandwidth drift) — keep the
//!   measured gain/comp profiles but re-predict each candidate's `t_sync`
//!   from the α-β cost model at the new link, re-solve.
//!
//! Exploration happens entirely under in-memory checkpoint/restore so
//! candidate CRs never damage the model (the paper's checkpoint-restore in
//! system memory). Its simulated time is accounted in
//! [`Trainer::explore_overhead_s`].

use crate::coordinator::selector;
use crate::coordinator::trainer::Trainer;
use crate::moo::problem::{candidate_crs, CandidateProfile, CrProblem};
use crate::netsim::cost_model::LinkParams;

/// Adaptive-CR configuration (defaults = the paper's §3-E1 values).
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    pub c_low: f64,
    pub c_high: f64,
    /// Geometric step between candidate CRs.
    pub factor: f64,
    /// Iterations each candidate runs during exploration.
    pub probe_iters: u64,
    /// Relative gain-drift trigger (0.1 = 10%).
    pub gain_threshold: f64,
    /// NSGA-II seed.
    pub seed: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            c_low: 0.001,
            c_high: 0.1,
            factor: 3.0,
            probe_iters: 10,
            gain_threshold: 0.1,
            seed: 0,
        }
    }
}

/// Controller state carried by the trainer.
#[derive(Debug)]
pub struct AdaptiveState {
    pub cfg: AdaptiveConfig,
    /// Last measured candidate profiles (refreshed on gain triggers).
    profiles: Option<Vec<CandidateProfile>>,
    /// How many explorations ran (observability/tests).
    pub explorations: u64,
    /// How many re-solves ran (gain + network triggers).
    pub resolves: u64,
}

impl AdaptiveState {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        AdaptiveState { cfg, profiles: None, explorations: 0, resolves: 0 }
    }

    /// Entry point called by the trainer after every recorded step.
    pub fn maybe_adapt(
        &mut self,
        t: &mut Trainer,
        net_changed: bool,
        gain_fired: bool,
        probed: LinkParams,
    ) {
        let need_explore = self.profiles.is_none() || gain_fired;
        if !(need_explore || net_changed) {
            return;
        }
        if need_explore {
            self.profiles = Some(self.explore(t, probed));
            self.explorations += 1;
            t.gain_tracker.rearm();
        } else if let Some(profiles) = &mut self.profiles {
            // Network changed: re-predict t_sync at the new link only.
            for p in profiles.iter_mut() {
                p.t_sync = selector::choose(probed, t.model_bytes(), t.cfg.n_workers, p.cr)
                    .predicted_s;
            }
        }
        let profiles = self.profiles.as_ref().expect("profiles set");
        let c_opt = CrProblem::new(profiles.clone()).solve(self.cfg.seed);
        t.cur_cr = c_opt.clamp(self.cfg.c_low, self.cfg.c_high);
        self.resolves += 1;
    }

    /// Probe every candidate CR for `probe_iters` steps under
    /// checkpoint/restore; returns measured profiles.
    fn explore(&self, t: &mut Trainer, probed: LinkParams) -> Vec<CandidateProfile> {
        let ck = t.snapshot();
        let saved_cr = t.cur_cr;
        let mut out = Vec::new();
        let mut overhead = 0.0;
        for cr in candidate_crs(self.cfg.c_low, self.cfg.c_high, self.cfg.factor) {
            t.cur_cr = cr;
            let (mut tc, mut ts, mut ga) = (0.0, 0.0, 0.0);
            for _ in 0..self.cfg.probe_iters {
                let m = t.step_once(false, probed);
                tc += m.t_comp;
                ts += m.t_sync;
                ga += m.gain;
                overhead += m.t_step();
            }
            let k = self.cfg.probe_iters as f64;
            out.push(CandidateProfile {
                cr,
                t_comp: tc / k,
                t_sync: ts / k,
                gain: (ga / k).clamp(1e-6, 1.0),
            });
            t.restore(&ck);
        }
        t.cur_cr = saved_cr;
        t.explore_overhead_s += overhead;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artopk::{ArFlavor, SelectionPolicy};
    use crate::coordinator::trainer::{CrControl, Strategy, TrainConfig};
    use crate::coordinator::worker::ComputeModel;
    use crate::netsim::schedule::NetSchedule;
    use crate::runtime::host_model::HostMlp;

    fn adaptive_trainer(schedule: NetSchedule, steps: u64) -> Trainer {
        let cfg = TrainConfig {
            n_workers: 4,
            steps,
            steps_per_epoch: 25,
            lr: 0.3,
            momentum: 0.6,
            strategy: Strategy::Flexible { policy: SelectionPolicy::Star },
            cr: CrControl::Adaptive(AdaptiveConfig {
                probe_iters: 3,
                ..Default::default()
            }),
            net: Box::new(schedule),
            compute: ComputeModel::fixed(0.005),
            eval_every: 0,
            seed: 5,
            ..Default::default()
        };
        Trainer::new(cfg, Box::new(HostMlp::default_preset(11)))
    }

    #[test]
    fn first_step_triggers_exploration_and_sets_cr() {
        let mut t = adaptive_trainer(NetSchedule::c2(4.0), 5);
        t.run();
        assert!(t.cur_cr >= 0.001 && t.cur_cr <= 0.1);
        assert!(t.explore_overhead_s > 0.0, "exploration must cost time");
        // Main log only contains the recorded steps.
        assert_eq!(t.metrics.steps.len(), 5);
    }

    #[test]
    fn exploration_does_not_corrupt_training() {
        // With restore, adaptive training must still learn.
        let mut t = adaptive_trainer(NetSchedule::c2(8.0), 200);
        t.run();
        let acc = t.metrics.final_accuracy().unwrap();
        assert!(acc > 0.7, "adaptive accuracy {acc}");
    }

    #[test]
    fn network_change_triggers_resolve_without_new_exploration() {
        // C2 at short epochs -> several network phase changes within run.
        let mut t = adaptive_trainer(NetSchedule::c2(4.0), 100);
        t.run();
        let st = {
            // Reach into the trainer's adaptive state via a fresh controller
            // run — instead verify observable effect: CR stayed in bounds
            // and multiple collectives/CRs were used across phases.
            let crs = t.metrics.crs_used();
            let distinct: std::collections::BTreeSet<u64> =
                crs.iter().map(|c| (c * 1e6) as u64).collect();
            distinct
        };
        assert!(st.len() >= 2, "adaptive CR never moved: {st:?}");
    }

    #[test]
    fn fixed_strategy_with_static_cr_never_adapts() {
        let cfg = TrainConfig {
            n_workers: 4,
            steps: 30,
            strategy: Strategy::ArTopkFixed {
                policy: SelectionPolicy::Star,
                flavor: ArFlavor::Ring,
            },
            cr: CrControl::Static(0.02),
            compute: ComputeModel::fixed(0.005),
            seed: 2,
            ..Default::default()
        };
        let mut t = Trainer::new(cfg, Box::new(HostMlp::default_preset(1)));
        t.run();
        assert!(t.metrics.crs_used().iter().all(|&c| (c - 0.02).abs() < 1e-12));
        assert_eq!(t.explore_overhead_s, 0.0);
    }
}
