//! Binomial-tree broadcast (Table I row 4): `α·log N + log N·Mβ`.
//!
//! AR-Topk's first phase: the selected worker disperses its top-k *indices*
//! to everyone (Alg 1 line 14).

use crate::collectives::{ceil_log2, CommReport};
use crate::netsim::cost_model::LinkParams;

/// Broadcast `data` from `src` to all `n` workers; returns the per-worker
/// received copy (trivially `data.clone()` — the data movement is the time
/// model; the bytes are what matters) and the comm report.
pub fn broadcast_bytes(bytes: f64, src: usize, n: usize, link: LinkParams) -> CommReport {
    assert!(src < n, "src {src} out of range for n={n}");
    let mut report = CommReport::default();
    if n <= 1 {
        return report;
    }
    for _ in 0..ceil_log2(n) {
        report.add_round(link, bytes);
    }
    report
}

/// Typed convenience wrapper: broadcast a u32 index list.
pub fn broadcast(data: &[u32], src: usize, n: usize, link: LinkParams) -> (Vec<u32>, CommReport) {
    let report = broadcast_bytes(4.0 * data.len() as f64, src, n, link);
    (data.to_vec(), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(1.0, 10.0)
    }

    #[test]
    fn time_matches_closed_form_pow2() {
        for n in [2usize, 4, 8, 16] {
            let m = 4096.0;
            let r = broadcast_bytes(m, 0, n, link());
            let want = cost_model::broadcast(link(), m, n);
            assert!(
                (r.seconds - want).abs() / want < 1e-9,
                "n={n}: sim {} vs model {}",
                r.seconds,
                want
            );
        }
    }

    #[test]
    fn content_is_replicated() {
        let (out, r) = broadcast(&[5, 7, 9], 2, 4, link());
        assert_eq!(out, vec![5, 7, 9]);
        assert_eq!(r.rounds, 2);
    }

    #[test]
    fn single_node_free() {
        let r = broadcast_bytes(1e6, 0, 1, link());
        assert_eq!(r.seconds, 0.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_src_panics() {
        broadcast_bytes(1.0, 3, 2, link());
    }
}
