//! Recursive halving-doubling allreduce (Rabenseifner): reduce-scatter by
//! recursive vector halving with distance doubling, then allgather by
//! recursive vector doubling with distance halving.
//!
//! Round structure for power-of-two N: `2·log2(N)` rounds; halving round
//! `d` sends `M/2^(d+1)` bytes and the doubling phase mirrors it — total
//! `2·log2(N)·α + 2·((N-1)/N)·Mβ`, matching
//! [`cost_model::halving_doubling_allreduce`](crate::netsim::cost_model::halving_doubling_allreduce):
//! the ring's bandwidth-optimal β-term at only log-many latency rounds.
//!
//! Non-power-of-two N first folds the `r = N - 2^⌊log2 N⌋` extra ranks
//! into partners (rank `2i+1` merges into `2i`, one full-vector round),
//! runs the power-of-two core over the survivors, and unfolds at the end —
//! `2α + 2Mβ` extra, accounted identically by the closed form.

use crate::collectives::CommReport;
use crate::netsim::cost_model::{prev_pow2, LinkParams};

/// In-place SUM halving-doubling allreduce over per-worker buffers (all the
/// same length). After the call every buffer holds the elementwise sum.
pub fn halving_doubling_allreduce(bufs: &mut [Vec<f32>], link: LinkParams) -> CommReport {
    let n = bufs.len();
    assert!(n >= 1);
    let m = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == m), "buffer length mismatch");
    let mut report = CommReport::default();
    if n == 1 || m == 0 {
        return report;
    }

    // Fold: ranks 2i+1 (i < r) merge their vector into rank 2i.
    let np = prev_pow2(n);
    let r = n - np;
    if r > 0 {
        for i in 0..r {
            let (lo, hi) = bufs.split_at_mut(2 * i + 1);
            for (dv, sv) in lo[2 * i].iter_mut().zip(&hi[0]) {
                *dv += *sv;
            }
        }
        report.add_round(link, 4.0 * m as f64);
    }
    // Participant ranks (power-of-two count np): the fold survivors.
    let parts: Vec<usize> = (0..r).map(|i| 2 * i).chain(2 * r..n).collect();
    debug_assert_eq!(parts.len(), np);
    let lgn = np.trailing_zeros();

    // Phase 1: recursive halving reduce-scatter. Each participant tracks
    // its owned segment [lo, hi); at round d partners at participant-index
    // distance np/2^(d+1) split the segment, exchange the half they drop,
    // and reduce the half they keep (lower index keeps the lower half).
    let mut seg: Vec<(usize, usize)> = vec![(0, m); np];
    for d in 0..lgn {
        let dist = np >> (d + 1);
        let mut max_sent = 0usize;
        for pi in 0..np {
            let pj = pi ^ dist;
            if pi > pj {
                continue; // each pair once
            }
            let (lo, hi) = seg[pi];
            debug_assert_eq!(seg[pj], (lo, hi), "partners must own the same segment");
            let mid = lo + (hi - lo) / 2;
            let (ra, rb) = (parts[pi], parts[pj]);
            // pi keeps [lo, mid) and receives rb's copy of it...
            let from_b: Vec<f32> = bufs[rb][lo..mid].to_vec();
            for (dv, sv) in bufs[ra][lo..mid].iter_mut().zip(&from_b) {
                *dv += *sv;
            }
            // ...pj keeps [mid, hi) and receives ra's copy of it.
            let from_a: Vec<f32> = bufs[ra][mid..hi].to_vec();
            for (dv, sv) in bufs[rb][mid..hi].iter_mut().zip(&from_a) {
                *dv += *sv;
            }
            max_sent = max_sent.max(hi - mid).max(mid - lo);
            seg[pi] = (lo, mid);
            seg[pj] = (mid, hi);
        }
        report.add_round(link, 4.0 * max_sent as f64);
    }

    // Phase 2: recursive doubling allgather — the exact mirror. Partners
    // hold the two halves of their round-d segment; exchanging them leaves
    // both with the union, and after the last round everyone has [0, m).
    for d in (0..lgn).rev() {
        let dist = np >> (d + 1);
        let mut max_sent = 0usize;
        for pi in 0..np {
            let pj = pi ^ dist;
            if pi > pj {
                continue;
            }
            let (la, ha) = seg[pi];
            let (lb, hb) = seg[pj];
            debug_assert_eq!(ha, lb, "owned halves must be adjacent");
            let (ra, rb) = (parts[pi], parts[pj]);
            let from_b: Vec<f32> = bufs[rb][lb..hb].to_vec();
            bufs[ra][lb..hb].copy_from_slice(&from_b);
            let from_a: Vec<f32> = bufs[ra][la..ha].to_vec();
            bufs[rb][la..ha].copy_from_slice(&from_a);
            max_sent = max_sent.max(ha - la).max(hb - lb);
            seg[pi] = (la, hb);
            seg[pj] = (la, hb);
        }
        report.add_round(link, 4.0 * max_sent as f64);
    }

    // Unfold: folded ranks receive the finished vector from their partner.
    if r > 0 {
        for i in 0..r {
            let (lo, hi) = bufs.split_at_mut(2 * i + 1);
            hi[0].copy_from_slice(&lo[2 * i]);
        }
        report.add_round(link, 4.0 * m as f64);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cost_model;
    use crate::util::proptest::{all_close, check, ensure};
    use crate::util::rng::Rng;

    fn link() -> LinkParams {
        LinkParams::from_ms_gbps(2.0, 10.0)
    }

    #[test]
    fn sums_exactly_pow2() {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|w| vec![w as f32 + 1.0; 6]).collect();
        halving_doubling_allreduce(&mut bufs, link());
        for b in &bufs {
            assert_eq!(b, &vec![10.0; 6]);
        }
    }

    #[test]
    fn time_matches_closed_form_pow2() {
        // Exact match when N | M (halves split evenly all the way down).
        for n in [2usize, 4, 8, 16] {
            let m = n * 512;
            let mut bufs = vec![vec![1.0f32; m]; n];
            let r = halving_doubling_allreduce(&mut bufs, link());
            let want = cost_model::halving_doubling_allreduce(link(), 4.0 * m as f64, n);
            assert!(
                (r.seconds - want).abs() / want < 1e-9,
                "n={n}: sim {} vs model {}",
                r.seconds,
                want
            );
            assert_eq!(r.rounds, 2 * n.trailing_zeros());
        }
    }

    #[test]
    fn time_matches_closed_form_non_pow2() {
        // N = 6 folds to 4 participants; exact when 4 | M.
        let n = 6;
        let m = 4 * 1000;
        let mut bufs = vec![vec![1.0f32; m]; n];
        let r = halving_doubling_allreduce(&mut bufs, link());
        let want = cost_model::halving_doubling_allreduce(link(), 4.0 * m as f64, n);
        assert!(
            (r.seconds - want).abs() / want < 1e-9,
            "sim {} vs model {}",
            r.seconds,
            want
        );
        // 2 fold rounds + 2·log2(4) core rounds.
        assert_eq!(r.rounds, 2 + 4);
        for b in &bufs {
            assert_eq!(b, &vec![6.0; m]);
        }
    }

    #[test]
    fn property_sum_any_n_m() {
        check("halving-doubling sums for any n,m", 60, |g| {
            let n = g.usize_in(1, 12);
            let m = g.usize_in(1, 200);
            let bufs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(m, 1.0)).collect();
            let mut want = vec![0.0f32; m];
            for b in &bufs {
                for (w, v) in want.iter_mut().zip(b) {
                    *w += v;
                }
            }
            let mut got = bufs;
            halving_doubling_allreduce(&mut got, link());
            for (w, b) in got.iter().enumerate() {
                all_close(b, &want, 1e-4).map_err(|e| format!("worker {w}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn fewer_latency_rounds_than_ring() {
        let m = 8 * 100;
        let mut a = vec![vec![1.0f32; m]; 8];
        let mut b = vec![vec![1.0f32; m]; 8];
        let hd = halving_doubling_allreduce(&mut a, link());
        let ring = crate::collectives::ring_allreduce(&mut b, link());
        assert!(hd.rounds < ring.rounds, "hd {} vs ring {}", hd.rounds, ring.rounds);
        // Same β volume: per-worker egress is identical when N | M.
        assert!((hd.bytes_per_worker - ring.bytes_per_worker).abs() < 1e-6);
        assert!(hd.seconds < ring.seconds);
    }

    #[test]
    fn single_worker_is_noop() {
        let mut bufs = vec![vec![1.0, 2.0]];
        let r = halving_doubling_allreduce(&mut bufs, link());
        assert_eq!(r.seconds, 0.0);
        assert_eq!(r.rounds, 0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn deterministic() {
        check("halving-doubling deterministic", 20, |g| {
            let n = g.usize_in(2, 9);
            let m = g.usize_in(1, 64);
            let bufs: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    let mut r = Rng::new(i as u64);
                    let mut v = vec![0.0; m];
                    r.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            let mut a = bufs.clone();
            let mut b = bufs;
            let ra = halving_doubling_allreduce(&mut a, link());
            let rb = halving_doubling_allreduce(&mut b, link());
            ensure(a == b && ra == rb, "nondeterministic")
        });
    }
}
