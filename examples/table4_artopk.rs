//! Table IV + Fig 4: DenseSGD (Tree-AR) vs STAR-Topk vs VAR-Topk at CRs
//! {0.1, 0.01, 0.001} on a 4ms/20Gbps link, plus the iteration-density
//! (KDE) of the broadcasting worker rank for both selection policies.
//!
//!     cargo run --release --example table4_artopk -- [--steps 600]
//!         [--models ResNet18,ViT|all] [--emit-kde] [--skew 0.0]
//!
//! `--skew 1.0` reproduces the §4 federated claim: with non-i.i.d. worker
//! shards VAR-Topk's variance-driven selection prioritizes the workers
//! holding under-shared classes.

use anyhow::Result;
use flexcomm::artopk::{ArFlavor, SelectionPolicy};
use flexcomm::coordinator::session::{Session, TrainReport};
use flexcomm::coordinator::trainer::{CrControl, DenseFlavor, Strategy, TrainConfig};
use flexcomm::experiments::{
    diff_row, print_diff_table, print_kde, proxy_cfg, write_csv, GPU_COMPRESS_SPEEDUP,
    PAPER_COMPUTE_MS, PAPER_MODELS,
};
use flexcomm::runtime::HostMlp;
use flexcomm::util::cli::Args;

const PROXY_PARAMS: f64 = 53_664.0;

fn run(cfg: TrainConfig, seed: u64, skew: f64) -> TrainReport {
    let mut src = HostMlp::hard_preset(seed);
    src.skew = skew;
    Session::from_config(cfg)
        .source(Box::new(src))
        .build()
        .expect("table4 config valid")
        .run()
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 600)?;
    let emit_kde = args.flag("emit-kde");
    let skew = args.f64_or("skew", 0.0)?;
    let want = args.str_or("models", "ResNet18,ViT");
    let crs = [0.1, 0.01, 0.001];
    let mut kde_csv = String::from("model,policy,cr,step,rank\n");

    for (model, params) in PAPER_MODELS {
        if want != "all" && !want.split(',').any(|m| m == model) {
            continue;
        }
        let msg_scale = params / PROXY_PARAMS;
        let compute_ms = PAPER_COMPUTE_MS.iter().find(|(m, _)| *m == model).unwrap().1;
        let mk_cfg = |strategy, cr: f64| {
            let mut cfg = proxy_cfg(strategy, CrControl::Static(cr), steps, 1);
            cfg.msg_scale = msg_scale;
            cfg.comp_scale = msg_scale / GPU_COMPRESS_SPEEDUP;
            cfg.compute = flexcomm::coordinator::worker::ComputeModel::with_jitter(
                compute_ms * 1e-3,
                0.05,
            );
            cfg
        };

        let mut rows = Vec::new();
        // DenseSGD with Tree-AR (the paper sets NCCL_ALGO=tree here).
        let dense = run(mk_cfg(Strategy::DenseSgd { flavor: DenseFlavor::Tree }, 1.0), 1, skew);
        rows.push(diff_row("DenseSGD (Tree-AR)", &dense));
        for (policy, label) in [
            (SelectionPolicy::Star, "STAR-Topk"),
            (SelectionPolicy::Var, "VAR-Topk"),
        ] {
            for &cr in &crs {
                let t = run(
                    mk_cfg(Strategy::ArTopkFixed { policy, flavor: ArFlavor::Ring }, cr),
                    1,
                    skew,
                );
                rows.push(diff_row(format!("{label} {cr}"), &t));
                if emit_kde {
                    for m in &t.metrics.steps {
                        if let Some(r) = m.selected_rank {
                            kde_csv.push_str(&format!("{model},{label},{cr},{},{r}\n", m.step));
                        }
                    }
                }
                if cr == 0.01 {
                    // Fig 4 terminal view at the CR the paper plots.
                    print_kde(
                        &format!("{model} {label} 0.01 rank density"),
                        &t.metrics.selected_ranks(),
                        -0.5,
                        7.5,
                    );
                }
            }
        }
        print_diff_table(
            &format!("Table IV — {model} (proxy, 4ms/20Gbps, skew={skew})"),
            &rows,
        );
    }
    if emit_kde {
        let p = write_csv("results/fig4_rank_density.csv", &kde_csv)?;
        println!("\nFig 4 rank densities -> {p}");
    }
    Ok(())
}
