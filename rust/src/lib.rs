//! # flexcomm
//!
//! Production-style reproduction of *"Flexible Communication for Optimal
//! Distributed Learning over Unpredictable Networks"* (Tyagi & Swany, IEEE
//! BigData 2023): AR-Topk compression (STAR/VAR worker selection), α-β
//! cost-model driven collective selection (Allgather vs AR-Topk ring/tree),
//! and NSGA-II multi-objective adaptation of the compression ratio.
//!
//! Layer map (see DESIGN.md for the full architecture, README.md for the
//! quickstart):
//! * L3 (this crate): coordinator, collectives (flat + topology-aware),
//!   network simulator, compressors, MOO controller.
//! * L2/L1 (python, build-time only): jax model + Pallas kernels, AOT-lowered
//!   to HLO text in `artifacts/`, executed here via PJRT ([`runtime`],
//!   behind the `pjrt` cargo feature).
//!
//! The offline build vendors only `xla` (optional, `pjrt` feature) +
//! `anyhow` (first-party shim under `rust/vendor/`); every other facility
//! (PRNG, config, CLI, stats/KDE, property testing, bench harness) is
//! first-party under [`util`].

pub mod analysis;
pub mod artopk;
pub mod collectives;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod models;
pub mod moo;
pub mod netsim;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Commonly used items for examples/benches.
pub mod prelude {
    pub use crate::artopk::{ArTopk, SelectionPolicy};
    pub use crate::collectives::CollectiveKind;
    pub use crate::compress::{Compressor, CompressorKind, SparseGrad};
    pub use crate::coordinator::controller::{
        AdaptiveConfig, ControlAction, ControlCtx, ControlDecision, Controller,
        ControllerError, GravacConfig, CONTROLLER_TABLE,
    };
    pub use crate::coordinator::fleet::{FleetConfig, FleetReport, FleetSim};
    pub use crate::coordinator::observer::{
        CrChange, CsvSink, EvalRecord, MembershipChange, NetChange, ProgressPrinter,
        StrategySwitch, SwitchDimension, TrainObserver,
    };
    pub use crate::coordinator::session::{
        ConfigError, Session, SessionBuilder, TrainReport,
    };
    pub use crate::coordinator::sweep::{
        SweepCell, SweepError, SweepObserver, SweepProgress, SweepReport, SweepRow, SweepSpec,
    };
    pub use crate::coordinator::strategy::{
        CommPlan, CommStrategy, ExchangeCtx, ExchangeOutcome, StepCtx,
    };
    pub use crate::coordinator::trainer::{CrControl, DenseFlavor, Strategy, TrainConfig, Trainer};
    pub use crate::models::{
        build_model, model_names, MatRegCheckpoint, MatrixRegressionSource, MlpSource,
        ModelError, MODEL_TABLE,
    };
    pub use crate::netsim::cost_model::{self, LinkParams, Topology};
    pub use crate::netsim::model::{parse_spec, NetModelError, NetworkModel, NET_TABLE};
    pub use crate::netsim::modifiers::{
        AsymmetricDegrade, Churn, CongestionEpisodes, Diurnal, Flapping, HeterogeneousLinks,
        Jitter, StragglerTail, TwoLevel,
    };
    pub use crate::netsim::schedule::NetSchedule;
    pub use crate::netsim::trace::{TraceModel, TracePoint};
    pub use crate::tensor::{Layout, ParamVec};
    pub use crate::util::pool::ThreadPool;
    pub use crate::util::rng::Rng;
}
