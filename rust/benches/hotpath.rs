//! Perf-pass micro-benches for the L3 hot paths (EXPERIMENTS.md §Perf):
//! Top-k selection (heap vs quickselect), MSTopk threshold rounds, ring
//! allreduce arithmetic, sparse allgather scatter, EF bookkeeping, and a
//! full trainer step on the proxy model.
//!
//!     cargo bench --bench hotpath

use flexcomm::artopk::{ArFlavor, ArTopk, SelectionPolicy};
use flexcomm::collectives::ring_allreduce;
use flexcomm::compress::topk::{topk_indices, topk_indices_select};
use flexcomm::compress::{Compressor, EfState, MsTopk};
use flexcomm::netsim::cost_model::LinkParams;
use flexcomm::tensor::Layout;
use flexcomm::util::bench::Bencher;
use flexcomm::util::rng::Rng;

fn main() {
    let fast = std::env::var("FLEXCOMM_BENCH_FAST").is_ok();
    let dim: usize = if fast { 200_000 } else { 4_000_000 };
    let mut rng = Rng::new(0);
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);
    let k = dim / 100;
    let mut b = Bencher::from_env();

    // Top-k selection: the paper's max-heap vs quickselect.
    b.bench(&format!("topk heap        G={dim} k={k}"), || {
        Bencher::black_box(topk_indices(&g, k));
    });
    b.bench(&format!("topk quickselect G={dim} k={k}"), || {
        Bencher::black_box(topk_indices_select(&g, k));
    });

    // MSTopk threshold rounds.
    for rounds in [10u32, 25] {
        let mut ms = MsTopk::new(rounds);
        b.bench(&format!("mstopk rounds={rounds} G={dim}"), || {
            Bencher::black_box(ms.compress(&g, 0.01, &Layout::single(dim)));
        });
    }

    // Ring allreduce arithmetic (data path, 8 workers).
    let n = 8;
    let bufs: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let link = LinkParams::from_ms_gbps(1.0, 10.0);
    b.bench(&format!("ring_allreduce data n={n} m={}", dim / 4), || {
        let mut bb = bufs.clone();
        Bencher::black_box(ring_allreduce(&mut bb, link));
    });

    // Full AR-Topk exchange (compress + residuals + reduce).
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; dim / 4];
            Rng::new(100 + i as u64).fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut art = ArTopk::new(SelectionPolicy::Star, ArFlavor::Ring);
    b.bench(&format!("artopk exchange n={n} G={} cr=0.01", dim / 4), || {
        let mut ef: Vec<EfState> = (0..n).map(|_| EfState::new(dim / 4)).collect();
        Bencher::black_box(art.exchange(&grads, &mut ef, 0.01, 0, link));
    });

    // EF bookkeeping alone.
    let mut ef = EfState::new(dim);
    let sparse = flexcomm::compress::SparseGrad {
        indices: (0..k as u32).collect(),
        values: vec![1.0; k],
        dense_len: dim,
    };
    b.bench(&format!("error-feedback update G={dim}"), || {
        let ge = ef.error_fed(&g);
        ef.update(Bencher::black_box(ge), &sparse);
    });

    println!("\n{} measurements recorded (see EXPERIMENTS.md §Perf).", b.results.len());
}
